//! Layer-pipelined execution identity and calibration: the staged
//! executor must be bit-identical to the serial `forward` across every
//! kernel flavour × compiled-in datapath × stage grouping (uniform and
//! degenerate), drain losslessly mid-stream, and — the sim-vs-reality
//! loop — the cycle simulator built from the *served* stage grouping
//! must identify the same bottleneck group the measured per-group
//! occupancy does (DESIGN.md §13). Replicated groups (DESIGN.md §15)
//! carry the same contract: round-robin dispatch across replica rings
//! must recombine in submit order bit-identically, drain losslessly on
//! a mid-stream close, degenerate to the single-worker executor at
//! R = 1, and keep the calibration loop closed with replication
//! factors ≥ 2. The throughput floors live in `benches/kernel_perf.rs`;
//! correctness lives here, where `cargo test` runs it.

use logicsparse::folding::{FoldingConfig, LayerFold, Style};
use logicsparse::graph::builder::{lenet5, mlp};
use logicsparse::graph::Graph;
use logicsparse::kernel::pipeline::DEFAULT_FIFO_DEPTH;
use logicsparse::kernel::{
    CompiledModel, Datapath, KernelSpec, NativeSparseBackend, StagedExecutor,
};
use logicsparse::runtime::{InferenceBackend, SyntheticRuntime};
use logicsparse::sim::Workload;
use logicsparse::weights::ModelParams;
use std::sync::Arc;

/// All three kernel flavours for one graph (same construction as
/// `tests/kernel_batch.rs`: awkward graphs get awkward lane divisors).
fn flavours(g: &Graph, seed: u64) -> Vec<(&'static str, Arc<CompiledModel>)> {
    let spec = KernelSpec::default();
    let dense_params = ModelParams::synthetic(g, seed);
    let mut sparse_params = ModelParams::synthetic(g, seed);
    sparse_params.prune_global(0.7, 0.05).unwrap();

    let mut cfg = FoldingConfig::default();
    for n in g.mac_nodes() {
        let simd = [8usize, 7, 5, 4, 3, 2]
            .into_iter()
            .find(|s| n.fold_in() % s == 0)
            .unwrap_or(1);
        cfg.set(
            &n.name,
            LayerFold { pe: 1, simd, style: Style::PartialSparse, sparsity: 0.5 },
        );
    }

    vec![
        (
            "dense",
            Arc::new(CompiledModel::compile_dense(g, &dense_params, &spec).unwrap()),
        ),
        (
            "unrolled_sparse",
            Arc::new(CompiledModel::compile_sparse(g, &sparse_params, &spec).unwrap()),
        ),
        (
            "block_partial_sparse",
            Arc::new(CompiledModel::compile(g, &sparse_params, &spec, &cfg).unwrap()),
        ),
    ]
}

/// A stream of `n` frames sized for `model`.
fn stream_for(model: &CompiledModel, n: usize) -> Vec<f32> {
    let px = model.input_pixels();
    (0..n)
        .flat_map(|i| (0..px).map(move |j| (((i * 31 + j * 7) % 97) as f32) / 97.0))
        .collect()
}

/// The reference: per-image scalar `forward`, concatenated.
fn per_image_scalar(model: &CompiledModel, x: &[f32], n: usize) -> Vec<f32> {
    let px = model.input_pixels();
    (0..n)
        .flat_map(|i| {
            model
                .forward_with(&x[i * px..(i + 1) * px], Datapath::Scalar)
                .unwrap()
        })
        .collect()
}

#[test]
fn pipeline_matches_forward_across_flavours_datapaths_and_groupings() {
    for (name, model) in flavours(&lenet5(), 51) {
        let n_stages = model.stages().len();
        let n = 9usize;
        let x = stream_for(&model, n);
        let want = per_image_scalar(&model, &x, n);
        // 1 = degenerate serial-on-a-worker; 2/3 = non-uniform groups
        // (the conv2 stage dominates, so balanced cuts are uneven in
        // stage count); n_stages = one worker per stage.
        for groups in [1usize, 2, 3, n_stages] {
            for dp in Datapath::all() {
                let exec =
                    StagedExecutor::with_config(Arc::clone(&model), groups, 2, dp).unwrap();
                assert_eq!(
                    exec.infer_batch(&x, n).unwrap(),
                    want,
                    "{name}: {} pipeline at {groups} groups != per-image forward",
                    dp.label()
                );
                let st = exec.stats();
                assert_eq!(st.in_flight(), 0, "{name}: frames lost at {groups} groups");
            }
        }
    }
}

#[test]
fn pipeline_matches_forward_on_non_lane_multiple_shapes() {
    // fold_ins 19 / 13 / 13 and couts 13 / 13 / 10: every remainder path
    // runs on every layer, and the stage list is short enough that the
    // group clamp (groups > stages) is exercised too.
    for (name, model) in flavours(&mlp(19, 13, 10), 52) {
        let n = 5usize;
        let x = stream_for(&model, n);
        let want = per_image_scalar(&model, &x, n);
        for groups in [1usize, 2, 16] {
            for dp in Datapath::all() {
                let exec =
                    StagedExecutor::with_config(Arc::clone(&model), groups, 2, dp).unwrap();
                assert_eq!(
                    exec.infer_batch(&x, n).unwrap(),
                    want,
                    "{name}: {} diverged on awkward shapes at {groups} groups",
                    dp.label()
                );
            }
        }
    }
}

#[test]
fn mid_stream_close_is_lossless() {
    let (_, model) = flavours(&lenet5(), 53).swap_remove(1);
    let exec = StagedExecutor::with_config(Arc::clone(&model), 3, 2, model.datapath()).unwrap();
    let px = model.input_pixels();
    let n = 24usize;
    let x = stream_for(&model, n);
    let want = per_image_scalar(&model, &x, n);
    // Submit the whole stream, then close while frames are still inside
    // the pipeline: every accepted frame must still deliver its logits,
    // bit-identically and in order.
    let rxs: Vec<_> = (0..n)
        .map(|i| exec.submit(&x[i * px..(i + 1) * px]).unwrap())
        .collect();
    exec.close();
    let got: Vec<f32> = rxs.into_iter().flat_map(|rx| rx.recv().unwrap()).collect();
    assert_eq!(got, want, "mid-stream close lost or corrupted frames");
    let st = exec.stats();
    assert_eq!(st.submitted, n as u64);
    assert_eq!(st.completed(), n as u64);
    assert_eq!(st.in_flight(), 0, "drain left frames in flight");
    // The submit side is closed for good — and stays closed (idempotent).
    assert!(exec.submit(&x[..px]).is_err());
    exec.close();
    assert!(exec.infer_batch(&x, n).is_err());
}

#[test]
fn replicated_pipeline_delivers_in_submit_order_bit_identically() {
    // Round-robin dispatch sprays consecutive frames across the
    // bottleneck group's replica rings; the recombination boundary must
    // hand them to the next group in seq order, so the delivered stream
    // is the per-image scalar reference exactly — per flavour and per
    // compiled-in datapath, at shallow FIFOs where backpressure and the
    // reorder buffer both engage.
    for (name, model) in flavours(&lenet5(), 57) {
        let px = model.input_pixels();
        let n = 16usize;
        let x = stream_for(&model, n);
        let want = per_image_scalar(&model, &x, n);
        for dp in Datapath::all() {
            let exec = StagedExecutor::with_bottleneck_replication(
                Arc::clone(&model),
                4,
                2,
                2,
                dp,
            )
            .unwrap();
            assert_eq!(exec.max_replication(), 2, "{name}: pin did not replicate");
            let rxs: Vec<_> = (0..n)
                .map(|i| exec.submit(&x[i * px..(i + 1) * px]).unwrap())
                .collect();
            let got: Vec<f32> =
                rxs.into_iter().flat_map(|rx| rx.recv().unwrap()).collect();
            assert_eq!(
                got,
                want,
                "{name}: {} replicated pipeline broke order or bits",
                dp.label()
            );
            let st = exec.stats();
            assert_eq!(st.in_flight(), 0, "{name}: replicated pipeline lost frames");
            // Round-robin actually fed both replicas of the pinned
            // group: with 16 sequential frames at seq % 2 dispatch,
            // each replica of the replicated group served exactly half.
            let g = exec
                .group_replicas()
                .iter()
                .position(|&r| r == 2)
                .expect("one group is replicated");
            let per_replica = &st.groups[g].replica_frames;
            assert_eq!(
                per_replica,
                &vec![8u64, 8],
                "{name}: dispatch was not round-robin"
            );
        }
    }
}

#[test]
fn replicated_mid_stream_close_is_lossless_with_uneven_replicas() {
    // Close while frames are still spread across both replicas of the
    // bottleneck group (depth-1 rings keep many in flight, and thread
    // scheduling makes one replica run behind the other): the cascade
    // close must still deliver every accepted frame, in order, bit
    // identically — the last replica out closes the downstream rings.
    let (_, model) = flavours(&lenet5(), 58).swap_remove(2);
    let exec = StagedExecutor::with_bottleneck_replication(
        Arc::clone(&model),
        3,
        2,
        1,
        model.datapath(),
    )
    .unwrap();
    let px = model.input_pixels();
    let n = 32usize;
    let x = stream_for(&model, n);
    let want = per_image_scalar(&model, &x, n);
    let rxs: Vec<_> = (0..n)
        .map(|i| exec.submit(&x[i * px..(i + 1) * px]).unwrap())
        .collect();
    exec.close();
    let got: Vec<f32> = rxs.into_iter().flat_map(|rx| rx.recv().unwrap()).collect();
    assert_eq!(got, want, "mid-stream close lost or corrupted replicated frames");
    let st = exec.stats();
    assert_eq!(st.submitted, n as u64);
    assert_eq!(st.completed(), n as u64);
    assert_eq!(st.in_flight(), 0, "drain left frames in flight");
    assert!(exec.submit(&x[..px]).is_err(), "submit must stay closed");
}

#[test]
fn pinned_r1_replication_degenerates_to_the_plain_executor() {
    // `with_bottleneck_replication(.., r = 1, ..)` is the PR 7 executor:
    // same grouping, one worker per group, one ring per boundary, and
    // bit-identical output.
    let (_, model) = flavours(&lenet5(), 59).swap_remove(0);
    let plain =
        StagedExecutor::with_config(Arc::clone(&model), 3, 2, model.datapath()).unwrap();
    let pinned = StagedExecutor::with_bottleneck_replication(
        Arc::clone(&model),
        3,
        1,
        2,
        model.datapath(),
    )
    .unwrap();
    assert_eq!(pinned.group_spans(), plain.group_spans());
    assert_eq!(pinned.group_costs(), plain.group_costs());
    assert_eq!(pinned.group_replicas(), &[1, 1, 1]);
    assert_eq!(pinned.worker_count(), plain.worker_count());
    assert_eq!(pinned.max_replication(), 1);
    let n = 8usize;
    let x = stream_for(&model, n);
    assert_eq!(
        pinned.infer_batch(&x, n).unwrap(),
        plain.infer_batch(&x, n).unwrap(),
        "R=1 pinned executor diverged from the plain one"
    );
    // A budget of exactly one worker per group is the same degenerate
    // plan.
    let budgeted =
        StagedExecutor::with_budget(Arc::clone(&model), 3, 3, 2, model.datapath()).unwrap();
    assert_eq!(budgeted.group_replicas(), &[1, 1, 1]);
}

#[test]
fn single_group_pipeline_degenerates_to_serial() {
    let (_, model) = flavours(&lenet5(), 54).swap_remove(0);
    let exec = StagedExecutor::with_config(Arc::clone(&model), 1, 2, model.datapath()).unwrap();
    assert_eq!(exec.groups(), 1);
    assert_eq!(exec.group_spans(), &[0..model.stages().len()]);
    let n = 4usize;
    let x = stream_for(&model, n);
    assert_eq!(
        exec.infer_batch(&x, n).unwrap(),
        per_image_scalar(&model, &x, n),
        "degenerate single-group pipeline diverged"
    );
}

#[test]
fn pipelined_backend_matches_plain_backend_end_to_end() {
    // The serving seam: NativeSparseBackend::with_pipeline must answer
    // exactly what the worker-less backend answers.
    for (name, model) in flavours(&lenet5(), 55) {
        let plain = NativeSparseBackend::new(Arc::clone(&model)).unwrap();
        let piped = NativeSparseBackend::with_pipeline(Arc::clone(&model), 4).unwrap();
        let n = 9usize;
        let x: Vec<f32> = (0..n).flat_map(SyntheticRuntime::stripe_image).collect();
        assert_eq!(
            piped.infer_padded(&x, n).unwrap(),
            plain.infer_padded(&x, n).unwrap(),
            "{name}: pipelined backend diverged"
        );
    }
}

#[test]
fn calibration_sim_agrees_with_measured_bottleneck() {
    // The sim-vs-reality loop: build the cycle simulator from the SAME
    // stage grouping the served executor runs, saturate both, and the
    // predicted bottleneck group must be the measured one. Dense LeNet-5
    // at 3 groups isolates conv2 with a ~1.7x cost margin over the next
    // group, so the agreement is robust to scheduling noise even on
    // starved single-core runners; the scalar datapath keeps measured
    // service time proportional to the MAC-count cost proxy.
    let g = lenet5();
    let params = ModelParams::synthetic(&g, 56);
    let model =
        Arc::new(CompiledModel::compile_dense(&g, &params, &KernelSpec::default()).unwrap());
    let exec = StagedExecutor::with_config(Arc::clone(&model), 3, 4, Datapath::Scalar).unwrap();

    // Predicted: saturate the simulated pipeline built from the served
    // grouping (same costs, same FIFO depth).
    let mut sim = exec.calibration_sim(100.0);
    let rep = sim.try_run(&Workload::parse("saturated", 64).unwrap()).unwrap();
    let predicted = rep.bottleneck_stage().name.clone();

    // Measured: stream the same number of frames through the real thing
    // and take the group that spent the most wall time executing.
    let n = 64usize;
    let x = stream_for(&model, n);
    exec.infer_batch(&x, n).unwrap();
    let st = exec.stats();
    let measured = st.groups[st.bottleneck_group()].name.clone();

    assert_eq!(
        predicted, measured,
        "simulator predicted '{predicted}' but measured occupancy says '{measured}' \
         (costs {:?}, busy {:?})",
        exec.group_costs(),
        st.groups.iter().map(|g| g.busy_s).collect::<Vec<_>>()
    );

    // And the sim's exported FIFO stats cover the served FIFO layout:
    // one per inter-group link plus source and sink ends.
    assert_eq!(rep.fifos.len(), exec.groups() + 1);
    assert!(rep.fifos.iter().all(|f| f.capacity == exec.fifo_depth()));
    assert!(rep.fifos.iter().any(|f| f.total_tokens > 0));
}

#[test]
fn calibration_sim_agrees_with_measured_bottleneck_under_replication() {
    // The same loop with the costliest group replicated 3x: predicted
    // and measured bottleneck must both move off the costliest group.
    // Dense LeNet-5 at 3 groups costs [89856, 153600, 42664]; conv2 at
    // 3 workers serves an effective 51200 cycles/frame, so the floor
    // moves to group 0 with a 1.75x margin over it — and total busy
    // time per group is proportional to cost however the OS schedules
    // the worker threads, so the measured argmax(busy / replicas) is
    // robust even on starved single-core runners.
    let g = lenet5();
    let params = ModelParams::synthetic(&g, 60);
    let model =
        Arc::new(CompiledModel::compile_dense(&g, &params, &KernelSpec::default()).unwrap());
    let exec = StagedExecutor::with_bottleneck_replication(
        Arc::clone(&model),
        3,
        3,
        DEFAULT_FIFO_DEPTH,
        Datapath::Scalar,
    )
    .unwrap();
    assert!(exec.max_replication() >= 2, "test needs a replicated group");

    let costliest = exec
        .group_costs()
        .iter()
        .enumerate()
        .max_by_key(|(_, c)| **c)
        .unwrap()
        .0;
    assert_eq!(exec.group_replicas()[costliest], 3);

    let mut sim = exec.calibration_sim(100.0);
    let rep = sim.try_run(&Workload::parse("saturated", 64).unwrap()).unwrap();
    let predicted = rep.bottleneck_stage().name.clone();
    assert_ne!(
        predicted, exec.group_names()[costliest],
        "replication did not move the predicted floor"
    );

    let n = 64usize;
    let x = stream_for(&model, n);
    exec.infer_batch(&x, n).unwrap();
    let st = exec.stats();
    let measured = st.groups[st.bottleneck_group()].name.clone();

    assert_eq!(
        predicted, measured,
        "simulator predicted '{predicted}' but measured occupancy says '{measured}' \
         (costs {:?}, replicas {:?}, busy {:?})",
        exec.group_costs(),
        exec.group_replicas(),
        st.groups.iter().map(|g| g.busy_s).collect::<Vec<_>>()
    );

    // Replica counts round-trip into the sim specs, and the replicated
    // group's frames were actually spread across its workers.
    for (spec, &r) in sim_replicas_of(&exec).iter().zip(exec.group_replicas()) {
        assert_eq!(*spec, r as u64);
    }
    let rg = &st.groups[costliest];
    assert_eq!(rg.replica_frames.iter().sum::<u64>(), n as u64);
    assert!(
        rg.replica_frames.iter().all(|&f| f > 0),
        "a replica served nothing: {:?}",
        rg.replica_frames
    );
}

/// The `replicas` field of each sim spec, in group order.
fn sim_replicas_of(exec: &StagedExecutor) -> Vec<u64> {
    exec.sim_specs().iter().map(|s| s.replicas).collect()
}
