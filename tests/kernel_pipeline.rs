//! Layer-pipelined execution identity and calibration: the staged
//! executor must be bit-identical to the serial `forward` across every
//! kernel flavour × compiled-in datapath × stage grouping (uniform and
//! degenerate), drain losslessly mid-stream, and — the sim-vs-reality
//! loop — the cycle simulator built from the *served* stage grouping
//! must identify the same bottleneck group the measured per-group
//! occupancy does (DESIGN.md §13). The throughput floor lives in
//! `benches/kernel_perf.rs`; correctness lives here, where `cargo test`
//! runs it.

use logicsparse::folding::{FoldingConfig, LayerFold, Style};
use logicsparse::graph::builder::{lenet5, mlp};
use logicsparse::graph::Graph;
use logicsparse::kernel::{
    CompiledModel, Datapath, KernelSpec, NativeSparseBackend, StagedExecutor,
};
use logicsparse::runtime::{InferenceBackend, SyntheticRuntime};
use logicsparse::sim::Workload;
use logicsparse::weights::ModelParams;
use std::sync::Arc;

/// All three kernel flavours for one graph (same construction as
/// `tests/kernel_batch.rs`: awkward graphs get awkward lane divisors).
fn flavours(g: &Graph, seed: u64) -> Vec<(&'static str, Arc<CompiledModel>)> {
    let spec = KernelSpec::default();
    let dense_params = ModelParams::synthetic(g, seed);
    let mut sparse_params = ModelParams::synthetic(g, seed);
    sparse_params.prune_global(0.7, 0.05).unwrap();

    let mut cfg = FoldingConfig::default();
    for n in g.mac_nodes() {
        let simd = [8usize, 7, 5, 4, 3, 2]
            .into_iter()
            .find(|s| n.fold_in() % s == 0)
            .unwrap_or(1);
        cfg.set(
            &n.name,
            LayerFold { pe: 1, simd, style: Style::PartialSparse, sparsity: 0.5 },
        );
    }

    vec![
        (
            "dense",
            Arc::new(CompiledModel::compile_dense(g, &dense_params, &spec).unwrap()),
        ),
        (
            "unrolled_sparse",
            Arc::new(CompiledModel::compile_sparse(g, &sparse_params, &spec).unwrap()),
        ),
        (
            "block_partial_sparse",
            Arc::new(CompiledModel::compile(g, &sparse_params, &spec, &cfg).unwrap()),
        ),
    ]
}

/// A stream of `n` frames sized for `model`.
fn stream_for(model: &CompiledModel, n: usize) -> Vec<f32> {
    let px = model.input_pixels();
    (0..n)
        .flat_map(|i| (0..px).map(move |j| (((i * 31 + j * 7) % 97) as f32) / 97.0))
        .collect()
}

/// The reference: per-image scalar `forward`, concatenated.
fn per_image_scalar(model: &CompiledModel, x: &[f32], n: usize) -> Vec<f32> {
    let px = model.input_pixels();
    (0..n)
        .flat_map(|i| {
            model
                .forward_with(&x[i * px..(i + 1) * px], Datapath::Scalar)
                .unwrap()
        })
        .collect()
}

#[test]
fn pipeline_matches_forward_across_flavours_datapaths_and_groupings() {
    for (name, model) in flavours(&lenet5(), 51) {
        let n_stages = model.stages().len();
        let n = 9usize;
        let x = stream_for(&model, n);
        let want = per_image_scalar(&model, &x, n);
        // 1 = degenerate serial-on-a-worker; 2/3 = non-uniform groups
        // (the conv2 stage dominates, so balanced cuts are uneven in
        // stage count); n_stages = one worker per stage.
        for groups in [1usize, 2, 3, n_stages] {
            for dp in Datapath::all() {
                let exec =
                    StagedExecutor::with_config(Arc::clone(&model), groups, 2, dp).unwrap();
                assert_eq!(
                    exec.infer_batch(&x, n).unwrap(),
                    want,
                    "{name}: {} pipeline at {groups} groups != per-image forward",
                    dp.label()
                );
                let st = exec.stats();
                assert_eq!(st.in_flight(), 0, "{name}: frames lost at {groups} groups");
            }
        }
    }
}

#[test]
fn pipeline_matches_forward_on_non_lane_multiple_shapes() {
    // fold_ins 19 / 13 / 13 and couts 13 / 13 / 10: every remainder path
    // runs on every layer, and the stage list is short enough that the
    // group clamp (groups > stages) is exercised too.
    for (name, model) in flavours(&mlp(19, 13, 10), 52) {
        let n = 5usize;
        let x = stream_for(&model, n);
        let want = per_image_scalar(&model, &x, n);
        for groups in [1usize, 2, 16] {
            for dp in Datapath::all() {
                let exec =
                    StagedExecutor::with_config(Arc::clone(&model), groups, 2, dp).unwrap();
                assert_eq!(
                    exec.infer_batch(&x, n).unwrap(),
                    want,
                    "{name}: {} diverged on awkward shapes at {groups} groups",
                    dp.label()
                );
            }
        }
    }
}

#[test]
fn mid_stream_close_is_lossless() {
    let (_, model) = flavours(&lenet5(), 53).swap_remove(1);
    let exec = StagedExecutor::with_config(Arc::clone(&model), 3, 2, model.datapath()).unwrap();
    let px = model.input_pixels();
    let n = 24usize;
    let x = stream_for(&model, n);
    let want = per_image_scalar(&model, &x, n);
    // Submit the whole stream, then close while frames are still inside
    // the pipeline: every accepted frame must still deliver its logits,
    // bit-identically and in order.
    let rxs: Vec<_> = (0..n)
        .map(|i| exec.submit(&x[i * px..(i + 1) * px]).unwrap())
        .collect();
    exec.close();
    let got: Vec<f32> = rxs.into_iter().flat_map(|rx| rx.recv().unwrap()).collect();
    assert_eq!(got, want, "mid-stream close lost or corrupted frames");
    let st = exec.stats();
    assert_eq!(st.submitted, n as u64);
    assert_eq!(st.completed(), n as u64);
    assert_eq!(st.in_flight(), 0, "drain left frames in flight");
    // The submit side is closed for good — and stays closed (idempotent).
    assert!(exec.submit(&x[..px]).is_err());
    exec.close();
    assert!(exec.infer_batch(&x, n).is_err());
}

#[test]
fn single_group_pipeline_degenerates_to_serial() {
    let (_, model) = flavours(&lenet5(), 54).swap_remove(0);
    let exec = StagedExecutor::with_config(Arc::clone(&model), 1, 2, model.datapath()).unwrap();
    assert_eq!(exec.groups(), 1);
    assert_eq!(exec.group_spans(), &[0..model.stages().len()]);
    let n = 4usize;
    let x = stream_for(&model, n);
    assert_eq!(
        exec.infer_batch(&x, n).unwrap(),
        per_image_scalar(&model, &x, n),
        "degenerate single-group pipeline diverged"
    );
}

#[test]
fn pipelined_backend_matches_plain_backend_end_to_end() {
    // The serving seam: NativeSparseBackend::with_pipeline must answer
    // exactly what the worker-less backend answers.
    for (name, model) in flavours(&lenet5(), 55) {
        let plain = NativeSparseBackend::new(Arc::clone(&model)).unwrap();
        let piped = NativeSparseBackend::with_pipeline(Arc::clone(&model), 4).unwrap();
        let n = 9usize;
        let x: Vec<f32> = (0..n).flat_map(SyntheticRuntime::stripe_image).collect();
        assert_eq!(
            piped.infer_padded(&x, n).unwrap(),
            plain.infer_padded(&x, n).unwrap(),
            "{name}: pipelined backend diverged"
        );
    }
}

#[test]
fn calibration_sim_agrees_with_measured_bottleneck() {
    // The sim-vs-reality loop: build the cycle simulator from the SAME
    // stage grouping the served executor runs, saturate both, and the
    // predicted bottleneck group must be the measured one. Dense LeNet-5
    // at 3 groups isolates conv2 with a ~1.7x cost margin over the next
    // group, so the agreement is robust to scheduling noise even on
    // starved single-core runners; the scalar datapath keeps measured
    // service time proportional to the MAC-count cost proxy.
    let g = lenet5();
    let params = ModelParams::synthetic(&g, 56);
    let model =
        Arc::new(CompiledModel::compile_dense(&g, &params, &KernelSpec::default()).unwrap());
    let exec = StagedExecutor::with_config(Arc::clone(&model), 3, 4, Datapath::Scalar).unwrap();

    // Predicted: saturate the simulated pipeline built from the served
    // grouping (same costs, same FIFO depth).
    let mut sim = exec.calibration_sim(100.0);
    let rep = sim.try_run(&Workload::parse("saturated", 64).unwrap()).unwrap();
    let predicted = rep.bottleneck_stage().name.clone();

    // Measured: stream the same number of frames through the real thing
    // and take the group that spent the most wall time executing.
    let n = 64usize;
    let x = stream_for(&model, n);
    exec.infer_batch(&x, n).unwrap();
    let st = exec.stats();
    let measured = st.groups[st.bottleneck_group()].name.clone();

    assert_eq!(
        predicted, measured,
        "simulator predicted '{predicted}' but measured occupancy says '{measured}' \
         (costs {:?}, busy {:?})",
        exec.group_costs(),
        st.groups.iter().map(|g| g.busy_s).collect::<Vec<_>>()
    );

    // And the sim's exported FIFO stats cover the served FIFO layout:
    // one per inter-group link plus source and sink ends.
    assert_eq!(rep.fifos.len(), exec.groups() + 1);
    assert!(rep.fifos.iter().all(|f| f.capacity == exec.fifo_depth()));
    assert!(rep.fifos.iter().any(|f| f.total_tokens > 0));
}
