//! Batched-forward identity: `infer_batch` (serial and pooled) must be
//! bit-identical to per-image `forward` across all three kernel flavours
//! and every compiled-in datapath — including layer shapes that are not
//! multiples of the dense 4-row fuse width, the 8-wide sparse lanes, or
//! the AVX2 tier's 16-wide chunks (DESIGN.md §15). This is the
//! test-side half of the PR-6 acceptance criteria (benches measure the
//! speedups; identity lives here, where `cargo test` runs it).

use logicsparse::folding::{FoldingConfig, LayerFold, Style};
use logicsparse::graph::builder::{lenet5, mlp};
use logicsparse::graph::Graph;
use logicsparse::kernel::{BatchPool, CompiledModel, Datapath, KernelSpec, NativeSparseBackend};
use logicsparse::runtime::{InferenceBackend, SyntheticRuntime};
use logicsparse::weights::ModelParams;
use std::sync::Arc;

/// All three kernel flavours for one graph: dense, unrolled sparse, and
/// block partial-sparse with per-layer lane widths picked to divide each
/// `fold_in` (folding enforces divisibility; awkward graphs get awkward
/// divisors, which is the point).
fn flavours(g: &Graph, seed: u64) -> Vec<(&'static str, Arc<CompiledModel>)> {
    let spec = KernelSpec::default();
    let dense_params = ModelParams::synthetic(g, seed);
    let mut sparse_params = ModelParams::synthetic(g, seed);
    sparse_params.prune_global(0.7, 0.05).unwrap();

    let mut cfg = FoldingConfig::default();
    for n in g.mac_nodes() {
        let simd = [8usize, 7, 5, 4, 3, 2]
            .into_iter()
            .find(|s| n.fold_in() % s == 0)
            .unwrap_or(1);
        cfg.set(
            &n.name,
            LayerFold { pe: 1, simd, style: Style::PartialSparse, sparsity: 0.5 },
        );
    }

    vec![
        (
            "dense",
            Arc::new(CompiledModel::compile_dense(g, &dense_params, &spec).unwrap()),
        ),
        (
            "unrolled_sparse",
            Arc::new(CompiledModel::compile_sparse(g, &sparse_params, &spec).unwrap()),
        ),
        (
            "block_partial_sparse",
            Arc::new(CompiledModel::compile(g, &sparse_params, &spec, &cfg).unwrap()),
        ),
    ]
}

/// A batch of `n` frames sized for `model`.
fn batch_for(model: &CompiledModel, n: usize) -> Vec<f32> {
    let px = model.input_pixels();
    (0..n)
        .flat_map(|i| {
            (0..px).map(move |j| (((i * 31 + j * 7) % 97) as f32) / 97.0)
        })
        .collect()
}

/// The reference: per-image scalar `forward`, concatenated.
fn per_image_scalar(model: &CompiledModel, x: &[f32], n: usize) -> Vec<f32> {
    let px = model.input_pixels();
    (0..n)
        .flat_map(|i| {
            model
                .forward_with(&x[i * px..(i + 1) * px], Datapath::Scalar)
                .unwrap()
        })
        .collect()
}

#[test]
fn infer_batch_matches_per_image_forward_on_lenet() {
    for (name, model) in flavours(&lenet5(), 41) {
        for n in [1usize, 2, 5, 8, 13] {
            let x = batch_for(&model, n);
            let want = per_image_scalar(&model, &x, n);
            for dp in Datapath::all() {
                assert_eq!(
                    model.infer_batch_with(&x, n, dp).unwrap(),
                    want,
                    "{name}: {} infer_batch != per-image forward at n={n}",
                    dp.label()
                );
            }
        }
    }
}

#[test]
fn infer_batch_matches_on_non_lane_multiple_shapes() {
    // fold_ins 19 / 13 / 13 and couts 13 / 13 / 10: no multiple of the
    // 4-row dense fuse width or the 8-wide lanes anywhere, so every
    // remainder path runs on every layer.
    for (name, model) in flavours(&mlp(19, 13, 10), 42) {
        for n in [1usize, 3, 7] {
            let x = batch_for(&model, n);
            let want = per_image_scalar(&model, &x, n);
            for dp in Datapath::all() {
                assert_eq!(
                    model.infer_batch_with(&x, n, dp).unwrap(),
                    want,
                    "{name}: {} diverged on awkward shapes at n={n}",
                    dp.label()
                );
            }
        }
    }
}

#[test]
fn infer_batch_matches_on_sixteen_lane_remainder_shapes() {
    // Shapes sized against the AVX2 tier's 16-lane chunks: fold_ins
    // 131 / 67 / 67 give sparse channels tens of nnz entries (full
    // 16-entry madd chunks plus a ragged tail), and couts 67 / 67 / 10
    // make every dense row one-or-more 16-channel passes plus a 3- or
    // 10-wide scalar tail. `Datapath::all()` includes the AVX2 tier
    // exactly when the host CPU reports it, so on AVX2 hardware this
    // pins the intrinsics against the scalar reference bit for bit; the
    // SSE2 and portable tiers cover the same remainders everywhere else.
    for (name, model) in flavours(&mlp(131, 67, 10), 46) {
        for n in [1usize, 3] {
            let x = batch_for(&model, n);
            let want = per_image_scalar(&model, &x, n);
            for dp in Datapath::all() {
                assert_eq!(
                    model.infer_batch_with(&x, n, dp).unwrap(),
                    want,
                    "{name}: {} diverged on 16-lane-remainder shapes at n={n}",
                    dp.label()
                );
            }
        }
    }
    // The AVX2 selector itself is safe to pin on any x86_64 host: when
    // the CPU lacks AVX2 it falls back to the SSE2 tier instead of
    // executing unsupported instructions, so the identity contract
    // holds regardless of detection.
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        for (name, model) in flavours(&mlp(131, 67, 10), 46) {
            let n = 3usize;
            let x = batch_for(&model, n);
            assert_eq!(
                model.infer_batch_with(&x, n, Datapath::Avx2).unwrap(),
                per_image_scalar(&model, &x, n),
                "{name}: pinned avx2 datapath diverged"
            );
        }
    }
}

#[test]
fn batch_pool_matches_serial_across_flavours() {
    let pool = BatchPool::new(3);
    for (name, model) in flavours(&lenet5(), 43) {
        for n in [1usize, 4, 8, 13] {
            let x = batch_for(&model, n);
            let want = per_image_scalar(&model, &x, n);
            assert_eq!(
                pool.infer_batch(&model, &x, n).unwrap(),
                want,
                "{name}: pooled batch != per-image scalar forward at n={n}"
            );
        }
    }
}

#[test]
fn pooled_backend_matches_plain_backend_end_to_end() {
    // The serving seam: NativeSparseBackend::with_workers must answer
    // exactly what the worker-less backend answers.
    for (name, model) in flavours(&lenet5(), 44) {
        let plain = NativeSparseBackend::new(Arc::clone(&model)).unwrap();
        let pooled = NativeSparseBackend::with_workers(Arc::clone(&model), 2).unwrap();
        let n = 9usize;
        let x: Vec<f32> = (0..n).flat_map(SyntheticRuntime::stripe_image).collect();
        assert_eq!(
            pooled.infer_padded(&x, n).unwrap(),
            plain.infer_padded(&x, n).unwrap(),
            "{name}: pooled backend diverged"
        );
    }
}

#[test]
fn batch_length_contract_holds_on_every_path() {
    let flavs = flavours(&lenet5(), 45);
    let model = &flavs[1].1;
    let pool = BatchPool::new(2);
    let x = batch_for(model, 8);
    for dp in Datapath::all() {
        assert!(model.infer_batch_with(&x[..10], 8, dp).is_err());
        assert!(model.infer_batch_with(&x, 7, dp).is_err());
    }
    assert!(pool.infer_batch(model, &x[..10], 8).is_err());
    assert!(pool.infer_batch(model, &x, 7).is_err());
}
