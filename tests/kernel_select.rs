//! Differential lock-down for cost-driven kernel selection (DESIGN.md
//! §14): every kernel flavour — auto-selected or forced — must be
//! bit-identical to the forced-dense compile of the same masked params,
//! across every datapath and every stage grouping, on lane-friendly and
//! awkward shapes alike. The i32 MAC schedules make this exact: pruned
//! entries quantize to code 0 and code-0 entries are sum-neutral, so any
//! schedule over the surviving weights (nnz-only, block, or padded N:M
//! fixed-stride) must land on the same logits bit for bit. This extends
//! the PR-2 flavour-identity invariant to the N:M flavour and to the
//! selection policy itself.

use logicsparse::graph::builder::{lenet5, mlp, ChainBuilder};
use logicsparse::graph::Graph;
use logicsparse::kernel::{
    ChoicePolicy, CompiledModel, Datapath, Flavour, KernelChoice, KernelSpec, StagedExecutor,
};
use logicsparse::weights::ModelParams;
use std::sync::Arc;

/// Every selectable flavour, auto included.
const FLAVOURS: [Flavour; 5] =
    [Flavour::Auto, Flavour::Dense, Flavour::Unrolled, Flavour::Block, Flavour::Nm];

/// Deterministic input stream of `n` frames for `model`.
fn stream_for(model: &CompiledModel, n: usize) -> Vec<f32> {
    let px = model.input_pixels();
    (0..n)
        .flat_map(|i| (0..px).map(move |j| (((i * 29 + j * 13) % 89) as f32) / 89.0))
        .collect()
}

/// The full differential grid for one (graph, params): every flavour x
/// every datapath x serial and pipelined groupings, all against the
/// forced-dense scalar reference on the same masked params.
fn assert_grid(g: &Graph, params: &ModelParams, label: &str) {
    let spec = KernelSpec::default();
    let n = 5usize;
    let dense = CompiledModel::compile_with_choice(g, params, &spec, Flavour::Dense).unwrap();
    let px = dense.input_pixels();
    let x = stream_for(&dense, n);
    let want: Vec<f32> = (0..n)
        .flat_map(|i| dense.forward_with(&x[i * px..(i + 1) * px], Datapath::Scalar).unwrap())
        .collect();

    for flavour in FLAVOURS {
        let model =
            Arc::new(CompiledModel::compile_with_choice(g, params, &spec, flavour).unwrap());
        // A sparse schedule never executes more MACs than the dense one.
        assert!(
            model.scheduled_macs_per_frame() <= dense.scheduled_macs_per_frame(),
            "{label}: {} schedules more MACs than dense",
            flavour.as_str()
        );
        let n_stages = model.stages().len();
        for dp in Datapath::all() {
            let got: Vec<f32> = (0..n)
                .flat_map(|i| model.forward_with(&x[i * px..(i + 1) * px], dp).unwrap())
                .collect();
            assert_eq!(
                got,
                want,
                "{label}: {} x {} diverged from the forced-dense reference",
                flavour.as_str(),
                dp.label()
            );
            // 1 = degenerate serial-on-a-worker, 2 = uneven cut,
            // n_stages = one worker per stage.
            for groups in [1usize, 2, n_stages] {
                let exec = StagedExecutor::with_config(Arc::clone(&model), groups, 2, dp).unwrap();
                assert_eq!(
                    exec.infer_batch(&x, n).unwrap(),
                    want,
                    "{label}: {} x {} pipelined at {groups} groups diverged",
                    flavour.as_str(),
                    dp.label()
                );
            }
        }
    }
}

#[test]
fn flavour_grid_matches_dense_on_unstructured_lenet() {
    let g = lenet5();
    let mut p = ModelParams::synthetic(&g, 61);
    p.prune_global(0.7, 0.05).unwrap();
    assert_grid(&g, &p, "lenet5 @0.7 unstructured");
}

#[test]
fn flavour_grid_matches_dense_on_nm_structured_lenet() {
    let g = lenet5();
    let mut p = ModelParams::synthetic(&g, 62);
    p.prune_nm(2, 4).unwrap();
    assert_grid(&g, &p, "lenet5 2:4 structured");
    // On exactly-N:M masks the policy itself lands on the N:M flavour
    // for every layer — the structured schedule stores no padding waste,
    // so it ties the nnz-only kernel on cost and wins on index width.
    let choice =
        KernelChoice::choose(&g, &p, &KernelSpec::default(), &ChoicePolicy::default()).unwrap();
    for l in &choice.layers {
        assert_eq!(l.flavour, Flavour::Nm, "{}: expected N:M, got {:?}", l.layer, l.flavour);
        assert!(l.feasible, "{}: N:M choice marked infeasible", l.layer);
    }
}

#[test]
fn flavour_grid_matches_dense_on_dense_masks() {
    // Dense masks are the degenerate sparsity: forced sparse flavours
    // must still agree (every weight survives, nothing is skipped).
    let g = lenet5();
    let p = ModelParams::synthetic(&g, 63);
    assert_grid(&g, &p, "lenet5 dense masks");
}

#[test]
fn flavour_grid_covers_non_lane_multiple_shapes() {
    // fold_ins 19 / 13 / 13 and couts 13 / 13 / 10: no lane multiple
    // anywhere, so every remainder path runs under every flavour.
    let g = mlp(19, 13, 10);
    let mut p = ModelParams::synthetic(&g, 64);
    p.prune_global(0.6, 0.05).unwrap();
    assert_grid(&g, &p, "mlp(19,13,10) @0.6");
}

#[test]
fn flavour_grid_covers_single_layer_degenerate_graph() {
    // One fc layer, prime shapes: the shortest possible stage chain,
    // where the grouping clamp and the tail-group N:M path both hit.
    let g = ChainBuilder::input(7, 1).fc("only", 5).build("one_fc", vec![1, 7], 4, 4);
    g.validate().unwrap();
    let dense = ModelParams::synthetic(&g, 65);
    assert_grid(&g, &dense, "one_fc dense");
    let mut pruned = ModelParams::synthetic(&g, 65);
    pruned.prune_nm(1, 2).unwrap();
    assert_grid(&g, &pruned, "one_fc 1:2 structured");
}

#[test]
fn auto_selection_is_deterministic_across_compiles() {
    // The compile-facing purity guarantee at the integration seam: two
    // auto compiles of the same inputs produce the same per-layer
    // flavours and the same packed bytes (summary covers sizes).
    let g = lenet5();
    let mut p = ModelParams::synthetic(&g, 66);
    p.prune_global(0.8, 0.05).unwrap();
    let spec = KernelSpec::default();
    let (m1, c1) = CompiledModel::compile_auto(&g, &p, &spec).unwrap();
    let (m2, c2) = CompiledModel::compile_auto(&g, &p, &spec).unwrap();
    assert_eq!(m1.summary(), m2.summary());
    let f1: Vec<_> = c1.layers.iter().map(|l| (l.layer.clone(), l.flavour)).collect();
    let f2: Vec<_> = c2.layers.iter().map(|l| (l.layer.clone(), l.flavour)).collect();
    assert_eq!(f1, f2);
    let x = stream_for(&m1, 3);
    assert_eq!(m1.infer_batch(&x, 3).unwrap(), m2.infer_batch(&x, 3).unwrap());
}
