//! Quantisation parity + sparsity round-trip tests.
//!
//! The rust `quant::QSpec` must stay numerically identical to
//! `python/compile/quant.py` (`quantize_weight_int` / `dequantize_weight`:
//! symmetric per-output-channel scales, qmax = 2^(b-1) - 1). The golden
//! vectors below were computed from the python definitions by hand;
//! values are chosen away from .5 rounding boundaries so the jnp.round
//! (half-to-even) vs f32::round (half-away-from-zero) difference cannot
//! bite — on such inputs both paths agree exactly.

use logicsparse::graph::builder::ChainBuilder;
use logicsparse::kernel::{pack, CompiledModel, Flavour, Kernel, KernelSpec};
use logicsparse::quant::{quantize_per_channel, QSpec};
use logicsparse::sparsity::nm::{nm_mask, nm_sparsity};
use logicsparse::sparsity::Mask;
use logicsparse::util::propcheck::check;
use logicsparse::util::rng::Pcg32;
use logicsparse::weights::ModelParams;

/// python: q, scale = quantize_weight_int(w, bits=4, per_channel=True)
/// with w of shape [cout=2, fold_in=4] transposed into our
/// [fold_in, cout] row-major layout.
///
/// col 0: [0.70, -0.23, 0.14, 0.06]  -> amax 0.70, scale 0.1
/// col 1: [-1.40, 0.35, 0.63, -0.07] -> amax 1.40, scale 0.2
#[test]
fn golden_per_channel_codes_match_python() {
    let spec = QSpec::new(4).unwrap();
    let w = vec![
        0.70f32, -1.40, //
        -0.23, 0.35, //
        0.14, 0.63, //
        0.06, -0.07,
    ];
    let (codes, scales) = quantize_per_channel(&w, 4, 2, spec).unwrap();
    assert!((scales[0] - 0.1).abs() < 1e-6, "scale[0] = {}", scales[0]);
    assert!((scales[1] - 0.2).abs() < 1e-6, "scale[1] = {}", scales[1]);
    // python: round(w / scale) clipped to [-7, 7].
    assert_eq!(codes, vec![7, -7, -2, 2, 1, 3, 1, 0]);
}

/// python: dequantize_weight(q, scale) = q * scale, and every dequantised
/// value must sit on the grid (`on_grid`) within float tolerance.
#[test]
fn golden_encode_decode_matches_python_dequant() {
    let spec = QSpec::new(4).unwrap();
    let scale = 0.2f32;
    let w = vec![1.40f32, -1.40, 0.42, -0.65, 0.0, 0.27];
    let codes = spec.encode(&w, scale);
    assert_eq!(codes, vec![7, -7, 2, -3, 0, 1]);
    let back = spec.decode(&codes, scale);
    for (b, expect) in back.iter().zip([1.4f32, -1.4, 0.4, -0.6, 0.0, 0.2]) {
        assert!((b - expect).abs() < 1e-6, "{b} vs {expect}");
    }
    assert!(spec.on_grid(&back, scale, 1e-5));
    // Values clip, never wrap: |w| far beyond amax saturates at qmax.
    assert_eq!(spec.encode(&[10.0, -10.0], scale), vec![7, -7]);
}

/// python guards fully-pruned channels with amax >= 1e-8 so the scale is
/// never zero; rust must do the same (no NaN codes on a dead channel).
#[test]
fn dead_channel_scale_guard_matches_python() {
    let spec = QSpec::new(4).unwrap();
    // col 1 is entirely zero (fully pruned).
    let w = vec![0.5f32, 0.0, -0.26, 0.0];
    let (codes, scales) = quantize_per_channel(&w, 2, 2, spec).unwrap();
    assert!(scales[1] > 0.0 && scales[1].is_finite());
    // -0.26 / (0.5/7) = -3.64 -> -4.
    assert_eq!(codes, vec![7, 0, -4, 0]);
}

/// W8 golden point (the other bit-width the python exporter emits for
/// ablations): qmax = 127.
#[test]
fn golden_w8_codes() {
    let spec = QSpec::new(8).unwrap();
    assert_eq!(spec.qmax(), 127);
    let w = vec![1.27f32, -0.64, 0.333];
    let scale = spec.scale(1.27);
    assert!((scale - 0.01).abs() < 1e-6);
    assert_eq!(spec.encode(&w, scale), vec![127, -64, 33]);
}

/// N:M masks are idempotent: re-running the mask generator on already
/// masked weights (distinct nonzero magnitudes) reproduces the mask
/// exactly — surviving weights always dominate the zeros in their group.
#[test]
fn nm_mask_round_trip_is_stable() {
    // fold_in = 8, cout = 3, distinct magnitudes everywhere.
    let fold_in = 8;
    let cout = 3;
    let w: Vec<f32> = (0..fold_in * cout)
        .map(|i| (i as f32 + 1.0) * if i % 2 == 0 { 0.013 } else { -0.029 })
        .collect();
    for (n, m) in [(2usize, 4usize), (1, 4), (2, 8)] {
        let mask = nm_mask(&w, fold_in, cout, n, m).unwrap();
        assert!((mask.sparsity() - nm_sparsity(n, m)).abs() < 1e-12);
        let mut masked = w.clone();
        mask.apply(&mut masked).unwrap();
        let again = nm_mask(&masked, fold_in, cout, n, m).unwrap();
        assert_eq!(mask, again, "{n}:{m} round trip diverged");
    }
}

/// Mask f32 round-trip: from_f32(apply(w)) reproduces the mask whenever
/// no surviving weight is exactly zero.
#[test]
fn mask_f32_round_trip() {
    let vals = vec![0.4f32, 0.0, -1.25, 2.0, 0.0, -0.01];
    let mask = Mask::from_f32(&vals);
    assert_eq!(mask.nnz(), 4);
    let mut w = vec![1.5f32; 6];
    mask.apply(&mut w).unwrap();
    assert_eq!(Mask::from_f32(&w), mask);
}

/// A single-fc graph of the given shape with the given weights and an
/// N:M mask — the smallest vehicle for baking one N:M kernel.
fn one_fc_params(fold_in: usize, cout: usize, w: Vec<f32>, n: usize, m: usize) -> (logicsparse::graph::Graph, ModelParams) {
    let g = ChainBuilder::input(fold_in, 1)
        .fc("fc1", cout)
        .build("one_fc", vec![1, fold_in], 4, 4);
    let mut p = ModelParams::synthetic(&g, 1);
    p.layers[0].w = w;
    p.layers[0].mask = nm_mask(&p.layers[0].w, fold_in, cout, n, m).unwrap();
    (g, p)
}

/// The fixed-stride N:M index stream round-trips exactly for every
/// (N, M) with N <= M <= 16: baking an `nm_mask`-generated mask into an
/// N:M kernel and decoding the packed offsets reproduces, per channel
/// and group, the surviving rows in row order followed by sum-neutral
/// code-0 pads at the group base. The decode is cross-checked against
/// the kernel's own rel stream (row == rel for fc layers), so the
/// packed bytes — not just the in-memory schedule — carry the mask.
#[test]
fn prop_nm_kernel_round_trips_indices_for_all_nm() {
    check("N:M bake/decode round trip", 60, |g| {
        let m = g.usize(1, 16);
        let n = g.usize(1, m);
        let fold_in = g.usize(m, 48);
        let cout = g.usize(1, 6);
        let mut rng = Pcg32::seeded(g.case + 19);
        let w: Vec<f32> = (0..fold_in * cout).map(|_| rng.normal() as f32).collect();
        let (graph, params) = one_fc_params(fold_in, cout, w, n, m);
        let keep = params.layers[0].mask.keep.clone();
        let model =
            CompiledModel::compile_with_choice(&graph, &params, &KernelSpec::default(), Flavour::Nm)
                .unwrap();
        let stage = model.mac_stages().next().unwrap();
        // The compile derives its own (N', M') from the mask; the
        // generating (n, m) is only an upper bound on the fit.
        let (n2, m2) = stage.nm.expect("N:M stage carries its fit");
        assert!(n2 <= m2, "fit {n2}:{m2} inverted");
        assert!(m2 <= 16, "fit group size {m2} escaped the candidate set");
        assert_eq!(stage.idx_bits, pack::index_bits(m2));
        let rows = pack::unpack_nm_rows(&stage.packed_rel, fold_in, n2, m2, cout);
        let Kernel::Sparse { rel, code, block, .. } = &stage.kernel else {
            panic!("N:M kernel is not a sparse schedule");
        };
        assert_eq!(*block, 1);
        // The packed stream IS the schedule: decode == rel, bit for bit.
        assert_eq!(&rows, rel, "packed N:M stream diverged from the baked schedule");
        // Per channel and group: survivors in row order, then pads at
        // the group base carrying code 0.
        let mut at = 0usize;
        for c in 0..cout {
            let mut base = 0usize;
            while base < fold_in {
                let hi = (base + m2).min(fold_in);
                let slots = n2.min(hi - base);
                let survivors: Vec<u32> = (base..hi)
                    .filter(|&row| keep[row * cout + c])
                    .map(|row| row as u32)
                    .collect();
                assert!(survivors.len() <= slots, "fit too tight for its own mask");
                assert_eq!(&rows[at..at + survivors.len()], &survivors[..]);
                for pad in survivors.len()..slots {
                    assert_eq!(rows[at + pad], base as u32, "pad not at group base");
                    assert_eq!(code[at + pad], 0, "pad slot carries a live code");
                }
                at += slots;
                base = hi;
            }
        }
        assert_eq!(at, rows.len(), "slot count mismatch");
    });
}

/// Golden N:M requant vectors pinned against `python/compile/quant.py`,
/// on the same weights as `golden_per_channel_codes_match_python` but
/// 2:4-masked before quantisation:
///
/// col 0 keeps {0.70, -0.23} -> amax 0.70, scale 0.1, codes [7, -2]
/// col 1 keeps {-1.40, 0.63} -> amax 1.40, scale 0.2, codes [-7, 3]
///
/// The baked kernel stream is channel-major: [7, -2, -7, 3] at rows
/// [0, 1, 0, 2] — exactly 2 slots per channel, no pads (the mask is
/// exactly 2:4).
#[test]
fn golden_nm_requant_matches_python() {
    let w = vec![
        0.70f32, -1.40, //
        -0.23, 0.35, //
        0.14, 0.63, //
        0.06, -0.07,
    ];
    let (graph, params) = one_fc_params(4, 2, w, 2, 4);
    assert_eq!(
        params.layers[0].mask.keep,
        vec![true, true, true, false, false, true, false, false]
    );
    let model =
        CompiledModel::compile_with_choice(&graph, &params, &KernelSpec::default(), Flavour::Nm)
            .unwrap();
    let stage = model.mac_stages().next().unwrap();
    assert_eq!(stage.nm, Some((2, 4)));
    let Kernel::Sparse { rel, code, .. } = &stage.kernel else {
        panic!("N:M kernel is not a sparse schedule");
    };
    assert_eq!(code, &vec![7i8, -2, -7, 3]);
    assert_eq!(rel, &vec![0u32, 1, 0, 2]);
    // The packed byte streams carry the same values.
    assert_eq!(pack::unpack_codes(&stage.packed_codes, 4, 4), vec![7, -2, -7, 3]);
    assert_eq!(stage.idx_bits, 2);
    assert_eq!(pack::unpack_nm_rows(&stage.packed_rel, 4, 2, 4, 2), vec![0, 1, 0, 2]);
}

/// The quant error bound python's QAT relies on: |w - dq| <= scale/2 for
/// in-range values — the STE round-trip guarantee.
#[test]
fn half_step_error_bound_holds() {
    let spec = QSpec::new(4).unwrap();
    let scale = 0.125f32;
    let w: Vec<f32> = (-80..=80).map(|i| i as f32 * 0.01).collect();
    let codes = spec.encode(&w, scale);
    let back = spec.decode(&codes, scale);
    for ((x, dq), &c) in w.iter().zip(&back).zip(&codes) {
        if x.abs() <= spec.qmax() as f32 * scale {
            assert!((x - dq).abs() <= scale / 2.0 + 1e-6, "w {x} dq {dq} code {c}");
        }
    }
}
