//! Integration tests over the real AOT artifacts (skipped with a notice
//! when `make artifacts` has not been run — CI without python still
//! passes the rest of the suite).

use logicsparse::coordinator::{BatchPolicy, Server, ServerOptions};
use logicsparse::experiments::Accuracies;
use logicsparse::graph::{builder::lenet5, import};
use logicsparse::quant::QSpec;
use logicsparse::runtime::{argmax_classes, ModelRuntime, IMG, NUM_CLASSES};
use logicsparse::util::lstw::Store;
use logicsparse::weights::ModelParams;
use std::path::Path;
use std::time::Duration;

fn have_artifacts() -> bool {
    Path::new("artifacts/graph.json").exists()
        && Path::new("artifacts/lenet_proposed_b1.hlo.txt").exists()
        && Path::new("artifacts/testset.lstw").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

#[test]
fn exported_graph_matches_native_builder() {
    require_artifacts!();
    let exported = import::load("artifacts/graph.json").unwrap();
    let native = lenet5();
    assert_eq!(exported, native, "python and rust LeNet-5 diverged");
}

#[test]
fn exported_weights_shapes_and_masks() {
    require_artifacts!();
    let g = import::load("artifacts/graph.json").unwrap();
    let store = Store::read_file("artifacts/params_proposed.lstw").unwrap();
    let mp = ModelParams::load(&store, &g).unwrap();
    let st = mp.sparsity();
    // The proposed model must actually be sparse (DSE targets > 0).
    assert!(
        st.global_sparsity() > 0.3,
        "global sparsity {} suspiciously low",
        st.global_sparsity()
    );
    // Zero blocks exist on the heavily pruned fc layers (engine-free wins).
    let fc1 = mp.get("fc1").unwrap();
    let (zero, total) = fc1.mask.zero_blocks(fc1.fold_in, fc1.cout, 16).unwrap();
    assert!(total > 0);
    // Masked weights really are masked.
    for l in &mp.layers {
        let mw = l.masked_w();
        for (v, k) in mw.iter().zip(&l.mask.keep) {
            if !k {
                assert_eq!(*v, 0.0);
            }
        }
    }
    let _ = zero;
}

#[test]
fn quant_grid_check_on_trained_weights() {
    require_artifacts!();
    // Trained weights are raw fp32 (QAT quantises at use time); verify the
    // per-channel quantiser reproduces W4 codes within half-step error.
    let g = import::load("artifacts/graph.json").unwrap();
    let store = Store::read_file("artifacts/params_stage1.lstw").unwrap();
    let mp = ModelParams::load(&store, &g).unwrap();
    let spec = QSpec::new(g.weight_bits).unwrap();
    for l in &mp.layers {
        let (codes, scales) =
            logicsparse::quant::quantize_per_channel(&l.w, l.fold_in, l.cout, spec).unwrap();
        let mse = logicsparse::quant::quant_mse(&l.w, &codes, l.fold_in, l.cout, &scales);
        let max_scale = scales.iter().fold(0.0f32, |a, &b| a.max(b)) as f64;
        assert!(
            mse <= (max_scale * 0.5).powi(2) + 1e-9,
            "{}: quant mse {mse} too high",
            l.name
        );
    }
}

#[test]
fn runtime_matches_labels_and_batch_variants_agree() {
    require_artifacts!();
    let rt = ModelRuntime::load("artifacts", "proposed").unwrap();
    assert_eq!(rt.batch_sizes(), vec![1, 8, 32]);

    let ts = Store::read_file("artifacts/testset.lstw").unwrap();
    let images = ts.req("images").unwrap().data.as_f32().unwrap().to_vec();
    let labels = ts.req("labels").unwrap().data.as_i32().unwrap().to_vec();
    let px = IMG * IMG;
    let n = 64.min(labels.len());

    // Accuracy through the PJRT path.
    let logits = rt.infer_padded(&images[..n * px], n).unwrap();
    let classes = argmax_classes(&logits);
    let correct = classes
        .iter()
        .zip(&labels[..n])
        .filter(|(c, l)| **c == **l as usize)
        .count();
    assert!(
        correct as f64 / n as f64 > 0.9,
        "served accuracy {}/{n} too low",
        correct
    );

    // Batch variants must agree numerically (same baked weights).
    let l1 = rt.pick(1).infer(&images[..px]).unwrap();
    let mut padded8 = images[..px].to_vec();
    padded8.resize(8 * px, 0.0);
    let l8 = rt.pick(8).infer(&padded8).unwrap();
    for k in 0..NUM_CLASSES {
        assert!(
            (l1[k] - l8[k]).abs() < 1e-3,
            "b1 vs b8 logit {k}: {} vs {}",
            l1[k],
            l8[k]
        );
    }
}

#[test]
fn coordinator_serves_with_full_accuracy() {
    require_artifacts!();
    let ts = Store::read_file("artifacts/testset.lstw").unwrap();
    let images = ts.req("images").unwrap().data.as_f32().unwrap().to_vec();
    let labels = ts.req("labels").unwrap().data.as_i32().unwrap().to_vec();
    let px = IMG * IMG;
    let n = 96.min(labels.len());

    let server = Server::start(ServerOptions {
        policy: BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(1) },
        engines: 1,
        ..ServerOptions::artifacts("artifacts", "proposed")
    })
    .unwrap();

    let mut rxs = Vec::new();
    for j in 0..n {
        rxs.push((server.submit(images[j * px..(j + 1) * px].to_vec()).unwrap(), labels[j]));
    }
    let mut correct = 0;
    for (rx, label) in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits.len(), NUM_CLASSES);
        assert!(resp.latency_s > 0.0);
        correct += (resp.class() == label as usize) as usize;
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed, n as u64);
    assert_eq!(snap.errors, 0);
    assert!(correct as f64 / n as f64 > 0.9, "served {correct}/{n}");

    // Served accuracy must match python's export-time measurement.
    let acc = Accuracies::load("artifacts").unwrap();
    if let Some(pa) = acc.proposed {
        let served = correct as f64 / n as f64;
        assert!(
            (served - pa).abs() < 0.08,
            "served {served} vs python {pa} diverged"
        );
    }
}

#[test]
fn unfold_pruned_artifacts_also_serve() {
    require_artifacts!();
    let rt = ModelRuntime::load("artifacts", "unfold_pruned").unwrap();
    let ts = Store::read_file("artifacts/testset.lstw").unwrap();
    let images = ts.req("images").unwrap().data.as_f32().unwrap().to_vec();
    let labels = ts.req("labels").unwrap().data.as_i32().unwrap().to_vec();
    let px = IMG * IMG;
    let n = 32.min(labels.len());
    let logits = rt.infer_padded(&images[..n * px], n).unwrap();
    let correct = argmax_classes(&logits)
        .iter()
        .zip(&labels[..n])
        .filter(|(c, l)| **c == **l as usize)
        .count();
    assert!(correct as f64 / n as f64 > 0.8, "unfold_pruned {correct}/{n}");
}
