//! Cross-module integration tests that need no artifacts: graph ↔ folding
//! ↔ cost ↔ DSE ↔ simulator consistency on several topologies/devices.

use logicsparse::config::PruneProfile;
use logicsparse::cost;
use logicsparse::device::{TINY, XCU50, ZCU104};
use logicsparse::dse::{self, DseOptions, Strategy};
use logicsparse::folding::FoldingConfig;
use logicsparse::graph::builder::{convnet, lenet5, mlp};
use logicsparse::sim::{self, Workload};
use logicsparse::util::propcheck::check;

#[test]
fn sim_matches_cost_model_for_every_strategy() {
    let g = lenet5();
    let profile = PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95);
    for st in Strategy::ALL {
        let r = dse::run(st, &g, &XCU50, &profile, &DseOptions::default()).unwrap();
        let frames = if st == Strategy::FullyFolded { 12 } else { 60 };
        let rep = sim::simulate_saturated(&g, &r.folding, &XCU50, frames, 8).unwrap();
        let ratio = rep.throughput_fps / r.cost.throughput_fps;
        assert!(
            (0.85..1.1).contains(&ratio),
            "{}: sim {} vs est {} (ratio {ratio})",
            st.as_str(),
            rep.throughput_fps,
            r.cost.throughput_fps
        );
        // Simulated latency must be at least the analytic fill and within
        // a small factor of the analytic first-frame estimate.
        assert!(
            rep.latency_s >= r.cost.latency_s * 0.3,
            "{}: sim latency {} vs est {}",
            st.as_str(),
            rep.latency_s,
            r.cost.latency_s
        );
    }
}

#[test]
fn dse_works_on_other_devices() {
    let g = lenet5();
    let profile = PruneProfile::uniform(&g, &[0.5, 0.8], 0.9);
    for dev in [ZCU104, TINY] {
        let opts = DseOptions { auto_fold_target_fps: 10_000.0, ..Default::default() };
        let r = dse::run(Strategy::Proposed, &g, &dev, &profile, &opts).unwrap();
        assert!(
            r.cost.total_luts <= dev.lut_budget(),
            "{}: {} LUTs over budget",
            dev.name,
            r.cost.total_luts
        );
        r.folding.check(&g).unwrap();
    }
}

#[test]
fn dse_works_on_other_topologies() {
    let profile_of = |g: &logicsparse::graph::Graph| PruneProfile::uniform(g, &[0.6, 0.8], 0.9);
    for g in [mlp(256, 128, 10), convnet(2, 8, 32, 10)] {
        g.validate().unwrap();
        let p = profile_of(&g);
        let opts = DseOptions { auto_fold_target_fps: 5_000.0, ..Default::default() };
        let r = dse::run(Strategy::Proposed, &g, &XCU50, &p, &opts).unwrap();
        let rep = sim::simulate_saturated(&g, &r.folding, &XCU50, 30, 8).unwrap();
        assert!(rep.throughput_fps > 0.0);
    }
}

#[test]
fn proposed_dominates_auto_fold_everywhere() {
    // The Pareto claim at the integration level: proposed is never worse
    // in throughput than its own auto-fold baseline under equal budgets.
    let g = lenet5();
    let profile = PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95);
    for frac in [0.05, 0.3, 1.0] {
        let opts = DseOptions { budget_fraction: frac, ..Default::default() };
        let auto = dse::run(Strategy::AutoFold, &g, &XCU50, &profile, &opts).unwrap();
        let prop = dse::run(Strategy::Proposed, &g, &XCU50, &profile, &opts).unwrap();
        assert!(
            prop.cost.throughput_fps >= auto.cost.throughput_fps * 0.999,
            "budget {frac}: proposed {} < auto {}",
            prop.cost.throughput_fps,
            auto.cost.throughput_fps
        );
    }
}

#[test]
fn simulator_backpressure_invariants() {
    // Property: for random legal foldings, the simulation completes, is
    // deterministic, and FIFO occupancy never exceeds capacity.
    let g = lenet5();
    check("random foldings simulate cleanly", 25, |gen| {
        let mut cfg = FoldingConfig::minimal(&g);
        for (name, f) in cfg.layers.iter_mut() {
            let node = g.node(name).unwrap();
            f.pe = gen.divisor_of(node.fold_out());
            f.simd = gen.divisor_of(node.fold_in());
        }
        let depth = gen.usize(2, 32);
        let mut p = sim::build(&g, &cfg, &XCU50, depth).unwrap();
        let rep = p.try_run(&Workload::Saturated { frames: 8 }).unwrap();
        assert_eq!(rep.frames, 8);
        for &occ in &rep.fifo_max_occupancy {
            assert!(occ <= depth);
        }
        assert!(rep.completions.windows(2).all(|w| w[0] <= w[1]));
    });
}

#[test]
fn poisson_underload_latency_is_flat() {
    // Under light Poisson traffic every frame should see near-constant
    // latency (no queueing) — a serving-path sanity check on the sim.
    let g = lenet5();
    let cfg = FoldingConfig::unrolled(&g);
    let est = cost::evaluate(&g, &cfg, &XCU50).unwrap();
    let light_rate = est.throughput_fps * 0.05;
    let mut p = sim::build(&g, &cfg, &XCU50, 8).unwrap();
    let rep = p
        .try_run(&Workload::Poisson { frames: 40, rate_fps: light_rate, seed: 3 })
        .unwrap();
    let p50 = rep.latency_pct_s(0.5);
    let p99 = rep.latency_pct_s(0.99);
    assert!(
        p99 < p50 * 2.0 + 1e-6,
        "latency should be flat under light load: p50 {p50} p99 {p99}"
    );
}

#[test]
fn saturated_throughput_beats_poisson_overload_latency() {
    // Overload: Poisson above capacity must show queueing growth.
    let g = lenet5();
    let cfg = FoldingConfig::unrolled(&g);
    let est = cost::evaluate(&g, &cfg, &XCU50).unwrap();
    let mut p = sim::build(&g, &cfg, &XCU50, 8).unwrap();
    let over = p
        .try_run(&Workload::Poisson { frames: 60, rate_fps: est.throughput_fps * 3.0, seed: 5 })
        .unwrap();
    let lats = over.per_frame_latency_cycles();
    // Later frames wait longer than early ones under overload.
    let early: u64 = lats[..10].iter().sum();
    let late: u64 = lats[lats.len() - 10..].iter().sum();
    assert!(late > early, "overload should grow queueing delay");
}

#[test]
fn fig2_and_table1_agree_on_ordering() {
    let g = lenet5();
    let profile = PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95);
    let acc = logicsparse::experiments::Accuracies::default();
    let rows =
        logicsparse::experiments::table1::measure(&g, &XCU50, &profile, &acc, 40).unwrap();
    let series = logicsparse::experiments::fig2::measure(&g, &XCU50, &profile).unwrap();
    // The strategy with the lowest per-layer bottleneck latency in Fig. 2
    // must be among the highest-throughput rows in Table I.
    let unfold_row = rows.iter().find(|r| r.strategy == Strategy::Unfold).unwrap();
    let auto_row = rows.iter().find(|r| r.strategy == Strategy::AutoFold).unwrap();
    assert!(unfold_row.throughput_fps > auto_row.throughput_fps);
    let _ = series; // shape-checked in unit tests
}
