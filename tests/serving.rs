//! Engine-free serving-plane integration tests (synthetic backend): the
//! sharded execution plane, admission control, open-loop load generation
//! and graceful shutdown are exercised without artifacts or XLA.

use logicsparse::coordinator::{
    loadgen, BatchPolicy, Server, ServerOptions, ShedMode,
};
use logicsparse::graph::builder::lenet5;
use logicsparse::kernel::{CompiledModel, KernelSpec};
use logicsparse::runtime::SyntheticRuntime;
use logicsparse::traffic::Traffic;
use logicsparse::weights::ModelParams;
use logicsparse::Error;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deterministic image whose synthetic class is `i % 10`.
fn image(i: u64) -> Vec<f32> {
    SyntheticRuntime::stripe_image(i as usize)
}

fn synth_server(engines: usize, per_image: Duration, admission: usize) -> Server {
    Server::start(ServerOptions {
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(300) },
        engines,
        admission_capacity: admission,
        queue_depth: 8,
        ..ServerOptions::synthetic(per_image)
    })
    .unwrap()
}

#[test]
fn shutdown_in_flight_loses_no_requests() {
    // Submit a pile of work, then shut down while most of it is still in
    // flight: every admitted request must still receive a real response.
    // (The seed had a bug here: shutdown joined the batcher while the
    // submit sender was alive, so the drain path never fired and
    // in-flight requests could be dropped.)
    let server = synth_server(2, Duration::from_micros(200), 4096);
    let n = 300u64;
    let mut rxs = Vec::new();
    for i in 0..n {
        rxs.push(server.submit(image(i)).unwrap());
    }
    // Immediately begin graceful shutdown — the queue is mostly unserved.
    let snap = server.shutdown();

    let mut answered = 0u64;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|_| panic!("request {i} dropped in shutdown"));
        assert!(!resp.is_error(), "request {i} failed");
        assert_eq!(resp.class(), (i % 10), "request {i} misclassified");
        answered += 1;
    }
    assert_eq!(answered, n);
    assert_eq!(snap.submitted, n);
    assert_eq!(snap.completed, n, "server lost admitted requests");
    assert_eq!(snap.errors, 0);
}

#[test]
fn responses_are_correct_per_request() {
    let server = synth_server(2, Duration::ZERO, 1024);
    for i in 0..40u64 {
        let resp = server.infer_blocking(image(i)).unwrap();
        assert_eq!(resp.class(), (i % 10) as usize);
        assert!(resp.latency_s >= 0.0);
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed, 40);
    assert_eq!(snap.shed, 0);
}

#[test]
fn overload_sheds_fast_and_admitted_requests_all_complete() {
    // Slow engine + tiny admission bound: a burst must shed quickly (no
    // unbounded queueing) while everything admitted still completes.
    let server = Server::start(ServerOptions {
        policy: BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(200) },
        engines: 1,
        admission_capacity: 8,
        queue_depth: 4,
        ..ServerOptions::synthetic(Duration::from_millis(2))
    })
    .unwrap();

    let mut accepted = Vec::new();
    let mut shed = 0u64;
    let t0 = Instant::now();
    for i in 0..64u64 {
        match server.submit(image(i)) {
            Ok(rx) => accepted.push(rx),
            Err(Error::Overloaded) => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let submit_wall = t0.elapsed();
    assert!(shed > 0, "64 fast submits over an 8-deep gate must shed");
    // Shedding is a fast reject: submitting 64 requests must not take
    // anywhere near the ~100ms the admitted work needs to execute.
    assert!(
        submit_wall < Duration::from_millis(50),
        "submit path blocked for {submit_wall:?}"
    );

    for rx in accepted {
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(!resp.is_error());
    }
    let snap = server.shutdown();
    assert_eq!(snap.shed, shed, "gate and client disagree on shed count");
    assert_eq!(snap.completed, snap.submitted);
}

#[test]
fn bad_image_is_rejected_without_admission_leak() {
    let server = synth_server(1, Duration::ZERO, 4);
    for _ in 0..16 {
        assert!(server.submit(vec![0.0; 3]).is_err());
    }
    // The gate must not have leaked: full capacity still available.
    for i in 0..4u64 {
        server.submit(image(i)).unwrap();
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed, 4);
}

#[test]
fn open_loop_poisson_accounting_is_consistent() {
    let server = synth_server(2, Duration::from_micros(100), 256);
    let traffic = Traffic::poisson(400, 4000.0, 17);
    let rep = loadgen::run_open_loop(&server, &traffic, image, ShedMode::Drop);
    let snap = server.shutdown();

    assert_eq!(rep.offered, 400);
    assert_eq!(rep.accepted + rep.shed, rep.offered);
    assert_eq!(rep.completed + rep.errors, rep.accepted, "requests unaccounted");
    assert_eq!(rep.lost, 0, "responses dropped");
    assert_eq!(rep.errors, 0);
    assert_eq!(snap.completed, rep.completed);
    assert_eq!(snap.shed, rep.shed);
    assert_eq!(rep.latencies_s.len() as u64, rep.completed);
    assert!(rep.latency_pct_s(0.5) <= rep.latency_pct_s(0.99));
    assert!(rep.wall_s > 0.0 && rep.achieved_rps > 0.0);
}

#[test]
fn engine_scaling_under_saturated_traffic() {
    // Sleep-based synthetic cost scales with replicas on any core count;
    // 4 engines must beat 1 engine clearly (the bench asserts the full
    // >= 2x claim; this test keeps a conservative floor so CI stays
    // stable on loaded machines).
    let run = |engines: usize| -> f64 {
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(300) },
            engines,
            admission_capacity: 256,
            queue_depth: 16,
            ..ServerOptions::synthetic(Duration::from_micros(100))
        })
        .unwrap();
        let rep = loadgen::run_open_loop(
            &server,
            &Traffic::saturated(800),
            image,
            ShedMode::Retry,
        );
        let snap = server.shutdown();
        assert_eq!(rep.lost, 0);
        assert_eq!(rep.completed, 800);
        assert_eq!(snap.completed, snap.submitted);
        rep.achieved_rps
    };
    let rps1 = run(1);
    let rps4 = run(4);
    assert!(
        rps4 > rps1 * 1.5,
        "4 engines ({rps4:.0} req/s) should clearly beat 1 ({rps1:.0} req/s)"
    );
}

#[test]
fn steals_rebalance_skewed_load() {
    // Many engines + deep saturation: the two-choice dispatcher plus
    // stealing keeps all rings busy; at least the counters must be sane
    // and total completions exact.
    let server = synth_server(4, Duration::from_micros(100), 1024);
    let rep = loadgen::run_open_loop(
        &server,
        &Traffic::saturated(600),
        image,
        ShedMode::Retry,
    );
    let snap = server.shutdown();
    assert_eq!(rep.completed, 600);
    assert_eq!(snap.completed, 600);
    // Steals are opportunistic, so only sanity-bound them.
    assert!(snap.steals <= snap.batches);
}

#[test]
fn shared_traffic_model_drives_sim_and_server_identically() {
    // The acceptance point of the unified traffic model: the *same*
    // Traffic schedule replayed by the server is the one the simulator
    // integrates over (cycle-rounded), so offered load is comparable.
    let traffic = Traffic::poisson(100, 5000.0, 23);
    let schedule = traffic.schedule();
    let cycles = traffic.to_cycles(200.0);
    assert_eq!(schedule.len(), cycles.len());
    for (s, c) in schedule.iter().zip(&cycles) {
        assert_eq!(*c, (s * 200e6).round() as u64);
    }

    // And the serving side accepts exactly that schedule.
    let server = synth_server(1, Duration::ZERO, 1024);
    let rep = loadgen::run_open_loop(&server, &traffic, image, ShedMode::Retry);
    assert_eq!(rep.offered, 100);
    assert_eq!(rep.completed, 100);
    let _ = server.shutdown();
}

#[test]
fn native_baked_kernels_serve_end_to_end() {
    // The tentpole acceptance path: a CompiledModel of baked sparse
    // kernels behind the sharded plane. Every served class must equal a
    // local forward pass of the same model (the oracle), nothing may be
    // dropped across graceful shutdown, and the engines must report the
    // native backend's integer datapath — no sleeps, no artifacts.
    let g = lenet5();
    let mut params = ModelParams::synthetic(&g, 33);
    params.prune_global(0.75, 0.05).unwrap();
    let model =
        Arc::new(CompiledModel::compile_sparse(&g, &params, &KernelSpec::default()).unwrap());
    assert!(model.sparsity().global_sparsity() >= 0.70);

    let server = Server::start(ServerOptions {
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(300) },
        engines: 2,
        admission_capacity: 1024,
        queue_depth: 8,
        ..ServerOptions::native(Arc::clone(&model))
    })
    .unwrap();

    let n = 60u64;
    let mut rxs = Vec::new();
    for i in 0..n {
        let img = image(i);
        let expect = model.classify(&img).unwrap();
        rxs.push((server.submit(img).unwrap(), expect));
    }
    // Shut down with most of the work still queued: the drain guarantee
    // must hold for the native backend exactly as for the others.
    let snap = server.shutdown();
    for (i, (rx, expect)) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|_| panic!("native request {i} dropped in shutdown"));
        assert!(!resp.is_error(), "native request {i} failed");
        assert_eq!(resp.class(), expect, "request {i} diverged from local forward");
    }
    assert_eq!(snap.submitted, n);
    assert_eq!(snap.completed, n, "native backend lost admitted requests");
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.shed, 0);
}

#[test]
fn native_dense_and_sparse_serve_identical_classes() {
    // Pruned weights quantise to zero in the dense kernel, so serving the
    // dense and nnz-only compilations of the *same masked params* must
    // classify identically — baked sparsity changes cost, never answers.
    let g = lenet5();
    let mut params = ModelParams::synthetic(&g, 34);
    params.prune_global(0.7, 0.05).unwrap();
    let spec = KernelSpec::default();
    let dense = Arc::new(CompiledModel::compile_dense(&g, &params, &spec).unwrap());
    let sparse = Arc::new(CompiledModel::compile_sparse(&g, &params, &spec).unwrap());
    let run = |model: Arc<CompiledModel>| -> Vec<usize> {
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(300) },
            engines: 1,
            admission_capacity: 256,
            queue_depth: 8,
            ..ServerOptions::native(model)
        })
        .unwrap();
        let classes: Vec<usize> = (0..20u64)
            .map(|i| server.infer_blocking(image(i)).unwrap().class())
            .collect();
        let snap = server.shutdown();
        assert_eq!(snap.completed, 20);
        classes
    };
    assert_eq!(run(dense), run(sparse));
}

#[test]
fn synthetic_oracle_matches_served_classes() {
    let server = synth_server(1, Duration::ZERO, 64);
    for i in 0..10u64 {
        let img = image(i);
        let expect = SyntheticRuntime::expected_class(&img);
        assert_eq!(server.infer_blocking(img).unwrap().class(), expect);
    }
    let _ = server.shutdown();
}
