//! Engine-free serving-plane integration tests (synthetic backend): the
//! sharded execution plane, admission control, open-loop load generation
//! and graceful shutdown are exercised without artifacts or XLA.

use logicsparse::coordinator::{
    loadgen, BatchPolicy, EngineBackend, Fleet, FleetOptions, ModelSpec, Phase, Server,
    ServerOptions, ShedMode,
};
use logicsparse::graph::builder::lenet5;
use logicsparse::kernel::{CompiledModel, KernelSpec};
use logicsparse::obs::ObsConfig;
use logicsparse::runtime::SyntheticRuntime;
use logicsparse::traffic::{Mix, Traffic};
use logicsparse::weights::ModelParams;
use logicsparse::Error;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Deterministic image whose synthetic class is `i % 10`.
fn image(i: u64) -> Vec<f32> {
    SyntheticRuntime::stripe_image(i as usize)
}

fn synth_server(engines: usize, per_image: Duration, admission: usize) -> Server {
    Server::start(ServerOptions {
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(300) },
        engines,
        admission_capacity: admission,
        queue_depth: 8,
        ..ServerOptions::synthetic(per_image)
    })
    .unwrap()
}

#[test]
fn shutdown_in_flight_loses_no_requests() {
    // Submit a pile of work, then shut down while most of it is still in
    // flight: every admitted request must still receive a real response.
    // (The seed had a bug here: shutdown joined the batcher while the
    // submit sender was alive, so the drain path never fired and
    // in-flight requests could be dropped.)
    let server = synth_server(2, Duration::from_micros(200), 4096);
    let n = 300u64;
    let mut rxs = Vec::new();
    for i in 0..n {
        rxs.push(server.submit(image(i)).unwrap());
    }
    // Immediately begin graceful shutdown — the queue is mostly unserved.
    let snap = server.shutdown();

    let mut answered = 0u64;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|_| panic!("request {i} dropped in shutdown"));
        assert!(!resp.is_error(), "request {i} failed");
        assert_eq!(resp.class(), (i % 10), "request {i} misclassified");
        answered += 1;
    }
    assert_eq!(answered, n);
    assert_eq!(snap.submitted, n);
    assert_eq!(snap.completed, n, "server lost admitted requests");
    assert_eq!(snap.errors, 0);
}

#[test]
fn responses_are_correct_per_request() {
    let server = synth_server(2, Duration::ZERO, 1024);
    for i in 0..40u64 {
        let resp = server.infer_blocking(image(i)).unwrap();
        assert_eq!(resp.class(), (i % 10) as usize);
        assert!(resp.latency_s >= 0.0);
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed, 40);
    assert_eq!(snap.shed, 0);
}

#[test]
fn overload_sheds_fast_and_admitted_requests_all_complete() {
    // Slow engine + tiny admission bound: a burst must shed quickly (no
    // unbounded queueing) while everything admitted still completes.
    let server = Server::start(ServerOptions {
        policy: BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(200) },
        engines: 1,
        admission_capacity: 8,
        queue_depth: 4,
        ..ServerOptions::synthetic(Duration::from_millis(2))
    })
    .unwrap();

    let mut accepted = Vec::new();
    let mut shed = 0u64;
    let t0 = Instant::now();
    for i in 0..64u64 {
        match server.submit(image(i)) {
            Ok(rx) => accepted.push(rx),
            Err(Error::Overloaded) => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let submit_wall = t0.elapsed();
    assert!(shed > 0, "64 fast submits over an 8-deep gate must shed");
    // Shedding is a fast reject: submitting 64 requests must not take
    // anywhere near the ~100ms the admitted work needs to execute.
    assert!(
        submit_wall < Duration::from_millis(50),
        "submit path blocked for {submit_wall:?}"
    );

    for rx in accepted {
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(!resp.is_error());
    }
    let snap = server.shutdown();
    assert_eq!(snap.shed, shed, "gate and client disagree on shed count");
    assert_eq!(snap.completed, snap.submitted);
}

#[test]
fn bad_image_is_rejected_without_admission_leak() {
    let server = synth_server(1, Duration::ZERO, 4);
    for _ in 0..16 {
        assert!(server.submit(vec![0.0; 3]).is_err());
    }
    // The gate must not have leaked: full capacity still available.
    for i in 0..4u64 {
        server.submit(image(i)).unwrap();
    }
    let snap = server.shutdown();
    assert_eq!(snap.completed, 4);
}

#[test]
fn open_loop_poisson_accounting_is_consistent() {
    let server = synth_server(2, Duration::from_micros(100), 256);
    let traffic = Traffic::poisson(400, 4000.0, 17);
    let rep = loadgen::run_open_loop(&server, &traffic, image, ShedMode::Drop);
    let snap = server.shutdown();

    assert_eq!(rep.offered, 400);
    assert_eq!(rep.accepted + rep.shed, rep.offered);
    assert_eq!(rep.completed + rep.errors, rep.accepted, "requests unaccounted");
    assert_eq!(rep.lost, 0, "responses dropped");
    assert_eq!(rep.errors, 0);
    assert_eq!(snap.completed, rep.completed);
    assert_eq!(snap.shed, rep.shed);
    assert_eq!(rep.latencies_s.len() as u64, rep.completed);
    assert!(rep.latency_pct_s(0.5) <= rep.latency_pct_s(0.99));
    assert!(rep.wall_s > 0.0 && rep.achieved_rps > 0.0);
}

#[test]
fn engine_scaling_under_saturated_traffic() {
    // Sleep-based synthetic cost scales with replicas on any core count;
    // 4 engines must beat 1 engine clearly (the bench asserts the full
    // >= 2x claim; this test keeps a conservative floor so CI stays
    // stable on loaded machines).
    let run = |engines: usize| -> f64 {
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(300) },
            engines,
            admission_capacity: 256,
            queue_depth: 16,
            ..ServerOptions::synthetic(Duration::from_micros(100))
        })
        .unwrap();
        let rep = loadgen::run_open_loop(
            &server,
            &Traffic::saturated(800),
            image,
            ShedMode::Retry,
        );
        let snap = server.shutdown();
        assert_eq!(rep.lost, 0);
        assert_eq!(rep.completed, 800);
        assert_eq!(snap.completed, snap.submitted);
        rep.achieved_rps
    };
    let rps1 = run(1);
    let rps4 = run(4);
    assert!(
        rps4 > rps1 * 1.5,
        "4 engines ({rps4:.0} req/s) should clearly beat 1 ({rps1:.0} req/s)"
    );
}

#[test]
fn steals_rebalance_skewed_load() {
    // Many engines + deep saturation: the two-choice dispatcher plus
    // stealing keeps all rings busy; at least the counters must be sane
    // and total completions exact.
    let server = synth_server(4, Duration::from_micros(100), 1024);
    let rep = loadgen::run_open_loop(
        &server,
        &Traffic::saturated(600),
        image,
        ShedMode::Retry,
    );
    let snap = server.shutdown();
    assert_eq!(rep.completed, 600);
    assert_eq!(snap.completed, 600);
    // Steals are opportunistic, so only sanity-bound them.
    assert!(snap.steals <= snap.batches);
}

#[test]
fn shared_traffic_model_drives_sim_and_server_identically() {
    // The acceptance point of the unified traffic model: the *same*
    // Traffic schedule replayed by the server is the one the simulator
    // integrates over (cycle-rounded), so offered load is comparable.
    let traffic = Traffic::poisson(100, 5000.0, 23);
    let schedule = traffic.schedule();
    let cycles = traffic.to_cycles(200.0);
    assert_eq!(schedule.len(), cycles.len());
    for (s, c) in schedule.iter().zip(&cycles) {
        assert_eq!(*c, (s * 200e6).round() as u64);
    }

    // And the serving side accepts exactly that schedule.
    let server = synth_server(1, Duration::ZERO, 1024);
    let rep = loadgen::run_open_loop(&server, &traffic, image, ShedMode::Retry);
    assert_eq!(rep.offered, 100);
    assert_eq!(rep.completed, 100);
    let _ = server.shutdown();
}

#[test]
fn native_baked_kernels_serve_end_to_end() {
    // The tentpole acceptance path: a CompiledModel of baked sparse
    // kernels behind the sharded plane. Every served class must equal a
    // local forward pass of the same model (the oracle), nothing may be
    // dropped across graceful shutdown, and the engines must report the
    // native backend's integer datapath — no sleeps, no artifacts.
    let g = lenet5();
    let mut params = ModelParams::synthetic(&g, 33);
    params.prune_global(0.75, 0.05).unwrap();
    let model =
        Arc::new(CompiledModel::compile_sparse(&g, &params, &KernelSpec::default()).unwrap());
    assert!(model.sparsity().global_sparsity() >= 0.70);

    let server = Server::start(ServerOptions {
        policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(300) },
        engines: 2,
        admission_capacity: 1024,
        queue_depth: 8,
        ..ServerOptions::native(Arc::clone(&model))
    })
    .unwrap();

    let n = 60u64;
    let mut rxs = Vec::new();
    for i in 0..n {
        let img = image(i);
        let expect = model.classify(&img).unwrap();
        rxs.push((server.submit(img).unwrap(), expect));
    }
    // Shut down with most of the work still queued: the drain guarantee
    // must hold for the native backend exactly as for the others.
    let snap = server.shutdown();
    for (i, (rx, expect)) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|_| panic!("native request {i} dropped in shutdown"));
        assert!(!resp.is_error(), "native request {i} failed");
        assert_eq!(resp.class(), expect, "request {i} diverged from local forward");
    }
    assert_eq!(snap.submitted, n);
    assert_eq!(snap.completed, n, "native backend lost admitted requests");
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.shed, 0);
}

#[test]
fn native_dense_and_sparse_serve_identical_classes() {
    // Pruned weights quantise to zero in the dense kernel, so serving the
    // dense and nnz-only compilations of the *same masked params* must
    // classify identically — baked sparsity changes cost, never answers.
    let g = lenet5();
    let mut params = ModelParams::synthetic(&g, 34);
    params.prune_global(0.7, 0.05).unwrap();
    let spec = KernelSpec::default();
    let dense = Arc::new(CompiledModel::compile_dense(&g, &params, &spec).unwrap());
    let sparse = Arc::new(CompiledModel::compile_sparse(&g, &params, &spec).unwrap());
    let run = |model: Arc<CompiledModel>| -> Vec<usize> {
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(300) },
            engines: 1,
            admission_capacity: 256,
            queue_depth: 8,
            ..ServerOptions::native(model)
        })
        .unwrap();
        let classes: Vec<usize> = (0..20u64)
            .map(|i| server.infer_blocking(image(i)).unwrap().class())
            .collect();
        let snap = server.shutdown();
        assert_eq!(snap.completed, 20);
        classes
    };
    assert_eq!(run(dense), run(sparse));
}

fn synth_backend(per_image: Duration) -> EngineBackend {
    EngineBackend::Synthetic { per_image }
}

#[test]
fn fleet_slow_tag_does_not_stall_other_planes() {
    // Isolation: a wedged/slow model fills only its own rings and
    // batcher; another tag's plane must keep its full dispatch path. The
    // planes share nothing but the admission gate (sized far above this
    // test's load, so it never interferes).
    let fleet = Fleet::start(FleetOptions {
        models: vec![
            ModelSpec::new("slow", synth_backend(Duration::from_millis(20)))
                .policy(BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(100) })
                .queue_depth(1),
            ModelSpec::new("fast", synth_backend(Duration::ZERO))
                .policy(BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) }),
        ],
        admission_capacity: 4096,
        autotune: None,
        obs: ObsConfig::default(),
    })
    .unwrap();

    // Wedge the slow plane: ~1.6s of strictly serial work (1 engine,
    // 1-batch rings, 1-request batches).
    let slow_rxs: Vec<_> = (0..80u64)
        .map(|i| fleet.submit("slow", image(i)).unwrap())
        .collect();

    // The fast tag must stay fully serviceable while slow is backed up.
    let t0 = Instant::now();
    for i in 0..50u64 {
        let resp = fleet.infer_blocking("fast", image(i)).unwrap();
        assert_eq!(resp.class(), (i % 10) as usize);
    }
    let fast_wall = t0.elapsed();
    let snap = fleet.stats();
    assert_eq!(snap.get("fast").unwrap().completed, 50);
    assert!(
        snap.get("slow").unwrap().completed < 80,
        "slow plane drained its backlog implausibly fast; the test lost its wedge"
    );
    assert!(
        fast_wall < Duration::from_secs(5),
        "fast tag stalled behind the slow tag's backlog: {fast_wall:?}"
    );

    // The lossless drain guarantee still covers the wedged backlog.
    let final_snap = fleet.shutdown();
    for (i, rx) in slow_rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|_| panic!("slow request {i} dropped in shutdown"));
        assert!(!resp.is_error(), "slow request {i} failed");
    }
    assert_eq!(final_snap.get("slow").unwrap().completed, 80);
    assert_eq!(final_snap.errors(), 0);
}

#[test]
fn fleet_unknown_model_is_rejected_without_side_effects() {
    let fleet = Fleet::start(FleetOptions {
        models: vec![ModelSpec::new("only", synth_backend(Duration::ZERO))],
        admission_capacity: 8,
        autotune: None,
        obs: ObsConfig::default(),
    })
    .unwrap();
    for _ in 0..16 {
        assert!(matches!(
            fleet.submit("ghost", image(0)),
            Err(Error::UnknownModel(_))
        ));
    }
    assert!(matches!(fleet.resolve("ghost"), Err(Error::UnknownModel(_))));
    assert!(fleet.handle("ghost").is_err());
    // Nothing was admitted or leaked: the full budget is still available
    // and the known tag serves normally.
    assert_eq!(fleet.in_flight(), 0);
    for i in 0..8u64 {
        fleet.infer_blocking("only", image(i)).unwrap();
    }
    let snap = fleet.shutdown();
    assert_eq!(snap.completed(), 8);
    assert_eq!(snap.submitted(), 8);
    // Unknown-tag rejects are not admission sheds.
    assert_eq!(snap.shed, 0);
    assert_eq!(snap.shed_by_tag(), 0);
}

#[test]
fn fleet_shutdown_loses_no_requests_across_three_tags() {
    // The single-plane drain guarantee, applied per tag: shut down with
    // most of a 3-tag fleet's work still queued; every admitted request
    // of every tag must receive a real response.
    let fleet = Fleet::start(FleetOptions {
        models: vec![
            ModelSpec::new("a", synth_backend(Duration::from_micros(200))),
            ModelSpec::new("b", synth_backend(Duration::from_micros(200))).engines(2),
            ModelSpec::new("c", synth_backend(Duration::from_micros(200))),
        ],
        admission_capacity: 4096,
        autotune: None,
        obs: ObsConfig::default(),
    })
    .unwrap();
    let tags = ["a", "b", "c"];
    let n = 240u64;
    let mut rxs = Vec::new();
    for i in 0..n {
        let tag = tags[(i % 3) as usize];
        rxs.push((i, fleet.submit(tag, image(i)).unwrap()));
    }
    // Immediately begin graceful shutdown — the queues are mostly unserved.
    let snap = fleet.shutdown();

    for (i, rx) in rxs {
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|_| panic!("request {i} dropped in fleet shutdown"));
        assert!(!resp.is_error(), "request {i} failed");
        assert_eq!(resp.class(), (i % 10) as usize, "request {i} misclassified");
    }
    for tag in tags {
        let s = snap.get(tag).unwrap();
        assert_eq!(s.submitted, n / 3, "[{tag}] submit accounting");
        assert_eq!(s.completed, n / 3, "[{tag}] lost admitted requests");
        assert_eq!(s.errors, 0, "[{tag}] errors");
    }
    assert_eq!(snap.completed(), n);
}

#[test]
fn fleet_shared_admission_shed_accounting_sums_across_tags() {
    // One shared budget governs both tags: a burst across the fleet must
    // shed once the *host-wide* bound is hit, the shared gate and the
    // per-tag counters must agree, and everything admitted completes.
    let fleet = Fleet::start(FleetOptions {
        models: vec![
            ModelSpec::new("a", synth_backend(Duration::from_millis(2)))
                .policy(BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(200) })
                .queue_depth(4),
            ModelSpec::new("b", synth_backend(Duration::from_millis(2)))
                .policy(BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(200) })
                .queue_depth(4),
        ],
        admission_capacity: 8,
        autotune: None,
        obs: ObsConfig::default(),
    })
    .unwrap();

    let mut client_shed = [0u64; 2];
    let mut accepted = Vec::new();
    for i in 0..64u64 {
        let k = (i % 2) as usize;
        let tag = if k == 0 { "a" } else { "b" };
        match fleet.submit(tag, image(i)) {
            Ok(rx) => accepted.push(rx),
            Err(Error::Overloaded) => client_shed[k] += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(
        client_shed[0] + client_shed[1] > 0,
        "64 fast submits over a shared 8-deep gate must shed"
    );
    for rx in accepted {
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(!resp.is_error());
    }
    let snap = fleet.shutdown();
    assert_eq!(
        snap.get("a").unwrap().shed,
        client_shed[0],
        "tag a's shed attribution disagrees with the client"
    );
    assert_eq!(
        snap.get("b").unwrap().shed,
        client_shed[1],
        "tag b's shed attribution disagrees with the client"
    );
    // The shared gate's total and the per-tag sum are two views of the
    // same events.
    assert_eq!(snap.shed, client_shed[0] + client_shed[1]);
    assert_eq!(snap.shed_by_tag(), snap.shed);
    assert_eq!(snap.completed(), snap.submitted());
}

#[test]
fn fleet_mixed_open_loop_replays_per_tag_traffic() {
    // The per-tag arrival mixes: a heterogeneous Mix replayed against the
    // fleet must offer each tag exactly its own Traffic while the
    // accounting stays complete per tag.
    let fleet = Fleet::start(FleetOptions {
        models: vec![
            ModelSpec::new("fast", synth_backend(Duration::ZERO)),
            ModelSpec::new("steady", synth_backend(Duration::from_micros(100))),
        ],
        admission_capacity: 1024,
        autotune: None,
        obs: ObsConfig::default(),
    })
    .unwrap();
    let mix = Mix::new()
        .stream("fast", Traffic::poisson(150, 3000.0, 5))
        .stream("steady", Traffic::periodic(100, 0.0005));
    let rep = loadgen::run_open_loop_mix(&fleet, &mix, |_, i| image(i), ShedMode::Retry)
        .unwrap();
    assert_eq!(rep.get("fast").unwrap().offered, 150);
    assert_eq!(rep.get("steady").unwrap().offered, 100);
    assert_eq!(rep.offered(), 250);
    assert_eq!(rep.completed(), 250);
    assert_eq!(rep.lost(), 0, "responses dropped");
    for (_, r) in &rep.per_tag {
        assert_eq!(r.completed + r.errors, r.accepted, "requests unaccounted");
        assert_eq!(r.latencies_s.len() as u64, r.completed);
    }
    assert!(rep.aggregate_rps() > 0.0);

    let snap = fleet.stats();
    assert_eq!(snap.get("fast").unwrap().completed, 150);
    assert_eq!(snap.get("steady").unwrap().completed, 100);

    // A mix naming an unserved tag is rejected before anything submits.
    let bad = Mix::new().stream("ghost", Traffic::saturated(5));
    assert!(matches!(
        loadgen::run_open_loop_mix(&fleet, &bad, |_, i| image(i), ShedMode::Retry),
        Err(Error::UnknownModel(_))
    ));
    let _ = fleet.shutdown();
}

#[test]
fn fleet_budgeted_admission_reconciles_under_burst() {
    // Per-tag budgets active (one tag carries an SLO weight), bursty
    // mixed traffic: the gate-total vs per-tag reconciliation must still
    // hold — the host gate counts exactly the per-tag `shed` sum, while
    // budget sheds stay a disjoint per-tag counter.
    let fleet = Fleet::start(FleetOptions {
        models: vec![
            ModelSpec::new("gold", synth_backend(Duration::from_millis(2)))
                .policy(BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(200) })
                .queue_depth(4)
                .slo(50.0, 3.0),
            ModelSpec::new("bulk", synth_backend(Duration::from_millis(2)))
                .policy(BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(200) })
                .queue_depth(4),
        ],
        admission_capacity: 12,
        autotune: None,
        obs: ObsConfig::default(),
    })
    .unwrap();
    // Weighted partition of 12 by 3:1 -> gold 9, bulk 3.
    let start = fleet.stats();
    assert_eq!(start.get("gold").unwrap().budget_capacity, Some(9));
    assert_eq!(start.get("bulk").unwrap().budget_capacity, Some(3));

    // Burst-shaped offered load on both tags, open-loop with drops.
    let mix = Mix::new()
        .stream("gold", Traffic::bursty(120, 24, 0.01, 7))
        .stream("bulk", Traffic::bursty(120, 24, 0.01, 9));
    let rep = loadgen::run_open_loop_mix(&fleet, &mix, |_, i| image(i), ShedMode::Drop)
        .unwrap();
    assert_eq!(rep.lost(), 0, "responses dropped");
    // 24-deep back-to-back bursts over a 3-deep budget must shed on the
    // bulk tag's own budget.
    let snap = fleet.shutdown();
    let bulk = snap.get("bulk").unwrap();
    assert!(bulk.shed_budget > 0, "bulk's 3-deep budget never shed under 24-bursts");
    // Client-observed sheds per tag = that tag's host sheds + budget
    // sheds (two scopes, one client-visible error).
    for tag in ["gold", "bulk"] {
        let s = snap.get(tag).unwrap();
        let r = rep.get(tag).unwrap();
        assert_eq!(
            s.shed + s.shed_budget,
            r.shed,
            "[{tag}] client and server disagree on total sheds"
        );
        assert_eq!(s.completed + s.errors, r.accepted, "[{tag}] unaccounted");
    }
    // The reconciliation identity with budgets active: the shared gate
    // counted exactly the host-scope sheds, no more, no less.
    assert_eq!(snap.shed, snap.shed_by_tag(), "gate total != per-tag host sheds");
    assert_eq!(snap.shed_retired, 0);
    // Budget occupancy fields are present in the roll-up.
    assert!(snap.render().contains("budget"));
}

#[test]
fn fleet_retire_mid_burst_is_lossless_and_invalidates_handles() {
    // Retire a tag while a burst of its work is still in flight: the
    // drain must answer every admitted request, later submits against
    // the tag (or its stale index) must fail UnknownModel, and the other
    // tag must be unaffected.
    let fleet = Fleet::start(FleetOptions {
        models: vec![
            ModelSpec::new("doomed", synth_backend(Duration::from_micros(500))),
            ModelSpec::new("stable", synth_backend(Duration::ZERO)),
        ],
        admission_capacity: 4096,
        autotune: None,
        obs: ObsConfig::default(),
    })
    .unwrap();
    let doomed_idx = fleet.resolve("doomed").unwrap();

    // A burst of 120 requests, most still queued when retire begins.
    let rxs: Vec<_> = (0..120u64)
        .map(|i| fleet.submit("doomed", image(i)).unwrap())
        .collect();
    let final_snap = fleet.retire("doomed").unwrap();
    assert_eq!(final_snap.submitted, 120);
    assert_eq!(final_snap.completed, 120, "retire dropped in-flight requests");
    assert_eq!(final_snap.errors, 0);
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(Duration::from_secs(10))
            .unwrap_or_else(|_| panic!("request {i} dropped in retire"));
        assert!(!resp.is_error(), "request {i} failed");
        assert_eq!(resp.class(), (i % 10), "request {i} misclassified");
    }

    // The tag and its stale index are gone — UnknownModel, not a silent
    // reroute.
    assert!(matches!(
        fleet.submit("doomed", image(0)),
        Err(Error::UnknownModel(_))
    ));
    assert!(matches!(
        fleet.submit_at(doomed_idx, image(0)),
        Err(Error::UnknownModel(_))
    ));
    assert_eq!(fleet.tags(), vec!["stable".to_string()]);
    // The survivor serves normally; registering the tag again revives it.
    fleet.infer_blocking("stable", image(1)).unwrap();
    fleet
        .register(ModelSpec::new("doomed", synth_backend(Duration::ZERO)))
        .unwrap();
    let resp = fleet.infer_blocking("doomed", image(5)).unwrap();
    assert_eq!(resp.class(), 5);
    let snap = fleet.shutdown();
    assert_eq!(snap.get("doomed").unwrap().completed, 1);
    assert_eq!(snap.get("stable").unwrap().completed, 1);
}

#[test]
fn phase_shift_run_replays_membership_and_offset_streams() {
    // The §11 phase-shift scenario: phase 1 serves one tag; phase 2
    // registers a second tag mid-run whose stream joins at an offset.
    // Every phase's accounting must be complete with zero losses.
    let fleet = Fleet::start(FleetOptions {
        models: vec![ModelSpec::new("base", synth_backend(Duration::from_micros(50)))],
        admission_capacity: 1024,
        autotune: None,
        obs: ObsConfig::default(),
    })
    .unwrap();
    let phases = vec![
        Phase {
            retire: Vec::new(),
            register: Vec::new(),
            mix: Mix::new().stream("base", Traffic::poisson(80, 4000.0, 11)),
        },
        Phase {
            retire: Vec::new(),
            register: vec![ModelSpec::new("joiner", synth_backend(Duration::ZERO))],
            mix: Mix::new()
                .stream("base", Traffic::poisson(60, 3000.0, 12))
                .stream_at("joiner", Traffic::poisson(40, 3000.0, 13), 0.005),
        },
    ];
    let reports =
        loadgen::run_phases(&fleet, &phases, |_, i| image(i), ShedMode::Retry).unwrap();
    assert_eq!(reports.len(), 2);
    assert_eq!(reports[0].offered(), 80);
    assert_eq!(reports[0].completed(), 80);
    assert_eq!(reports[0].lost(), 0);
    assert_eq!(reports[1].get("base").unwrap().completed, 60);
    assert_eq!(reports[1].get("joiner").unwrap().completed, 40);
    assert_eq!(reports[1].lost(), 0);
    let snap = fleet.shutdown();
    assert_eq!(snap.get("base").unwrap().completed, 140);
    assert_eq!(snap.get("joiner").unwrap().completed, 40);
}

#[test]
fn weighted_tag_keeps_headroom_while_noisy_neighbour_sheds() {
    // The admission-policy acceptance shape at test scale: the noisy
    // tag's weighted cap keeps it from spending the shared budget, so
    // the SLO tag never sheds even while the neighbour saturates.
    let fleet = Fleet::start(FleetOptions {
        models: vec![
            ModelSpec::new("slo", synth_backend(Duration::from_micros(100))).slo(50.0, 8.0),
            ModelSpec::new("noisy", synth_backend(Duration::from_millis(2)))
                .policy(BatchPolicy { max_batch: 2, max_wait: Duration::from_micros(200) })
                .queue_depth(2),
        ],
        admission_capacity: 63,
        autotune: None,
        obs: ObsConfig::default(),
    })
    .unwrap();
    // Saturate the noisy tag far beyond its 7-slot budget.
    let mut noisy_rxs = Vec::new();
    let mut noisy_shed = 0u64;
    for i in 0..200u64 {
        match fleet.submit("noisy", image(i)) {
            Ok(rx) => noisy_rxs.push(rx),
            Err(Error::Overloaded) => noisy_shed += 1,
            Err(e) => panic!("unexpected: {e}"),
        }
    }
    assert!(noisy_shed > 0, "200 fast submits over a 7-slot budget must shed");
    // The SLO tag retains headroom: a full window of its own budget
    // admits without a single shed.
    for i in 0..50u64 {
        let resp = fleet.infer_blocking("slo", image(i)).unwrap();
        assert_eq!(resp.class(), (i % 10) as usize);
    }
    let snap = fleet.shutdown();
    assert_eq!(snap.get("slo").unwrap().shed_total(), 0, "SLO tag shed");
    assert_eq!(snap.get("slo").unwrap().completed, 50);
    assert_eq!(
        snap.get("noisy").unwrap().shed_total(),
        noisy_shed,
        "noisy shed attribution disagrees with the client"
    );
    for rx in noisy_rxs {
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(!resp.is_error());
    }
}

#[test]
fn synthetic_oracle_matches_served_classes() {
    let server = synth_server(1, Duration::ZERO, 64);
    for i in 0..10u64 {
        let img = image(i);
        let expect = SyntheticRuntime::expected_class(&img);
        assert_eq!(server.infer_blocking(img).unwrap().class(), expect);
    }
    let _ = server.shutdown();
}

#[test]
fn observability_never_changes_acceptance_accounting() {
    // The observer must be a read-only plane: the same workload served
    // dark and served with tracing at sample_rate < 1.0 plus a
    // concurrent metrics scraper must produce identical acceptance
    // accounting. Retry mode makes the counts workload-determined
    // (every offered request is eventually admitted and completed), so
    // any observer-induced drop or double-count shows up exactly.
    use logicsparse::obs::{metrics::Registry, trace::Tracer, ObsConfig};

    let run = |obs: ObsConfig| {
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(300) },
            engines: 2,
            admission_capacity: 256,
            queue_depth: 8,
            obs,
            ..ServerOptions::synthetic(Duration::from_micros(100))
        })
        .unwrap();
        let rep = loadgen::run_open_loop(
            &server,
            &Traffic::poisson(200, 4000.0, 17),
            image,
            ShedMode::Retry,
        );
        let snap = server.shutdown();
        (rep, snap)
    };

    let (dark_rep, dark_snap) = run(ObsConfig::default());

    let tracer = Tracer::new(0.25);
    let registry = Registry::new();
    let obs = ObsConfig {
        tracer: Some(Arc::clone(&tracer)),
        metrics: Some(Arc::clone(&registry)),
    };
    // Scrape aggressively while the traced run is in flight.
    let stop = std::sync::atomic::AtomicBool::new(false);
    let (obs_rep, obs_snap) = std::thread::scope(|s| {
        s.spawn(|| {
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let _ = registry.snapshot().render();
                std::thread::sleep(Duration::from_micros(500));
            }
        });
        let out = run(obs);
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        out
    });

    assert_eq!(obs_rep.completed, dark_rep.completed);
    assert_eq!(obs_rep.errors, dark_rep.errors);
    assert_eq!(obs_rep.lost, 0);
    assert_eq!(obs_snap.completed, dark_snap.completed);
    assert_eq!(obs_snap.errors, dark_snap.errors);
    assert_eq!(obs_snap.completed, obs_snap.submitted);

    // The registry's view is the same cells the snapshot read.
    let scrape = registry.snapshot();
    assert_eq!(scrape.counter("serve.completed"), Some(obs_snap.completed));
    assert_eq!(scrape.counter("serve.submitted"), Some(obs_snap.submitted));
    // Sub-unit sampling recorded a strict subset of request lifecycles.
    assert!(tracer.recorded_events() > 0, "0.25 sampling captured nothing");
    assert!(
        tracer.stage_breakdown().spans <= obs_snap.completed as usize,
        "sampled spans exceed completed requests"
    );
}
