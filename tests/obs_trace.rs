//! Observability-plane integration tests: live trace capture on a
//! running fleet, and the capture → [`Traffic::replay`] round trip that
//! closes the loop between tracing and the shared traffic model
//! (ROADMAP #4). Engine-free throughout (synthetic backends).

use logicsparse::coordinator::{
    loadgen, EngineBackend, Fleet, FleetOptions, ModelSpec, ShedMode,
};
use logicsparse::obs::{metrics::Registry, trace::Tracer, ObsConfig};
use logicsparse::runtime::SyntheticRuntime;
use logicsparse::traffic::{Mix, Traffic};
use std::time::Duration;

fn image(i: u64) -> Vec<f32> {
    SyntheticRuntime::stripe_image(i as usize)
}

fn synth(per_image: Duration) -> EngineBackend {
    EngineBackend::Synthetic { per_image }
}

/// Start a two-tag fleet wired to a fresh tracer + registry, run the
/// given mix through it open-loop, shut down, and return the tracer.
fn traced_run(mix: &Mix) -> (std::sync::Arc<Tracer>, std::sync::Arc<Registry>) {
    let tracer = Tracer::new(1.0);
    let registry = Registry::new();
    let fleet = Fleet::start(FleetOptions {
        models: vec![
            ModelSpec::new("alpha", synth(Duration::from_micros(80))),
            ModelSpec::new("beta", synth(Duration::from_micros(120))),
        ],
        admission_capacity: 4096,
        autotune: None,
        obs: ObsConfig {
            tracer: Some(std::sync::Arc::clone(&tracer)),
            metrics: Some(std::sync::Arc::clone(&registry)),
        },
    })
    .unwrap();
    let rep = loadgen::run_open_loop_mix(&fleet, mix, |_, i| image(i), ShedMode::Retry)
        .unwrap();
    let snap = fleet.shutdown();
    assert_eq!(rep.lost(), 0, "responses dropped");
    assert_eq!(snap.errors(), 0, "synthetic backends must not fail");
    (tracer, registry)
}

#[test]
fn capture_replays_through_traffic_model() {
    // Capture leg: two Poisson streams with distinct rates/seeds so the
    // tags interleave non-trivially.
    let mix = Mix::new()
        .stream("alpha", Traffic::poisson(90, 3000.0, 7))
        .stream("beta", Traffic::poisson(60, 2000.0, 11));
    let (tracer, _) = traced_run(&mix);

    assert_eq!(
        tracer.dropped_events(),
        0,
        "default ring capacity must hold this test's event volume"
    );
    let schedule = tracer.arrival_schedule();
    let count = |tag: &str| {
        schedule
            .iter()
            .find(|(t, _)| t == tag)
            .map(|(_, v)| v.len())
            .unwrap_or(0)
    };
    // Every admitted arrival must appear in the capture (sample rate
    // 1.0, ShedMode::Retry so every offered request is admitted once).
    assert_eq!(count("alpha"), 90, "alpha admissions missing from capture");
    assert_eq!(count("beta"), 60, "beta admissions missing from capture");
    for (tag, offsets) in &schedule {
        assert!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "{tag}: captured offsets must be monotone"
        );
        assert!(
            offsets.first().copied().unwrap_or(0.0) >= 0.0,
            "{tag}: offsets are relative to the first admission overall"
        );
    }

    // Replay leg: feed the captured offsets back through the shared
    // traffic model and serve them on a fresh fleet. The round trip
    // must preserve per-tag arrival counts exactly.
    let mut replay_mix = Mix::new();
    for (tag, offsets) in &schedule {
        replay_mix = replay_mix.stream(tag.as_str(), Traffic::replay(offsets.clone()));
    }
    let (tracer2, _) = traced_run(&replay_mix);
    let schedule2 = tracer2.arrival_schedule();
    for (tag, offsets) in &schedule {
        let replayed = schedule2
            .iter()
            .find(|(t, _)| t == tag)
            .map(|(_, v)| v.len())
            .unwrap_or(0);
        assert_eq!(
            replayed,
            offsets.len(),
            "{tag}: replay leg admitted a different arrival count than captured"
        );
    }
}

#[test]
fn chrome_export_and_breakdown_are_well_formed() {
    let mix = Mix::new()
        .stream("alpha", Traffic::poisson(40, 2500.0, 3))
        .stream("beta", Traffic::periodic(30, 0.0004));
    let (tracer, registry) = traced_run(&mix);

    // Span assembly: every request completed, so the breakdown covers
    // all 70 and per-span total >= exec (admitted precedes dispatch).
    let b = tracer.stage_breakdown();
    assert_eq!(b.spans, 70, "every completed request must assemble a span");
    assert!(b.total_us >= b.exec_us, "total {} < exec {}", b.total_us, b.exec_us);
    assert!(b.total_us > 0.0);

    // Chrome trace-event document shape: traceEvents is a non-empty
    // array, every event carries name/ph, timed events carry ts/pid/tid,
    // and otherData reports the drop accounting trace-validate gates on.
    let doc = tracer.chrome_trace();
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents array");
    assert!(!events.is_empty());
    for e in events {
        let ph = e.get("ph").and_then(|v| v.as_str()).expect("ph");
        assert!(e.get("name").is_some(), "event missing name");
        if ph != "M" {
            assert!(e.get("ts").is_some() && e.get("pid").is_some() && e.get("tid").is_some());
        }
    }
    let dropped = doc
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(|v| v.as_u64())
        .expect("otherData.dropped_events");
    assert_eq!(dropped, 0);

    // The metrics registry saw the same run: per-tag counters must agree
    // with the workload, and the scrape must render without panicking.
    let snap = registry.snapshot();
    assert_eq!(snap.counter("alpha.completed"), Some(40));
    assert_eq!(snap.counter("beta.completed"), Some(30));
    assert_eq!(snap.counter("alpha.errors"), Some(0));
    assert!(!snap.render().is_empty());
}

#[test]
fn drop_oldest_ring_reports_losses_honestly() {
    // A deliberately tiny ring must overwrite oldest events and say so,
    // rather than blocking the recorder or silently lying.
    let tracer = Tracer::with_capacity(1.0, 16);
    let h = tracer.register("tiny");
    let tag = tracer.intern("t");
    for i in 0..64u64 {
        h.request(logicsparse::obs::trace::EventKind::Admitted, i, tag);
    }
    assert_eq!(tracer.recorded_events(), 64);
    assert_eq!(tracer.dropped_events(), 64 - 16);
    // The survivors are the newest 16, still decodable in order.
    let events = tracer.events();
    assert_eq!(events.len(), 16);
    assert!(events.windows(2).all(|w| w[0].req_id < w[1].req_id));
    assert_eq!(events.last().unwrap().req_id, 63);
}
