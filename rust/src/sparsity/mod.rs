//! Sparsity substrate (S7): masks, magnitude thresholds, per-layer
//! statistics, N:M structured baseline and engine-free compression
//! accounting.
//!
//! The python compile path performs the *training-time* pruning; this
//! module gives the DSE and the benches the same primitives natively so
//! they can (a) analyse exported masks, (b) run what-if sweeps without a
//! python round-trip, and (c) compute the paper's compression headline.

pub mod magnitude;
pub mod nm;

use crate::util::error::{Error, Result};

/// A binary mask over one layer's weights (flat, C-order).
#[derive(Debug, Clone, PartialEq)]
pub struct Mask {
    /// `true` = the weight survives, `false` = pruned.
    pub keep: Vec<bool>,
}

impl Mask {
    /// An all-keep mask of `n` weights.
    pub fn dense(n: usize) -> Self {
        Mask { keep: vec![true; n] }
    }

    /// A mask keeping every nonzero entry of `vals` (the LSTW
    /// interchange encodes masks as f32 0/1).
    pub fn from_f32(vals: &[f32]) -> Self {
        Mask { keep: vals.iter().map(|&v| v != 0.0).collect() }
    }

    /// Total weights the mask covers.
    pub fn len(&self) -> usize {
        self.keep.len()
    }

    /// True for a zero-length mask.
    pub fn is_empty(&self) -> bool {
        self.keep.is_empty()
    }

    /// Surviving weights.
    pub fn nnz(&self) -> usize {
        self.keep.iter().filter(|&&k| k).count()
    }

    /// Fraction pruned (0.0 for an empty mask).
    pub fn sparsity(&self) -> f64 {
        if self.keep.is_empty() {
            return 0.0;
        }
        1.0 - self.nnz() as f64 / self.len() as f64
    }

    /// Apply to a weight vector (panics on length mismatch guarded by Err).
    pub fn apply(&self, w: &mut [f32]) -> Result<()> {
        if w.len() != self.keep.len() {
            return Err(Error::lstw(format!(
                "mask len {} vs weights len {}",
                self.keep.len(),
                w.len()
            )));
        }
        for (x, &k) in w.iter_mut().zip(&self.keep) {
            if !k {
                *x = 0.0;
            }
        }
        Ok(())
    }

    /// Count of all-zero SIMD blocks along the input axis — what the
    /// engine-free kernel (and unrolled hardware) can elide entirely.
    /// Layout: weights are [fold_in, cout] row-major; a block is `block`
    /// consecutive input rows.
    pub fn zero_blocks(&self, fold_in: usize, cout: usize, block: usize) -> Result<(usize, usize)> {
        if fold_in * cout != self.len() {
            return Err(Error::lstw(format!(
                "mask len {} != fold_in {fold_in} * cout {cout}",
                self.len()
            )));
        }
        let n_blocks = fold_in.div_ceil(block);
        let mut zero = 0;
        for b in 0..n_blocks {
            let lo = b * block;
            let hi = ((b + 1) * block).min(fold_in);
            let any_live = (lo..hi).any(|r| (0..cout).any(|c| self.keep[r * cout + c]));
            if !any_live {
                zero += 1;
            }
        }
        Ok((zero, n_blocks))
    }
}

/// Per-layer sparsity statistics for a whole model.
#[derive(Debug, Clone, Default)]
pub struct ModelSparsity {
    /// (layer name, weights, nnz)
    pub layers: Vec<(String, usize, usize)>,
}

impl ModelSparsity {
    /// Append one layer's accounting.
    pub fn push(&mut self, name: impl Into<String>, weights: usize, nnz: usize) {
        self.layers.push((name.into(), weights, nnz));
    }

    /// Sparsity of layer `name`, if recorded.
    pub fn layer_sparsity(&self, name: &str) -> Option<f64> {
        self.layers
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, w, nnz)| 1.0 - *nnz as f64 / (*w).max(1) as f64)
    }

    /// Dense weight count across every layer.
    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|(_, w, _)| w).sum()
    }

    /// Surviving weights across every layer.
    pub fn total_nnz(&self) -> usize {
        self.layers.iter().map(|(_, _, n)| n).sum()
    }

    /// Model-wide pruned fraction.
    pub fn global_sparsity(&self) -> f64 {
        1.0 - self.total_nnz() as f64 / self.total_weights().max(1) as f64
    }
}

/// Engine-free compression ratio (paper headline: 51.6×).
///
/// Dense fp32 bits over surviving-weight bits at `weight_bits`; there is
/// **no index-storage term** because weight positions are baked into logic
/// — this is exactly the paper's "no sparse engine" accounting, and it is
/// what makes unstructured sparsity free at run time in this flow.
pub fn compression_ratio(total_weights: usize, nnz: usize, weight_bits: usize) -> f64 {
    let dense_bits = total_weights as f64 * 32.0;
    let sparse_bits = (nnz as f64 * weight_bits as f64).max(1.0);
    dense_bits / sparse_bits
}

/// CSR-style compression for comparison: sparse engines must store one
/// index per surviving weight (here `idx_bits`), which erodes the ratio —
/// the quantitative argument for engine-free mapping at low bit-widths.
pub fn compression_ratio_csr(
    total_weights: usize,
    nnz: usize,
    weight_bits: usize,
    idx_bits: usize,
) -> f64 {
    let dense_bits = total_weights as f64 * 32.0;
    let sparse_bits = (nnz as f64 * (weight_bits + idx_bits) as f64).max(1.0);
    dense_bits / sparse_bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn mask_basics() {
        let m = Mask::from_f32(&[1.0, 0.0, 2.0, 0.0]);
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.sparsity(), 0.5);
        let mut w = vec![5.0, 5.0, 5.0, 5.0];
        m.apply(&mut w).unwrap();
        assert_eq!(w, vec![5.0, 0.0, 5.0, 0.0]);
        assert!(m.apply(&mut vec![1.0; 3]).is_err());
    }

    #[test]
    fn zero_block_detection() {
        // fold_in=4, cout=2, block=2: rows 2..4 all zero -> 1 of 2 blocks.
        let keep = vec![1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0];
        let m = Mask::from_f32(&keep);
        let (zero, total) = m.zero_blocks(4, 2, 2).unwrap();
        assert_eq!((zero, total), (1, 2));
        assert!(m.zero_blocks(3, 2, 2).is_err());
    }

    #[test]
    fn zero_block_tail_handling() {
        // fold_in=5 with block=2 -> 3 blocks, last has one row.
        let m = Mask::from_f32(&[0.0, 0.0, 1.0, 0.0, 0.0]);
        let (zero, total) = m.zero_blocks(5, 1, 2).unwrap();
        assert_eq!(total, 3);
        assert_eq!(zero, 2); // rows {0,1} zero, row {4} zero, rows {2,3} live
    }

    #[test]
    fn headline_compression_arithmetic() {
        // 32->4 bits with 15.5% kept ~= 51.6x (DESIGN.md §7).
        let total = 44_190;
        let nnz = (total as f64 * 0.155).round() as usize;
        let r = compression_ratio(total, nnz, 4);
        assert!((r - 51.6).abs() < 0.5, "got {r}");
    }

    #[test]
    fn csr_is_worse_than_engine_free() {
        check("CSR ratio strictly below engine-free", 100, |g| {
            let total = g.usize(100, 100_000);
            let nnz = g.usize(1, total);
            let wb = g.usize(2, 8);
            let free = compression_ratio(total, nnz, wb);
            let csr = compression_ratio_csr(total, nnz, wb, 16);
            assert!(csr < free);
        });
    }

    #[test]
    fn prop_sparsity_in_unit_interval() {
        check("mask sparsity in [0,1]", 200, |g| {
            let n = g.usize(1, 500);
            let mut rng = Pcg32::seeded(g.case);
            let vals: Vec<f32> = (0..n).map(|_| if rng.bool(0.3) { 1.0 } else { 0.0 }).collect();
            let m = Mask::from_f32(&vals);
            let s = m.sparsity();
            assert!((0.0..=1.0).contains(&s));
            assert_eq!(m.nnz() + vals.iter().filter(|&&v| v == 0.0).count(), n);
        });
    }

    #[test]
    fn model_sparsity_aggregation() {
        let mut ms = ModelSparsity::default();
        ms.push("a", 100, 25);
        ms.push("b", 300, 150);
        assert_eq!(ms.total_weights(), 400);
        assert_eq!(ms.total_nnz(), 175);
        assert!((ms.global_sparsity() - (1.0 - 175.0 / 400.0)).abs() < 1e-12);
        assert_eq!(ms.layer_sparsity("a"), Some(0.75));
        assert_eq!(ms.layer_sparsity("zzz"), None);
    }
}
