//! Magnitude pruning, rust side: global threshold across layers plus
//! per-layer top-k — mirrors `python/compile/prune.py` so the DSE can run
//! what-if sparsity sweeps on exported weights without a python round-trip
//! (python remains the authority for training-time masks).

use super::Mask;
use crate::util::error::{Error, Result};

/// Weights of one layer, flat.
pub struct LayerWeights<'a> {
    /// Layer name.
    pub name: &'a str,
    /// Flattened weights.
    pub w: &'a [f32],
}

/// Global magnitude pruning: one |w| threshold so that `sparsity` of all
/// weights fall below it; per-layer floor keeps at least `layer_floor` of
/// each layer (avoids disconnecting small layers — same rule as python).
pub fn global_masks(
    layers: &[LayerWeights<'_>],
    sparsity: f64,
    layer_floor: f64,
) -> Result<Vec<(String, Mask)>> {
    if !(0.0..1.0).contains(&sparsity) {
        return Err(Error::lstw(format!("sparsity {sparsity} out of [0,1)")));
    }
    let mut all: Vec<f32> = layers.iter().flat_map(|l| l.w.iter().map(|v| v.abs())).collect();
    if all.is_empty() {
        return Err(Error::lstw("no weights"));
    }
    let k = ((all.len() as f64) * sparsity).floor() as usize;
    let thr = if k == 0 {
        -1.0
    } else {
        // Threshold at the k-th smallest magnitude (index k-1): dropping
        // everything <= it removes exactly the k smallest entries.
        // total_cmp keeps the selection total when weights contain NaN
        // (a NaN magnitude orders above every finite one, so it is
        // treated as "large" and never lowers the threshold).
        let idx = (k - 1).min(all.len() - 1);
        let (_, &mut t, _) = all.select_nth_unstable_by(idx, |a, b| a.total_cmp(b));
        t
    };

    let mut out = Vec::with_capacity(layers.len());
    for l in layers {
        let mut keep: Vec<bool> = l.w.iter().map(|v| v.abs() > thr).collect();
        let kept = keep.iter().filter(|&&b| b).count();
        let floor_n = ((l.w.len() as f64) * layer_floor).ceil() as usize;
        if kept < floor_n.max(1) {
            // keep the top floor_n by magnitude instead
            let mut idx: Vec<usize> = (0..l.w.len()).collect();
            idx.sort_by(|&a, &b| l.w[b].abs().total_cmp(&l.w[a].abs()));
            keep = vec![false; l.w.len()];
            for &i in idx.iter().take(floor_n.max(1)) {
                keep[i] = true;
            }
        }
        out.push((l.name.to_string(), Mask { keep }));
    }
    Ok(out)
}

/// Per-layer pruning at exact target sparsities (DSE-chosen layers).
pub fn layer_mask(w: &[f32], sparsity: f64) -> Result<Mask> {
    if !(0.0..1.0).contains(&sparsity) {
        return Err(Error::lstw(format!("sparsity {sparsity} out of [0,1)")));
    }
    let n = w.len();
    let keep_n = (((n as f64) * (1.0 - sparsity)).round() as usize).max(1);
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| w[b].abs().total_cmp(&w[a].abs()));
    let mut keep = vec![false; n];
    for &i in idx.iter().take(keep_n) {
        keep[i] = true;
    }
    Ok(Mask { keep })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::Pcg32;

    fn randw(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn global_hits_target() {
        let a = randw(4000, 1);
        let b = randw(6000, 2);
        let layers = vec![
            LayerWeights { name: "a", w: &a },
            LayerWeights { name: "b", w: &b },
        ];
        let masks = global_masks(&layers, 0.8, 0.0).unwrap();
        let nnz: usize = masks.iter().map(|(_, m)| m.nnz()).sum();
        let global = 1.0 - nnz as f64 / 10_000.0;
        assert!((global - 0.8).abs() < 0.02, "global {global}");
    }

    #[test]
    fn global_keeps_largest() {
        let w = vec![0.01, 10.0, 0.02, 9.0, 0.03];
        let layers = vec![LayerWeights { name: "x", w: &w }];
        let masks = global_masks(&layers, 0.6, 0.0).unwrap();
        let m = &masks[0].1;
        assert!(m.keep[1] && m.keep[3]);
        assert!(!m.keep[0] && !m.keep[2]);
    }

    #[test]
    fn floor_protects_small_layers() {
        // Tiny layer with small magnitudes would be wiped by the global thr.
        let small = vec![0.001f32; 100];
        let big = randw(10_000, 3);
        let layers = vec![
            LayerWeights { name: "small", w: &small },
            LayerWeights { name: "big", w: &big },
        ];
        let masks = global_masks(&layers, 0.9, 0.05).unwrap();
        let small_mask = &masks[0].1;
        assert!(small_mask.nnz() >= 5, "floor violated: {}", small_mask.nnz());
    }

    #[test]
    fn layer_mask_exact() {
        let w = randw(1000, 4);
        let m = layer_mask(&w, 0.75).unwrap();
        assert_eq!(m.nnz(), 250);
        // Kept entries dominate dropped entries in magnitude.
        let min_kept = w
            .iter()
            .zip(&m.keep)
            .filter(|(_, &k)| k)
            .map(|(v, _)| v.abs())
            .fold(f32::INFINITY, f32::min);
        let max_dropped = w
            .iter()
            .zip(&m.keep)
            .filter(|(_, &k)| !k)
            .map(|(v, _)| v.abs())
            .fold(0.0f32, f32::max);
        assert!(min_kept >= max_dropped);
    }

    #[test]
    fn prop_layer_mask_monotone_in_sparsity() {
        check("higher sparsity keeps a subset", 100, |g| {
            let n = g.usize(10, 400);
            let w = randw(n, g.case + 100);
            let s1 = g.f64(0.0, 0.5);
            let s2 = g.f64(s1 + 0.01, 0.95);
            let m1 = layer_mask(&w, s1).unwrap();
            let m2 = layer_mask(&w, s2).unwrap();
            assert!(m2.nnz() <= m1.nnz());
        });
    }

    #[test]
    fn nan_weights_never_panic() {
        // Regression: the sorts used partial_cmp().unwrap() and panicked
        // the moment an exported tensor carried a NaN (e.g. a divergent
        // training run). NaN magnitudes now have a total order (sorted as
        // largest), so the masks stay well-formed instead of panicking.
        let mut w = randw(200, 9);
        w[17] = f32::NAN;
        w[90] = f32::NAN;

        let m = layer_mask(&w, 0.5).unwrap();
        assert_eq!(m.len(), 200);
        assert_eq!(m.nnz(), 100);
        assert!(m.keep[17] && m.keep[90], "NaN sorts as large magnitude: kept");

        let clean = randw(300, 10);
        let layers = vec![
            LayerWeights { name: "nan", w: &w },
            LayerWeights { name: "clean", w: &clean },
        ];
        let masks = global_masks(&layers, 0.6, 0.05).unwrap();
        assert_eq!(masks.len(), 2);
        for (_, m) in &masks {
            assert!(m.nnz() >= 1, "floor keeps every layer connected");
        }
        // All-NaN input is the worst case: still no panic.
        let all_nan = vec![f32::NAN; 32];
        let m = layer_mask(&all_nan, 0.75).unwrap();
        assert_eq!(m.nnz(), 8);
        let layers = vec![LayerWeights { name: "allnan", w: &all_nan }];
        assert!(global_masks(&layers, 0.5, 0.1).is_ok());
    }

    #[test]
    fn rejects_bad_sparsity() {
        let w = vec![1.0f32; 4];
        assert!(layer_mask(&w, 1.0).is_err());
        assert!(layer_mask(&w, -0.1).is_err());
        let layers = vec![LayerWeights { name: "x", w: &w }];
        assert!(global_masks(&layers, 1.5, 0.0).is_err());
    }
}
