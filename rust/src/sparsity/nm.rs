//! N:M structured sparsity baseline.
//!
//! The paper's introduction positions N:M (e.g. NVIDIA 2:4, Vitis-AI) as
//! the hardware-friendly compromise that unstructured pruning should beat.
//! This implements N:M mask generation so the ablation benches can compare
//! achievable sparsity and resource savings against the unstructured
//! engine-free flow on the same weights.

use super::Mask;
use crate::util::error::{Error, Result};

/// Keep the `n` largest of every `m` consecutive weights along the input
/// axis. `w` is [fold_in, cout] row-major; groups run down the input axis
/// within one output column (the layout hardware N:M units use).
pub fn nm_mask(w: &[f32], fold_in: usize, cout: usize, n: usize, m: usize) -> Result<Mask> {
    if n == 0 || m == 0 || n > m {
        return Err(Error::lstw(format!("bad N:M = {n}:{m}")));
    }
    if fold_in * cout != w.len() {
        return Err(Error::lstw(format!(
            "w len {} != fold_in {fold_in} * cout {cout}",
            w.len()
        )));
    }
    let mut keep = vec![false; w.len()];
    for c in 0..cout {
        let mut r = 0;
        while r < fold_in {
            let hi = (r + m).min(fold_in);
            // indices of this group in flat layout
            let mut idx: Vec<usize> = (r..hi).map(|row| row * cout + c).collect();
            // NaN-total order: a NaN magnitude counts as largest, so it is
            // kept rather than panicking (consistent with `magnitude`).
            idx.sort_by(|&a, &b| w[b].abs().total_cmp(&w[a].abs()));
            let keep_n = n.min(idx.len());
            for &i in idx.iter().take(keep_n) {
                keep[i] = true;
            }
            r = hi;
        }
    }
    Ok(Mask { keep })
}

/// The sparsity an N:M scheme achieves (exact for full groups).
pub fn nm_sparsity(n: usize, m: usize) -> f64 {
    1.0 - n as f64 / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn two_of_four() {
        // fold_in=4, cout=1: one group of 4, keep the 2 largest.
        let w = vec![0.1, 3.0, 0.2, 2.0];
        let m = nm_mask(&w, 4, 1, 2, 4).unwrap();
        assert_eq!(m.keep, vec![false, true, false, true]);
        assert_eq!(m.sparsity(), nm_sparsity(2, 4));
    }

    #[test]
    fn per_column_grouping() {
        // fold_in=2, cout=2; column 0 = [5, 0.1], column 1 = [0.1, 5]
        let w = vec![5.0, 0.1, 0.1, 5.0];
        let m = nm_mask(&w, 2, 2, 1, 2).unwrap();
        assert_eq!(m.keep, vec![true, false, false, true]);
    }

    #[test]
    fn tail_group_keeps_min() {
        // fold_in=5, m=4: tail group has 1 element, kept.
        let w = vec![1.0, 2.0, 3.0, 4.0, 0.001];
        let m = nm_mask(&w, 5, 1, 2, 4).unwrap();
        assert!(m.keep[4]);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn prop_nm_rate_exact_for_divisible() {
        check("N:M keeps exactly n/m when m | fold_in", 100, |g| {
            let m_ = *g.choose(&[2usize, 4, 8]);
            let n_ = g.usize(1, m_);
            let groups = g.usize(1, 20);
            let cout = g.usize(1, 8);
            let fold_in = groups * m_;
            let mut rng = Pcg32::seeded(g.case + 7);
            let w: Vec<f32> = (0..fold_in * cout).map(|_| rng.normal() as f32).collect();
            let mask = nm_mask(&w, fold_in, cout, n_, m_).unwrap();
            assert_eq!(mask.nnz(), groups * n_ * cout);
        });
    }

    #[test]
    fn rejects_bad_params() {
        let w = vec![1.0f32; 8];
        assert!(nm_mask(&w, 4, 2, 0, 4).is_err());
        assert!(nm_mask(&w, 4, 2, 5, 4).is_err());
        assert!(nm_mask(&w, 3, 2, 2, 4).is_err());
    }
}
