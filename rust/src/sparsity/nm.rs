//! N:M structured sparsity baseline.
//!
//! The paper's introduction positions N:M (e.g. NVIDIA 2:4, Vitis-AI) as
//! the hardware-friendly compromise that unstructured pruning should beat.
//! This implements N:M mask generation so the ablation benches can compare
//! achievable sparsity and resource savings against the unstructured
//! engine-free flow on the same weights.

use super::Mask;
use crate::util::error::{Error, Result};

/// Keep the `n` largest of every `m` consecutive weights along the input
/// axis. `w` is [fold_in, cout] row-major; groups run down the input axis
/// within one output column (the layout hardware N:M units use).
pub fn nm_mask(w: &[f32], fold_in: usize, cout: usize, n: usize, m: usize) -> Result<Mask> {
    if n == 0 || m == 0 || n > m {
        return Err(Error::lstw(format!("bad N:M = {n}:{m}")));
    }
    if fold_in * cout != w.len() {
        return Err(Error::lstw(format!(
            "w len {} != fold_in {fold_in} * cout {cout}",
            w.len()
        )));
    }
    let mut keep = vec![false; w.len()];
    for c in 0..cout {
        let mut r = 0;
        while r < fold_in {
            let hi = (r + m).min(fold_in);
            // indices of this group in flat layout
            let mut idx: Vec<usize> = (r..hi).map(|row| row * cout + c).collect();
            // NaN-total order: a NaN magnitude counts as largest, so it is
            // kept rather than panicking (consistent with `magnitude`).
            idx.sort_by(|&a, &b| w[b].abs().total_cmp(&w[a].abs()));
            let keep_n = n.min(idx.len());
            for &i in idx.iter().take(keep_n) {
                keep[i] = true;
            }
            r = hi;
        }
    }
    Ok(Mask { keep })
}

/// The sparsity an N:M scheme achieves (exact for full groups).
pub fn nm_sparsity(n: usize, m: usize) -> f64 {
    1.0 - n as f64 / m as f64
}

/// The tightest N:M description of an existing mask at group size `m`:
/// how a fixed-stride schedule would store it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NmFit {
    /// Max surviving weights in any m-group of any column (>= 1).
    pub n: usize,
    /// Group size along the input axis.
    pub m: usize,
    /// Rows a fixed n-slot-per-group schedule stores per column,
    /// padding included: `(fold_in / m)·n + min(n, fold_in % m)`.
    pub stored_rows: usize,
}

impl NmFit {
    /// Fraction of the input axis a fixed-stride schedule *skips* —
    /// the sparsity annotation an `NmStructured` fold carries (padding
    /// slots count as stored, so this is honest about the schedule, not
    /// the mask).
    pub fn stored_sparsity(&self, fold_in: usize) -> f64 {
        1.0 - self.stored_rows as f64 / fold_in as f64
    }
}

/// Fit an existing `keep` mask (`[fold_in, cout]` row-major, same layout
/// as [`nm_mask`]) to group size `m`: `n` is the worst-case survivor
/// count over every m-group of every column (tail group included),
/// clamped to >= 1 so the schedule always has a slot to carry a
/// sum-neutral pad.
pub fn nm_fit(keep: &[bool], fold_in: usize, cout: usize, m: usize) -> Result<NmFit> {
    if m == 0 {
        return Err(Error::lstw("N:M fit needs m >= 1"));
    }
    if fold_in * cout != keep.len() {
        return Err(Error::lstw(format!(
            "mask len {} != fold_in {fold_in} * cout {cout}",
            keep.len()
        )));
    }
    let mut n = 1usize;
    for c in 0..cout {
        let mut r = 0;
        while r < fold_in {
            let hi = (r + m).min(fold_in);
            let survivors = (r..hi).filter(|&row| keep[row * cout + c]).count();
            n = n.max(survivors);
            r = hi;
        }
    }
    let tail = fold_in % m;
    let stored_rows = (fold_in / m) * n + n.min(tail);
    Ok(NmFit { n, m, stored_rows })
}

/// Candidate group sizes [`detect_nm`] scans, smallest first.
const NM_CANDIDATE_M: [usize; 4] = [2, 4, 8, 16];

/// Pick the group size that stores an existing mask most compactly as a
/// fixed-stride N:M schedule: scan m in {2, 4, 8, 16} (filtered to
/// m <= fold_in, falling back to m = fold_in when none fit), fit each
/// with [`nm_fit`], and keep the fit with the fewest stored rows —
/// ties to the smaller m (narrower offsets). Deterministic: the same
/// mask always yields the same fit, so the compile pass and the
/// selection policy can both call this and agree.
pub fn detect_nm(keep: &[bool], fold_in: usize, cout: usize) -> Result<NmFit> {
    let mut candidates: Vec<usize> = NM_CANDIDATE_M
        .into_iter()
        .filter(|&m| m <= fold_in)
        .collect();
    if candidates.is_empty() {
        candidates.push(fold_in.max(1));
    }
    let mut best: Option<NmFit> = None;
    for m in candidates {
        let fit = nm_fit(keep, fold_in, cout, m)?;
        if best.map(|b| fit.stored_rows < b.stored_rows).unwrap_or(true) {
            best = Some(fit);
        }
    }
    Ok(best.expect("at least one candidate m"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn two_of_four() {
        // fold_in=4, cout=1: one group of 4, keep the 2 largest.
        let w = vec![0.1, 3.0, 0.2, 2.0];
        let m = nm_mask(&w, 4, 1, 2, 4).unwrap();
        assert_eq!(m.keep, vec![false, true, false, true]);
        assert_eq!(m.sparsity(), nm_sparsity(2, 4));
    }

    #[test]
    fn per_column_grouping() {
        // fold_in=2, cout=2; column 0 = [5, 0.1], column 1 = [0.1, 5]
        let w = vec![5.0, 0.1, 0.1, 5.0];
        let m = nm_mask(&w, 2, 2, 1, 2).unwrap();
        assert_eq!(m.keep, vec![true, false, false, true]);
    }

    #[test]
    fn tail_group_keeps_min() {
        // fold_in=5, m=4: tail group has 1 element, kept.
        let w = vec![1.0, 2.0, 3.0, 4.0, 0.001];
        let m = nm_mask(&w, 5, 1, 2, 4).unwrap();
        assert!(m.keep[4]);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn prop_nm_rate_exact_for_divisible() {
        check("N:M keeps exactly n/m when m | fold_in", 100, |g| {
            let m_ = *g.choose(&[2usize, 4, 8]);
            let n_ = g.usize(1, m_);
            let groups = g.usize(1, 20);
            let cout = g.usize(1, 8);
            let fold_in = groups * m_;
            let mut rng = Pcg32::seeded(g.case + 7);
            let w: Vec<f32> = (0..fold_in * cout).map(|_| rng.normal() as f32).collect();
            let mask = nm_mask(&w, fold_in, cout, n_, m_).unwrap();
            assert_eq!(mask.nnz(), groups * n_ * cout);
        });
    }

    #[test]
    fn rejects_bad_params() {
        let w = vec![1.0f32; 8];
        assert!(nm_mask(&w, 4, 2, 0, 4).is_err());
        assert!(nm_mask(&w, 4, 2, 5, 4).is_err());
        assert!(nm_mask(&w, 3, 2, 2, 4).is_err());
        assert!(nm_fit(&[true; 8], 4, 2, 0).is_err());
        assert!(nm_fit(&[true; 7], 4, 2, 4).is_err());
    }

    #[test]
    fn fit_recovers_the_generating_nm() {
        // A mask generated as 2:4 on divisible fold_in fits back as n=2
        // at m=4 with no padding waste.
        let fold_in = 16;
        let cout = 3;
        let mut rng = Pcg32::seeded(99);
        let w: Vec<f32> = (0..fold_in * cout).map(|_| rng.normal() as f32).collect();
        let mask = nm_mask(&w, fold_in, cout, 2, 4).unwrap();
        let fit = nm_fit(&mask.keep, fold_in, cout, 4).unwrap();
        assert_eq!(fit, NmFit { n: 2, m: 4, stored_rows: 8 });
        assert!((fit.stored_sparsity(fold_in) - 0.5).abs() < 1e-12);
        // detect_nm scans group sizes and lands on a fit at least as
        // compact as the generating one.
        let det = detect_nm(&mask.keep, fold_in, cout).unwrap();
        assert!(det.stored_rows <= fit.stored_rows);
    }

    #[test]
    fn fit_counts_tail_groups() {
        // fold_in = 25 pruned 2:8: worst group holds 2, tail of 1 holds
        // min(2, 1) = 1 -> stored = 3*2 + 1 = 7 rows.
        let fold_in = 25;
        let mut rng = Pcg32::seeded(41);
        let w: Vec<f32> = (0..fold_in).map(|_| rng.normal() as f32).collect();
        let mask = nm_mask(&w, fold_in, 1, 2, 8).unwrap();
        let fit = nm_fit(&mask.keep, fold_in, 1, 8).unwrap();
        assert_eq!(fit, NmFit { n: 2, m: 8, stored_rows: 7 });
    }

    #[test]
    fn detect_is_deterministic_and_clamps_n() {
        // A fully dense mask fits as n = m everywhere; a fully pruned
        // mask clamps n to 1 (a slot must exist to carry the pad).
        let dense = vec![true; 32];
        let d1 = detect_nm(&dense, 32, 1).unwrap();
        assert_eq!(d1, detect_nm(&dense, 32, 1).unwrap());
        assert_eq!(d1.stored_rows, 32);
        let empty = vec![false; 32];
        let e = detect_nm(&empty, 32, 1).unwrap();
        assert_eq!(e.n, 1);
        // Tiny fold_in falls back to a single whole-axis group.
        let tiny = detect_nm(&[true], 1, 1).unwrap();
        assert_eq!((tiny.n, tiny.m), (1, 1));
    }
}
