//! Measured simulation results: latency distribution, throughput, stage
//! utilisation and FIFO high-water marks — the numbers the Table-I bench
//! reports and the coordinator's capacity planner consumes.

use super::fifo::Fifo;
use super::stage::StageState;

/// Per-stage utilisation snapshot.
#[derive(Debug, Clone)]
pub struct StageStats {
    /// Stage (layer) name.
    pub name: String,
    /// Output tokens the stage produced.
    pub emitted_tokens: u64,
    /// Cycles the stage spent computing (summed across replicas).
    pub busy_cycles: u64,
    /// Parallel compute units serving the stage (≥ 1).
    pub replicas: u64,
    /// busy_cycles over the run length × replicas: the per-unit
    /// occupancy, so a replicated stage stays comparable to the served
    /// executor's per-replica roll-up.
    pub utilization: f64,
}

/// Per-FIFO occupancy and utilisation snapshot — the sizing and
/// bottleneck-location signal: a FIFO pinned at capacity sits *in front
/// of* the bottleneck stage, a near-empty one sits behind it.
#[derive(Debug, Clone)]
pub struct FifoStats {
    /// Configured capacity, in tokens.
    pub capacity: usize,
    /// High-water occupancy over the run, in tokens.
    pub max_occupancy: usize,
    /// Tokens that passed through over the whole run.
    pub total_tokens: u64,
    /// High-water occupancy as a fraction of capacity (1.0 = the FIFO
    /// filled at least once).
    pub fill_frac: f64,
    /// Mean tokens transferred per cycle over the run.
    pub tokens_per_cycle: f64,
}

/// Full report of one simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Frames completed in the run.
    pub frames: u64,
    /// Arrival cycle of each frame.
    pub arrivals: Vec<u64>,
    /// Completion cycle of each frame (monotone).
    pub completions: Vec<u64>,
    /// Cycles from t=0 to the first frame out (the paper's latency).
    pub first_frame_latency_cycles: u64,
    /// Steady-state cycles/frame measured over the back half of the run.
    pub steady_cycles_per_frame: f64,
    /// Pipeline clock the cycle counts convert to time with.
    pub f_mhz: f64,
    /// Steady-state frames/second at `f_mhz`.
    pub throughput_fps: f64,
    /// First-frame latency in seconds at `f_mhz`.
    pub latency_s: f64,
    /// Per-stage utilisation snapshots.
    pub stages: Vec<StageStats>,
    /// Per-FIFO high-water marks (sizing input; kept for report
    /// stability — the same numbers appear in [`SimReport::fifos`]).
    pub fifo_max_occupancy: Vec<usize>,
    /// Per-FIFO occupancy/utilisation snapshots, in pipeline order
    /// (`fifos[i]` feeds stage i; the last one feeds the sink).
    pub fifos: Vec<FifoStats>,
    /// Cycle the simulation drained at.
    pub end_cycle: u64,
}

impl SimReport {
    /// Assemble a report from the raw simulation traces.
    pub fn build(
        arrivals: &[u64],
        completions: &[u64],
        stages: &[StageState],
        fifos: &[Fifo],
        f_mhz: f64,
        end_cycle: u64,
    ) -> Self {
        let frames = completions.len() as u64;
        let first = completions.first().copied().unwrap_or(0);
        // Steady-state rate: completions over the back half (skips fill).
        let steady = if frames >= 4 {
            let half = completions.len() / 2;
            let span = completions[completions.len() - 1] - completions[half];
            let n = (completions.len() - 1 - half) as f64;
            if n > 0.0 {
                span as f64 / n
            } else {
                first as f64
            }
        } else {
            first.max(1) as f64
        };
        let cycle_s = 1.0 / (f_mhz * 1e6);
        SimReport {
            frames,
            arrivals: arrivals.to_vec(),
            completions: completions.to_vec(),
            first_frame_latency_cycles: first,
            steady_cycles_per_frame: steady,
            f_mhz,
            throughput_fps: 1.0 / (steady.max(1.0) * cycle_s),
            latency_s: first as f64 * cycle_s,
            stages: stages
                .iter()
                .map(|s| StageStats {
                    name: s.spec.name.clone(),
                    emitted_tokens: s.emitted,
                    busy_cycles: s.busy_cycles,
                    replicas: s.spec.replicas.max(1),
                    utilization: s.busy_cycles as f64
                        / (end_cycle.max(1) as f64 * s.spec.replicas.max(1) as f64),
                })
                .collect(),
            fifo_max_occupancy: fifos.iter().map(|f| f.max_occupancy()).collect(),
            fifos: fifos
                .iter()
                .map(|f| FifoStats {
                    capacity: f.capacity,
                    max_occupancy: f.max_occupancy(),
                    total_tokens: f.total_tokens(),
                    fill_frac: f.max_occupancy() as f64 / f.capacity.max(1) as f64,
                    tokens_per_cycle: f.total_tokens() as f64 / end_cycle.max(1) as f64,
                })
                .collect(),
            end_cycle,
        }
    }

    /// Per-frame latency (completion - arrival) in cycles.
    pub fn per_frame_latency_cycles(&self) -> Vec<u64> {
        self.completions
            .iter()
            .zip(&self.arrivals)
            .map(|(c, a)| c.saturating_sub(*a))
            .collect()
    }

    /// Latency percentile in seconds (q in [0,1]).
    pub fn latency_pct_s(&self, q: f64) -> f64 {
        let mut lats = self.per_frame_latency_cycles();
        lats.sort_unstable();
        if lats.is_empty() {
            return 0.0;
        }
        let idx = ((lats.len() - 1) as f64 * q).round() as usize;
        lats[idx] as f64 / (self.f_mhz * 1e6)
    }

    /// The busiest stage (the measured bottleneck).
    pub fn bottleneck_stage(&self) -> &StageStats {
        self.stages
            .iter()
            .max_by(|a, b| a.utilization.total_cmp(&b.utilization))
            .expect("non-empty pipeline")
    }

    /// Multi-line human-readable report.
    pub fn render(&self) -> String {
        let mut s = format!(
            "sim: {} frames @ {:.1} MHz | latency {:.2} us (p50 {:.2}, p99 {:.2}) | \
             steady {:.1} cyc/frame -> {:.0} FPS\n",
            self.frames,
            self.f_mhz,
            self.latency_s * 1e6,
            self.latency_pct_s(0.5) * 1e6,
            self.latency_pct_s(0.99) * 1e6,
            self.steady_cycles_per_frame,
            self.throughput_fps,
        );
        for st in &self.stages {
            let rep = if st.replicas > 1 {
                format!("  x{}", st.replicas)
            } else {
                String::new()
            };
            s.push_str(&format!(
                "  {:<12} util {:>5.1}%  tokens {}{rep}\n",
                st.name,
                st.utilization * 100.0,
                st.emitted_tokens
            ));
        }
        for (i, f) in self.fifos.iter().enumerate() {
            s.push_str(&format!(
                "  fifo[{i}]      fill {:>2}/{:<3} ({:>5.1}%)  {:.3} tok/cyc\n",
                f.max_occupancy,
                f.capacity,
                f.fill_frac * 100.0,
                f.tokens_per_cycle
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::stage::{Kind, StageSpec};

    fn fake_report() -> SimReport {
        let spec = StageSpec {
            name: "x".into(),
            kind: Kind::Fc,
            tokens_per_frame: 1,
            in_tokens_per_frame: 1,
            ii_cycles_per_frame: 10,
            fill_cycles: 5,
            replicas: 1,
        };
        let mut st = StageState::new(spec);
        st.emitted = 10;
        st.busy_cycles = 50;
        SimReport::build(
            &[0, 0, 0, 0, 0, 0, 0, 0],
            &[100, 110, 120, 130, 140, 150, 160, 170],
            &[st],
            &[Fifo::new(2)],
            100.0,
            170,
        )
    }

    #[test]
    fn steady_rate_from_back_half() {
        let r = fake_report();
        assert!((r.steady_cycles_per_frame - 10.0).abs() < 1e-9);
        // 100 MHz, 10 cyc/frame -> 10M FPS
        assert!((r.throughput_fps - 1e7).abs() / 1e7 < 1e-9);
        assert_eq!(r.first_frame_latency_cycles, 100);
    }

    #[test]
    fn percentiles_ordered() {
        let r = fake_report();
        assert!(r.latency_pct_s(0.1) <= r.latency_pct_s(0.9));
    }

    #[test]
    fn render_mentions_stage() {
        assert!(fake_report().render().contains("util"));
    }

    #[test]
    fn fifo_stats_expose_occupancy_and_utilisation() {
        let mut fifo = Fifo::new(4);
        assert!(fifo.push(3));
        assert!(fifo.pop(1));
        assert!(fifo.push(1));
        let spec = StageSpec {
            name: "x".into(),
            kind: Kind::Fc,
            tokens_per_frame: 1,
            in_tokens_per_frame: 1,
            ii_cycles_per_frame: 10,
            fill_cycles: 0,
            replicas: 1,
        };
        let r = SimReport::build(&[0], &[10], &[StageState::new(spec)], &[fifo], 100.0, 10);
        assert_eq!(r.fifos.len(), 1);
        let f = &r.fifos[0];
        assert_eq!(f.capacity, 4);
        assert_eq!(f.max_occupancy, 3);
        assert_eq!(f.total_tokens, 4);
        assert!((f.fill_frac - 0.75).abs() < 1e-9);
        assert!((f.tokens_per_cycle - 0.4).abs() < 1e-9);
        // The legacy high-water vector reports the same marks.
        assert_eq!(r.fifo_max_occupancy, vec![3]);
        assert!(r.render().contains("fifo[0]"));
    }
}
