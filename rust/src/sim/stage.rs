//! Stage actor: the timing state machine of one dataflow layer.
//!
//! A stage emits `tokens_per_frame` output tokens per frame. Token `j` of
//! frame `f` becomes ready at
//!
//! ```text
//! emit(f, j) = frame_base(f) + fill + floor(j · II / TPF)
//! frame_base(f) = max(inputs-ready time, frame_base(f-1) + II)
//! ```
//!
//! and additionally cannot leave before its *input coupling* is satisfied:
//! a conv output pixel needs the window rows beneath it, a pool output its
//! k×k tile, an fc output the whole input frame. This is what produces
//! realistic pipeline overlap (downstream layers start long before
//! upstream frames finish) and what the fill/II analytic model can't see:
//! stalls when FIFOs run dry or fill up.

use crate::graph::{Node, Op};

/// Input-coupling shape of a stage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Kind {
    /// VALID conv: k, ifm (input tokens = ifm²).
    Conv { k: u64, ifm: u64, ofm: u64 },
    /// Pool with stride = window = k.
    Pool { k: u64, ifm: u64, ofm: u64 },
    /// Fully connected: needs the whole input frame.
    Fc,
    /// Source: no input.
    Source,
}

/// Static stage description (built by `sim::build`).
#[derive(Debug, Clone)]
pub struct StageSpec {
    /// Stage (layer) name.
    pub name: String,
    /// Input-coupling shape.
    pub kind: Kind,
    /// Output tokens emitted per frame.
    pub tokens_per_frame: u64,
    /// Input tokens consumed per frame.
    pub in_tokens_per_frame: u64,
    /// Initiation interval: cycles between successive frame starts.
    pub ii_cycles_per_frame: u64,
    /// Pipeline fill cycles before the first token of a frame.
    pub fill_cycles: u64,
    /// Parallel compute units serving this stage (≥ 1). Frame f runs on
    /// unit f mod R: per-frame service time stays `ii_cycles_per_frame`,
    /// but a unit only floors the start of frame f+R, so the *effective*
    /// initiation interval is II / R — the simulator's model of the
    /// served executor's replicated stage-group workers.
    pub replicas: u64,
}

impl StageSpec {
    /// Build the timing spec of one graph node from the cost model's
    /// II/fill estimates.
    pub fn from_node(node: &Node, ii: u64, fill: u64, in_tokens: u64) -> Self {
        let kind = match node.op {
            Op::Conv => Kind::Conv {
                k: node.k as u64,
                ifm: node.ifm as u64,
                ofm: node.ofm as u64,
            },
            Op::MaxPool => Kind::Pool {
                k: node.k as u64,
                ifm: node.ifm as u64,
                ofm: node.ofm as u64,
            },
            Op::Fc => Kind::Fc,
        };
        let tokens = match node.op {
            Op::Conv | Op::MaxPool => node.out_pixels() as u64,
            Op::Fc => 1,
        };
        StageSpec {
            name: node.name.clone(),
            kind,
            tokens_per_frame: tokens,
            in_tokens_per_frame: in_tokens,
            ii_cycles_per_frame: ii.max(1),
            fill_cycles: fill,
            replicas: 1,
        }
    }

    /// Cumulative input tokens needed before output token `j` may leave.
    pub fn in_needed(&self, j: u64) -> u64 {
        let total = self.in_tokens_per_frame;
        match self.kind {
            Kind::Source => 0,
            Kind::Fc => total,
            Kind::Conv { k, ifm, ofm } => {
                let r = j / ofm;
                let c = j % ofm;
                ((r + k - 1) * ifm + c + k).min(total)
            }
            Kind::Pool { k, ifm, ofm } => {
                let r = j / ofm;
                let c = j % ofm;
                ((r * k + k - 1) * ifm + c * k + k).min(total)
            }
        }
    }

    /// Compute-ready offset of token `j` within a frame.
    pub fn emit_offset(&self, j: u64) -> u64 {
        self.fill_cycles + j * self.ii_cycles_per_frame / self.tokens_per_frame
    }
}

/// Mutable run state of one stage.
#[derive(Debug, Clone)]
pub struct StageState {
    /// The static timing spec this state advances.
    pub spec: StageSpec,
    /// Current output frame.
    pub frame: u64,
    /// Next output token within the frame.
    pub token: u64,
    /// Input tokens consumed, cumulative across frames: the stage's line
    /// buffer keeps filling with frame f+1's rows while frame f drains
    /// (real SWUs overlap fills across frames; without this the fill
    /// serialises with emission and the pipeline loses ~20% steady rate).
    pub consumed: u64,
    /// Compute base time of the current frame (set at first token).
    pub frame_base: u64,
    /// Whether `frame_base` has been fixed for the current frame.
    pub frame_base_set: bool,
    /// Time the current frame's first-token inputs became available
    /// (recorded at pop time so a stage still draining frame f doesn't
    /// charge frame f+1 for its own emission tail).
    pub input_ready_at: Option<u64>,
    /// Same, tracked ahead for frame f+1 while f still drains (prefetch
    /// crosses the next frame's first window long before f completes).
    pub next_input_ready_at: Option<u64>,
    /// Per-replica frame-end times: slot r holds frame_base(f) + II of
    /// the last frame f with f mod R == r. With R == 1 this is the
    /// classic "frame_base(f-1) + II" floor; with R > 1 frame f only
    /// waits for frame f−R (its unit's previous occupant).
    pub prev_frame_ends: Vec<u64>,
    /// Total tokens emitted (across frames).
    pub emitted: u64,
    /// Busy-cycle accumulator for utilisation reporting.
    pub busy_cycles: u64,
}

impl StageState {
    /// Fresh run state at t=0.
    pub fn new(spec: StageSpec) -> Self {
        let slots = spec.replicas.max(1) as usize;
        StageState {
            spec,
            frame: 0,
            token: 0,
            consumed: 0,
            frame_base: 0,
            frame_base_set: false,
            input_ready_at: None,
            next_input_ready_at: None,
            prev_frame_ends: vec![0; slots],
            emitted: 0,
            busy_cycles: 0,
        }
    }

    /// Earliest cycle the *current* frame may start on its compute unit:
    /// the recorded end of frame f−R (0 if that unit never ran).
    pub fn next_start_floor(&self) -> u64 {
        self.prev_frame_ends[(self.frame % self.prev_frame_ends.len() as u64) as usize]
    }

    /// Has this stage emitted every token of `frames` frames?
    pub fn done(&self, frames: u64) -> bool {
        self.frame >= frames
    }

    /// Advance the frame counters after emitting the last token.
    /// `consumed` is cumulative and deliberately NOT reset.
    pub fn complete_frame(&mut self) {
        let slot = (self.frame % self.prev_frame_ends.len() as u64) as usize;
        self.prev_frame_ends[slot] = self.frame_base + self.spec.ii_cycles_per_frame;
        self.frame += 1;
        self.token = 0;
        self.frame_base_set = false;
        self.input_ready_at = self.next_input_ready_at.take();
    }

    /// Cumulative input tokens required before output token `token` of the
    /// current frame may leave.
    pub fn needed_total(&self) -> u64 {
        self.frame * self.spec.in_tokens_per_frame + self.spec.in_needed(self.token)
    }

    /// Prefetch ceiling: the line buffer may run one full frame ahead.
    pub fn prefetch_cap(&self) -> u64 {
        (self.frame + 2) * self.spec.in_tokens_per_frame
    }

    /// Average cycles of work represented by one emitted token.
    pub fn cycles_per_token(&self) -> f64 {
        self.spec.ii_cycles_per_frame as f64 / self.spec.tokens_per_frame as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::lenet5;

    fn spec(name: &str) -> StageSpec {
        let g = lenet5();
        let node = g.node(name).unwrap();
        let in_tokens = match node.op {
            Op::Fc => 1,
            _ => (node.ifm * node.ifm) as u64,
        };
        StageSpec::from_node(node, 576, 118, in_tokens)
    }

    #[test]
    fn conv_in_coupling_monotone_and_capped() {
        let s = spec("conv1"); // k=5, ifm=28, ofm=24, in 784
        assert_eq!(s.in_needed(0), 4 * 28 + 5); // first window
        let mut prev = 0;
        for j in 0..s.tokens_per_frame {
            let need = s.in_needed(j);
            assert!(need >= prev);
            assert!(need <= 784);
            prev = need;
        }
        assert_eq!(s.in_needed(s.tokens_per_frame - 1), 784);
    }

    #[test]
    fn pool_needs_full_tile() {
        let g = lenet5();
        let node = g.node("conv1_pool").unwrap(); // k=2, ifm=24, ofm=12
        let s = StageSpec::from_node(node, 144, 49, 576);
        // token 0 = tile rows 0..2, cols 0..2 -> (1)*24 + 2 = 26
        assert_eq!(s.in_needed(0), 26);
        assert_eq!(s.in_needed(143), 576);
    }

    #[test]
    fn fc_needs_everything() {
        let g = lenet5();
        let node = g.node("fc1").unwrap();
        let s = StageSpec::from_node(node, 240, 240, 16);
        assert_eq!(s.tokens_per_frame, 1);
        assert_eq!(s.in_needed(0), 16);
    }

    #[test]
    fn emit_offsets_span_ii() {
        let s = spec("conv1");
        assert_eq!(s.emit_offset(0), 118);
        let last = s.emit_offset(s.tokens_per_frame - 1);
        assert!(last < 118 + 576);
        assert!(last >= 118 + 570);
    }

    #[test]
    fn frame_lifecycle() {
        let s = spec("conv1");
        let mut st = StageState::new(s);
        st.frame_base = 10;
        st.frame_base_set = true;
        st.complete_frame();
        assert_eq!(st.frame, 1);
        assert_eq!(st.next_start_floor(), 10 + 576);
        assert!(!st.frame_base_set);
        assert!(!st.done(2));
        st.complete_frame();
        assert!(st.done(2));
    }

    #[test]
    fn replicated_stage_floors_on_frame_f_minus_r() {
        let mut s = spec("conv1"); // II = 576
        s.replicas = 2;
        let mut st = StageState::new(s);
        // Frame 0 on unit 0.
        st.frame_base = 10;
        st.frame_base_set = true;
        st.complete_frame();
        // Frame 1 runs on unit 1, which has never run: floor is 0, not
        // frame 0's end — the replicated units overlap frames.
        assert_eq!(st.next_start_floor(), 0);
        st.frame_base = 12;
        st.frame_base_set = true;
        st.complete_frame();
        // Frame 2 reuses unit 0 and must wait for frame 0's end.
        assert_eq!(st.next_start_floor(), 10 + 576);
        st.frame_base = 586;
        st.frame_base_set = true;
        st.complete_frame();
        // Frame 3 reuses unit 1 (frame 1 ended at 12 + 576).
        assert_eq!(st.next_start_floor(), 12 + 576);
    }
}
