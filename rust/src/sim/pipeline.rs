//! Discrete-event engine: source → stage₀ → … → stageₙ₋₁ → sink over
//! bounded FIFOs, with an event heap keyed by cycle time.
//!
//! Wake protocol: an actor that pushes wakes its consumer; an actor that
//! pops wakes its producer; compute-bound actors schedule their own timed
//! wake. Duplicate wakes are harmless (actors are idempotent); deadlock
//! (empty heap before the sink finishes) is an error surfaced to the
//! caller — it indicates an impossible FIFO/rate configuration.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::util::error::{Error, Result};

use super::fifo::Fifo;
use super::metrics::SimReport;
use super::stage::{Kind, StageSpec, StageState};

// The workload model lives in the shared `traffic` module now (the serving
// load generator samples the same arrival processes); re-exported here so
// `sim::pipeline::Workload` keeps resolving.
pub use crate::traffic::Workload;

/// Result of one actor activation.
struct Activation {
    /// Timed self-wake (compute not ready yet).
    wake_at: Option<u64>,
    /// Pushed ≥1 token downstream.
    pushed: bool,
    /// Popped ≥1 token upstream.
    popped: bool,
}

/// The assembled pipeline.
pub struct Pipeline {
    stages: Vec<StageState>,
    /// fifos[i] feeds stages[i]; fifos[n] feeds the sink.
    fifos: Vec<Fifo>,
    source: StageState,
    f_mhz: f64,
}

const SOURCE: usize = usize::MAX;
const SINK: usize = usize::MAX - 1;

impl Pipeline {
    /// `specs` are the graph stages in stream order (source added here).
    ///
    /// `link_tokens_per_cycle` is the input DMA width: FINN designs size
    /// the input interface so the accelerator, not the link, is the
    /// bottleneck; `sim::build` computes the width from the design's II.
    pub fn new(specs: Vec<StageSpec>, fifo_depth: usize, f_mhz: f64) -> Self {
        Self::with_link(specs, fifo_depth, f_mhz, 1)
    }

    /// Like [`Pipeline::new`] with an explicit input-link width in
    /// tokens per cycle.
    pub fn with_link(
        specs: Vec<StageSpec>,
        fifo_depth: usize,
        f_mhz: f64,
        link_tokens_per_cycle: u64,
    ) -> Self {
        assert!(!specs.is_empty());
        assert!(link_tokens_per_cycle >= 1);
        let in_tokens = specs[0].in_tokens_per_frame;
        let source_spec = StageSpec {
            name: "__source".into(),
            kind: Kind::Source,
            tokens_per_frame: in_tokens,
            in_tokens_per_frame: 0,
            ii_cycles_per_frame: in_tokens.div_ceil(link_tokens_per_cycle).max(1),
            fill_cycles: 0,
            replicas: 1,
        };
        let fifos = (0..=specs.len()).map(|_| Fifo::new(fifo_depth)).collect();
        Pipeline {
            stages: specs.into_iter().map(StageState::new).collect(),
            fifos,
            source: StageState::new(source_spec),
            f_mhz,
        }
    }

    /// The pipeline clock in MHz.
    pub fn f_mhz(&self) -> f64 {
        self.f_mhz
    }

    /// Stage names in stream order (source excluded).
    pub fn stage_names(&self) -> Vec<&str> {
        self.stages.iter().map(|s| s.spec.name.as_str()).collect()
    }

    /// Run the workload to completion (panics on deadlock — use `try_run`
    /// for fallible callers).
    pub fn run(&mut self, wl: &Workload) -> SimReport {
        self.try_run(wl).expect("simulation deadlock")
    }

    /// Run the workload to completion, failing on deadlock instead of
    /// panicking.
    pub fn try_run(&mut self, wl: &Workload) -> Result<SimReport> {
        let frames = wl.frames();
        if frames == 0 {
            return Err(Error::sim("zero-frame workload"));
        }
        let arrivals = wl.arrivals(self.f_mhz);
        let n = self.stages.len();

        let mut heap: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        heap.push(Reverse((arrivals[0], SOURCE)));
        // Timed self-wake dedup: a compute-bound actor re-woken by its
        // neighbours would otherwise re-arm the same future wake many
        // times over, growing the heap into a standing wave of duplicates
        // (thousands of events per simulated cycle). One pending timed
        // wake per actor is enough. Index n = SOURCE.
        let mut timed: Vec<u64> = vec![u64::MAX; n + 1];
        let slot = |actor: usize| if actor == SOURCE { n } else { actor };

        let mut completions: Vec<u64> = Vec::with_capacity(frames as usize);
        let mut sink_tokens_in_frame: u64 = 0;
        let last_tpf = self.stages[n - 1].spec.tokens_per_frame;
        let mut guard: u64 = 0;
        const GUARD_MAX: u64 = 500_000_000;

        while let Some(Reverse((now, actor))) = heap.pop() {
            guard += 1;
            if guard > GUARD_MAX {
                let mut diag = format!(
                    "event budget exceeded (livelock?): now={now} actor={actor} \
                     completions={} source(frame={},tok={})",
                    completions.len(),
                    self.source.frame,
                    self.source.token
                );
                for (i, st) in self.stages.iter().enumerate() {
                    diag.push_str(&format!(
                        " | s{i} {} f={} t={} c={} occ={}",
                        st.spec.name, st.frame, st.token, st.consumed,
                        self.fifos[i].occupancy()
                    ));
                }
                return Err(Error::sim(diag));
            }
            match actor {
                SOURCE => {
                    if timed[slot(SOURCE)] <= now {
                        timed[slot(SOURCE)] = u64::MAX;
                    }
                    let act = self.advance_source(now, &arrivals, frames);
                    if let Some(t) = act.wake_at {
                        if t < timed[slot(SOURCE)] {
                            timed[slot(SOURCE)] = t;
                            heap.push(Reverse((t, SOURCE)));
                        }
                    }
                    if act.pushed {
                        heap.push(Reverse((now, 0)));
                    }
                }
                SINK => {
                    let avail = self.fifos[n].occupancy();
                    if avail > 0 {
                        self.fifos[n].pop(avail);
                        heap.push(Reverse((now, n - 1)));
                        sink_tokens_in_frame += avail as u64;
                        while sink_tokens_in_frame >= last_tpf {
                            sink_tokens_in_frame -= last_tpf;
                            completions.push(now);
                        }
                    }
                }
                i => {
                    if timed[i] <= now {
                        timed[i] = u64::MAX;
                    }
                    let act = self.advance_stage(i, now, frames);
                    if let Some(t) = act.wake_at {
                        if t < timed[i] {
                            timed[i] = t;
                            heap.push(Reverse((t, i)));
                        }
                    }
                    if act.pushed {
                        let consumer = if i + 1 < n { i + 1 } else { SINK };
                        heap.push(Reverse((now, consumer)));
                    }
                    if act.popped {
                        let producer = if i == 0 { SOURCE } else { i - 1 };
                        heap.push(Reverse((now, producer)));
                    }
                }
            }
            if completions.len() as u64 >= frames {
                let end = *completions.last().unwrap();
                return Ok(SimReport::build(
                    &arrivals,
                    &completions,
                    &self.stages,
                    &self.fifos,
                    self.f_mhz,
                    end,
                ));
            }
        }
        Err(Error::sim(format!(
            "deadlock: {} of {frames} frames completed",
            completions.len()
        )))
    }

    /// Source actor: streams input tokens at 1/cycle subject to arrivals
    /// and FIFO space.
    fn advance_source(&mut self, now: u64, arrivals: &[u64], frames: u64) -> Activation {
        let mut act = Activation { wake_at: None, pushed: false, popped: false };
        loop {
            let st = &mut self.source;
            if st.done(frames) {
                break;
            }
            let arrival = arrivals[st.frame as usize];
            if !st.frame_base_set {
                let base = now.max(arrival).max(st.next_start_floor());
                if base > now {
                    act.wake_at = Some(base);
                    break;
                }
                st.frame_base = base;
                st.frame_base_set = true;
            }
            let emit_t = st.frame_base + st.spec.emit_offset(st.token);
            if emit_t > now {
                act.wake_at = Some(emit_t);
                break;
            }
            if self.fifos[0].is_full() {
                break; // stage 0's pop wakes us
            }
            self.fifos[0].push(1);
            act.pushed = true;
            let st = &mut self.source;
            st.emitted += 1;
            st.busy_cycles += 1;
            st.token += 1;
            if st.token == st.spec.tokens_per_frame {
                st.complete_frame();
            }
        }
        act
    }

    /// Graph-stage actor.
    fn advance_stage(&mut self, i: usize, now: u64, frames: u64) -> Activation {
        let mut act = Activation { wake_at: None, pushed: false, popped: false };
        loop {
            let (needed, cap) = {
                let st = &self.stages[i];
                if st.done(frames) {
                    break;
                }
                (st.needed_total(), st.prefetch_cap())
            };
            // Consume inputs, prefetching up to one frame ahead (the line
            // buffer fills with frame f+1 while frame f drains). Starved
            // -> upstream push wakes us.
            {
                let room = cap.saturating_sub(self.stages[i].consumed) as usize;
                let got = room.min(self.fifos[i].occupancy());
                if got > 0 {
                    self.fifos[i].pop(got);
                    self.stages[i].consumed += got as u64;
                    act.popped = true;
                }
                // Record when this frame's and the next frame's first
                // windows became available (frame_base must not charge a
                // frame for its predecessor's emission tail).
                let st = &mut self.stages[i];
                let itf = st.spec.in_tokens_per_frame;
                let first = st.spec.in_needed(0);
                if st.input_ready_at.is_none() && st.consumed >= st.frame * itf + first {
                    st.input_ready_at = Some(now);
                }
                if st.next_input_ready_at.is_none()
                    && st.consumed >= (st.frame + 1) * itf + first
                {
                    st.next_input_ready_at = Some(now);
                }
            }
            if self.stages[i].consumed < needed {
                break; // starved
            }
            // Inputs ready; pin the frame base at the first token.
            {
                let st = &mut self.stages[i];
                if !st.frame_base_set {
                    let ready = st.input_ready_at.unwrap_or(now);
                    st.frame_base = ready.max(st.next_start_floor());
                    st.frame_base_set = true;
                }
                let emit_t = st.frame_base + st.spec.emit_offset(st.token);
                if emit_t > now {
                    act.wake_at = Some(emit_t);
                    break;
                }
            }
            // Emit if downstream has space (full -> downstream pop wakes us).
            if self.fifos[i + 1].is_full() {
                break;
            }
            self.fifos[i + 1].push(1);
            act.pushed = true;
            let st = &mut self.stages[i];
            st.emitted += 1;
            st.busy_cycles += st.cycles_per_token().ceil() as u64;
            st.token += 1;
            if st.token == st.spec.tokens_per_frame {
                st.complete_frame();
            }
        }
        act
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::lenet5;
    use crate::graph::Op;

    fn lenet_specs(ii_scale: u64) -> Vec<StageSpec> {
        let g = lenet5();
        let mut in_tokens = (28 * 28) as u64;
        let mut specs = Vec::new();
        for node in &g.nodes {
            let tokens = match node.op {
                Op::Fc => 1,
                _ => node.out_pixels() as u64,
            };
            // Simple timing: II = tokens * scale, fill = 10.
            let spec = StageSpec::from_node(node, tokens * ii_scale, 10, in_tokens);
            in_tokens = tokens;
            specs.push(spec);
        }
        specs
    }

    #[test]
    fn completes_all_frames() {
        let mut p = Pipeline::new(lenet_specs(2), 8, 200.0);
        let rep = p.run(&Workload::Saturated { frames: 20 });
        assert_eq!(rep.frames, 20);
        assert!(rep.first_frame_latency_cycles > 0);
        assert!(rep.throughput_fps > 0.0);
    }

    #[test]
    fn completions_monotone_and_after_arrivals() {
        let mut p = Pipeline::new(lenet_specs(1), 8, 200.0);
        let wl = Workload::Periodic { frames: 15, interval_cycles: 2000 };
        let rep = p.try_run(&wl).unwrap();
        assert!(rep.completions.windows(2).all(|w| w[0] <= w[1]));
        let arr = wl.arrivals(200.0);
        for (c, a) in rep.completions.iter().zip(&arr) {
            assert!(c > a, "completion {c} before arrival {a}");
        }
    }

    #[test]
    fn slow_arrivals_mean_idle_pipeline() {
        // With huge inter-arrival gaps latency per frame is constant and
        // throughput equals the arrival rate, not the pipeline capacity.
        let mut p = Pipeline::new(lenet_specs(1), 8, 200.0);
        let wl = Workload::Periodic { frames: 10, interval_cycles: 1_000_000 };
        let rep = p.try_run(&wl).unwrap();
        let lat: Vec<u64> = rep.per_frame_latency_cycles();
        let spread = lat.iter().max().unwrap() - lat.iter().min().unwrap();
        assert!(spread <= 2, "latency spread {spread} on an idle pipeline");
    }

    #[test]
    fn poisson_arrivals_complete() {
        let mut p = Pipeline::new(lenet_specs(1), 16, 200.0);
        let rep = p
            .try_run(&Workload::Poisson { frames: 25, rate_fps: 50_000.0, seed: 9 })
            .unwrap();
        assert_eq!(rep.frames, 25);
    }

    #[test]
    fn burst_arrivals_complete() {
        // Burst shape from the shared traffic model drives the simulator
        // exactly like the classic shapes.
        let mut p = Pipeline::new(lenet_specs(1), 16, 200.0);
        let rep = p
            .try_run(&Workload::Burst { frames: 24, burst: 6, gap_cycles: 50_000, seed: 4 })
            .unwrap();
        assert_eq!(rep.frames, 24);
        assert!(rep.completions.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn replay_trace_drives_sim() {
        let mut p = Pipeline::new(lenet_specs(1), 16, 200.0);
        let trace: Vec<u64> = (0..10).map(|k| k * 40_000).collect();
        let rep = p.try_run(&Workload::Replay { arrival_cycles: trace.clone() }).unwrap();
        assert_eq!(rep.frames, 10);
        let arr = Workload::Replay { arrival_cycles: trace }.arrivals(200.0);
        for (c, a) in rep.completions.iter().zip(&arr) {
            assert!(c > a);
        }
    }

    #[test]
    fn replicated_stage_lifts_the_ii_floor() {
        // Two Fc stages; the second is the costlier one. Unreplicated it
        // floors the steady rate at its own II; with two replicas its
        // effective II halves and the bottleneck moves to the first
        // stage — the model mirrored by StagedExecutor::sim_specs.
        let spec = |name: &str, ii: u64, replicas: u64| StageSpec {
            name: name.into(),
            kind: Kind::Fc,
            tokens_per_frame: 1,
            in_tokens_per_frame: 1,
            ii_cycles_per_frame: ii,
            fill_cycles: 0,
            replicas,
        };
        let run = |reps: u64| {
            let mut p =
                Pipeline::new(vec![spec("light", 100, 1), spec("heavy", 150, reps)], 4, 200.0);
            p.run(&Workload::Saturated { frames: 64 })
        };
        let base = run(1);
        assert!((base.steady_cycles_per_frame - 150.0).abs() < 5.0);
        assert_eq!(base.bottleneck_stage().name, "heavy");
        let replicated = run(2);
        // Effective II of "heavy" drops to 75; "light" now floors at 100.
        assert!((replicated.steady_cycles_per_frame - 100.0).abs() < 5.0);
        assert_eq!(replicated.bottleneck_stage().name, "light");
        // Per-unit occupancy: each of the two replicas is busy 150 of
        // every 200 cycles, so utilisation reports ~0.75, not ~1.5.
        let heavy = &replicated.stages[1];
        assert_eq!(heavy.replicas, 2);
        assert!(heavy.utilization < 1.0 + 1e-9);
    }

    #[test]
    fn zero_frames_rejected() {
        let mut p = Pipeline::new(lenet_specs(1), 8, 200.0);
        assert!(p.try_run(&Workload::Saturated { frames: 0 }).is_err());
    }

    #[test]
    fn deterministic() {
        let run = || {
            let mut p = Pipeline::new(lenet_specs(3), 4, 200.0);
            p.run(&Workload::Saturated { frames: 12 }).completions
        };
        assert_eq!(run(), run());
    }
}
