//! Cycle-level streaming-dataflow simulator (substrate S9).
//!
//! This is the "measured" column of Table I: where the paper runs the
//! generated bitstream on the XCU50, we run the configured accelerator in
//! a discrete-event simulation. Stages (one per graph node, plus source
//! and sink) exchange *tokens* through bounded FIFOs with backpressure;
//! one token = one output-pixel bundle (conv/pool) or one frame vector
//! (fc). Each stage's token rate comes from the same folding algebra the
//! cost model uses (`cycles_per_token = II / tokens_per_frame`), and its
//! first-token fill from `cost::latency::fill_cycles`, so the simulator
//! agrees with the analytic model to first order but additionally captures
//! FIFO sizing, pipeline overlap, arrival burstiness and backpressure.
//!
//! Frame latency and steady-state throughput are measured, not derived:
//! the integration tests cross-check them against `cost::evaluate` and the
//! Table-I bench feeds them into the reported rows.

pub mod fifo;
pub mod metrics;
pub mod pipeline;
pub mod stage;

pub use metrics::{FifoStats, SimReport};
pub use pipeline::Pipeline;
// `Workload` moved to the shared `traffic` module (one arrival-process
// implementation for simulator and server); the historical `sim::Workload`
// path keeps working through this re-export.
pub use crate::traffic::Workload;

use crate::cost;
use crate::device::Device;
use crate::folding::FoldingConfig;
use crate::graph::Graph;
use crate::util::error::Result;

/// Build a pipeline for `g` under `cfg` on `dev`.
///
/// `fifo_depth` is the inter-stage buffer capacity in tokens (FINN inserts
/// stream FIFOs between layers; 2 is the minimum for rate decoupling).
pub fn build(g: &Graph, cfg: &FoldingConfig, dev: &Device, fifo_depth: usize) -> Result<Pipeline> {
    cfg.check(g)?;
    let mc = cost::evaluate(g, cfg, dev)?;

    let mut stages = Vec::with_capacity(g.nodes.len());
    // Token granularity chains stage to stage: a stage's input tokens per
    // frame are its producer's output tokens (the source feeds ifm² pixel
    // tokens to the first stage).
    let first = &g.nodes[0];
    let mut in_tokens = (first.ifm * first.ifm) as u64;
    for node in &g.nodes {
        let lc = mc.layer(&node.name).expect("cost covers all nodes");
        let spec = stage::StageSpec::from_node(node, lc.ii_cycles, lc.fill_cycles, in_tokens);
        in_tokens = spec.tokens_per_frame;
        stages.push(spec);
    }

    // Size the input DMA so the link never throttles the design: enough
    // tokens/cycle that the source's frame time stays at or below the
    // accelerator's steady-state II (FINN sizes its input DMA the same
    // way; the link is reported, not searched, by the DSE).
    let in_tokens = (first.ifm * first.ifm) as u64;
    let link = in_tokens.div_ceil(mc.max_ii.max(1)).max(1);
    Ok(Pipeline::with_link(stages, fifo_depth, mc.f_mhz, link))
}

/// Convenience: simulate `frames` back-to-back frames (saturated input)
/// and return the measured report.
pub fn simulate_saturated(
    g: &Graph,
    cfg: &FoldingConfig,
    dev: &Device,
    frames: u64,
    fifo_depth: usize,
) -> Result<SimReport> {
    let mut p = build(g, cfg, dev, fifo_depth)?;
    Ok(p.run(&Workload::Saturated { frames }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::XCU50;
    use crate::folding::FoldingConfig;
    use crate::graph::builder::lenet5;

    #[test]
    fn saturated_throughput_matches_analytic_bottleneck() {
        let g = lenet5();
        for cfg in [FoldingConfig::unrolled(&g), FoldingConfig::minimal(&g)] {
            let mc = cost::evaluate(&g, &cfg, &XCU50).unwrap();
            let rep = simulate_saturated(&g, &cfg, &XCU50, 50, 4).unwrap();
            let analytic = mc.throughput_fps;
            let ratio = rep.throughput_fps / analytic;
            assert!(
                (0.85..1.10).contains(&ratio),
                "sim {} vs analytic {} (ratio {ratio})",
                rep.throughput_fps,
                analytic
            );
        }
    }

    #[test]
    fn latency_at_least_fill_sum() {
        let g = lenet5();
        let cfg = FoldingConfig::unrolled(&g);
        let mc = cost::evaluate(&g, &cfg, &XCU50).unwrap();
        let rep = simulate_saturated(&g, &cfg, &XCU50, 10, 4).unwrap();
        let min_cycles: u64 = mc.layers.iter().map(|l| l.fill_cycles).sum();
        assert!(
            rep.first_frame_latency_cycles >= min_cycles,
            "{} < {min_cycles}",
            rep.first_frame_latency_cycles
        );
    }

    #[test]
    fn deeper_fifos_never_hurt() {
        let g = lenet5();
        let cfg = FoldingConfig::minimal(&g);
        let shallow = simulate_saturated(&g, &cfg, &XCU50, 30, 2).unwrap();
        let deep = simulate_saturated(&g, &cfg, &XCU50, 30, 64).unwrap();
        assert!(deep.throughput_fps >= shallow.throughput_fps * 0.999);
    }
}
