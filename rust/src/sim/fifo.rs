//! Bounded token FIFO with occupancy tracking — the inter-stage stream
//! buffer of the dataflow pipeline (FINN's StreamingFIFO).

/// A bounded FIFO counting tokens (token payloads are implicit: the
//  simulator tracks timing, not values).
#[derive(Debug, Clone)]
pub struct Fifo {
    /// Maximum tokens the FIFO can hold.
    pub capacity: usize,
    occupancy: usize,
    /// High-water mark, for FIFO-sizing reports.
    max_occupancy: usize,
    /// Total tokens that passed through.
    total: u64,
}

impl Fifo {
    /// An empty FIFO of the given capacity (>= 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "FIFO capacity must be >= 1");
        Fifo { capacity, occupancy: 0, max_occupancy: 0, total: 0 }
    }

    /// Tokens currently buffered.
    pub fn occupancy(&self) -> usize {
        self.occupancy
    }

    /// High-water mark since construction.
    pub fn max_occupancy(&self) -> usize {
        self.max_occupancy
    }

    /// Total tokens ever pushed.
    pub fn total_tokens(&self) -> u64 {
        self.total
    }

    /// Free slots remaining.
    pub fn free(&self) -> usize {
        self.capacity - self.occupancy
    }

    /// True when no slot is free.
    pub fn is_full(&self) -> bool {
        self.occupancy == self.capacity
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.occupancy == 0
    }

    /// Push `n` tokens; returns false (and pushes nothing) if they don't fit.
    pub fn push(&mut self, n: usize) -> bool {
        if n > self.free() {
            return false;
        }
        self.occupancy += n;
        self.max_occupancy = self.max_occupancy.max(self.occupancy);
        self.total += n as u64;
        true
    }

    /// Pop `n` tokens; returns false (and pops nothing) if not available.
    pub fn pop(&mut self, n: usize) -> bool {
        if n > self.occupancy {
            return false;
        }
        self.occupancy -= n;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;

    #[test]
    fn push_pop_bounds() {
        let mut f = Fifo::new(4);
        assert!(f.push(3));
        assert!(!f.push(2));
        assert!(f.push(1));
        assert!(f.is_full());
        assert!(f.pop(2));
        assert!(!f.pop(3));
        assert_eq!(f.occupancy(), 2);
        assert_eq!(f.max_occupancy(), 4);
        assert_eq!(f.total_tokens(), 4);
    }

    #[test]
    #[should_panic]
    fn zero_capacity_rejected() {
        Fifo::new(0);
    }

    #[test]
    fn prop_occupancy_invariant() {
        check("0 <= occupancy <= capacity always", 200, |g| {
            let cap = g.usize(1, 32);
            let mut f = Fifo::new(cap);
            let mut model = 0usize; // reference occupancy
            for _ in 0..g.usize(1, 100) {
                let n = g.usize(0, 8);
                if g.bool(0.5) {
                    if f.push(n) {
                        model += n;
                    }
                } else if f.pop(n) {
                    model -= n;
                }
                assert_eq!(f.occupancy(), model);
                assert!(f.occupancy() <= cap);
                assert!(f.max_occupancy() <= cap);
            }
        });
    }
}
