//! ASCII table rendering for experiment outputs (Table I, Fig. 2 series).
//!
//! The bench harness prints the same rows the paper reports; keeping the
//! renderer in the library means examples, benches and the CLI all emit the
//! same layout, and the integration tests can assert on structure.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    /// Pad on the right.
    Left,
    /// Pad on the left (numeric columns).
    Right,
}

/// A simple table builder.
#[derive(Debug, Clone)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers (all right-aligned).
    pub fn new(headers: &[&str]) -> Self {
        Table {
            aligns: headers.iter().map(|_| Align::Right).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Set alignment for a column (default Right; first column often Left).
    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    /// Append one row (arity must match the headers).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Rows appended so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render the boxed ASCII table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let sep = |out: &mut String| {
            out.push('+');
            for w in &widths {
                out.push_str(&"-".repeat(w + 2));
                out.push('+');
            }
            out.push('\n');
        };
        let line = |out: &mut String, cells: &[String], aligns: &[Align]| {
            out.push('|');
            for i in 0..ncol {
                let c = &cells[i];
                match aligns[i] {
                    Align::Left => out.push_str(&format!(" {:<w$} ", c, w = widths[i])),
                    Align::Right => out.push_str(&format!(" {:>w$} ", c, w = widths[i])),
                }
                out.push('|');
            }
            out.push('\n');
        };
        sep(&mut out);
        line(&mut out, &self.headers, &vec![Align::Left; ncol]);
        sep(&mut out);
        for row in &self.rows {
            line(&mut out, row, &self.aligns);
        }
        sep(&mut out);
        out
    }
}

/// Human formatting helpers shared by experiment printers.
pub fn fmt_si(v: f64) -> String {
    let a = v.abs();
    if a >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if a >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if a >= 1e3 {
        format!("{:.1}k", v / 1e3)
    } else if a >= 1.0 || a == 0.0 {
        format!("{v:.1}")
    } else {
        format!("{v:.4}")
    }
}

/// Integer with thousands separators.
pub fn fmt_int(v: f64) -> String {
    let n = v.round() as i64;
    let s = n.abs().to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    if n < 0 {
        format!("-{out}")
    } else {
        out
    }
}

/// Seconds rendered as microseconds with two decimals.
pub fn fmt_us(seconds: f64) -> String {
    format!("{:.2}", seconds * 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Work", "LUTs"]).align(0, Align::Left);
        t.row(vec!["Proposed".into(), "23,465".into()]);
        t.row(vec!["Unfold".into(), "433,249".into()]);
        let r = t.render();
        assert!(r.contains("| Proposed |"));
        assert!(r.contains("|  23,465 |"));
        let widths: Vec<usize> = r.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "ragged table:\n{r}");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn int_grouping() {
        assert_eq!(fmt_int(433249.0), "433,249");
        assert_eq!(fmt_int(1000.0), "1,000");
        assert_eq!(fmt_int(-1234567.0), "-1,234,567");
        assert_eq!(fmt_int(12.0), "12");
    }

    #[test]
    fn si_units() {
        assert_eq!(fmt_si(265_429.0), "265.4k");
        assert_eq!(fmt_si(2_650_000.0), "2.65M");
        assert_eq!(fmt_si(0.0123), "0.0123");
    }

    #[test]
    fn microseconds() {
        assert_eq!(fmt_us(18.13e-6), "18.13");
    }
}
