//! Deterministic PCG32 RNG (the `rand` crate is unavailable offline).
//!
//! Used by the simulator's arrival processes, the property-test harness and
//! synthetic workload generators. PCG32 (O'Neill 2014, XSH-RR 64/32) is
//! small, fast, and statistically solid for simulation purposes.

/// PCG32 generator: 64-bit state, 64-bit stream.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create from a seed and stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor with a fixed stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Next 32 random bits (the PCG-XSH-RR output function).
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 random bits (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire reduction).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u32) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one value per call; cheap enough).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (inter-arrival sampling).
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(1, 1);
        let mut b = Pcg32::new(1, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut rng = Pcg32::seeded(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut rng = Pcg32::seeded(13);
        let n = 20_000;
        let sum: f64 = (0..n).map(|_| rng.exp(2.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.03, "mean {mean}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Pcg32::seeded(5);
        let mut xs: Vec<u32> = (0..20).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(xs, (0..20).collect::<Vec<_>>());
    }
}
