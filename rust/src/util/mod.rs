//! First-party substrates: everything a normal project would pull from
//! crates.io but this offline environment cannot (serde, rand, proptest,
//! criterion, clap). Each submodule is small, tested, and used by the rest
//! of the crate — see DESIGN.md §5 (S1–S3, S16–S17).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod lstw;
pub mod propcheck;
pub mod ring;
pub mod rng;
pub mod table;
