//! Crate-wide error type.
//!
//! One enum instead of `anyhow` on the hot path: the coordinator matches on
//! error classes (e.g. `QueueClosed` vs `Artifact`) to decide whether to
//! retry, shed load, or abort.

use std::fmt;

/// Crate result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All error classes the library produces.
#[derive(Debug)]
pub enum Error {
    /// I/O failures (artifact files, exports).
    Io(std::io::Error),
    /// JSON syntax or schema violations (graph.json, configs).
    Json { msg: String, offset: usize },
    /// LSTW tensor-store format violations.
    Lstw(String),
    /// Graph construction / validation failures.
    Graph(String),
    /// Illegal folding configuration (PE/SIMD divisibility, bounds).
    Folding(String),
    /// DSE could not satisfy the resource constraint.
    Dse(String),
    /// Simulator invariant violation (deadlock, FIFO misuse).
    Sim(String),
    /// PJRT / XLA runtime failures.
    Xla(String),
    /// Baked-kernel compile or execution failures.
    Kernel(String),
    /// Serving-path failures (queue closed, batcher shutdown).
    QueueClosed,
    /// Admission control shed the request: the in-flight bound is hit.
    /// A fast reject at submit time — retry later or drop (never queued).
    Overloaded,
    /// The fleet has no serving plane for the requested model tag. A fast
    /// reject at submit time, distinct from [`Error::Overloaded`]: retrying
    /// cannot help until an operator registers the model.
    UnknownModel(String),
    /// Config file / CLI argument problems.
    Config(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json { msg, offset } => write!(f, "json at byte {offset}: {msg}"),
            Error::Lstw(m) => write!(f, "lstw: {m}"),
            Error::Graph(m) => write!(f, "graph: {m}"),
            Error::Folding(m) => write!(f, "folding: {m}"),
            Error::Dse(m) => write!(f, "dse: {m}"),
            Error::Sim(m) => write!(f, "sim: {m}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
            Error::Kernel(m) => write!(f, "kernel: {m}"),
            Error::QueueClosed => write!(f, "request queue closed"),
            Error::Overloaded => write!(f, "overloaded: admission queue full, request shed"),
            Error::UnknownModel(tag) => {
                write!(f, "unknown model: no serving plane for tag '{tag}'")
            }
            Error::Config(m) => write!(f, "config: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Convenience constructors used across the crate.
impl Error {
    /// Build an [`Error::Graph`].
    pub fn graph(msg: impl Into<String>) -> Self {
        Error::Graph(msg.into())
    }
    /// Build an [`Error::Folding`].
    pub fn folding(msg: impl Into<String>) -> Self {
        Error::Folding(msg.into())
    }
    /// Build an [`Error::Dse`].
    pub fn dse(msg: impl Into<String>) -> Self {
        Error::Dse(msg.into())
    }
    /// Build an [`Error::Sim`].
    pub fn sim(msg: impl Into<String>) -> Self {
        Error::Sim(msg.into())
    }
    /// Build an [`Error::Config`].
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// Build an [`Error::Lstw`].
    pub fn lstw(msg: impl Into<String>) -> Self {
        Error::Lstw(msg.into())
    }
    /// Build an [`Error::Kernel`].
    pub fn kernel(msg: impl Into<String>) -> Self {
        Error::Kernel(msg.into())
    }
    /// Build an [`Error::UnknownModel`].
    pub fn unknown_model(tag: impl Into<String>) -> Self {
        Error::UnknownModel(tag.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_class() {
        let e = Error::dse("no legal move");
        assert_eq!(e.to_string(), "dse: no legal move");
        let e = Error::Json { msg: "bad token".into(), offset: 17 };
        assert!(e.to_string().contains("byte 17"));
        let e = Error::unknown_model("resnet");
        assert!(matches!(e, Error::UnknownModel(_)));
        assert!(e.to_string().contains("'resnet'"));
    }

    #[test]
    fn io_conversion() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
