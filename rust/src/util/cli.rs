//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and a generated usage string. Subcommand dispatch lives in `main.rs`.

use crate::util::error::{Error, Result};
use std::collections::BTreeMap;

/// Declarative option spec.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| Error::config(format!("missing required option --{name}")))
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| Error::config(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| Error::config(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Parse `argv` (without the program/subcommand prefix) against `opts`.
pub fn parse(argv: &[String], opts: &[Opt]) -> Result<Args> {
    let mut args = Args::default();
    // Seed defaults.
    for o in opts {
        if let Some(d) = o.default {
            args.values.insert(o.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(stripped) = a.strip_prefix("--") {
            let (name, inline) = match stripped.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (stripped, None),
            };
            let spec = opts
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| Error::config(format!("unknown option --{name}")))?;
            if spec.takes_value {
                let val = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| Error::config(format!("--{name} needs a value")))?
                    }
                };
                args.values.insert(name.to_string(), val);
            } else {
                if inline.is_some() {
                    return Err(Error::config(format!("--{name} takes no value")));
                }
                args.flags.push(name.to_string());
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Render a usage block for a subcommand.
pub fn usage(cmd: &str, about: &str, opts: &[Opt]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for o in opts {
        let mut left = format!("  --{}", o.name);
        if o.takes_value {
            left.push_str(" <v>");
        }
        let def = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("{left:<28}{}{def}\n", o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> Vec<Opt> {
        vec![
            Opt { name: "device", takes_value: true, default: Some("xcu50"), help: "device" },
            Opt { name: "steps", takes_value: true, default: None, help: "steps" },
            Opt { name: "verbose", takes_value: false, default: None, help: "log more" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse(&sv(&["--steps", "5"]), &opts()).unwrap();
        assert_eq!(a.get("device"), Some("xcu50"));
        assert_eq!(a.get_usize("steps").unwrap(), Some(5));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse(&sv(&["--device=sim", "--verbose", "pos1"]), &opts()).unwrap();
        assert_eq!(a.get("device"), Some("sim"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn errors() {
        assert!(parse(&sv(&["--nope"]), &opts()).is_err());
        assert!(parse(&sv(&["--steps"]), &opts()).is_err());
        assert!(parse(&sv(&["--verbose=1"]), &opts()).is_err());
        let a = parse(&sv(&["--steps", "abc"]), &opts()).unwrap();
        assert!(a.get_usize("steps").is_err());
    }

    #[test]
    fn usage_renders() {
        let u = usage("dse", "run the design-space exploration", &opts());
        assert!(u.contains("--device"));
        assert!(u.contains("[default: xcu50]"));
    }
}
