//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! **repeatable** value options ([`Args::get_all`] — e.g. `serve`'s
//! `--model` fleet spec) and a generated usage string. Subcommand
//! dispatch lives in `main.rs`.

use crate::util::error::{Error, Result};
use std::collections::{BTreeMap, BTreeSet};

/// Declarative option spec.
#[derive(Debug, Clone)]
pub struct Opt {
    /// Long option name (without the `--`).
    pub name: &'static str,
    /// Whether the option consumes a value (`--key value` / `--key=v`).
    pub takes_value: bool,
    /// Default value seeded before parsing (value options only).
    pub default: Option<&'static str>,
    /// One-line help text for the usage block.
    pub help: &'static str,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    /// Every value given per option. The first explicit occurrence
    /// replaces the seeded default; later occurrences accumulate, so
    /// options are repeatable ([`Args::get_all`]) while [`Args::get`]
    /// keeps last-one-wins semantics.
    values: BTreeMap<String, Vec<String>>,
    /// Options whose current value is still the seeded default.
    defaulted: BTreeSet<String>,
    flags: Vec<String>,
    /// Non-option arguments, in order.
    pub positional: Vec<String>,
}

impl Args {
    /// The value of `name` (the last occurrence when repeated), if any.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// Every explicitly given value of a repeated option, in order.
    /// Empty when the option was never given explicitly (a seeded
    /// default does not count as an occurrence here).
    pub fn get_all(&self, name: &str) -> &[String] {
        if self.defaulted.contains(name) {
            return &[];
        }
        self.values.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// The value of `name`, or a config error naming the option.
    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| Error::config(format!("missing required option --{name}")))
    }

    /// Parse the value of `name` as `usize`, if present.
    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| Error::config(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    /// Parse the value of `name` as `f64`, if present.
    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| Error::config(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    /// Whether the boolean flag `name` was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Parse `argv` (without the program/subcommand prefix) against `opts`.
pub fn parse(argv: &[String], opts: &[Opt]) -> Result<Args> {
    let mut args = Args::default();
    // Seed defaults.
    for o in opts {
        if let Some(d) = o.default {
            args.values.insert(o.name.to_string(), vec![d.to_string()]);
            args.defaulted.insert(o.name.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(stripped) = a.strip_prefix("--") {
            let (name, inline) = match stripped.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (stripped, None),
            };
            let spec = opts
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| Error::config(format!("unknown option --{name}")))?;
            if spec.takes_value {
                let val = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| Error::config(format!("--{name} needs a value")))?
                    }
                };
                if args.defaulted.remove(name) {
                    // First explicit occurrence replaces the default.
                    args.values.insert(name.to_string(), vec![val]);
                } else {
                    args.values.entry(name.to_string()).or_default().push(val);
                }
            } else {
                if inline.is_some() {
                    return Err(Error::config(format!("--{name} takes no value")));
                }
                args.flags.push(name.to_string());
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

/// Validate that the repeated values of `--{option}` have unique keys,
/// where the key is the text before the first `=` (the whole value when
/// there is no `=`). Used by `serve` so `--model a=... --model a=...`
/// fails with a clear CLI-shaped error instead of relying on whatever
/// the downstream consumer does with the duplicate.
pub fn check_unique_keys(option: &str, values: &[String]) -> Result<()> {
    let mut seen: BTreeSet<&str> = BTreeSet::new();
    for v in values {
        let key = v.split_once('=').map_or(v.as_str(), |(k, _)| k);
        if !seen.insert(key) {
            return Err(Error::config(format!(
                "--{option}: duplicate tag '{key}' (each --{option} needs a unique tag)"
            )));
        }
    }
    Ok(())
}

/// Render a usage block for a subcommand.
pub fn usage(cmd: &str, about: &str, opts: &[Opt]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for o in opts {
        let mut left = format!("  --{}", o.name);
        if o.takes_value {
            left.push_str(" <v>");
        }
        let def = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("{left:<28}{}{def}\n", o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> Vec<Opt> {
        vec![
            Opt { name: "device", takes_value: true, default: Some("xcu50"), help: "device" },
            Opt { name: "steps", takes_value: true, default: None, help: "steps" },
            Opt { name: "verbose", takes_value: false, default: None, help: "log more" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse(&sv(&["--steps", "5"]), &opts()).unwrap();
        assert_eq!(a.get("device"), Some("xcu50"));
        assert_eq!(a.get_usize("steps").unwrap(), Some(5));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse(&sv(&["--device=sim", "--verbose", "pos1"]), &opts()).unwrap();
        assert_eq!(a.get("device"), Some("sim"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn repeated_options_accumulate() {
        let a = parse(&sv(&["--steps", "1", "--steps=2", "--steps", "3"]), &opts()).unwrap();
        assert_eq!(a.get_all("steps"), &["1".to_string(), "2".into(), "3".into()]);
        // Scalar accessors keep last-one-wins semantics.
        assert_eq!(a.get_usize("steps").unwrap(), Some(3));
        // A seeded default is not an explicit occurrence...
        assert_eq!(a.get_all("device"), &[] as &[String]);
        // ...and the first explicit occurrence replaces it.
        let b = parse(&sv(&["--device", "tiny", "--device", "zcu104"]), &opts()).unwrap();
        assert_eq!(b.get_all("device"), &["tiny".to_string(), "zcu104".into()]);
        assert_eq!(b.get("device"), Some("zcu104"));
    }

    #[test]
    fn errors() {
        assert!(parse(&sv(&["--nope"]), &opts()).is_err());
        assert!(parse(&sv(&["--steps"]), &opts()).is_err());
        assert!(parse(&sv(&["--verbose=1"]), &opts()).is_err());
        let a = parse(&sv(&["--steps", "abc"]), &opts()).unwrap();
        assert!(a.get_usize("steps").is_err());
    }

    #[test]
    fn unique_keys_rejects_duplicate_tags() {
        // Distinct tags pass, whatever follows the '='.
        check_unique_keys("model", &sv(&["a=native:0.8", "b=native:0.8"])).unwrap();
        // Same tag twice is a loud error naming the tag and the option.
        let err = check_unique_keys("model", &sv(&["a=native", "a=synthetic"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("duplicate tag 'a'"), "{err}");
        assert!(err.contains("--model"), "{err}");
        // Values without '=' compare whole.
        assert!(check_unique_keys("slo", &sv(&["x", "x"])).is_err());
        check_unique_keys("slo", &sv(&[])).unwrap();
    }

    #[test]
    fn usage_renders() {
        let u = usage("dse", "run the design-space exploration", &opts());
        assert!(u.contains("--device"));
        assert!(u.contains("[default: xcu50]"));
    }
}
