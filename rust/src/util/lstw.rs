//! LSTW ("LogicSparse Tensor Weights") binary tensor store — the
//! python↔rust interchange for weights, masks and the serving test set.
//!
//! Mirrors `python/compile/export.py` byte for byte; both sides have
//! round-trip tests and the integration suite reads a python-written file.
//! Layout (little-endian):
//! ```text
//! magic   8B  "LSTW0001"
//! u32     n_tensors
//! per tensor:
//!   u16 name_len, name utf-8
//!   u8  dtype (0=f32, 1=i32, 2=i8, 3=u8)
//!   u8  ndim
//!   u32 dims[ndim]
//!   u64 payload_bytes
//!   raw payload (C order)
//! ```

use crate::util::error::{Error, Result};
use byteorder::{LittleEndian, ReadBytesExt, WriteBytesExt};
use std::io::{Read, Write};

/// File magic: format name + version.
pub const MAGIC: &[u8; 8] = b"LSTW0001";

/// Element type of a stored tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32 = 0,
    /// 32-bit signed integer.
    I32 = 1,
    /// 8-bit signed integer.
    I8 = 2,
    /// 8-bit unsigned integer.
    U8 = 3,
}

impl DType {
    fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::I8,
            3 => DType::U8,
            _ => return Err(Error::lstw(format!("unknown dtype code {c}"))),
        })
    }

    /// Bytes per element.
    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
        }
    }
}

/// Tensor payload, kept in its native representation.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    /// f32 payload.
    F32(Vec<f32>),
    /// i32 payload.
    I32(Vec<i32>),
    /// i8 payload.
    I8(Vec<i8>),
    /// u8 payload.
    U8(Vec<u8>),
}

impl Data {
    /// The element type of this payload.
    pub fn dtype(&self) -> DType {
        match self {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
            Data::I8(_) => DType::I8,
            Data::U8(_) => DType::U8,
        }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::I8(v) => v.len(),
            Data::U8(v) => v.len(),
        }
    }

    /// True for a zero-element payload.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View as f32, converting integer types (mask files are u8).
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            Data::F32(v) => v.clone(),
            Data::I32(v) => v.iter().map(|&x| x as f32).collect(),
            Data::I8(v) => v.iter().map(|&x| x as f32).collect(),
            Data::U8(v) => v.iter().map(|&x| x as f32).collect(),
        }
    }

    /// Borrow as f32, erroring on other dtypes.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Data::F32(v) => Ok(v),
            _ => Err(Error::lstw("tensor is not f32")),
        }
    }

    /// Borrow as i32, erroring on other dtypes.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Data::I32(v) => Ok(v),
            _ => Err(Error::lstw("tensor is not i32")),
        }
    }
}

/// A named tensor with shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Tensor name (store lookup key).
    pub name: String,
    /// Dimensions, C order.
    pub shape: Vec<usize>,
    /// The payload.
    pub data: Data,
}

impl Tensor {
    /// Build an f32 tensor.
    pub fn f32(name: impl Into<String>, shape: Vec<usize>, data: Vec<f32>) -> Self {
        Tensor { name: name.into(), shape, data: Data::F32(data) }
    }

    /// Element count the shape implies.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn validate(&self) -> Result<()> {
        if self.elements() != self.data.len() {
            return Err(Error::lstw(format!(
                "tensor '{}': shape {:?} implies {} elements but payload has {}",
                self.name,
                self.shape,
                self.elements(),
                self.data.len()
            )));
        }
        Ok(())
    }
}

/// An ordered collection of tensors (a whole LSTW file).
#[derive(Debug, Clone, Default)]
pub struct Store {
    /// Tensors in file order.
    pub tensors: Vec<Tensor>,
}

impl Store {
    /// An empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a tensor.
    pub fn push(&mut self, t: Tensor) {
        self.tensors.push(t);
    }

    /// The tensor called `name`, if present.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// The tensor called `name`, or an LSTW error.
    pub fn req(&self, name: &str) -> Result<&Tensor> {
        self.get(name)
            .ok_or_else(|| Error::lstw(format!("tensor '{name}' not found")))
    }

    /// Every tensor name, in file order.
    pub fn names(&self) -> Vec<&str> {
        self.tensors.iter().map(|t| t.name.as_str()).collect()
    }

    /// Read a whole LSTW file.
    pub fn read_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let bytes = std::fs::read(&path)?;
        Self::read(&mut &bytes[..]).map_err(|e| {
            Error::lstw(format!("{}: {e}", path.as_ref().display()))
        })
    }

    /// Write a whole LSTW file (creating parent directories).
    pub fn write_file(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut buf = Vec::new();
        self.write(&mut buf)?;
        std::fs::write(path, buf)?;
        Ok(())
    }

    /// Decode a store from a reader.
    pub fn read(r: &mut impl Read) -> Result<Self> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::lstw("bad magic"));
        }
        let n = r.read_u32::<LittleEndian>()?;
        let mut tensors = Vec::with_capacity(n as usize);
        for _ in 0..n {
            let name_len = r.read_u16::<LittleEndian>()? as usize;
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).map_err(|_| Error::lstw("bad name utf-8"))?;
            let dt = DType::from_code(r.read_u8()?)?;
            let ndim = r.read_u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.read_u32::<LittleEndian>()? as usize);
            }
            let nbytes = r.read_u64::<LittleEndian>()? as usize;
            let n_el: usize = shape.iter().product();
            if nbytes != n_el * dt.size() {
                return Err(Error::lstw(format!(
                    "tensor '{name}': payload {nbytes}B != {} elements * {}B",
                    n_el,
                    dt.size()
                )));
            }
            let mut raw = vec![0u8; nbytes];
            r.read_exact(&mut raw)?;
            let data = match dt {
                DType::F32 => Data::F32(
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                DType::I32 => Data::I32(
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                ),
                DType::I8 => Data::I8(raw.iter().map(|&b| b as i8).collect()),
                DType::U8 => Data::U8(raw),
            };
            tensors.push(Tensor { name, shape, data });
        }
        Ok(Store { tensors })
    }

    /// Encode the store to a writer.
    pub fn write(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_u32::<LittleEndian>(self.tensors.len() as u32)?;
        for t in &self.tensors {
            t.validate()?;
            let name = t.name.as_bytes();
            if name.len() > u16::MAX as usize {
                return Err(Error::lstw("tensor name too long"));
            }
            w.write_u16::<LittleEndian>(name.len() as u16)?;
            w.write_all(name)?;
            w.write_u8(t.data.dtype() as u8)?;
            w.write_u8(t.shape.len() as u8)?;
            for &d in &t.shape {
                w.write_u32::<LittleEndian>(d as u32)?;
            }
            let nbytes = t.data.len() * t.data.dtype().size();
            w.write_u64::<LittleEndian>(nbytes as u64)?;
            match &t.data {
                Data::F32(v) => {
                    for &x in v {
                        w.write_f32::<LittleEndian>(x)?;
                    }
                }
                Data::I32(v) => {
                    for &x in v {
                        w.write_i32::<LittleEndian>(x)?;
                    }
                }
                Data::I8(v) => {
                    for &x in v {
                        w.write_i8(x)?;
                    }
                }
                Data::U8(v) => w.write_all(v)?,
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Store {
        let mut s = Store::new();
        s.push(Tensor::f32("conv1.w", vec![5, 5, 1, 6], (0..150).map(|i| i as f32).collect()));
        s.push(Tensor {
            name: "labels".into(),
            shape: vec![4],
            data: Data::I32(vec![1, -2, 3, 7]),
        });
        s.push(Tensor {
            name: "mask".into(),
            shape: vec![2, 3],
            data: Data::U8(vec![1, 0, 1, 1, 0, 0]),
        });
        s.push(Tensor {
            name: "codes".into(),
            shape: vec![3],
            data: Data::I8(vec![-7, 0, 7]),
        });
        s
    }

    #[test]
    fn roundtrip() {
        let s = sample();
        let mut buf = Vec::new();
        s.write(&mut buf).unwrap();
        let s2 = Store::read(&mut &buf[..]).unwrap();
        assert_eq!(s.tensors, s2.tensors);
    }

    #[test]
    fn lookup_and_convert() {
        let s = sample();
        assert_eq!(s.req("mask").unwrap().data.to_f32(), vec![1.0, 0.0, 1.0, 1.0, 0.0, 0.0]);
        assert!(s.req("nope").is_err());
        assert_eq!(s.get("conv1.w").unwrap().elements(), 150);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = Vec::new();
        sample().write(&mut buf).unwrap();
        buf[0] = b'X';
        assert!(Store::read(&mut &buf[..]).is_err());
    }

    #[test]
    fn rejects_shape_payload_mismatch() {
        let t = Tensor::f32("bad", vec![2, 2], vec![1.0; 3]);
        let mut s = Store::new();
        s.push(t);
        let mut buf = Vec::new();
        assert!(s.write(&mut buf).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut buf = Vec::new();
        sample().write(&mut buf).unwrap();
        let cut = &buf[..buf.len() - 5];
        assert!(Store::read(&mut &cut[..]).is_err());
    }

    #[test]
    fn empty_store() {
        let s = Store::new();
        let mut buf = Vec::new();
        s.write(&mut buf).unwrap();
        assert!(Store::read(&mut &buf[..]).unwrap().tensors.is_empty());
    }
}
