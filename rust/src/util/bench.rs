//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! The `cargo bench` targets (`benches/*.rs`, `harness = false`) use this:
//! warmup, calibrated iteration counts, median/p10/p90 over samples, and a
//! one-line report compatible with the EXPERIMENTS.md §Perf tables.
//! [`BenchLog`] additionally writes the per-scenario numbers as JSON
//! (`BENCH_<name>.json`) so the perf trajectory is machine-trackable
//! across PRs instead of living only in scrollback.

use crate::util::json::{self, Value};
use std::time::Instant;

/// Scenario name -> flat metric map, serialised by [`BenchLog::write`].
type Metrics = Vec<(String, f64)>;

/// The `model` label [`BenchLog::push`] stamps on rows that predate the
/// multi-model fleet (single-model scenarios).
pub const SINGLE_MODEL: &str = "single";

/// Machine-readable results of one bench binary.
#[derive(Debug, Clone)]
pub struct BenchLog {
    bench: String,
    /// `(scenario, model, metrics)` rows in insertion order.
    scenarios: Vec<(String, String, Metrics)>,
}

impl BenchLog {
    /// A fresh log for the bench binary `bench`.
    pub fn new(bench: impl Into<String>) -> Self {
        BenchLog { bench: bench.into(), scenarios: Vec::new() }
    }

    /// Record one single-model scenario's metrics (insertion-ordered,
    /// overwrites an existing scenario of the same name). The row's
    /// `model` field defaults to [`SINGLE_MODEL`].
    pub fn push(&mut self, scenario: &str, metrics: &[(&str, f64)]) {
        self.push_model(scenario, SINGLE_MODEL, metrics);
    }

    /// Record one scenario's metrics labelled with the model (tag) they
    /// were measured on, so fleet rows stay distinguishable across PRs.
    /// Rows are keyed by `(scenario, model)`: the same scenario measured
    /// on two models keeps both rows (the JSON keys disambiguate as
    /// `scenario@model`), while re-pushing the same pair overwrites.
    pub fn push_model(&mut self, scenario: &str, model: &str, metrics: &[(&str, f64)]) {
        let entry: Metrics = metrics.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        match self
            .scenarios
            .iter_mut()
            .find(|(n, m, _)| n == scenario && m == model)
        {
            Some((_, _, ms)) => *ms = entry,
            None => self
                .scenarios
                .push((scenario.to_string(), model.to_string(), entry)),
        }
    }

    /// True when no scenario has been recorded.
    pub fn is_empty(&self) -> bool {
        self.scenarios.is_empty()
    }

    /// Write `{"bench": ..., "results": {scenario: {"model": ..., metric:
    /// value}}}`. A scenario recorded under several models emits one key
    /// per row, disambiguated as `scenario@model` so keys stay unique.
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> crate::util::error::Result<()> {
        let results = Value::Obj(
            self.scenarios
                .iter()
                .map(|(name, model, ms)| {
                    let multi =
                        self.scenarios.iter().filter(|(n, _, _)| n == name).count() > 1;
                    let key = if multi { format!("{name}@{model}") } else { name.clone() };
                    let mut fields = vec![("model".to_string(), json::s(model.clone()))];
                    fields.extend(ms.iter().map(|(k, v)| (k.clone(), Value::Num(*v))));
                    (key, Value::Obj(fields))
                })
                .collect(),
        );
        let doc = json::obj(vec![
            ("bench", json::s(self.bench.clone())),
            ("results", results),
        ]);
        json::write_file(path, &doc)
    }
}

/// Which way a metric improves, inferred from its naming convention so
/// the baseline diff needs no per-metric registry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Larger is better (throughput-like: `rps`, `*_per_s`, `*_x`, ...).
    HigherBetter,
    /// Smaller is better (latency-like: `*_ms`, `*_us`, ...).
    LowerBetter,
}

/// Marker prefix a committed-but-unmeasured baseline carries in its
/// top-level `provenance` string (`BENCH_baseline.json` was seeded in
/// an environment with no Rust toolchain, so it holds no rows).
pub const UNMEASURED_MARKER: &str = "UNMEASURED";

/// True when a baseline's provenance string marks it as the unmeasured
/// placeholder. `bench-compare` downgrades to a one-line report-only
/// verdict in that case: there is nothing to diff against, and strict
/// mode must not fail a run for drift that cannot exist yet.
pub fn is_unmeasured_baseline(provenance: &str) -> bool {
    provenance.trim_start().starts_with(UNMEASURED_MARKER)
}

/// Classify a metric name by suffix/stem convention; `None` means the
/// metric is a descriptive counter (shed counts, worker counts, model
/// sparsity, ...) that a regression diff should skip rather than judge.
pub fn metric_direction(name: &str) -> Option<Direction> {
    let higher = ["rps", "per_s", "throughput", "speedup", "ratio"];
    if higher.iter().any(|s| name == *s || name.ends_with(&format!("_{s}")))
        || name.ends_with("_x")
    {
        return Some(Direction::HigherBetter);
    }
    let lower = ["ms", "us", "ns", "s", "cycles", "latency"];
    if lower.iter().any(|s| name.ends_with(&format!("_{s}")) || name == *s) {
        return Some(Direction::LowerBetter);
    }
    None
}

/// One metric's baseline-vs-current comparison.
#[derive(Debug, Clone)]
pub struct MetricDelta {
    /// Scenario key the metric lives under (`scenario` or
    /// `scenario@model`).
    pub scenario: String,
    /// Metric name within the scenario.
    pub metric: String,
    /// Baseline value.
    pub base: f64,
    /// Current value.
    pub current: f64,
    /// `current / base` (0.0 when the baseline value is 0).
    pub ratio: f64,
    /// Worse than baseline beyond the noise band.
    pub regressed: bool,
    /// Better than baseline beyond the noise band.
    pub improved: bool,
}

/// Result of diffing one bench's current results against its baseline.
#[derive(Debug, Clone, Default)]
pub struct CompareReport {
    /// Judged metrics, in baseline order.
    pub deltas: Vec<MetricDelta>,
    /// Baseline scenarios absent from the current run.
    pub missing: Vec<String>,
    /// Direction-classified baseline metrics absent from the current
    /// run's row (`scenario.metric`). A judged series (e.g. `p99_ms`)
    /// silently disappearing is drift, not noise, so it is surfaced
    /// instead of skipped.
    pub missing_metrics: Vec<String>,
    /// Direction-classified metrics present in the current run but not
    /// in the baseline: `(scenario.metric, value)`. Not judged (there is
    /// nothing to diff against), but listed so a new tracked series is
    /// visible until the baseline is refreshed to include it.
    pub new_series: Vec<(String, f64)>,
}

impl CompareReport {
    /// Metrics that regressed beyond the noise band.
    pub fn regressions(&self) -> Vec<&MetricDelta> {
        self.deltas.iter().filter(|d| d.regressed).collect()
    }

    /// One line per judged metric plus a verdict line.
    pub fn render(&self, bench: &str) -> String {
        let mut out = String::new();
        for d in &self.deltas {
            let verdict = if d.regressed {
                "REGRESSED"
            } else if d.improved {
                "improved"
            } else {
                "ok"
            };
            out.push_str(&format!(
                "  {:<52} {:>12.3} -> {:>12.3}  ({:>6.2}x)  {}\n",
                format!("{}.{}", d.scenario, d.metric),
                d.base,
                d.current,
                d.ratio,
                verdict
            ));
        }
        for m in &self.missing {
            out.push_str(&format!("  {m:<52} missing from current run\n"));
        }
        for m in &self.missing_metrics {
            out.push_str(&format!("  {m:<52} tracked metric missing from current run\n"));
        }
        for (m, v) in &self.new_series {
            out.push_str(&format!(
                "  {m:<52} {v:>12.3} new series (not in baseline; refresh to track)\n"
            ));
        }
        let n_reg = self.regressions().len();
        out.push_str(&format!(
            "{bench}: {} metrics judged, {} regressed, {} missing, {} new\n",
            self.deltas.len(),
            n_reg,
            self.missing.len() + self.missing_metrics.len(),
            self.new_series.len()
        ));
        out
    }
}

/// Diff `current` (a `BENCH_*.json` document) against `baseline` (the
/// same `results` shape). A metric regresses when it is worse than the
/// baseline by more than `noise` (fractional, e.g. 0.3 = 30%) in its
/// [`metric_direction`]; direction-less counters are skipped. Whole
/// scenarios present only in the current run are ignored (new benches
/// are not drift), while baseline scenarios absent from the current run
/// are reported in `missing`. Within a shared scenario, judged series
/// that appear on only one side are surfaced rather than skipped: a
/// baseline metric the current row dropped lands in `missing_metrics`,
/// and a current metric the baseline predates (e.g. `p99_ms` added to a
/// bench after the baseline was captured) lands in `new_series` so the
/// latency trajectory is visible until the baseline is refreshed.
pub fn compare(baseline: &Value, current: &Value, noise: f64) -> CompareReport {
    let mut report = CompareReport::default();
    let empty: &[(String, Value)] = &[];
    let base_results = baseline.get("results").and_then(Value::as_obj).unwrap_or(empty);
    let cur_results = current.get("results").and_then(Value::as_obj).unwrap_or(empty);
    for (scenario, base_row) in base_results {
        let Some(cur_row) = cur_results
            .iter()
            .find(|(k, _)| k == scenario)
            .map(|(_, v)| v)
        else {
            report.missing.push(scenario.clone());
            continue;
        };
        let Some(base_metrics) = base_row.as_obj() else { continue };
        // Judged series the baseline predates: visible, not judged.
        for (metric, cur_val) in cur_row.as_obj().unwrap_or(empty) {
            if metric_direction(metric).is_some()
                && base_metrics.iter().all(|(k, _)| k != metric)
            {
                if let Some(v) = cur_val.as_f64() {
                    report.new_series.push((format!("{scenario}.{metric}"), v));
                }
            }
        }
        for (metric, base_val) in base_metrics {
            let Some(dir) = metric_direction(metric) else { continue };
            let (Some(base), Some(current)) =
                (base_val.as_f64(), cur_row.get(metric).and_then(Value::as_f64))
            else {
                if base_val.as_f64().is_some() {
                    report.missing_metrics.push(format!("{scenario}.{metric}"));
                }
                continue;
            };
            let ratio = if base != 0.0 { current / base } else { 0.0 };
            let (regressed, improved) = match dir {
                Direction::HigherBetter => {
                    (current < base * (1.0 - noise), current > base * (1.0 + noise))
                }
                Direction::LowerBetter => {
                    (current > base * (1.0 + noise), current < base * (1.0 - noise))
                }
            };
            report.deltas.push(MetricDelta {
                scenario: scenario.clone(),
                metric: metric.clone(),
                base,
                current,
                ratio,
                regressed,
                improved,
            });
        }
    }
    report
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark name.
    pub name: String,
    /// Iterations each sample timed.
    pub iters_per_sample: u64,
    /// Seconds per iteration, one entry per sample.
    pub samples: Vec<f64>,
}

impl Stats {
    fn pct(&self, q: f64) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((s.len() - 1) as f64 * q).round() as usize;
        s[idx]
    }

    /// Median seconds per iteration.
    pub fn median(&self) -> f64 {
        self.pct(0.5)
    }

    /// 10th-percentile seconds per iteration.
    pub fn p10(&self) -> f64 {
        self.pct(0.1)
    }

    /// 90th-percentile seconds per iteration.
    pub fn p90(&self) -> f64 {
        self.pct(0.9)
    }

    /// Iterations per second at the median.
    pub fn throughput(&self) -> f64 {
        1.0 / self.median()
    }

    /// One-line report in the EXPERIMENTS.md §Perf format.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (p10 {}, p90 {}, {} samples x {} iters)",
            self.name,
            fmt_dur(self.median()),
            fmt_dur(self.p10()),
            fmt_dur(self.p90()),
            self.samples.len(),
            self.iters_per_sample,
        )
    }
}

/// Human-readable duration (s / ms / us / ns).
pub fn fmt_dur(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}us", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

/// Benchmark runner with a time budget per benchmark.
pub struct Bencher {
    /// Warmup + calibration budget in seconds.
    pub warmup_s: f64,
    /// Target seconds per sample.
    pub sample_s: f64,
    /// Samples to collect.
    pub n_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup_s: 0.3, sample_s: 0.1, n_samples: 12 }
    }
}

impl Bencher {
    /// A fast low-fidelity configuration for smoke runs.
    pub fn quick() -> Self {
        Bencher { warmup_s: 0.05, sample_s: 0.02, n_samples: 5 }
    }

    /// Run `f` repeatedly; `f` should perform ONE unit of work. A
    /// `black_box`-style sink prevents the optimiser deleting the work:
    /// return something cheap from `f` and it is consumed here.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Stats {
        // Warmup + calibration: how many iters fit in sample_s?
        let t0 = Instant::now();
        let mut iters = 0u64;
        while t0.elapsed().as_secs_f64() < self.warmup_s {
            std::hint::black_box(f());
            iters += 1;
        }
        let per_iter = self.warmup_s / iters.max(1) as f64;
        let iters_per_sample = ((self.sample_s / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.n_samples);
        for _ in 0..self.n_samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                std::hint::black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        let stats = Stats { name: name.to_string(), iters_per_sample, samples };
        println!("{}", stats.report());
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_plausible() {
        let b = Bencher { warmup_s: 0.01, sample_s: 0.005, n_samples: 4 };
        let stats = b.run("sum-1k", || (0..1000u64).sum::<u64>());
        assert!(stats.median() > 0.0);
        assert!(stats.median() < 0.01, "1k sum should be far below 10ms");
        assert_eq!(stats.samples.len(), 4);
    }

    #[test]
    fn unmeasured_marker_detected_only_as_prefix() {
        assert!(is_unmeasured_baseline("UNMEASURED seed baseline committed with PR 6"));
        assert!(is_unmeasured_baseline("  UNMEASURED"));
        assert!(!is_unmeasured_baseline("measured snapshot written by bench-compare"));
        assert!(!is_unmeasured_baseline("snapshot replacing the UNMEASURED seed"));
    }

    #[test]
    fn percentiles_ordered() {
        let s = Stats {
            name: "x".into(),
            iters_per_sample: 1,
            samples: vec![3.0, 1.0, 2.0, 5.0, 4.0],
        };
        assert!(s.p10() <= s.median() && s.median() <= s.p90());
        assert_eq!(s.median(), 3.0);
    }

    #[test]
    fn bench_log_roundtrips_through_json() {
        let mut log = BenchLog::new("unit");
        log.push("scenario_a", &[("rps", 1234.5), ("p99_ms", 7.25)]);
        log.push("scenario_b", &[("shed", 0.0)]);
        log.push("scenario_a", &[("rps", 2000.0)]); // overwrite wins
        log.push_model("scenario_fleet", "lenet-sparse", &[("rps", 500.0)]);
        // Same scenario on two models: both rows survive, keys
        // disambiguate.
        log.push_model("per_tag", "dense", &[("rps", 100.0)]);
        log.push_model("per_tag", "sparse", &[("rps", 300.0)]);
        log.push_model("per_tag", "sparse", &[("rps", 350.0)]); // same pair overwrites
        let path = std::env::temp_dir().join(format!("bench_log_{}.json", std::process::id()));
        log.write(&path).unwrap();
        let v = json::parse_file(&path).unwrap();
        assert_eq!(v.req_str("bench").unwrap(), "unit");
        let results = v.req("results").unwrap();
        assert_eq!(results.get("scenario_a").unwrap().req_f64("rps").unwrap(), 2000.0);
        assert!(results.get("scenario_a").unwrap().get("p99_ms").is_none());
        assert_eq!(results.get("scenario_b").unwrap().req_f64("shed").unwrap(), 0.0);
        // Single-model rows default the model field; fleet rows carry
        // their tag.
        assert_eq!(
            results.get("scenario_a").unwrap().req_str("model").unwrap(),
            SINGLE_MODEL
        );
        assert_eq!(
            results.get("scenario_fleet").unwrap().req_str("model").unwrap(),
            "lenet-sparse"
        );
        assert_eq!(results.get("per_tag@dense").unwrap().req_f64("rps").unwrap(), 100.0);
        assert_eq!(results.get("per_tag@sparse").unwrap().req_f64("rps").unwrap(), 350.0);
        assert!(results.get("per_tag").is_none(), "multi-model scenario must split keys");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn metric_directions_follow_naming_convention() {
        assert_eq!(metric_direction("rps"), Some(Direction::HigherBetter));
        assert_eq!(metric_direction("achieved_rps"), Some(Direction::HigherBetter));
        assert_eq!(metric_direction("frames_per_s"), Some(Direction::HigherBetter));
        assert_eq!(metric_direction("speedup_vs_scalar_x"), Some(Direction::HigherBetter));
        assert_eq!(metric_direction("batch_speedup"), Some(Direction::HigherBetter));
        assert_eq!(metric_direction("p99_ms"), Some(Direction::LowerBetter));
        assert_eq!(metric_direction("median_us"), Some(Direction::LowerBetter));
        assert_eq!(metric_direction("wall_s"), Some(Direction::LowerBetter));
        // Counters and labels are skipped, not judged.
        assert_eq!(metric_direction("shed"), None);
        assert_eq!(metric_direction("completed"), None);
        assert_eq!(metric_direction("workers"), None);
        assert_eq!(metric_direction("sparsity"), None);
    }

    fn doc(rows: Vec<(&str, Vec<(&str, f64)>)>) -> Value {
        json::obj(vec![
            ("bench", json::s("unit")),
            (
                "results",
                Value::Obj(
                    rows.into_iter()
                        .map(|(k, ms)| {
                            (
                                k.to_string(),
                                Value::Obj(
                                    ms.into_iter()
                                        .map(|(m, v)| (m.to_string(), Value::Num(v)))
                                        .collect(),
                                ),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    #[test]
    fn compare_flags_regressions_beyond_noise() {
        let base = doc(vec![
            ("throughput", vec![("rps", 1000.0), ("p99_ms", 10.0), ("shed", 5.0)]),
            ("gone", vec![("rps", 1.0)]),
        ]);
        let cur = doc(vec![
            // rps fell 50% (regressed beyond 30% noise); p99 doubled
            // (regressed); shed is a counter (skipped).
            ("throughput", vec![("rps", 500.0), ("p99_ms", 20.0), ("shed", 50.0)]),
            ("brand_new", vec![("rps", 9.0)]),
        ]);
        let rep = compare(&base, &cur, 0.3);
        assert_eq!(rep.deltas.len(), 2, "counter must be skipped: {:?}", rep.deltas);
        assert!(rep.deltas.iter().all(|d| d.regressed));
        assert_eq!(rep.regressions().len(), 2);
        assert_eq!(rep.missing, vec!["gone".to_string()]);
        let rendered = rep.render("unit");
        assert!(rendered.contains("REGRESSED"));
        assert!(rendered.contains("2 regressed"));
        assert!(rendered.contains("missing from current run"));
    }

    #[test]
    fn compare_inside_noise_band_is_quiet() {
        let base = doc(vec![("t", vec![("rps", 1000.0), ("p99_ms", 10.0)])]);
        let cur = doc(vec![("t", vec![("rps", 900.0), ("p99_ms", 11.0)])]);
        let rep = compare(&base, &cur, 0.3);
        assert_eq!(rep.deltas.len(), 2);
        assert!(rep.regressions().is_empty());
        assert!(rep.deltas.iter().all(|d| !d.improved));
        assert!(rep.missing.is_empty());
        // A big gain is reported as improved, not regressed.
        let fast = doc(vec![("t", vec![("rps", 2000.0), ("p99_ms", 2.0)])]);
        let rep = compare(&base, &fast, 0.3);
        assert!(rep.deltas.iter().all(|d| d.improved && !d.regressed));
    }

    #[test]
    fn compare_tracks_series_added_or_dropped_within_a_scenario() {
        // The baseline predates p99 tracking; the current run both adds
        // p99_ms (new series, listed but unjudged) and drops median_us
        // (tracked metric gone — drift, surfaced loudly). Counters that
        // appear or vanish stay silent either way.
        let base = doc(vec![(
            "serve",
            vec![("rps", 1000.0), ("median_us", 800.0), ("shed", 1.0)],
        )]);
        let cur = doc(vec![(
            "serve",
            vec![("rps", 1010.0), ("p99_ms", 7.5), ("workers", 4.0)],
        )]);
        let rep = compare(&base, &cur, 0.3);
        assert_eq!(rep.deltas.len(), 1, "only rps is judged on both sides");
        assert!(rep.regressions().is_empty());
        assert_eq!(rep.missing_metrics, vec!["serve.median_us".to_string()]);
        assert_eq!(rep.new_series, vec![("serve.p99_ms".to_string(), 7.5)]);
        let rendered = rep.render("unit");
        assert!(rendered.contains("serve.median_us"));
        assert!(rendered.contains("tracked metric missing"));
        assert!(rendered.contains("serve.p99_ms"));
        assert!(rendered.contains("new series"));
        assert!(rendered.contains("1 missing, 1 new"));
        // Once the baseline is refreshed to carry p99_ms, it is judged
        // like any latency series: a doubled p99 regresses.
        let refreshed = doc(vec![("serve", vec![("rps", 1000.0), ("p99_ms", 7.5)])]);
        let slow = doc(vec![("serve", vec![("rps", 1000.0), ("p99_ms", 16.0)])]);
        let rep = compare(&refreshed, &slow, 0.3);
        let p99 = rep.deltas.iter().find(|d| d.metric == "p99_ms").unwrap();
        assert!(p99.regressed, "doubled p99_ms must regress: {p99:?}");
        assert!(rep.new_series.is_empty() && rep.missing_metrics.is_empty());
    }

    #[test]
    fn compare_tolerates_empty_or_malformed_documents() {
        let empty = json::obj(vec![("bench", json::s("x"))]);
        let base = doc(vec![("t", vec![("rps", 100.0)])]);
        let rep = compare(&empty, &base, 0.3);
        assert!(rep.deltas.is_empty() && rep.missing.is_empty());
        let rep = compare(&base, &empty, 0.3);
        assert!(rep.deltas.is_empty());
        assert_eq!(rep.missing, vec!["t".to_string()]);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(2.5), "2.500s");
        assert_eq!(fmt_dur(0.0025), "2.500ms");
        assert_eq!(fmt_dur(2.5e-6), "2.500us");
        assert_eq!(fmt_dur(2.5e-8), "25.0ns");
    }
}
