//! Mini property-testing harness (proptest is unavailable offline).
//!
//! Provides the 20% of proptest this crate needs: seeded generators built
//! on [`crate::util::rng::Pcg32`], a `check` driver that runs N cases, and
//! greedy input shrinking for failing cases. Used by the folding, sparsity,
//! simulator and coordinator invariant tests (DESIGN.md §5 S3).
//!
//! ```no_run
//! // (no_run: doctest binaries miss the libxla rpath in this sandbox)
//! use logicsparse::util::propcheck::check;
//! check("add commutes", 200, |g| {
//!     let a = g.usize(0, 1000);
//!     let b = g.usize(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Pcg32;

/// Per-case generator handle. Records draws so failures can be replayed.
pub struct Gen {
    rng: Pcg32,
    /// Scale factor in (0, 1]: shrinking re-runs with smaller scale to bias
    /// generated sizes toward minimal counterexamples.
    scale: f64,
    /// Index of the current case (usable as an auxiliary seed).
    pub case: u64,
}

impl Gen {
    fn new(seed: u64, case: u64, scale: f64) -> Self {
        Gen { rng: Pcg32::new(seed, case), scale, case }
    }

    fn scaled(&self, lo: usize, hi: usize) -> usize {
        if hi <= lo + 1 {
            return hi;
        }
        let span = (hi - lo) as f64 * self.scale;
        lo + 1 + span.ceil() as usize
    }

    /// usize in `[lo, hi]` (inclusive), biased smaller while shrinking.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let cap = self.scaled(lo, hi).min(hi + 1);
        self.rng.range(lo, cap.max(lo + 1))
    }

    /// u64 in `[lo, hi]` (inclusive).
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + (self.rng.next_u64() % (hi - lo + 1))
    }

    /// f64 uniform in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    /// f32 uniform in `[lo, hi)`.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.f32() * (hi - lo)
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// A vector of values from `f`, with length in `[min_len, max_len]`.
    pub fn vec<T>(&mut self, min_len: usize, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let n = self.usize(min_len, max_len);
        (0..n).map(|_| f(self)).collect()
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.range(0, xs.len());
        &xs[i]
    }

    /// A divisor of `n` chosen uniformly among all divisors.
    pub fn divisor_of(&mut self, n: usize) -> usize {
        let divs: Vec<usize> = (1..=n).filter(|d| n % d == 0).collect();
        *self.choose(&divs)
    }

    /// Raw RNG access for custom distributions.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. On failure, retry the same case seed
/// at smaller scales (greedy shrink), then panic with the reproducer.
///
/// Set `LOGICSPARSE_PROP_SEED` to override the base seed.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let seed = std::env::var("LOGICSPARSE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x1095_1c5e_u64);

    for case in 0..cases {
        let failed = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, case, 1.0);
            prop(&mut g);
        })
        .is_err();

        if failed {
            // Greedy shrink: same stream, smaller scales.
            let mut min_scale = 1.0;
            for &scale in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                let still_fails = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, case, scale);
                    prop(&mut g);
                })
                .is_err();
                if still_fails {
                    min_scale = scale;
                } else {
                    break;
                }
            }
            // Re-run the minimal failing case outside catch_unwind so the
            // original assertion message reaches the test output.
            eprintln!(
                "propcheck '{name}': case {case} failed (seed {seed}, scale {min_scale}); replaying:"
            );
            let mut g = Gen::new(seed, case, min_scale);
            prop(&mut g);
            unreachable!("property failed under catch_unwind but passed on replay");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check("reverse twice is identity", 100, |g| {
            let xs = g.vec(0, 50, |g| g.usize(0, 100));
            let mut ys = xs.clone();
            ys.reverse();
            ys.reverse();
            assert_eq!(xs, ys);
        });
    }

    #[test]
    #[should_panic]
    fn catches_invalid_property() {
        check("all vecs shorter than 3", 200, |g| {
            let xs = g.vec(0, 10, |g| g.usize(0, 1));
            assert!(xs.len() < 3);
        });
    }

    #[test]
    fn divisor_of_divides() {
        check("divisor_of returns divisors", 100, |g| {
            let n = g.usize(1, 360);
            let d = g.divisor_of(n);
            assert_eq!(n % d, 0);
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Gen::new(9, 3, 1.0);
        let mut b = Gen::new(9, 3, 1.0);
        for _ in 0..50 {
            assert_eq!(a.usize(0, 1000), b.usize(0, 1000));
        }
    }
}
