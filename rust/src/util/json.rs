//! Minimal, correct JSON (RFC 8259) parser + writer.
//!
//! serde/serde_json are unavailable offline, and the compile path exchanges
//! `graph.json`, `prune_profile.json`, `folding_config.json` and
//! `metrics.json` with python — so this is a first-class substrate (S1),
//! not a toy: full escape handling, float/exponent forms, deep-nesting
//! guard, byte-offset error reporting. Objects preserve insertion order so
//! emitted configs diff cleanly against python's `json.dump(sort_keys)`.

use crate::util::error::{Error, Result};
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (f64, as in javascript).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Value>),
    /// Insertion-ordered object (no hashing: objects here are small).
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required schema fields.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| Error::Json {
            msg: format!("missing required key '{key}'"),
            offset: 0,
        })
    }

    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if it is one exactly.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// [`Value::as_u64`] narrowed to `usize`.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The fields, if this is an object.
    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Required typed accessors (schema errors carry the key name).
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| Error::Json {
            msg: format!("key '{key}' is not a number"),
            offset: 0,
        })
    }

    /// Required non-negative integer field `key`.
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?.as_usize().ok_or_else(|| Error::Json {
            msg: format!("key '{key}' is not a non-negative integer"),
            offset: 0,
        })
    }

    /// Required string field `key`.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| Error::Json {
            msg: format!("key '{key}' is not a string"),
            offset: 0,
        })
    }

    /// Serialise compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialise with 2-space indentation (matches python's exporter).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(out, *n),
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Value::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !kv.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Build an object value (builder helpers keep call sites terse).
pub fn obj(kv: Vec<(&str, Value)>) -> Value {
    Value::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Build a number value.
pub fn num(n: f64) -> Value {
    Value::Num(n)
}

/// Build a string value.
pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

/// Build an array value.
pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: impl AsRef<std::path::Path>) -> Result<Value> {
    let text = std::fs::read_to_string(&path)?;
    parse(&text).map_err(|e| match e {
        Error::Json { msg, offset } => Error::Json {
            msg: format!("{}: {msg}", path.as_ref().display()),
            offset,
        },
        other => other,
    })
}

/// Write a value to a file, pretty-printed.
pub fn write_file(path: impl AsRef<std::path::Path>, v: &Value) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, v.to_string_pretty())?;
    Ok(())
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Json { msg: msg.into(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected '{word}'")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(kv));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            kv.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(kv)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'[')?;
        let mut vals = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(vals));
        }
        loop {
            self.skip_ws();
            vals.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(vals)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c).ok_or_else(|| self.err("bad utf-8"))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // int part
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
            }
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit required after '.'"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit required in exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("number out of range"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC2..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF4 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-0.5e2").unwrap(), Value::Num(-50.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn escapes_roundtrip() {
        let orig = Value::Str("line\n\"quote\"\tతెలుగు \\ end".into());
        let text = orig.to_string_compact();
        assert_eq!(parse(&text).unwrap(), orig);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
        // surrogate pair: U+1F600
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,]", "{\"a\":}", "01", "\"\\x\"", "tru", "1 2", "", "\"\u{1}\""] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn rejects_deep_nesting() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&deep).is_err());
    }

    #[test]
    fn pretty_roundtrip() {
        let v = obj(vec![
            ("layers", arr(vec![num(1.0), num(2.5)])),
            ("name", s("lenet5")),
            ("ok", Value::Bool(true)),
        ]);
        let text = v.to_string_pretty();
        assert_eq!(parse(&text).unwrap(), v);
        assert!(text.contains("  \"layers\""));
    }

    #[test]
    fn integers_stay_integers() {
        // Python json.load must see ints where we wrote ints.
        let v = obj(vec![("pe", num(16.0))]);
        assert_eq!(v.to_string_compact(), r#"{"pe":16}"#);
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"n": 3, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 3);
        assert_eq!(v.req_str("s").unwrap(), "x");
        assert_eq!(v.req_f64("f").unwrap(), 1.5);
        assert!(v.req_usize("f").is_err());
        assert!(v.req("missing").is_err());
    }

    #[test]
    fn order_preserved() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }
}
