//! Bounded MPMC ring queue + thread parker (substrate S18) — the
//! first-party building blocks of the sharded execution plane
//! (`coordinator::shard`); crossbeam is unavailable offline.
//!
//! [`RingQueue`] is a bounded multi-producer/multi-consumer queue over
//! pre-allocated ring storage. Every operation is a short critical
//! section (one lock, no allocation after construction); blocking is
//! layered on top with [`Parker`], so a work-stealing consumer can probe
//! many queues cheaply and only sleep once *all* of them came up empty.
//! The capacity bound is **adjustable** ([`RingQueue::set_capacity`]):
//! the policy control plane retunes ring depths between batches
//! (DESIGN.md §11), so the bound is an atomic consulted by `try_push`
//! rather than a construction-time constant. Shrinking below the current
//! occupancy never drops queued items — pushes simply fail `Full` until
//! consumers drain under the new bound.
//! Close semantics are drain-friendly: after [`RingQueue::close`] pushes
//! fail immediately, but pops keep draining and report [`PopError::Closed`]
//! only once the queue is also empty — exactly the contract deterministic
//! shutdown needs (no token may be lost between "stop producing" and
//! "workers exited").
//!
//! [`Parker`] has crossbeam-style single-token semantics: `unpark` deposits
//! a token; `park*` consumes it or blocks. A token deposited while the
//! owner is running makes the *next* park return immediately, which closes
//! the classic "checked empty → producer pushed + unparked → consumer
//! parks forever" race.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a push was refused. The value is handed back so callers can retry
/// or redirect it without a clone.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// Queue at capacity.
    Full(T),
    /// Queue closed for producers.
    Closed(T),
}

/// Why a pop returned nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopError {
    /// Nothing queued right now (more may arrive).
    Empty,
    /// Closed **and** fully drained — no item will ever arrive.
    Closed,
}

struct RingState<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// Bounded MPMC queue with drain-friendly close and an adjustable bound.
pub struct RingQueue<T> {
    state: Mutex<RingState<T>>,
    /// Signalled on push and on close (for blocked `pop_timeout` callers).
    not_empty: Condvar,
    /// Current capacity bound; adjustable at runtime (policy autotuning).
    capacity: AtomicUsize,
}

impl<T> RingQueue<T> {
    /// A queue holding at most `capacity` items (>= 1); storage is
    /// allocated once, here.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "ring capacity must be >= 1");
        RingQueue {
            state: Mutex::new(RingState {
                buf: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            not_empty: Condvar::new(),
            capacity: AtomicUsize::new(capacity),
        }
    }

    /// Maximum entries the ring currently admits.
    pub fn capacity(&self) -> usize {
        self.capacity.load(Ordering::Acquire)
    }

    /// Retune the capacity bound (>= 1). Takes effect on subsequent
    /// pushes; shrinking below the current occupancy drops nothing —
    /// pushes fail [`PushError::Full`] until consumers drain below the
    /// new bound.
    pub fn set_capacity(&self, capacity: usize) {
        assert!(capacity >= 1, "ring capacity must be >= 1");
        self.capacity.store(capacity, Ordering::Release);
    }

    /// Entries currently queued.
    pub fn len(&self) -> usize {
        self.state.lock().expect("ring poisoned").buf.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once [`RingQueue::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.state.lock().expect("ring poisoned").closed
    }

    /// Non-blocking push.
    pub fn try_push(&self, v: T) -> Result<(), PushError<T>> {
        let mut st = self.state.lock().expect("ring poisoned");
        if st.closed {
            return Err(PushError::Closed(v));
        }
        if st.buf.len() >= self.capacity() {
            return Err(PushError::Full(v));
        }
        st.buf.push_back(v);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking pop. `Err(Closed)` means closed and drained.
    pub fn try_pop(&self) -> Result<T, PopError> {
        let mut st = self.state.lock().expect("ring poisoned");
        match st.buf.pop_front() {
            Some(v) => Ok(v),
            None if st.closed => Err(PopError::Closed),
            None => Err(PopError::Empty),
        }
    }

    /// Pop, blocking up to `timeout` for an item. `Err(Empty)` on timeout,
    /// `Err(Closed)` once closed and drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Result<T, PopError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().expect("ring poisoned");
        loop {
            if let Some(v) = st.buf.pop_front() {
                return Ok(v);
            }
            if st.closed {
                return Err(PopError::Closed);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(PopError::Empty);
            }
            let (guard, _timed_out) = self
                .not_empty
                .wait_timeout(st, deadline - now)
                .expect("ring poisoned");
            st = guard;
        }
    }

    /// Stop producers: subsequent pushes fail, pops drain the remainder.
    /// Idempotent; wakes every blocked popper.
    pub fn close(&self) {
        let mut st = self.state.lock().expect("ring poisoned");
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
    }
}

struct ParkState {
    token: Mutex<bool>,
    cv: Condvar,
}

/// Owner half of a one-token parker; hand out [`Unparker`]s to wakers.
pub struct Parker {
    inner: Arc<ParkState>,
}

/// Waker half; cheap to clone and `Send`.
#[derive(Clone)]
pub struct Unparker {
    inner: Arc<ParkState>,
}

impl Default for Parker {
    fn default() -> Self {
        Self::new()
    }
}

impl Parker {
    /// A parker with no token pending.
    pub fn new() -> Parker {
        Parker {
            inner: Arc::new(ParkState { token: Mutex::new(false), cv: Condvar::new() }),
        }
    }

    /// A cloneable wake handle for this parker.
    pub fn unparker(&self) -> Unparker {
        Unparker { inner: Arc::clone(&self.inner) }
    }

    /// Block until a token is available, then consume it.
    pub fn park(&self) {
        let mut token = self.inner.token.lock().expect("parker poisoned");
        while !*token {
            token = self.inner.cv.wait(token).expect("parker poisoned");
        }
        *token = false;
    }

    /// Like [`Parker::park`] but gives up after `timeout`. Returns `true`
    /// if a token was consumed, `false` on timeout.
    pub fn park_timeout(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut token = self.inner.token.lock().expect("parker poisoned");
        while !*token {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _timed_out) = self
                .inner
                .cv
                .wait_timeout(token, deadline - now)
                .expect("parker poisoned");
            token = guard;
        }
        *token = false;
        true
    }
}

impl Unparker {
    /// Deposit the token (idempotent) and wake the parked owner if any.
    pub fn unpark(&self) {
        let mut token = self.inner.token.lock().expect("parker poisoned");
        *token = true;
        drop(token);
        self.inner.cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn bounded_capacity_rejects_at_cap() {
        let q = RingQueue::new(2);
        assert_eq!(q.try_push(1), Ok(()));
        assert_eq!(q.try_push(2), Ok(()));
        assert_eq!(q.try_push(3), Err(PushError::Full(3)));
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Ok(1));
        assert_eq!(q.try_push(3), Ok(()));
        assert_eq!(q.try_pop(), Ok(2));
        assert_eq!(q.try_pop(), Ok(3));
        assert_eq!(q.try_pop(), Err(PopError::Empty));
    }

    #[test]
    fn set_capacity_retunes_without_dropping() {
        let q = RingQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(PushError::Full(3)));
        // Grow: the next push fits immediately.
        q.set_capacity(4);
        assert_eq!(q.capacity(), 4);
        q.try_push(3).unwrap();
        // Shrink below occupancy: nothing queued is lost, but pushes
        // fail until consumers drain under the new bound.
        q.set_capacity(1);
        assert_eq!(q.len(), 3, "shrink must not drop queued items");
        assert_eq!(q.try_push(4), Err(PushError::Full(4)));
        assert_eq!(q.try_pop(), Ok(1));
        assert_eq!(q.try_pop(), Ok(2));
        assert_eq!(q.try_pop(), Ok(3));
        q.try_push(4).unwrap();
        assert_eq!(q.try_push(5), Err(PushError::Full(5)));
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q = RingQueue::new(4);
        q.try_push("a").unwrap();
        q.try_push("b").unwrap();
        q.close();
        assert_eq!(q.try_push("c"), Err(PushError::Closed("c")));
        // Items queued before close are still delivered, in order.
        assert_eq!(q.try_pop(), Ok("a"));
        assert_eq!(q.try_pop(), Ok("b"));
        assert_eq!(q.try_pop(), Err(PopError::Closed));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Err(PopError::Closed));
    }

    #[test]
    fn pop_timeout_times_out_then_receives() {
        let q = Arc::new(RingQueue::new(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(5)), Err(PopError::Empty));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            q2.try_push(99u64).unwrap();
        });
        assert_eq!(q.pop_timeout(Duration::from_secs(2)), Ok(99));
        h.join().unwrap();
    }

    #[test]
    fn close_wakes_blocked_popper() {
        let q = Arc::new(RingQueue::<u8>::new(1));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_timeout(Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), Err(PopError::Closed));
    }

    #[test]
    fn parker_token_prevents_lost_wakeup() {
        let p = Parker::new();
        // Token deposited before park: the next park returns immediately.
        p.unparker().unpark();
        p.unparker().unpark(); // idempotent — still one token
        assert!(p.park_timeout(Duration::from_millis(1)));
        // Token consumed: the next park times out.
        assert!(!p.park_timeout(Duration::from_millis(5)));
    }

    #[test]
    fn parker_wakes_across_threads() {
        let p = Parker::new();
        let u = p.unparker();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            u.unpark();
        });
        assert!(p.park_timeout(Duration::from_secs(2)));
        h.join().unwrap();
    }

    /// Multi-threaded property: with P producers each pushing a tagged
    /// sequence and C consumers draining, no token is lost or duplicated,
    /// and within each consumer's pop stream every producer's sequence is
    /// strictly increasing (per-producer FIFO — the strongest order an
    /// MPMC queue promises; the global interleaving across consumers is
    /// unordered by design).
    #[test]
    fn propcheck_no_loss_no_dup_per_producer_fifo() {
        check("ring MPMC invariants", 12, |g| {
            let producers = g.usize(1, 4);
            let consumers = g.usize(1, 4);
            let per_producer = g.usize(1, 120);
            let capacity = g.usize(1, 16);
            let total = producers * per_producer;

            let q = RingQueue::new(capacity);
            let popped = AtomicUsize::new(0);

            // One pop stream per consumer, returned through the scope.
            let streams: Vec<Vec<(usize, usize)>> = std::thread::scope(|s| {
                for pid in 0..producers {
                    let q = &q;
                    s.spawn(move || {
                        for seq in 0..per_producer {
                            let mut item = (pid, seq);
                            loop {
                                match q.try_push(item) {
                                    Ok(()) => break,
                                    Err(PushError::Full(v)) => {
                                        item = v;
                                        std::thread::yield_now();
                                    }
                                    Err(PushError::Closed(_)) => {
                                        panic!("queue closed mid-produce")
                                    }
                                }
                            }
                        }
                    });
                }
                let handles: Vec<_> = (0..consumers)
                    .map(|_| {
                        let q = &q;
                        let popped = &popped;
                        s.spawn(move || {
                            let mut local = Vec::new();
                            loop {
                                if popped.load(Ordering::SeqCst) >= total {
                                    break;
                                }
                                match q.try_pop() {
                                    Ok(item) => {
                                        popped.fetch_add(1, Ordering::SeqCst);
                                        local.push(item);
                                    }
                                    Err(PopError::Empty) => std::thread::yield_now(),
                                    Err(PopError::Closed) => break,
                                }
                            }
                            local
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });

            // No loss, no duplication: exact multiset across all streams.
            let mut by_pid: Vec<Vec<usize>> = vec![Vec::new(); producers];
            for stream in &streams {
                // Per-producer FIFO within each consumer's stream.
                let mut last = vec![None::<usize>; producers];
                for &(pid, seq) in stream {
                    if let Some(prev) = last[pid] {
                        assert!(seq > prev, "producer {pid}: {seq} after {prev}");
                    }
                    last[pid] = Some(seq);
                    by_pid[pid].push(seq);
                }
            }
            for (pid, seqs) in by_pid.iter_mut().enumerate() {
                seqs.sort_unstable();
                assert_eq!(
                    *seqs,
                    (0..per_producer).collect::<Vec<_>>(),
                    "producer {pid}: lost or duplicated tokens"
                );
            }
        });
    }

    /// Under contention the occupancy bound must hold at every instant the
    /// lock is released; sampling `len()` from a racing thread can never
    /// observe more than `capacity`.
    #[test]
    fn propcheck_occupancy_never_exceeds_capacity() {
        check("ring occupancy bound", 8, |g| {
            let capacity = g.usize(1, 8);
            let q = RingQueue::new(capacity);
            let done = AtomicUsize::new(0);
            std::thread::scope(|s| {
                let done = &done;
                for _ in 0..2 {
                    let q = &q;
                    s.spawn(move || {
                        for i in 0..500u32 {
                            let _ = q.try_push(i);
                            if i % 3 == 0 {
                                let _ = q.try_pop();
                            }
                        }
                        done.fetch_add(1, Ordering::SeqCst);
                    });
                }
                let q = &q;
                s.spawn(move || {
                    while done.load(Ordering::SeqCst) < 2 {
                        assert!(q.len() <= capacity, "occupancy over capacity");
                    }
                });
            });
        });
    }
}
