//! Native model builders: the canonical LeNet-5 (must agree with
//! `python/compile/model.py::LAYERS` — checked by the integration tests
//! against the exported graph.json) plus parametric generators used by the
//! DSE/simulator test suites and the scaling ablations.

use super::{Graph, Node, Op};

/// A fluent chain builder that tracks the running stream shape.
pub struct ChainBuilder {
    nodes: Vec<Node>,
    ch: usize,
    dim: usize,
    counter: usize,
}

impl ChainBuilder {
    /// Start from an input of `ch` channels at `dim`x`dim` (dim=1 for
    /// vector inputs).
    pub fn input(ch: usize, dim: usize) -> Self {
        ChainBuilder { nodes: Vec::new(), ch, dim, counter: 0 }
    }

    fn next_name(&mut self, prefix: &str) -> String {
        self.counter += 1;
        format!("{prefix}{}", self.counter)
    }

    /// Append an auto-named VALID conv of `cout` channels, kernel `k`.
    pub fn conv(mut self, cout: usize, k: usize) -> Self {
        let name = self.next_name("conv");
        let ifm = self.dim;
        assert!(ifm >= k, "conv '{name}': input {ifm} smaller than kernel {k}");
        let ofm = ifm - k + 1;
        self.nodes.push(Node { name, op: Op::Conv, cin: self.ch, cout, k, ifm, ofm });
        self.ch = cout;
        self.dim = ofm;
        self
    }

    /// Append a named VALID conv of `cout` channels, kernel `k`.
    pub fn named_conv(mut self, name: &str, cout: usize, k: usize) -> Self {
        let ifm = self.dim;
        assert!(ifm >= k, "conv '{name}': input {ifm} smaller than kernel {k}");
        let ofm = ifm - k + 1;
        self.nodes.push(Node {
            name: name.to_string(),
            op: Op::Conv,
            cin: self.ch,
            cout,
            k,
            ifm,
            ofm,
        });
        self.ch = cout;
        self.dim = ofm;
        self
    }

    /// Append a named max-pool with window = stride = `k`.
    pub fn maxpool(mut self, name: &str, k: usize) -> Self {
        let ifm = self.dim;
        let ofm = ifm / k;
        self.nodes.push(Node {
            name: name.to_string(),
            op: Op::MaxPool,
            cin: self.ch,
            cout: self.ch,
            k,
            ifm,
            ofm,
        });
        self.dim = ofm;
        self
    }

    /// Append a named fully connected layer of `out` features
    /// (flattens the running stream shape).
    pub fn fc(mut self, name: &str, out: usize) -> Self {
        let cin = self.ch * self.dim * self.dim;
        self.nodes.push(Node {
            name: name.to_string(),
            op: Op::Fc,
            cin,
            cout: out,
            k: 1,
            ifm: 1,
            ofm: 1,
        });
        self.ch = out;
        self.dim = 1;
        self
    }

    /// Finish the chain into a [`Graph`] with the given metadata.
    pub fn build(self, model: &str, input: Vec<usize>, wbits: usize, abits: usize) -> Graph {
        let out = self.ch * self.dim * self.dim;
        Graph {
            model: model.to_string(),
            input,
            output: vec![1, out],
            weight_bits: wbits,
            act_bits: abits,
            nodes: self.nodes,
        }
    }
}

/// The paper's LeNet-5 (W4A4, 28x28x1) — single source of truth on the
/// rust side, cross-checked against python's export.
pub fn lenet5() -> Graph {
    ChainBuilder::input(1, 28)
        .named_conv("conv1", 6, 5)
        .maxpool("conv1_pool", 2)
        .named_conv("conv2", 16, 5)
        .maxpool("conv2_pool", 2)
        .fc("fc1", 120)
        .fc("fc2", 84)
        .fc("fc3", 10)
        .build("lenet5", vec![1, 28, 28, 1], 4, 4)
}

/// A 3-layer MLP — minimal chain for unit tests.
pub fn mlp(inp: usize, hidden: usize, out: usize) -> Graph {
    ChainBuilder::input(inp, 1)
        .fc("fc1", hidden)
        .fc("fc2", hidden)
        .fc("fc3", out)
        .build("mlp", vec![1, inp], 4, 4)
}

/// A parametric VGG-ish conv stack for DSE scaling tests: `blocks` of
/// (conv k3, pool2) starting at `ch0` channels, doubling per block, then a
/// classifier head.
pub fn convnet(blocks: usize, ch0: usize, img: usize, classes: usize) -> Graph {
    assert!(blocks >= 1);
    let mut b = ChainBuilder::input(3, img);
    let mut ch = ch0;
    for i in 0..blocks {
        b = b.named_conv(&format!("conv{}", i + 1), ch, 3);
        b = b.maxpool(&format!("pool{}", i + 1), 2);
        ch *= 2;
    }
    b.fc("head", classes).build("convnet", vec![1, img, img, 3], 4, 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_validates() {
        lenet5().validate().unwrap();
    }

    #[test]
    fn lenet_shapes() {
        let g = lenet5();
        let names: Vec<_> = g.nodes.iter().map(|n| n.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["conv1", "conv1_pool", "conv2", "conv2_pool", "fc1", "fc2", "fc3"]
        );
        assert_eq!(g.node("conv2").unwrap().ifm, 12);
        assert_eq!(g.node("conv2").unwrap().ofm, 8);
        assert_eq!(g.node("fc1").unwrap().cin, 256);
    }

    #[test]
    fn mlp_validates() {
        let g = mlp(64, 32, 10);
        g.validate().unwrap();
        assert_eq!(g.total_weights(), 64 * 32 + 32 * 32 + 32 * 10);
    }

    #[test]
    fn convnet_validates_multiple_sizes() {
        for blocks in 1..=3 {
            let g = convnet(blocks, 8, 32, 10);
            g.validate().unwrap();
            assert_eq!(g.mac_nodes().count(), blocks + 1);
        }
    }

    #[test]
    fn convnet_channel_doubling() {
        let g = convnet(3, 8, 32, 10);
        assert_eq!(g.node("conv1").unwrap().cout, 8);
        assert_eq!(g.node("conv2").unwrap().cout, 16);
        assert_eq!(g.node("conv3").unwrap().cout, 32);
    }
}
