//! ONNX-like layer graph of the QNN (substrate S4).
//!
//! The paper's DSE works on "the ONNX graph" of the model; this module is
//! that graph: a linear chain of dataflow stages (LeNet-class models are
//! chains; the representation allows any chain of conv/pool/fc). Imported
//! from the python exporter (`graph.json`) or built natively by
//! [`builder`]; the integration tests assert the two agree node-for-node.

pub mod builder;
pub mod import;

use crate::util::error::{Error, Result};

/// Operator kind of a dataflow stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// VALID 2-D convolution, square kernel `k`.
    Conv,
    /// Fully connected (matrix–vector per frame).
    Fc,
    /// Max pooling, square window `k`, stride `k`.
    MaxPool,
}

impl Op {
    /// Canonical `graph.json` name of the operator.
    pub fn as_str(&self) -> &'static str {
        match self {
            Op::Conv => "conv",
            Op::Fc => "fc",
            Op::MaxPool => "maxpool",
        }
    }

    /// Parse a canonical operator name.
    pub fn parse(s: &str) -> Result<Op> {
        match s {
            "conv" => Ok(Op::Conv),
            "fc" => Ok(Op::Fc),
            "maxpool" => Ok(Op::MaxPool),
            other => Err(Error::graph(format!("unknown op '{other}'"))),
        }
    }

    /// Does this stage perform MACs (and therefore carry weights)?
    pub fn has_weights(&self) -> bool {
        matches!(self, Op::Conv | Op::Fc)
    }
}

/// One dataflow stage.
#[derive(Debug, Clone, PartialEq)]
pub struct Node {
    /// Unique layer name.
    pub name: String,
    /// Operator kind.
    pub op: Op,
    /// Input channels (fc: input features).
    pub cin: usize,
    /// Output channels (fc: output features).
    pub cout: usize,
    /// Square kernel size (fc: 1).
    pub k: usize,
    /// Input spatial dim (fc: 1).
    pub ifm: usize,
    /// Output spatial dim (fc: 1).
    pub ofm: usize,
}

impl Node {
    /// Number of weights in this stage.
    pub fn weights(&self) -> usize {
        if self.op.has_weights() {
            self.cout * self.cin * self.k * self.k
        } else {
            0
        }
    }

    /// MACs per inference frame.
    pub fn macs_per_frame(&self) -> usize {
        match self.op {
            Op::Conv => self.ofm * self.ofm * self.weights(),
            Op::Fc => self.weights(),
            Op::MaxPool => 0,
        }
    }

    /// Output pixels per frame (1 for fc).
    pub fn out_pixels(&self) -> usize {
        self.ofm * self.ofm
    }

    /// SIMD (input-parallelism) axis extent: K²·Cin for conv, IN for fc.
    pub fn fold_in(&self) -> usize {
        match self.op {
            Op::Conv => self.k * self.k * self.cin,
            Op::Fc => self.cin,
            Op::MaxPool => self.cin,
        }
    }

    /// PE (output-parallelism) axis extent.
    pub fn fold_out(&self) -> usize {
        self.cout
    }

    /// Elements streamed out per frame.
    pub fn out_elements(&self) -> usize {
        self.out_pixels() * self.cout
    }

    fn validate(&self) -> Result<()> {
        let e = |m: String| Err(Error::Graph(m));
        if self.cin == 0 || self.cout == 0 || self.k == 0 || self.ifm == 0 || self.ofm == 0 {
            return e(format!("{}: zero dimension", self.name));
        }
        match self.op {
            Op::Conv => {
                if self.ifm < self.k {
                    return e(format!("{}: ifm {} < k {}", self.name, self.ifm, self.k));
                }
                if self.ofm != self.ifm - self.k + 1 {
                    return e(format!(
                        "{}: VALID conv shape mismatch: ofm {} != ifm {} - k {} + 1",
                        self.name, self.ofm, self.ifm, self.k
                    ));
                }
            }
            Op::MaxPool => {
                if self.cin != self.cout {
                    return e(format!("{}: pool must preserve channels", self.name));
                }
                if self.ofm != self.ifm / self.k {
                    return e(format!(
                        "{}: pool shape mismatch: ofm {} != ifm {} / k {}",
                        self.name, self.ofm, self.ifm, self.k
                    ));
                }
            }
            Op::Fc => {
                if self.k != 1 || self.ifm != 1 || self.ofm != 1 {
                    return e(format!("{}: fc must have k=ifm=ofm=1", self.name));
                }
            }
        }
        Ok(())
    }
}

/// A dataflow model: metadata + an ordered chain of stages.
#[derive(Debug, Clone, PartialEq)]
pub struct Graph {
    /// Model name (e.g. "lenet5").
    pub model: String,
    /// Input tensor shape (NHWC, batch omitted).
    pub input: Vec<usize>,
    /// Output tensor shape.
    pub output: Vec<usize>,
    /// Weight quantisation width the model was trained at.
    pub weight_bits: usize,
    /// Activation quantisation width the model was trained at.
    pub act_bits: usize,
    /// The stage chain in stream order.
    pub nodes: Vec<Node>,
}

impl Graph {
    /// Validate per-node shapes and inter-node stream compatibility.
    pub fn validate(&self) -> Result<()> {
        if self.nodes.is_empty() {
            return Err(Error::graph("empty graph"));
        }
        for n in &self.nodes {
            n.validate()?;
        }
        for w in self.nodes.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            match b.op {
                Op::Conv | Op::MaxPool => {
                    if a.cout != b.cin {
                        return Err(Error::graph(format!(
                            "{} -> {}: channel mismatch {} vs {}",
                            a.name, b.name, a.cout, b.cin
                        )));
                    }
                    if a.op != Op::Fc && a.ofm != b.ifm {
                        return Err(Error::graph(format!(
                            "{} -> {}: spatial mismatch {} vs {}",
                            a.name, b.name, a.ofm, b.ifm
                        )));
                    }
                }
                Op::Fc => {
                    let flat = a.out_elements();
                    if flat != b.cin {
                        return Err(Error::graph(format!(
                            "{} -> {}: flatten mismatch {} vs {}",
                            a.name, b.name, flat, b.cin
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// The node called `name`, or a graph error.
    pub fn node(&self, name: &str) -> Result<&Node> {
        self.nodes
            .iter()
            .find(|n| n.name == name)
            .ok_or_else(|| Error::graph(format!("no node '{name}'")))
    }

    /// MAC stages only (the ones folding/sparsity apply to).
    pub fn mac_nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| n.op.has_weights())
    }

    /// Dense weight count across every stage.
    pub fn total_weights(&self) -> usize {
        self.nodes.iter().map(|n| n.weights()).sum()
    }

    /// Dense MACs per frame across every stage.
    pub fn total_macs_per_frame(&self) -> usize {
        self.nodes.iter().map(|n| n.macs_per_frame()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::builder::lenet5;
    use super::*;

    #[test]
    fn lenet_totals_match_paper_arithmetic() {
        // DESIGN.md §7: 44,190 weights, 281,640 MACs/frame.
        let g = lenet5();
        g.validate().unwrap();
        assert_eq!(g.total_weights(), 44_190);
        assert_eq!(g.total_macs_per_frame(), 281_640);
    }

    #[test]
    fn per_layer_weights() {
        let g = lenet5();
        assert_eq!(g.node("conv1").unwrap().weights(), 150);
        assert_eq!(g.node("conv2").unwrap().weights(), 2_400);
        assert_eq!(g.node("fc1").unwrap().weights(), 30_720);
        assert_eq!(g.node("fc2").unwrap().weights(), 10_080);
        assert_eq!(g.node("fc3").unwrap().weights(), 840);
    }

    #[test]
    fn fold_axes() {
        let g = lenet5();
        let c1 = g.node("conv1").unwrap();
        assert_eq!(c1.fold_in(), 25);
        assert_eq!(c1.fold_out(), 6);
        assert_eq!(c1.out_pixels(), 576);
        let f1 = g.node("fc1").unwrap();
        assert_eq!(f1.fold_in(), 256);
        assert_eq!(f1.out_pixels(), 1);
    }

    #[test]
    fn validation_catches_breaks() {
        let mut g = lenet5();
        g.nodes[0].cout = 7; // conv1 now emits 7ch, pool expects 6
        assert!(g.validate().is_err());

        let mut g = lenet5();
        g.nodes[0].ofm = 23; // VALID shape broken
        assert!(g.validate().is_err());

        let mut g = lenet5();
        g.nodes.clear();
        assert!(g.validate().is_err());
    }

    #[test]
    fn flatten_edge_checked() {
        let mut g = lenet5();
        // fc1 expects 4*4*16 = 256 inputs.
        {
            let f1 = g.nodes.iter_mut().find(|n| n.name == "fc1").unwrap();
            f1.cin = 200;
        }
        assert!(g.validate().is_err());
    }

    #[test]
    fn op_roundtrip() {
        for op in [Op::Conv, Op::Fc, Op::MaxPool] {
            assert_eq!(Op::parse(op.as_str()).unwrap(), op);
        }
        assert!(Op::parse("softmax").is_err());
    }
}
