//! Import the python-exported `graph.json` (the compile path's ONNX-like
//! dump) into a [`Graph`]. Schema errors carry node names so a mismatched
//! exporter fails loudly at load time, not deep inside the DSE.

use super::{Graph, Node, Op};
use crate::util::error::{Error, Result};
use crate::util::json::{self, Value};

/// Parse a graph from a JSON value (see `python/compile/model.py::graph_dict`).
pub fn from_json(v: &Value) -> Result<Graph> {
    let nodes_v = v
        .req("nodes")?
        .as_arr()
        .ok_or_else(|| Error::graph("'nodes' is not an array"))?;

    let mut nodes = Vec::with_capacity(nodes_v.len());
    for nv in nodes_v {
        let name = nv.req_str("name")?.to_string();
        let node = Node {
            op: Op::parse(nv.req_str("op")?)
                .map_err(|e| Error::graph(format!("node '{name}': {e}")))?,
            cin: nv.req_usize("cin")?,
            cout: nv.req_usize("cout")?,
            k: nv.req_usize("k")?,
            ifm: nv.req_usize("ifm")?,
            ofm: nv.req_usize("ofm")?,
            name,
        };
        // Cross-check the exporter's derived fields when present: a
        // disagreement means the two layers' models have diverged.
        if let Some(w) = nv.get("weights").and_then(Value::as_usize) {
            if w != node.weights() {
                return Err(Error::graph(format!(
                    "node '{}': exporter says {} weights, rust derives {}",
                    node.name,
                    w,
                    node.weights()
                )));
            }
        }
        if let Some(m) = nv.get("macs_per_frame").and_then(Value::as_usize) {
            if m != node.macs_per_frame() {
                return Err(Error::graph(format!(
                    "node '{}': exporter says {} MACs, rust derives {}",
                    node.name,
                    m,
                    node.macs_per_frame()
                )));
            }
        }
        nodes.push(node);
    }

    let dims = |key: &str| -> Result<Vec<usize>> {
        v.req(key)?
            .as_arr()
            .ok_or_else(|| Error::graph(format!("'{key}' is not an array")))?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| Error::graph(format!("'{key}' has non-integer dim")))
            })
            .collect()
    };

    let g = Graph {
        model: v.req_str("model")?.to_string(),
        input: dims("input")?,
        output: dims("output")?,
        weight_bits: v.req_usize("weight_bits")?,
        act_bits: v.req_usize("act_bits")?,
        nodes,
    };
    g.validate()?;
    Ok(g)
}

/// Load `graph.json` from disk.
pub fn load(path: impl AsRef<std::path::Path>) -> Result<Graph> {
    from_json(&json::parse_file(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::lenet5;

    /// Emit the same JSON shape python produces, from a native graph.
    fn to_json(g: &Graph) -> Value {
        let nodes = g
            .nodes
            .iter()
            .map(|n| {
                json::obj(vec![
                    ("name", json::s(n.name.clone())),
                    ("op", json::s(n.op.as_str())),
                    ("cin", json::num(n.cin as f64)),
                    ("cout", json::num(n.cout as f64)),
                    ("k", json::num(n.k as f64)),
                    ("ifm", json::num(n.ifm as f64)),
                    ("ofm", json::num(n.ofm as f64)),
                    ("weights", json::num(n.weights() as f64)),
                    ("macs_per_frame", json::num(n.macs_per_frame() as f64)),
                ])
            })
            .collect();
        json::obj(vec![
            ("model", json::s(g.model.clone())),
            ("input", json::arr(g.input.iter().map(|&d| json::num(d as f64)).collect())),
            ("output", json::arr(g.output.iter().map(|&d| json::num(d as f64)).collect())),
            ("weight_bits", json::num(g.weight_bits as f64)),
            ("act_bits", json::num(g.act_bits as f64)),
            ("nodes", Value::Arr(nodes)),
        ])
    }

    #[test]
    fn roundtrip_via_json() {
        let g = lenet5();
        let v = to_json(&g);
        let text = v.to_string_pretty();
        let g2 = from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn rejects_derived_field_mismatch() {
        let g = lenet5();
        let mut v = to_json(&g);
        // Corrupt conv1's weight count.
        if let Value::Obj(kv) = &mut v {
            if let Some((_, Value::Arr(nodes))) = kv.iter_mut().find(|(k, _)| k == "nodes") {
                if let Value::Obj(n0) = &mut nodes[0] {
                    for (k, val) in n0.iter_mut() {
                        if k == "weights" {
                            *val = Value::Num(999.0);
                        }
                    }
                }
            }
        }
        let err = from_json(&v).unwrap_err();
        assert!(err.to_string().contains("conv1"), "{err}");
    }

    #[test]
    fn rejects_missing_keys() {
        let v = json::parse(r#"{"model": "x"}"#).unwrap();
        assert!(from_json(&v).is_err());
    }
}
