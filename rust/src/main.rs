//! `logicsparse` — CLI for the LogicSparse reproduction.
//!
//! Subcommands mirror the Fig. 1 workflow plus deployment:
//!
//! ```text
//! logicsparse dse      run the DSE, write artifacts/folding_config.json
//! logicsparse table1   regenerate Table I (estimates + simulator)
//! logicsparse fig2     regenerate Fig. 2 per-layer series
//! logicsparse sim      simulate one strategy under a traffic model
//! logicsparse serve    serve the AOT artifacts through the coordinator
//! logicsparse pareto   sweep budgets -> Pareto frontier ablation
//! ```
//!
//! Observability (`serve --trace`, `serve --metrics-interval`,
//! `trace-validate`) is documented in the README's operator guide.

use logicsparse::config::{PolicyConfig, PruneProfile};
use logicsparse::coordinator::{
    AutotuneConfig, BatchPolicy, EngineBackend, Fleet, FleetOptions, ModelSpec, Server,
    ServerOptions,
};
use logicsparse::dse::{self, DseOptions, Strategy};
use logicsparse::experiments::{fig2, headline, table1, Accuracies};
use logicsparse::graph::builder::lenet5;
use logicsparse::kernel::{self, CompiledModel, Flavour, KernelSpec};
use logicsparse::obs::{metrics::Registry, trace::Tracer, ObsConfig};
use logicsparse::util::cli::{self, Opt};
use logicsparse::util::error::Result;
use logicsparse::util::lstw::Store;
use logicsparse::weights::ModelParams;
use logicsparse::{device, graph, runtime, sim};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

const GLOBAL_USAGE: &str =
    "logicsparse <dse|table1|fig2|sim|serve|pareto|bench-compare|trace-validate> [options]
Run `logicsparse <cmd> --help` for per-command options.";

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        println!("{GLOBAL_USAGE}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "dse" => cmd_dse(rest),
        "table1" => cmd_table1(rest),
        "fig2" => cmd_fig2(rest),
        "sim" => cmd_sim(rest),
        "serve" => cmd_serve(rest),
        "pareto" => cmd_pareto(rest),
        "bench-compare" => cmd_bench_compare(rest),
        "trace-validate" => cmd_trace_validate(rest),
        "--help" | "-h" | "help" => {
            println!("{GLOBAL_USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{GLOBAL_USAGE}");
            Ok(())
        }
    }
}

fn common_opts() -> Vec<Opt> {
    vec![
        Opt { name: "device", takes_value: true, default: Some("xcu50"), help: "target device (xcu50|zcu104|tiny)" },
        Opt { name: "artifacts", takes_value: true, default: Some("artifacts"), help: "artifacts directory" },
        Opt { name: "help", takes_value: false, default: None, help: "show usage" },
    ]
}

/// Load graph + prune profile from artifacts when present, otherwise fall
/// back to the native LeNet-5 builder and a uniform reference profile.
fn load_inputs(artifacts: &str) -> Result<(graph::Graph, PruneProfile)> {
    let gpath = std::path::Path::new(artifacts).join("graph.json");
    let g = if gpath.exists() {
        graph::import::load(&gpath)?
    } else {
        eprintln!("note: {} missing, using built-in LeNet-5 graph", gpath.display());
        lenet5()
    };
    let ppath = std::path::Path::new(artifacts).join("prune_profile.json");
    let profile = if ppath.exists() {
        PruneProfile::load(&ppath)?
    } else {
        eprintln!("note: {} missing, using uniform 0.8 pruning profile", ppath.display());
        PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95)
    };
    Ok((g, profile))
}

fn cmd_dse(argv: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.extend([
        Opt { name: "strategy", takes_value: true, default: Some("proposed"), help: "strategy to emit" },
        Opt { name: "target-fps", takes_value: true, default: None, help: "auto-fold throughput target" },
        Opt { name: "budget-fraction", takes_value: true, default: None, help: "fraction of device LUTs usable" },
        Opt { name: "min-accuracy", takes_value: true, default: None, help: "pruning-reference accuracy floor" },
        Opt { name: "verbose", takes_value: false, default: None, help: "print the full DSE trace" },
        Opt { name: "out", takes_value: true, default: None, help: "output path (default <artifacts>/folding_config.json)" },
    ]);
    let a = cli::parse(argv, &opts)?;
    if a.flag("help") {
        println!("{}", cli::usage("dse", "run the LogicSparse design-space exploration", &opts));
        return Ok(());
    }
    let dev = device::by_name(a.req("device")?)?;
    let artifacts = a.req("artifacts")?;
    let (g, profile) = load_inputs(artifacts)?;
    let strategy = Strategy::parse(a.req("strategy")?)?;
    let mut dopts = DseOptions::default();
    if let Some(t) = a.get_f64("target-fps")? {
        dopts.auto_fold_target_fps = t;
    }
    if let Some(b) = a.get_f64("budget-fraction")? {
        dopts.budget_fraction = b;
    }
    if let Some(m) = a.get_f64("min-accuracy")? {
        dopts.min_reference_accuracy = m;
    }

    let result = dse::run(strategy, &g, &dev, &profile, &dopts)?;
    if a.flag("verbose") {
        println!("{}", result.report.render());
    } else if let Some(sum) = &result.report.final_summary {
        println!("{sum}");
    }
    for (name, f) in &result.folding.layers {
        println!(
            "  {name:<8} {:<16} PE={:<4} SIMD={:<4} s={:.2}  serves as {}",
            f.style.as_str(),
            f.pe,
            f.simd,
            f.sparsity,
            kernel::served_flavour(f.style)
        );
    }
    let out = a
        .get("out")
        .map(|s| s.to_string())
        .unwrap_or_else(|| format!("{artifacts}/folding_config.json"));
    result.to_file(&dev).save(&out)?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_table1(argv: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.push(Opt { name: "frames", takes_value: true, default: Some("200"), help: "simulated frames per row" });
    let a = cli::parse(argv, &opts)?;
    if a.flag("help") {
        println!("{}", cli::usage("table1", "regenerate Table I", &opts));
        return Ok(());
    }
    let dev = device::by_name(a.req("device")?)?;
    let artifacts = a.req("artifacts")?;
    let (g, profile) = load_inputs(artifacts)?;
    let acc = Accuracies::load(artifacts)?;
    let frames = a.get_usize("frames")?.unwrap_or(200) as u64;

    let rows = table1::measure(&g, &dev, &profile, &acc, frames)?;
    println!("{}", table1::render(&rows));
    for v in table1::shape_checks(&rows) {
        println!("{v}");
    }
    let h = headline::measure(&rows, artifacts)?;
    println!();
    println!("{}", headline::render(&h));
    Ok(())
}

fn cmd_fig2(argv: &[String]) -> Result<()> {
    let opts = common_opts();
    let a = cli::parse(argv, &opts)?;
    if a.flag("help") {
        println!("{}", cli::usage("fig2", "regenerate Fig. 2 per-layer series", &opts));
        return Ok(());
    }
    let dev = device::by_name(a.req("device")?)?;
    let (g, profile) = load_inputs(a.req("artifacts")?)?;
    let series = fig2::measure(&g, &dev, &profile)?;
    println!("{}", fig2::render(&series));
    for v in fig2::shape_checks(&series) {
        println!("{v}");
    }
    Ok(())
}

fn cmd_sim(argv: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.extend([
        Opt { name: "strategy", takes_value: true, default: Some("proposed"), help: "strategy to simulate" },
        Opt { name: "frames", takes_value: true, default: Some("500"), help: "frames" },
        Opt { name: "traffic", takes_value: true, default: Some("saturated"), help: "saturated|poisson:<fps>|periodic:<cycles>|burst:<size>:<gap_cycles>" },
        Opt { name: "fifo-depth", takes_value: true, default: Some("8"), help: "inter-stage FIFO depth" },
    ]);
    let a = cli::parse(argv, &opts)?;
    if a.flag("help") {
        println!("{}", cli::usage("sim", "cycle-level simulation of one strategy", &opts));
        return Ok(());
    }
    let dev = device::by_name(a.req("device")?)?;
    let (g, profile) = load_inputs(a.req("artifacts")?)?;
    let strategy = Strategy::parse(a.req("strategy")?)?;
    let frames = a.get_usize("frames")?.unwrap_or(500) as u64;
    let depth = a.get_usize("fifo-depth")?.unwrap_or(8);

    let r = dse::run(strategy, &g, &dev, &profile, &DseOptions::default())?;
    let mut pipe = sim::build(&g, &r.folding, &dev, depth)?;
    // The spec grammar lives in the shared traffic module — the same
    // shapes the serving load generator replays.
    let wl = sim::Workload::parse(a.req("traffic")?, frames)?;
    let rep = pipe.try_run(&wl)?;
    println!("{}", rep.render());
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.extend([
        Opt { name: "tag", takes_value: true, default: Some("proposed"), help: "artifact tag to serve" },
        Opt { name: "requests", takes_value: true, default: Some("2048"), help: "requests to replay from the test set" },
        Opt { name: "max-batch", takes_value: true, default: Some("32"), help: "batcher max batch" },
        Opt { name: "max-wait-us", takes_value: true, default: Some("2000"), help: "batcher deadline (us)" },
        Opt { name: "engines", takes_value: true, default: Some("1"), help: "engine replicas" },
        Opt { name: "admission", takes_value: true, default: Some("1024"), help: "in-flight admission bound (overload sheds)" },
        Opt { name: "queue-depth", takes_value: true, default: Some("16"), help: "per-engine work-ring depth (batches)" },
        Opt { name: "synthetic-us", takes_value: true, default: None, help: "use the synthetic backend at this per-image cost (us) instead of artifacts" },
        Opt { name: "native-sparsity", takes_value: true, default: None, help: "serve baked native kernels at this unstructured sparsity (engine-free: no artifacts, no XLA)" },
        Opt { name: "pipeline", takes_value: true, default: None, help: "run native kernels layer-pipelined: 'auto' (groups + replication from the core budget), N (N stage groups, budget slack replicates bottlenecks), or NxR (N groups, costliest pinned to R workers); needs --native-sparsity" },
        Opt { name: "kernel", takes_value: true, default: Some("unrolled"), help: "kernel flavour for native kernels: auto (cost-model per-layer selection, prints the audit table)|dense|unrolled|block|nm (needs --native-sparsity)" },
        Opt { name: "model", takes_value: true, default: None, help: "repeatable fleet member 'tag=synthetic[:us]|native[:sparsity[:atag]]|artifacts[:atag]': serve a multi-model fleet behind one shared admission gate" },
        Opt { name: "slo", takes_value: true, default: None, help: "repeatable per-tag SLO 'tag=p99_ms[:weight]': partition the shared admission budget by weight (fleet mode)" },
        Opt { name: "autotune", takes_value: false, default: None, help: "enable queue-depth autotuning from queue-full/steal telemetry (fleet mode)" },
        Opt { name: "churn", takes_value: true, default: None, help: "live-membership demo: retire this tag halfway through the run and re-register it at 3/4 (fleet mode)" },
        Opt { name: "trace", takes_value: true, default: None, help: "record per-request trace events and write Chrome trace JSON to PATH[:sample_rate] at shutdown (rate in (0,1], default 1.0; sheds always recorded)" },
        Opt { name: "metrics-interval", takes_value: true, default: None, help: "attach the metrics registry and print a scrape every MS milliseconds (plus a final scrape at shutdown)" },
    ]);
    let a = cli::parse(argv, &opts)?;
    if a.flag("help") {
        println!("{}", cli::usage("serve", "serve AOT artifacts and replay the test set", &opts));
        return Ok(());
    }
    if !a.get_all("model").is_empty() {
        // Fleet mode: the single-model backend selectors would be
        // silently ignored, so reject the combination loudly.
        for conflicting in ["tag", "synthetic-us", "native-sparsity", "pipeline", "kernel"] {
            if !a.get_all(conflicting).is_empty() {
                return Err(logicsparse::Error::config(format!(
                    "--{conflicting} conflicts with --model; put the backend in the \
                     model spec instead (tag=synthetic[:us]|native[:sparsity[:atag]]|\
                     artifacts[:atag])"
                )));
            }
        }
        return cmd_serve_fleet(&a);
    }
    // The policy-control-plane options only make sense for a fleet.
    for fleet_only in ["slo", "churn"] {
        if !a.get_all(fleet_only).is_empty() {
            return Err(logicsparse::Error::config(format!(
                "--{fleet_only} needs fleet mode: add at least one --model"
            )));
        }
    }
    if a.flag("autotune") {
        return Err(logicsparse::Error::config(
            "--autotune needs fleet mode: add at least one --model",
        ));
    }
    let artifacts = a.req("artifacts")?;
    let tag = a.req("tag")?;
    let n_req = a.get_usize("requests")?.unwrap_or(2048);
    let px = runtime::IMG * runtime::IMG;

    // Backend + request stream: the exported test set through PJRT; with
    // --synthetic-us, generated images through the synthetic engine; with
    // --native-sparsity, baked sparse kernels compiled on the spot (the
    // labels come from the compiled model itself, so served classes are
    // checked against a local forward pass of the same artifact).
    let (backend, imgs, labels) = if let Some(s) = a.get_f64("native-sparsity")? {
        let flavour = Flavour::parse(a.req("kernel")?)?;
        let model = compile_native(artifacts, tag, s, flavour)?;
        println!(
            "native kernels ({}, datapath {}): {}",
            flavour.as_str(),
            model.datapath().label(),
            model.summary()
        );
        let n = 256usize;
        let (imgs, _) = runtime::SyntheticRuntime::dataset(n);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            labels.push(model.classify(&imgs[i * px..(i + 1) * px])? as i32);
        }
        let backend = match parse_pipeline_opt(&a)? {
            Some((stages, replicas)) => {
                match (stages, replicas) {
                    (0, _) => println!("pipeline: auto stage groups + replication (core budget)"),
                    (n, 0) => println!("pipeline: {n} stage groups (budget slack replicates bottlenecks)"),
                    (n, r) => println!("pipeline: {n} stage groups, costliest pinned to {r} workers"),
                }
                EngineBackend::NativePipelined { model, stages, replicas }
            }
            None => EngineBackend::Native { model },
        };
        (backend, imgs, labels)
    } else if !a.get_all("pipeline").is_empty() {
        return Err(logicsparse::Error::config(
            "--pipeline needs native kernels: add --native-sparsity",
        ));
    } else if !a.get_all("kernel").is_empty() {
        return Err(logicsparse::Error::config(
            "--kernel needs native kernels: add --native-sparsity",
        ));
    } else if let Some(us) = a.get_usize("synthetic-us")? {
        let (imgs, labels) = runtime::SyntheticRuntime::dataset(512);
        let backend = EngineBackend::Synthetic {
            per_image: Duration::from_micros(us as u64),
        };
        (backend, imgs, labels)
    } else {
        let ts = Store::read_file(std::path::Path::new(artifacts).join("testset.lstw"))?;
        let imgs = ts.req("images")?.data.as_f32()?.to_vec();
        let labels = ts.req("labels")?.data.as_i32()?.to_vec();
        let backend = EngineBackend::Artifacts {
            dir: artifacts.to_string(),
            tag: tag.to_string(),
        };
        (backend, imgs, labels)
    };
    let n_avail = labels.len();

    let setup = parse_obs_opts(&a)?;
    let server = Server::start(ServerOptions {
        policy: BatchPolicy {
            max_batch: a.get_usize("max-batch")?.unwrap_or(32),
            max_wait: Duration::from_micros(a.get_usize("max-wait-us")?.unwrap_or(2000) as u64),
        },
        engines: a.get_usize("engines")?.unwrap_or(1),
        backend,
        admission_capacity: a.get_usize("admission")?.unwrap_or(1024),
        queue_depth: a.get_usize("queue-depth")?.unwrap_or(16),
        obs: setup.obs.clone(),
    })?;
    println!("serving tag '{tag}' from {artifacts} ({n_avail} test images)");

    let mut correct = 0usize;
    let t0 = std::time::Instant::now();
    let stop = std::sync::atomic::AtomicBool::new(false);
    let served: Result<()> = std::thread::scope(|s| {
        setup.spawn_scraper(s, &stop);
        // Run the client loop in a closure so every exit path — errors
        // included — still stops the scraper before the scope joins it.
        let run = (|| -> Result<()> {
            let mut pending = Vec::new();
            for i in 0..n_req {
                let j = i % n_avail;
                // Closed-loop client: when admission sheds, back off and
                // retry.
                let rx = loop {
                    match server.submit(imgs[j * px..(j + 1) * px].to_vec()) {
                        Ok(rx) => break rx,
                        Err(logicsparse::Error::Overloaded) => std::thread::yield_now(),
                        Err(e) => return Err(e),
                    }
                };
                pending.push((rx, labels[j]));
                // Keep a bounded in-flight window, like a real client
                // pool.
                if pending.len() >= 256 {
                    for (rx, label) in pending.drain(..) {
                        let resp =
                            rx.recv().map_err(|_| logicsparse::Error::QueueClosed)?;
                        if resp.class() == label as usize {
                            correct += 1;
                        }
                    }
                }
            }
            for (rx, label) in pending.drain(..) {
                let resp = rx.recv().map_err(|_| logicsparse::Error::QueueClosed)?;
                if resp.class() == label as usize {
                    correct += 1;
                }
            }
            Ok(())
        })();
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        run
    });
    served?;
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.shutdown();
    println!("{}", snap.render());
    println!(
        "accuracy {:.2}% over {} requests | wall {:.2}s | {:.0} req/s",
        100.0 * correct as f64 / n_req as f64,
        n_req,
        wall,
        n_req as f64 / wall
    );
    setup.finish()
}

/// Observability wiring parsed from `serve`'s `--trace` /
/// `--metrics-interval` flags: the [`ObsConfig`] handed to the serving
/// plane plus the CLI-side halves (trace output path, scrape period).
struct ObsSetup {
    obs: ObsConfig,
    trace_path: Option<String>,
    metrics_interval: Option<Duration>,
}

/// Parse `--trace PATH[:sample_rate]` and `--metrics-interval MS` into
/// an [`ObsSetup`]. A `:suffix` that parses as f64 is the sample rate
/// (clamped to (0, 1]); otherwise the whole value is the path.
fn parse_obs_opts(a: &cli::Args) -> Result<ObsSetup> {
    let mut setup = ObsSetup {
        obs: ObsConfig::default(),
        trace_path: None,
        metrics_interval: None,
    };
    if let Some(v) = a.get("trace") {
        let (path, rate) = match v.rsplit_once(':') {
            Some((p, r)) if !p.is_empty() => match r.parse::<f64>() {
                Ok(rate) => (p.to_string(), rate),
                Err(_) => (v.to_string(), 1.0),
            },
            _ => (v.to_string(), 1.0),
        };
        if !(rate > 0.0 && rate <= 1.0) {
            return Err(logicsparse::Error::config(format!(
                "--trace sample rate must be in (0, 1], got {rate}"
            )));
        }
        setup.obs.tracer = Some(Tracer::new(rate));
        setup.trace_path = Some(path);
    }
    if let Some(ms) = a.get_usize("metrics-interval")? {
        if ms == 0 {
            return Err(logicsparse::Error::config(
                "--metrics-interval must be >= 1 ms",
            ));
        }
        setup.obs.metrics = Some(Registry::new());
        setup.metrics_interval = Some(Duration::from_millis(ms as u64));
    }
    Ok(setup)
}

impl ObsSetup {
    /// Spawn the periodic scrape printer inside `scope` (no-op without
    /// `--metrics-interval`); it stops when `stop` is set.
    fn spawn_scraper<'s, 'e: 's>(
        &'e self,
        scope: &'s std::thread::Scope<'s, 'e>,
        stop: &'e std::sync::atomic::AtomicBool,
    ) {
        use std::sync::atomic::Ordering;
        let (Some(reg), Some(iv)) = (&self.obs.metrics, self.metrics_interval) else {
            return;
        };
        scope.spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(iv);
                println!("[metrics]\n{}", reg.snapshot().render());
            }
        });
    }

    /// Shutdown-time reporting: the final metrics scrape, the Chrome
    /// trace file, and the trace-derived per-stage latency breakdown.
    fn finish(&self) -> Result<()> {
        if let Some(reg) = &self.obs.metrics {
            println!("[metrics] final scrape\n{}", reg.snapshot().render());
        }
        if let (Some(tracer), Some(path)) = (&self.obs.tracer, &self.trace_path) {
            tracer.write_chrome(path)?;
            println!(
                "trace: {} events recorded, {} dropped (sample rate {:.3}) -> {path}",
                tracer.recorded_events(),
                tracer.dropped_events(),
                tracer.sample_rate(),
            );
            let b = tracer.stage_breakdown();
            if b.spans > 0 {
                println!(
                    "trace: {} completed spans | mean queue {:.0}us | exec {:.0}us | \
                     total {:.0}us",
                    b.spans, b.queue_us, b.exec_us, b.total_us
                );
            }
        }
        Ok(())
    }
}

/// Parse `--pipeline auto|N[xR]` into `Some((stage_groups, replicas))`,
/// or `None` when the flag was not given. `auto` → `(0, 0)`: the
/// coordinator sizes groups from the per-engine core budget and spends
/// any slack on bottleneck replication. `N` → `(N, 0)`: N groups, auto
/// replication. `NxR` → `(N, R)`: N groups with the costliest group
/// pinned to R workers (clamped to the core budget downstream).
fn parse_pipeline_opt(a: &cli::Args) -> Result<Option<(usize, usize)>> {
    let Some(v) = a.get_all("pipeline").last() else {
        return Ok(None);
    };
    if v == "auto" {
        return Ok(Some((0, 0)));
    }
    let bad = || {
        logicsparse::Error::config(format!(
            "--pipeline expects 'auto', a stage-group count N, or NxR \
             (N groups, R workers on the costliest), got '{v}'"
        ))
    };
    if let Some((n, r)) = v.split_once('x') {
        let n = n.parse::<usize>().map_err(|_| bad())?;
        let r = r.parse::<usize>().map_err(|_| bad())?;
        if n == 0 || r == 0 {
            return Err(bad());
        }
        return Ok(Some((n, r)));
    }
    v.parse::<usize>().map(|n| Some((n, 0))).map_err(|_| bad())
}

/// Compile a baked native model for serving: artifact-backed params when
/// `params_<tag>.lstw` exists, synthetic weights otherwise, pruned to
/// `sparsity` and compiled to the requested kernel flavour. `auto` runs
/// the cost-model selection and prints its per-layer audit table.
fn compile_native(
    artifacts: &str,
    tag: &str,
    sparsity: f64,
    flavour: Flavour,
) -> Result<Arc<CompiledModel>> {
    let g = lenet5();
    let mut params = match ModelParams::load_artifacts(artifacts, tag, &g) {
        Ok(p) => p,
        Err(_) => {
            eprintln!("note: no params_{tag}.lstw — using synthetic weights");
            ModelParams::synthetic(&g, 17)
        }
    };
    params.prune_global(sparsity, 0.05)?;
    let spec = KernelSpec::default();
    let model = match flavour {
        Flavour::Auto => {
            let (model, choice) = CompiledModel::compile_auto(&g, &params, &spec)?;
            println!("{}", choice.render());
            println!("datapath: {} (inner-loop tier, all rows)", model.datapath().label());
            model
        }
        forced => CompiledModel::compile_with_choice(&g, &params, &spec, forced)?,
    };
    Ok(Arc::new(model))
}

/// How to check a fleet tag's served classes (None = no local oracle).
enum Oracle {
    /// Synthetic stripe-sum rule.
    Stripe,
    /// Local forward pass of the same compiled model.
    Native(Arc<CompiledModel>),
    /// PJRT artifacts: no engine-free oracle for synthetic inputs.
    None,
}

/// Parse one `--model` spec: `tag=synthetic[:us]` |
/// `tag=native[:sparsity[:atag]]` | `tag=artifacts[:atag]`.
///
/// `atag` names the artifact set on disk when it differs from the
/// routing tag — e.g. `a=native:0.5:proposed` and `b=native:0.9:proposed`
/// serve two sparsity variants of `params_proposed.lstw`.
fn parse_model_spec(
    spec: &str,
    artifacts: &str,
) -> Result<(String, EngineBackend, Oracle)> {
    let bad = || {
        logicsparse::Error::config(format!(
            "--model wants tag=synthetic[:us]|native[:sparsity[:atag]]|artifacts[:atag], \
             got '{spec}'"
        ))
    };
    let (tag, rest) = spec.split_once('=').ok_or_else(bad)?;
    if tag.is_empty() || rest.is_empty() {
        return Err(bad());
    }
    let (kind, param) = match rest.split_once(':') {
        Some((k, p)) => (k, Some(p)),
        None => (rest, None),
    };
    match kind {
        "synthetic" => {
            let us: u64 = match param {
                Some(p) => p.parse().map_err(|_| bad())?,
                None => 150,
            };
            let backend = EngineBackend::Synthetic { per_image: Duration::from_micros(us) };
            Ok((tag.to_string(), backend, Oracle::Stripe))
        }
        "native" => {
            let (sparsity, atag) = match param {
                Some(p) => {
                    let (s, atag) = match p.split_once(':') {
                        Some((s, atag)) if !atag.is_empty() => (s, atag),
                        Some(_) => return Err(bad()),
                        None => (p, tag),
                    };
                    (s.parse().map_err(|_| bad())?, atag)
                }
                None => (0.75, tag),
            };
            let model = compile_native(artifacts, atag, sparsity, Flavour::Unrolled)?;
            println!("[{tag}] native kernels: {}", model.summary());
            let backend = EngineBackend::Native { model: Arc::clone(&model) };
            Ok((tag.to_string(), backend, Oracle::Native(model)))
        }
        "artifacts" => {
            let atag = param.unwrap_or(tag);
            let backend = EngineBackend::Artifacts {
                dir: artifacts.to_string(),
                tag: atag.to_string(),
            };
            Ok((tag.to_string(), backend, Oracle::None))
        }
        _ => Err(bad()),
    }
}

/// `serve --model a=native:0.8 --model b=synthetic:100 ...`: start one
/// plane per tag behind the shared admission gate (with per-tag `--slo`
/// budgets and optional `--autotune` ring retuning), replay a
/// closed-loop round-robin request stream across the tags, and print the
/// fleet summary (per-tag stats roll-up plus accuracy where an oracle
/// exists). With `--churn <tag>` the run additionally demonstrates live
/// membership: the tag is retired (lossless drain) halfway through and
/// re-registered at three quarters.
fn cmd_serve_fleet(a: &cli::Args) -> Result<()> {
    let artifacts = a.req("artifacts")?;
    let n_req = a.get_usize("requests")?.unwrap_or(2048);
    let policy = BatchPolicy {
        max_batch: a.get_usize("max-batch")?.unwrap_or(32),
        max_wait: Duration::from_micros(a.get_usize("max-wait-us")?.unwrap_or(2000) as u64),
    };
    let engines = a.get_usize("engines")?.unwrap_or(1);
    let queue_depth = a.get_usize("queue-depth")?.unwrap_or(16);

    // Duplicate --model tags are a CLI error before anything spawns
    // (duplicate --slo tags are rejected by add_slo_arg below).
    cli::check_unique_keys("model", a.get_all("model"))?;
    let mut pcfg = PolicyConfig::default();
    for spec in a.get_all("slo") {
        pcfg.add_slo_arg(spec)?;
    }
    if a.flag("autotune") {
        pcfg.autotune = Some(AutotuneConfig::default());
    }

    let mut models = Vec::new();
    let mut route: Vec<String> = Vec::new();
    let mut oracles = Vec::new();
    for spec in a.get_all("model") {
        let (tag, backend, oracle) = parse_model_spec(spec, artifacts)?;
        let mut m = ModelSpec::new(tag.clone(), backend)
            .policy(policy.clone())
            .engines(engines)
            .queue_depth(queue_depth);
        if let Some(slo) = pcfg.slo_for(&tag) {
            m = m.slo(slo.p99_ms, slo.weight);
        }
        models.push(m);
        route.push(tag);
        oracles.push(oracle);
    }
    for (tag, _) in &pcfg.slos {
        if !route.contains(tag) {
            return Err(logicsparse::Error::config(format!(
                "--slo names tag '{tag}' but no --model declares it"
            )));
        }
    }
    let churn: Option<ModelSpec> = match a.get("churn") {
        None => None,
        Some(tag) => {
            let k = route.iter().position(|t| t == tag).ok_or_else(|| {
                logicsparse::Error::config(format!(
                    "--churn names tag '{tag}' but no --model declares it"
                ))
            })?;
            Some(models[k].clone())
        }
    };

    let autotune_on = pcfg.autotune.is_some();
    let setup = parse_obs_opts(a)?;
    let fleet = Fleet::start(FleetOptions {
        models,
        admission_capacity: a.get_usize("admission")?.unwrap_or(1024),
        autotune: pcfg.autotune,
        obs: setup.obs.clone(),
    })?;
    println!(
        "fleet: {} models ({}) | shared admission {} | {} engines/plane{}{}",
        route.len(),
        route.join(", "),
        fleet.admission_capacity(),
        engines,
        if pcfg.slos.is_empty() { "" } else { " | slo budgets active" },
        if autotune_on { " | autotune on" } else { "" },
    );
    if !pcfg.slos.is_empty() {
        for (tag, snap) in &fleet.stats().per_model {
            if let Some(cap) = snap.budget_capacity {
                println!("  [{tag}] admission budget {cap}");
            }
        }
    }

    // One synthetic request set shared by every tag; per-tag expected
    // classes wherever a local oracle exists.
    let px = runtime::IMG * runtime::IMG;
    let n_imgs = 256usize;
    let (imgs, _) = runtime::SyntheticRuntime::dataset(n_imgs);
    let mut expected: Vec<Option<Vec<usize>>> = Vec::with_capacity(oracles.len());
    for oracle in &oracles {
        expected.push(match oracle {
            Oracle::Stripe => Some(
                (0..n_imgs)
                    .map(|j| {
                        runtime::SyntheticRuntime::expected_class(&imgs[j * px..(j + 1) * px])
                    })
                    .collect(),
            ),
            Oracle::Native(m) => {
                let mut v = Vec::with_capacity(n_imgs);
                for j in 0..n_imgs {
                    v.push(m.classify(&imgs[j * px..(j + 1) * px])?);
                }
                Some(v)
            }
            Oracle::None => None,
        });
    }

    let n_tags = route.len();
    let mut correct = vec![0usize; n_tags];
    let mut checked = vec![0usize; n_tags];
    let mut skipped_retired = 0usize;
    type Pending = Vec<(usize, std::sync::mpsc::Receiver<logicsparse::coordinator::Response>, usize)>;
    let mut pending: Pending = Vec::new();
    let drain = |pending: &mut Pending,
                 correct: &mut [usize],
                 checked: &mut [usize]|
     -> Result<()> {
        for (k, rx, j) in pending.drain(..) {
            let resp = rx.recv().map_err(|_| logicsparse::Error::QueueClosed)?;
            if let Some(labels) = &expected[k] {
                checked[k] += 1;
                if resp.class() == labels[j] {
                    correct[k] += 1;
                }
            }
        }
        Ok(())
    };

    // Pre-resolved routing (route order == initial slot order): the hot
    // loop submits by index; only the churn events change the mapping
    // (retire leaves a tombstone the loop skips via UnknownModel, and
    // re-registration refreshes the index).
    let mut slot_of: Vec<usize> = (0..n_tags).collect();
    let t0 = std::time::Instant::now();
    // Policy cadence: with autotuning on, a background thread ticks the
    // control loop on a fixed period instead of the request loop pausing
    // every 256 submits. `Fleet::tick` snapshots telemetry on the calling
    // (cadence) thread and the policies are pure functions of that
    // snapshot sequence, so decisions stay replay-deterministic — only
    // *when* a snapshot is taken moved off the hot path.
    let stop = std::sync::atomic::AtomicBool::new(false);
    let served = std::thread::scope(|s| -> Result<()> {
        use std::sync::atomic::Ordering;
        setup.spawn_scraper(s, &stop);
        if autotune_on {
            let (fleet, stop) = (&fleet, &stop);
            s.spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    for d in fleet.tick() {
                        println!("[policy] {d:?}");
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
            });
        }
        // Run the request loop in a closure so every exit path — errors
        // included — still stops the cadence thread before the scope
        // joins it.
        let run = (|| -> Result<()> {
            for i in 0..n_req {
                // The live-membership demo: retire the churn tag at the
                // halfway point (its in-flight responses keep arriving —
                // the drain is lossless) and bring it back at three
                // quarters.
                if let Some(spec) = &churn {
                    if i == n_req / 2 {
                        let snap = fleet.retire(&spec.tag)?;
                        println!(
                            "[churn] retired '{}' at request {i}: {}",
                            spec.tag,
                            snap.render()
                        );
                    } else if i == n_req * 3 / 4 {
                        fleet.register(spec.clone())?;
                        let k = route
                            .iter()
                            .position(|t| t == &spec.tag)
                            .expect("churn tag routed");
                        slot_of[k] = fleet.resolve(&spec.tag)?;
                        println!("[churn] re-registered '{}' at request {i}", spec.tag);
                    }
                }
                // Round-robin across tags so every plane sees the stream.
                let k = i % n_tags;
                let j = i % n_imgs;
                let rx = loop {
                    match fleet.submit_at(slot_of[k], imgs[j * px..(j + 1) * px].to_vec()) {
                        Ok(rx) => break Some(rx),
                        Err(logicsparse::Error::Overloaded) => std::thread::yield_now(),
                        Err(logicsparse::Error::UnknownModel(_)) => {
                            // The churn tag is retired right now; skip
                            // its slot.
                            skipped_retired += 1;
                            break None;
                        }
                        Err(e) => return Err(e),
                    }
                };
                if let Some(rx) = rx {
                    pending.push((k, rx, j));
                }
                // Keep a bounded in-flight window, like a real client
                // pool.
                if pending.len() >= 256 {
                    drain(&mut pending, &mut correct, &mut checked)?;
                }
            }
            drain(&mut pending, &mut correct, &mut checked)
        })();
        stop.store(true, Ordering::Relaxed);
        run
    });
    served?;
    let wall = t0.elapsed().as_secs_f64();

    let snap = fleet.shutdown();
    println!("{}", snap.render());
    for (k, tag) in route.iter().enumerate() {
        if checked[k] > 0 {
            println!(
                "  [{tag}] accuracy {:.2}% over {} checked requests",
                100.0 * correct[k] as f64 / checked[k] as f64,
                checked[k],
            );
        } else {
            println!("  [{tag}] accuracy n/a (no local oracle for this backend)");
        }
    }
    if skipped_retired > 0 {
        println!("[churn] {skipped_retired} arrivals skipped while the tag was retired");
    }
    println!(
        "fleet total: {} requests | wall {:.2}s | {:.0} req/s aggregate",
        n_req,
        wall,
        n_req as f64 / wall
    );
    setup.finish()
}

/// Diff the `BENCH_*.json` files of the current run against the
/// committed `BENCH_baseline.json`, flagging drift beyond a noise band.
/// Reporting-only by default (CI runs it on every PR without gating);
/// `--strict` turns regressions into a nonzero exit, and
/// `--write-baseline` refreshes the committed snapshot from the bench
/// files present in the working directory.
fn cmd_bench_compare(argv: &[String]) -> Result<()> {
    use logicsparse::util::bench;
    use logicsparse::util::json::{self, Value};

    let opts = vec![
        Opt { name: "baseline", takes_value: true, default: Some("BENCH_baseline.json"), help: "baseline snapshot path" },
        Opt { name: "noise", takes_value: true, default: None, help: "noise band fraction (default: baseline's, else 0.3)" },
        Opt { name: "strict", takes_value: false, default: None, help: "exit nonzero on regressions" },
        Opt { name: "write-baseline", takes_value: false, default: None, help: "rewrite the baseline from current BENCH_*.json files" },
        Opt { name: "help", takes_value: false, default: None, help: "show usage" },
    ];
    let a = cli::parse(argv, &opts)?;
    if a.flag("help") {
        println!("{}", cli::usage("bench-compare", "diff BENCH_*.json against the committed baseline", &opts));
        return Ok(());
    }
    let baseline_path = a.req("baseline")?;

    // The bench files a run produces, in report order.
    let bench_files: Vec<String> = {
        let mut v: Vec<String> = std::fs::read_dir(".")
            .map_err(logicsparse::Error::Io)?
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| {
                n.starts_with("BENCH_")
                    && n.ends_with(".json")
                    && n != baseline_path
                    && n != "BENCH_baseline.json"
            })
            .collect();
        v.sort();
        v
    };

    if a.flag("write-baseline") {
        if bench_files.is_empty() {
            return Err(logicsparse::Error::config(
                "no BENCH_*.json files to snapshot; run `make bench` first",
            ));
        }
        let mut benches = Vec::new();
        for f in &bench_files {
            benches.push((f.clone(), json::parse_file(f)?));
        }
        let doc = json::obj(vec![
            (
                "provenance",
                json::s(
                    "measured snapshot written by `logicsparse bench-compare \
                     --write-baseline` (see `make bench-baseline`); diff with \
                     `make bench-compare`",
                ),
            ),
            ("noise", Value::Num(0.3)),
            ("benches", Value::Obj(benches)),
        ]);
        json::write_file(baseline_path, &doc)?;
        println!("baseline written to {baseline_path} ({} benches)", bench_files.len());
        return Ok(());
    }

    let baseline = json::parse_file(baseline_path)?;
    let noise = match a.get_f64("noise")? {
        Some(n) => n,
        None => baseline.get("noise").and_then(Value::as_f64).unwrap_or(0.3),
    };
    let provenance = baseline.get("provenance").and_then(Value::as_str);
    if let Some(p) = provenance {
        println!("baseline: {p}");
    }
    let empty: &[(String, Value)] = &[];
    let benches = baseline.get("benches").and_then(Value::as_obj).unwrap_or(empty);
    if provenance.is_some_and(bench::is_unmeasured_baseline) {
        // One-line verdict for the seed placeholder: nothing to diff
        // against, nothing judged, and strict mode must not gate on it.
        println!(
            "bench-compare: baseline is the UNMEASURED placeholder — current \
             numbers reported as-is, 0 regressions judged; run `make bench` then \
             `make bench-baseline` on a machine with a Rust toolchain"
        );
        return Ok(());
    }
    if benches.is_empty() {
        println!(
            "baseline holds no measured benches yet; run `make bench` then \
             `make bench-baseline` on a machine with a Rust toolchain"
        );
        return Ok(());
    }

    let mut regressions = 0usize;
    let mut missing_files = 0usize;
    let mut dropped_series = 0usize;
    for (file, base_doc) in benches {
        match json::parse_file(file) {
            Ok(current) => {
                let rep = bench::compare(base_doc, &current, noise);
                print!("{}", rep.render(file));
                regressions += rep.regressions().len();
                dropped_series += rep.missing_metrics.len();
            }
            Err(_) => {
                println!("{file}: not present in this run (baseline has it)");
                missing_files += 1;
            }
        }
    }
    println!(
        "bench-compare: {} regressions, {} baseline benches missing, {} tracked \
         series dropped (noise band {:.0}%)",
        regressions,
        missing_files,
        dropped_series,
        noise * 100.0
    );
    // New series (current-only metrics, e.g. p99_ms before a baseline
    // refresh) are reported per-bench above but never gate: there is no
    // baseline value to judge them against.
    if a.flag("strict") && (regressions > 0 || missing_files > 0 || dropped_series > 0) {
        return Err(logicsparse::Error::config(format!(
            "strict mode: {regressions} regressions, {missing_files} missing benches, \
             {dropped_series} tracked series dropped"
        )));
    }
    Ok(())
}

/// Validate a Chrome trace-event file written by `serve --trace`:
/// `traceEvents` must be a well-formed array (every event an object with
/// `name`/`ph`, and `ts`/`pid`/`tid` on timed events), timestamps must
/// be monotone per thread lane in array order (the writer sorts by
/// `(tid, ts)`), and `otherData.dropped_events` must be reported.
/// Violations exit nonzero — the CI trace-smoke step gates on this.
fn cmd_trace_validate(argv: &[String]) -> Result<()> {
    use logicsparse::util::json::{self, Value};

    let opts = vec![Opt {
        name: "help",
        takes_value: false,
        default: None,
        help: "show usage",
    }];
    let a = cli::parse(argv, &opts)?;
    if a.flag("help") || a.positional.is_empty() {
        println!("usage: logicsparse trace-validate <TRACE.json>");
        return if a.flag("help") {
            Ok(())
        } else {
            Err(logicsparse::Error::config("trace-validate needs a trace file path"))
        };
    }
    let path = &a.positional[0];
    let doc = json::parse_file(path)?;
    let bad = |msg: String| logicsparse::Error::config(format!("{path}: {msg}"));

    let events = doc
        .get("traceEvents")
        .and_then(Value::as_arr)
        .ok_or_else(|| bad("no traceEvents array".into()))?;
    // Per-lane monotonicity: the writer sorts by (tid, ts), so within
    // one tid the timestamps must never step backwards in array order.
    let mut last: Vec<(u64, f64)> = Vec::new();
    let mut timed = 0usize;
    for (i, e) in events.iter().enumerate() {
        let name = e
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| bad(format!("event {i} has no name")))?;
        let ph = e
            .get("ph")
            .and_then(Value::as_str)
            .ok_or_else(|| bad(format!("event {i} ('{name}') has no ph")))?;
        if ph == "M" {
            continue; // metadata events carry no timestamp
        }
        let ts = e
            .get("ts")
            .and_then(Value::as_f64)
            .ok_or_else(|| bad(format!("event {i} ('{name}', ph {ph}) has no ts")))?;
        let tid = e
            .get("tid")
            .and_then(Value::as_u64)
            .ok_or_else(|| bad(format!("event {i} ('{name}') has no tid")))?;
        e.get("pid")
            .and_then(Value::as_u64)
            .ok_or_else(|| bad(format!("event {i} ('{name}') has no pid")))?;
        timed += 1;
        match last.iter_mut().find(|(t, _)| *t == tid) {
            Some((_, prev)) => {
                if ts < *prev {
                    return Err(bad(format!(
                        "event {i} ('{name}') on tid {tid}: ts {ts} < previous {prev} \
                         (per-thread timestamps must be monotone)"
                    )));
                }
                *prev = ts;
            }
            None => last.push((tid, ts)),
        }
    }
    let dropped = doc
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(Value::as_f64)
        .ok_or_else(|| bad("otherData.dropped_events missing".into()))?;
    println!(
        "trace-validate: {path} OK — {} events ({timed} timed) across {} thread \
         lanes, {dropped} dropped",
        events.len(),
        last.len(),
    );
    Ok(())
}

fn cmd_pareto(argv: &[String]) -> Result<()> {
    let mut opts = common_opts();
    opts.push(Opt { name: "points", takes_value: true, default: Some("8"), help: "budget sweep points" });
    let a = cli::parse(argv, &opts)?;
    if a.flag("help") {
        println!("{}", cli::usage("pareto", "budget sweep -> Pareto frontier", &opts));
        return Ok(());
    }
    let dev = device::by_name(a.req("device")?)?;
    let (g, profile) = load_inputs(a.req("artifacts")?)?;
    let points = a.get_usize("points")?.unwrap_or(8);

    let mut all = Vec::new();
    for i in 0..points {
        let frac = 0.02 + 0.98 * (i as f64 / (points.max(2) - 1) as f64);
        for (st, with_sparsity) in [(Strategy::Proposed, true), (Strategy::AutoFold, false)] {
            let mut dopts = DseOptions { budget_fraction: frac, ..Default::default() };
            if !with_sparsity {
                dopts.auto_fold_target_fps = 1e9; // push to the budget
            }
            if let Ok(r) = dse::run(st, &g, &dev, &profile, &dopts) {
                all.push(logicsparse::dse::pareto::Point {
                    label: format!("{}@{:.0}%", st.as_str(), frac * 100.0),
                    luts: r.cost.total_luts,
                    throughput_fps: r.cost.throughput_fps,
                });
            }
        }
    }
    let front = logicsparse::dse::pareto::frontier(&all);
    println!("budget sweep ({} evaluated, {} on frontier):", all.len(), front.len());
    for p in &front {
        println!("  {:<24} {:>9} LUTs  {:>12.0} FPS", p.label, p.luts, p.throughput_fps);
    }
    let hv = logicsparse::dse::pareto::hypervolume(&front, dev.lut_budget(), 0.0);
    println!("frontier hypervolume: {hv:.3e}");
    Ok(())
}
