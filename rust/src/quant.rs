//! Quantisation helpers, rust side (substrate S10).
//!
//! The python compile path performs QAT; here we provide the matching
//! integer-grid arithmetic for (a) verifying exported weights actually lie
//! on the W4 grid, (b) packing int codes for size accounting, and (c) the
//! compression headline. Kept numerically identical to
//! `python/compile/quant.py` (symmetric per-channel, qmax = 2^(b-1) - 1).

use crate::util::error::{Error, Result};

/// Symmetric quantisation spec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QSpec {
    /// Code width in bits (2..=8; 4 is the paper's W4 point).
    pub bits: usize,
}

impl QSpec {
    /// A spec of `bits` bits; rejects widths outside [2, 8].
    pub fn new(bits: usize) -> Result<Self> {
        if !(2..=8).contains(&bits) {
            return Err(Error::config(format!("weight bits {bits} out of [2,8]")));
        }
        Ok(QSpec { bits })
    }

    /// Largest positive level.
    pub fn qmax(&self) -> i32 {
        (1 << (self.bits - 1)) - 1
    }

    /// Scale for a channel with max-abs `amax`.
    pub fn scale(&self, amax: f32) -> f32 {
        amax.max(1e-8) / self.qmax() as f32
    }

    /// Quantise to integer codes with the given scale.
    pub fn encode(&self, w: &[f32], scale: f32) -> Vec<i8> {
        let qmax = self.qmax();
        w.iter()
            .map(|&x| ((x / scale).round() as i32).clamp(-qmax, qmax) as i8)
            .collect()
    }

    /// Dequantise integer codes back to floats with the given scale.
    pub fn decode(&self, codes: &[i8], scale: f32) -> Vec<f32> {
        codes.iter().map(|&c| c as f32 * scale).collect()
    }

    /// Does every value lie on the quantisation grid for `scale`
    /// (within float tolerance)? Exported "baked" weights must.
    pub fn on_grid(&self, w: &[f32], scale: f32, tol: f32) -> bool {
        let qmax = self.qmax() as f32;
        w.iter().all(|&x| {
            let q = x / scale;
            q.abs() <= qmax + 0.5 && (q - q.round()).abs() <= tol
        })
    }
}

/// Per-output-channel quantisation of a [fold_in, cout] matrix: returns
/// (codes, per-channel scales). Matches python's per_channel=True path.
pub fn quantize_per_channel(
    w: &[f32],
    fold_in: usize,
    cout: usize,
    spec: QSpec,
) -> Result<(Vec<i8>, Vec<f32>)> {
    if w.len() != fold_in * cout {
        return Err(Error::config(format!(
            "weight len {} != {fold_in}x{cout}",
            w.len()
        )));
    }
    let mut scales = vec![0.0f32; cout];
    for c in 0..cout {
        let amax = (0..fold_in)
            .map(|r| w[r * cout + c].abs())
            .fold(0.0f32, f32::max);
        scales[c] = spec.scale(amax);
    }
    let qmax = spec.qmax();
    let mut codes = vec![0i8; w.len()];
    for r in 0..fold_in {
        for c in 0..cout {
            let i = r * cout + c;
            codes[i] = ((w[i] / scales[c]).round() as i32).clamp(-qmax, qmax) as i8;
        }
    }
    Ok((codes, scales))
}

/// Mean-squared error introduced by quantisation (diagnostics).
pub fn quant_mse(w: &[f32], codes: &[i8], fold_in: usize, cout: usize, scales: &[f32]) -> f64 {
    let mut acc = 0.0f64;
    for r in 0..fold_in {
        for c in 0..cout {
            let i = r * cout + c;
            let d = (w[i] - codes[i] as f32 * scales[c]) as f64;
            acc += d * d;
        }
    }
    acc / w.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn qmax_values() {
        assert_eq!(QSpec::new(4).unwrap().qmax(), 7);
        assert_eq!(QSpec::new(8).unwrap().qmax(), 127);
        assert!(QSpec::new(1).is_err());
        assert!(QSpec::new(16).is_err());
    }

    #[test]
    fn encode_decode_roundtrip_on_grid() {
        let spec = QSpec::new(4).unwrap();
        let scale = 0.25;
        let w: Vec<f32> = (-7..=7).map(|i| i as f32 * scale).collect();
        let codes = spec.encode(&w, scale);
        let back = spec.decode(&codes, scale);
        assert_eq!(w, back);
        assert!(spec.on_grid(&back, scale, 1e-6));
    }

    #[test]
    fn off_grid_detected() {
        let spec = QSpec::new(4).unwrap();
        assert!(!spec.on_grid(&[0.26], 0.25, 1e-3));
        assert!(spec.on_grid(&[0.25], 0.25, 1e-3));
    }

    #[test]
    fn per_channel_scales_independent() {
        let spec = QSpec::new(4).unwrap();
        // col 0 max 7.0, col 1 max 0.7
        let w = vec![7.0, 0.7, -3.5, -0.35];
        let (codes, scales) = quantize_per_channel(&w, 2, 2, spec).unwrap();
        assert!((scales[0] - 1.0).abs() < 1e-6);
        assert!((scales[1] - 0.1).abs() < 1e-6);
        assert_eq!(codes, vec![7, 7, -4, -4]);
    }

    #[test]
    fn prop_quant_error_bounded_by_half_scale() {
        check("|w - dq| <= scale/2 within range", 150, |g| {
            let spec = QSpec::new(*g.choose(&[3usize, 4, 6])).unwrap();
            let fold_in = g.usize(1, 40);
            let cout = g.usize(1, 8);
            let mut rng = Pcg32::seeded(g.case);
            let w: Vec<f32> = (0..fold_in * cout).map(|_| rng.normal() as f32).collect();
            let (codes, scales) = quantize_per_channel(&w, fold_in, cout, spec).unwrap();
            for r in 0..fold_in {
                for c in 0..cout {
                    let i = r * cout + c;
                    let dq = codes[i] as f32 * scales[c];
                    assert!(
                        (w[i] - dq).abs() <= scales[c] * 0.5 + 1e-6,
                        "w {} dq {} scale {}",
                        w[i],
                        dq,
                        scales[c]
                    );
                }
            }
        });
    }

    #[test]
    fn mse_decreases_with_bits() {
        let mut rng = Pcg32::seeded(5);
        let w: Vec<f32> = (0..2000).map(|_| rng.normal() as f32).collect();
        let mut prev = f64::INFINITY;
        for bits in [2usize, 4, 6, 8] {
            let spec = QSpec::new(bits).unwrap();
            let (codes, scales) = quantize_per_channel(&w, 500, 4, spec).unwrap();
            let mse = quant_mse(&w, &codes, 500, 4, &scales);
            assert!(mse < prev, "bits {bits}: {mse} !< {prev}");
            prev = mse;
        }
    }
}
