//! FPGA device models — the substitute for the paper's physical XCU50
//! board (DESIGN.md §2). A device is a resource budget plus base timing;
//! the cost models in [`crate::cost`] estimate per-layer usage against it
//! and the DSE treats the budget as its hard constraint.

use crate::util::error::{Error, Result};

/// Static description of a target FPGA.
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    /// Canonical device name (CLI key).
    pub name: &'static str,
    /// Total 6-input LUTs.
    pub luts: u64,
    /// Total flip-flops.
    pub ffs: u64,
    /// Total 36kb BRAM blocks.
    pub bram36: u64,
    /// Total DSP48 slices.
    pub dsps: u64,
    /// Nominal dataflow clock in MHz for shallow logic (the f_max model in
    /// `cost::clock` derates this with combinational depth).
    pub f_base_mhz: f64,
    /// Fraction of LUTs usable by the accelerator (shell/infrastructure
    /// overhead reserves the rest — Alveo shells are substantial).
    pub usable_fraction: f64,
}

impl Device {
    /// LUT budget available to the generated accelerator.
    pub fn lut_budget(&self) -> u64 {
        (self.luts as f64 * self.usable_fraction) as u64
    }

    /// BRAM budget available to the generated accelerator.
    pub fn bram_budget(&self) -> u64 {
        (self.bram36 as f64 * self.usable_fraction) as u64
    }

    /// DSP budget available to the generated accelerator.
    pub fn dsp_budget(&self) -> u64 {
        (self.dsps as f64 * self.usable_fraction) as u64
    }
}

/// Xilinx Alveo U50 (XCU50): the paper's evaluation board.
pub const XCU50: Device = Device {
    name: "xcu50",
    luts: 871_680,
    ffs: 1_743_360,
    bram36: 1_344,
    dsps: 5_952,
    f_base_mhz: 300.0,
    usable_fraction: 0.80,
};

/// Zynq UltraScale+ ZCU104 — a smaller edge board used by several FINN
/// papers; exercised by the resource-constraint ablations.
pub const ZCU104: Device = Device {
    name: "zcu104",
    luts: 230_400,
    ffs: 460_800,
    bram36: 312,
    dsps: 1_728,
    f_base_mhz: 250.0,
    usable_fraction: 0.85,
};

/// Tiny synthetic device for tests: forces the DSE into its constrained
/// branches with LeNet-scale workloads.
pub const TINY: Device = Device {
    name: "tiny",
    luts: 30_000,
    ffs: 60_000,
    bram36: 64,
    dsps: 128,
    f_base_mhz: 200.0,
    usable_fraction: 1.0,
};

/// Look up a device preset by name.
pub fn by_name(name: &str) -> Result<Device> {
    match name {
        "xcu50" => Ok(XCU50),
        "zcu104" => Ok(ZCU104),
        "tiny" => Ok(TINY),
        other => Err(Error::config(format!(
            "unknown device '{other}' (known: xcu50, zcu104, tiny)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_below_totals() {
        for d in [XCU50, ZCU104, TINY] {
            assert!(d.lut_budget() <= d.luts);
            assert!(d.bram_budget() <= d.bram36);
            assert!(d.dsp_budget() <= d.dsps);
            assert!(d.f_base_mhz > 0.0);
        }
    }

    #[test]
    fn xcu50_is_large_enough_for_dense_unroll() {
        // Table I's Unfold row needs ~433k LUTs; the XCU50 (871k) fits it.
        assert!(XCU50.luts > 433_249);
    }

    #[test]
    fn lookup() {
        assert_eq!(by_name("xcu50").unwrap(), XCU50);
        assert!(by_name("virtex2").is_err());
    }
}
