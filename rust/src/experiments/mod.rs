//! Experiment drivers: every table and figure of the paper's evaluation
//! (DESIGN.md §6), shared between the `cargo bench` targets, the examples
//! and the CLI so all three print identical rows.

pub mod baselines;
pub mod fig2;
pub mod headline;
pub mod table1;

use crate::util::error::Result;
use crate::util::json::{self, Value};
use std::path::Path;

/// Accuracies measured by the python compile path (metrics.json), when
/// artifacts have been built; table rows fall back to "n/a" otherwise.
#[derive(Debug, Clone, Default)]
pub struct Accuracies {
    /// Dense (unpruned) test accuracy.
    pub dense: Option<f64>,
    /// Globally pruned reference accuracy.
    pub pruned_global: Option<f64>,
    /// Proposed (re-sparse fine-tuned) accuracy.
    pub proposed: Option<f64>,
}

impl Accuracies {
    /// Read accuracies from `metrics.json` (or the stage-1 subset);
    /// missing files yield the all-`None` default.
    pub fn load(artifacts: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts.as_ref();
        let full = dir.join("metrics.json");
        let stage1 = dir.join("metrics_stage1.json");
        if full.exists() {
            let v = json::parse_file(full)?;
            Ok(Accuracies {
                dense: v.get("dense_accuracy").and_then(Value::as_f64),
                pruned_global: v.get("pruned_global_accuracy").and_then(Value::as_f64),
                proposed: v.get("proposed_accuracy").and_then(Value::as_f64),
            })
        } else if stage1.exists() {
            let v = json::parse_file(stage1)?;
            Ok(Accuracies {
                dense: v.get("dense_accuracy").and_then(Value::as_f64),
                ..Default::default()
            })
        } else {
            Ok(Accuracies::default())
        }
    }

    /// Render one accuracy as percent, or "n/a".
    pub fn fmt(a: Option<f64>) -> String {
        match a {
            Some(v) => format!("{:.2}", v * 100.0),
            None => "n/a".into(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_metrics_is_default() {
        let a = Accuracies::load("/definitely/not/here").unwrap();
        assert!(a.dense.is_none());
        assert_eq!(Accuracies::fmt(None), "n/a");
        assert_eq!(Accuracies::fmt(Some(0.9782)), "97.82");
    }
}
