//! External baselines and the paper's published numbers.
//!
//! Rama et al. [8] and FPGA-QNN [9] are *cited* rows in Table I — the
//! paper did not re-implement them and neither do we; their published
//! numbers are carried verbatim for the comparison printout. The paper's
//! own five rows are recorded too so every bench can print
//! paper-vs-measured side by side (EXPERIMENTS.md is generated from
//! exactly these constants).

/// One Table-I row as published.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PaperRow {
    /// Work / strategy label as printed in the paper.
    pub work: &'static str,
    /// Published test accuracy in percent.
    pub accuracy_pct: f64,
    /// Published single-frame latency.
    pub latency_us: f64,
    /// Published throughput.
    pub throughput_fps: f64,
    /// Published LUT usage.
    pub luts: u64,
    /// Our measurement reproduces this row (vs cited external work).
    pub reproduced: bool,
}

/// Table I of the paper, verbatim.
pub const TABLE1_PAPER: [PaperRow; 7] = [
    PaperRow {
        work: "Rama et al. [8]",
        accuracy_pct: 98.89,
        latency_us: 1565.0,
        throughput_fps: 995.0,
        luts: 35_644,
        reproduced: false,
    },
    PaperRow {
        work: "FPGA-QNN [9]",
        accuracy_pct: 95.40,
        latency_us: 1380.0,
        throughput_fps: 6816.0,
        luts: 44_000,
        reproduced: false,
    },
    PaperRow {
        work: "Auto folding",
        accuracy_pct: 98.91,
        latency_us: 44.67,
        throughput_fps: 65_731.0,
        luts: 9_420,
        reproduced: true,
    },
    PaperRow {
        work: "Auto+Pruning",
        accuracy_pct: 97.78,
        latency_us: 44.56,
        throughput_fps: 65_866.0,
        luts: 8_553,
        reproduced: true,
    },
    PaperRow {
        work: "Unfold",
        accuracy_pct: 98.91,
        latency_us: 18.18,
        throughput_fps: 214_919.0,
        luts: 433_249,
        reproduced: true,
    },
    PaperRow {
        work: "Unfold+Pruning",
        accuracy_pct: 97.78,
        latency_us: 15.52,
        throughput_fps: 251_265.0,
        luts: 100_687,
        reproduced: true,
    },
    PaperRow {
        work: "Proposed",
        accuracy_pct: 97.82,
        latency_us: 18.13,
        throughput_fps: 265_429.0,
        luts: 23_465,
        reproduced: true,
    },
];

/// The published row for `work`, if Table I carries one.
pub fn paper_row(work: &str) -> Option<&'static PaperRow> {
    TABLE1_PAPER.iter().find(|r| r.work == work)
}

/// The paper's headline ratios, derived from Table I.
pub mod headline_claims {
    /// "51.6x compression"
    pub const COMPRESSION: f64 = 51.6;
    /// "1.23x throughput improvement" (Proposed vs Unfold).
    pub const THROUGHPUT_GAIN: f64 = 265_429.0 / 214_919.0;
    /// "using only 5.12% of LUTs" (Proposed vs Unfold).
    pub const LUT_FRACTION: f64 = 23_465.0 / 433_249.0;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_ratios_consistent_with_table() {
        // The paper's own arithmetic.
        assert!((headline_claims::THROUGHPUT_GAIN - 1.235).abs() < 0.01);
        assert!((headline_claims::LUT_FRACTION - 0.0542).abs() < 0.005);
    }

    #[test]
    fn proposed_dominates_unfold_in_paper() {
        let p = paper_row("Proposed").unwrap();
        let u = paper_row("Unfold").unwrap();
        assert!(p.throughput_fps > u.throughput_fps);
        assert!(p.luts < u.luts / 10);
        assert!(p.latency_us < u.latency_us + 0.1);
    }

    #[test]
    fn lookup() {
        assert!(paper_row("Rama et al. [8]").is_some());
        assert!(paper_row("nope").is_none());
    }
}
