//! Fig. 2 regeneration: estimated per-layer latency and LUT utilisation of
//! LeNet-5 under the different folding/pruning strategies.
//!
//! The paper plots two bar groups per layer (latency µs, LUTs) for the
//! fully-folded, auto-folded, fully-unrolled and proposed designs; we
//! print the same series as aligned tables (and expose the raw numbers to
//! the bench target).

use crate::config::PruneProfile;
use crate::cost;
use crate::device::Device;
use crate::dse::{self, DseOptions, Strategy};
use crate::graph::Graph;
use crate::util::error::Result;
use crate::util::table::{fmt_int, Align, Table};

/// Per-layer series for one strategy.
#[derive(Debug, Clone)]
pub struct Series {
    /// The strategy the series was measured for.
    pub strategy: Strategy,
    /// (layer, latency_us_per_frame, luts)
    pub layers: Vec<(String, f64, u64)>,
}

/// Strategies Fig. 2 compares.
pub const FIG2_STRATEGIES: [Strategy; 4] = [
    Strategy::FullyFolded,
    Strategy::AutoFold,
    Strategy::Unfold,
    Strategy::Proposed,
];

/// Compute the per-layer estimate series for each strategy.
pub fn measure(g: &Graph, dev: &Device, profile: &PruneProfile) -> Result<Vec<Series>> {
    let opts = DseOptions::default();
    let mut out = Vec::new();
    for st in FIG2_STRATEGIES {
        let r = dse::run(st, g, dev, profile, &opts)?;
        let mc = cost::evaluate(g, &r.folding, dev)?;
        let layers = mc
            .layers
            .iter()
            .filter(|l| g.node(&l.name).map(|n| n.op.has_weights()).unwrap_or(false))
            .map(|l| {
                let us = l.ii_cycles as f64 / (mc.f_mhz * 1e6) * 1e6;
                (l.name.clone(), us, l.luts)
            })
            .collect();
        out.push(Series { strategy: st, layers });
    }
    Ok(out)
}

/// The layer that dominates latency in a series.
pub fn bottleneck(series: &Series) -> &(String, f64, u64) {
    series
        .layers
        .iter()
        .max_by(|a, b| a.1.total_cmp(&b.1))
        .expect("non-empty series")
}

/// Render both panels of Fig. 2.
pub fn render(series: &[Series]) -> String {
    let mut headers = vec!["Layer"];
    let labels: Vec<String> = series.iter().map(|s| s.strategy.label().to_string()).collect();
    for l in &labels {
        headers.push(l);
    }

    let layer_names: Vec<&str> = series[0].layers.iter().map(|(n, _, _)| n.as_str()).collect();

    let mut lat = Table::new(&headers).align(0, Align::Left);
    for (i, name) in layer_names.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for s in series {
            row.push(format!("{:.3}", s.layers[i].1));
        }
        lat.row(row);
    }

    let mut luts = Table::new(&headers).align(0, Align::Left);
    for (i, name) in layer_names.iter().enumerate() {
        let mut row = vec![name.to_string()];
        for s in series {
            row.push(fmt_int(s.layers[i].2 as f64));
        }
        luts.row(row);
    }

    format!(
        "Fig. 2a — estimated per-layer latency (us/frame):\n{}\n\
         Fig. 2b — estimated per-layer LUT utilisation:\n{}",
        lat.render(),
        luts.render()
    )
}

/// The paper's Fig. 2 narrative, as checkable assertions.
pub fn shape_checks(series: &[Series]) -> Vec<String> {
    let get = |st: Strategy| series.iter().find(|s| s.strategy == st);
    let mut out = Vec::new();
    let (Some(folded), Some(auto), Some(unfold), Some(proposed)) = (
        get(Strategy::FullyFolded),
        get(Strategy::AutoFold),
        get(Strategy::Unfold),
        get(Strategy::Proposed),
    ) else {
        return vec!["FAIL missing series".into()];
    };
    let mut check = |name: &str, ok: bool, detail: String| {
        out.push(format!("{} {name}: {detail}", if ok { "PASS" } else { "FAIL" }));
    };

    // "For the fully folded network, the second convolutional layer
    // constitutes the major bottleneck."
    let fb = bottleneck(folded);
    check("fully-folded bottleneck is conv2", fb.0 == "conv2", fb.0.clone());

    // "In the automatic unfolding scenario, this bottleneck is
    // significantly alleviated."
    let fold_conv2 = folded.layers.iter().find(|(n, _, _)| n == "conv2").unwrap().1;
    let auto_conv2 = auto.layers.iter().find(|(n, _, _)| n == "conv2").unwrap().1;
    check(
        "auto folding alleviates conv2",
        auto_conv2 < fold_conv2 / 10.0,
        format!("{fold_conv2:.1} -> {auto_conv2:.3} us"),
    );

    // "Fully unrolling achieves the lowest bottleneck latency but at the
    // cost of a huge resource increase" (paper: ~1300x vs fully folded).
    let unfold_luts: u64 = unfold.layers.iter().map(|(_, _, l)| l).sum();
    let folded_luts: u64 = folded.layers.iter().map(|(_, _, l)| l).sum();
    let ratio = unfold_luts as f64 / folded_luts as f64;
    check(
        "unroll costs orders of magnitude more LUTs (paper ~1300x)",
        ratio > 25.0,
        format!("{ratio:.0}x"),
    );
    check(
        "unroll has the lowest bottleneck latency",
        bottleneck(unfold).1 <= bottleneck(folded).1 && bottleneck(unfold).1 <= bottleneck(auto).1,
        format!("{:.3} us", bottleneck(unfold).1),
    );

    // "Our design achieves performance close to the fully unrolled
    // configuration, while consuming significantly fewer resources."
    let prop_luts: u64 = proposed.layers.iter().map(|(_, _, l)| l).sum();
    check(
        "proposed near-unroll latency at a fraction of the LUTs",
        bottleneck(proposed).1 <= bottleneck(unfold).1 * 1.5
            && (prop_luts as f64) < unfold_luts as f64 * 0.12,
        format!(
            "lat {:.3} vs {:.3} us, LUTs {} vs {}",
            bottleneck(proposed).1,
            bottleneck(unfold).1,
            prop_luts,
            unfold_luts
        ),
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::XCU50;
    use crate::graph::builder::lenet5;

    #[test]
    fn fig2_shape_reproduced() {
        let g = lenet5();
        let profile = PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95);
        let series = measure(&g, &XCU50, &profile).unwrap();
        assert_eq!(series.len(), 4);
        for v in shape_checks(&series) {
            assert!(v.starts_with("PASS"), "{v}");
        }
        let text = render(&series);
        assert!(text.contains("Fig. 2a"));
        assert!(text.contains("conv2"));
    }
}
