//! Headline-claim verification: "51.6× compression, 1.23× throughput,
//! ~5% of LUTs" — computed from our measured rows and the exported masks.

use crate::sparsity::{compression_ratio, compression_ratio_csr, ModelSparsity};
use crate::util::error::Result;
use crate::util::json::{self, Value};
use std::path::Path;

use super::table1::Row;
use crate::dse::Strategy;

/// Measured headline numbers.
#[derive(Debug, Clone)]
pub struct Headline {
    /// Engine-free compression ratio (None without sparsity masks).
    pub compression: Option<f64>,
    /// What a CSR-style sparse engine would achieve on the same masks.
    pub compression_csr_equiv: Option<f64>,
    /// Proposed-vs-Unfold throughput ratio.
    pub throughput_gain: f64,
    /// Proposed-vs-Unfold LUT fraction.
    pub lut_fraction: f64,
}

/// Engine-free + CSR-equivalent compression for one sparsity accounting —
/// the single formula every producer uses: metrics.json written by the
/// python exporter, `kernel::CompiledModel::compression`, and the bench
/// reports all derive their headline number through here, so they cannot
/// drift apart.
pub fn compression_from_sparsity(ms: &ModelSparsity, weight_bits: usize) -> (f64, f64) {
    (
        compression_ratio(ms.total_weights(), ms.total_nnz(), weight_bits),
        compression_ratio_csr(ms.total_weights(), ms.total_nnz(), weight_bits, 16),
    )
}

/// Compression from real exported masks (metrics.json written by stage 2);
/// `None` before artifacts exist.
pub fn compression_from_metrics(artifacts: impl AsRef<Path>) -> Result<Option<(f64, f64)>> {
    let path = artifacts.as_ref().join("metrics.json");
    if !path.exists() {
        return Ok(None);
    }
    let v = json::parse_file(path)?;
    let Some(masks) = v.get("proposed_masks") else {
        return Ok(None);
    };
    let wb = v.get("weight_bits").and_then(Value::as_usize).unwrap_or(4);
    let mut ms = ModelSparsity::default();
    if let Some(layers) = masks.get("layers").and_then(|l| l.as_obj()) {
        for (name, lv) in layers {
            let w = lv.req_usize("weights")?;
            let nnz = lv.req_usize("nnz")?;
            ms.push(name.clone(), w, nnz);
        }
    }
    Ok(Some(compression_from_sparsity(&ms, wb)))
}

/// Assemble the headline from measured Table-I rows (+ optional metrics).
pub fn measure(rows: &[Row], artifacts: impl AsRef<Path>) -> Result<Headline> {
    let get = |s: Strategy| {
        rows.iter()
            .find(|r| r.strategy == s)
            .expect("row present")
    };
    let unfold = get(Strategy::Unfold);
    let proposed = get(Strategy::Proposed);
    let comp = compression_from_metrics(artifacts)?;
    Ok(Headline {
        compression: comp.map(|(f, _)| f),
        compression_csr_equiv: comp.map(|(_, c)| c),
        throughput_gain: proposed.throughput_fps / unfold.throughput_fps,
        lut_fraction: proposed.luts as f64 / unfold.luts as f64,
    })
}

/// Render the paper-vs-measured headline comparison block.
pub fn render(h: &Headline) -> String {
    let mut s = String::from("Headline claims (paper -> measured):\n");
    s.push_str(&format!(
        "  compression       51.6x  -> {}\n",
        h.compression
            .map(|c| format!("{c:.1}x (CSR-engine equivalent would be {:.1}x)",
                h.compression_csr_equiv.unwrap_or(0.0)))
            .unwrap_or_else(|| "n/a (build artifacts for measured masks)".into())
    ));
    s.push_str(&format!(
        "  throughput gain   1.23x  -> {:.2}x (proposed vs dense unfold)\n",
        h.throughput_gain
    ));
    s.push_str(&format!(
        "  LUT fraction      5.4%   -> {:.1}% (proposed vs dense unfold)\n",
        h.lut_fraction * 100.0
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PruneProfile;
    use crate::device::XCU50;
    use crate::experiments::{table1, Accuracies};
    use crate::graph::builder::lenet5;

    #[test]
    fn shared_compression_formula_pins_headline() {
        let mut ms = ModelSparsity::default();
        ms.push("all", 44_190, (44_190f64 * 0.155).round() as usize);
        let (free, csr) = compression_from_sparsity(&ms, 4);
        assert!((free - 51.6).abs() < 0.5, "engine-free {free}");
        assert!(csr < free, "CSR must pay the index tax");
    }

    #[test]
    fn headline_without_artifacts() {
        let g = lenet5();
        let profile = PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95);
        let rows =
            table1::measure(&g, &XCU50, &profile, &Accuracies::default(), 30).unwrap();
        let h = measure(&rows, "/no/artifacts").unwrap();
        assert!(h.throughput_gain > 1.05);
        assert!(h.lut_fraction < 0.12);
        assert!(h.compression.is_none());
        assert!(render(&h).contains("throughput gain"));
    }
}
