//! Table I regeneration: run every strategy through the DSE, measure
//! latency/throughput in the cycle-level simulator, join trained
//! accuracies, and print the paper's rows side by side with ours.

use crate::config::PruneProfile;
use crate::device::Device;
use crate::dse::{self, DseOptions, Strategy};
use crate::graph::Graph;
use crate::sim;
use crate::util::error::Result;
use crate::util::table::{fmt_int, Align, Table};

use super::baselines::{paper_row, TABLE1_PAPER};
use super::Accuracies;

/// One measured Table-I row.
#[derive(Debug, Clone)]
pub struct Row {
    /// Strategy the row measures.
    pub strategy: Strategy,
    /// Accuracy from the python metrics, when artifacts exist.
    pub accuracy_pct: Option<f64>,
    /// Simulator-measured single-frame latency.
    pub latency_us: f64,
    /// Simulator-measured saturated throughput.
    pub throughput_fps: f64,
    /// Cost-model LUT estimate.
    pub luts: u64,
    /// Cost-model clock estimate.
    pub f_mhz: f64,
}

/// Run all five reproduced strategies: DSE estimate + simulator
/// measurement (`frames` saturated frames each).
pub fn measure(
    g: &Graph,
    dev: &Device,
    profile: &PruneProfile,
    acc: &Accuracies,
    frames: u64,
) -> Result<Vec<Row>> {
    let opts = DseOptions::default();
    let mut rows = Vec::new();
    for st in [
        Strategy::AutoFold,
        Strategy::AutoFoldPrune,
        Strategy::Unfold,
        Strategy::UnfoldPrune,
        Strategy::Proposed,
    ] {
        let r = dse::run(st, g, dev, profile, &opts)?;
        let rep = sim::simulate_saturated(g, &r.folding, dev, frames, 8)?;
        let accuracy = match st {
            Strategy::AutoFold | Strategy::Unfold => acc.dense,
            Strategy::AutoFoldPrune | Strategy::UnfoldPrune => acc.pruned_global,
            Strategy::Proposed => acc.proposed,
            Strategy::FullyFolded => acc.dense,
        };
        rows.push(Row {
            strategy: st,
            accuracy_pct: accuracy.map(|a| a * 100.0),
            latency_us: rep.latency_s * 1e6,
            throughput_fps: rep.throughput_fps,
            luts: r.cost.total_luts,
            f_mhz: r.cost.f_mhz,
        });
    }
    Ok(rows)
}

/// Render the measured rows plus the paper's published rows.
pub fn render(rows: &[Row]) -> String {
    let mut t = Table::new(&[
        "Work",
        "Acc (%)",
        "Latency (us)",
        "Thrpt (FPS)",
        "LUTs",
        "f (MHz)",
        "Paper lat/thr/LUT",
    ])
    .align(0, Align::Left);

    // Cited external baselines first, as in the paper.
    for r in TABLE1_PAPER.iter().filter(|r| !r.reproduced) {
        t.row(vec![
            r.work.into(),
            format!("{:.2}", r.accuracy_pct),
            format!("{:.2}", r.latency_us),
            fmt_int(r.throughput_fps),
            fmt_int(r.luts as f64),
            "-".into(),
            "(cited)".into(),
        ]);
    }
    for row in rows {
        let label = row.strategy.label();
        let paper = paper_row(label)
            .map(|p| {
                format!(
                    "{:.2}us / {} / {}",
                    p.latency_us,
                    fmt_int(p.throughput_fps),
                    fmt_int(p.luts as f64)
                )
            })
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            label.into(),
            row.accuracy_pct
                .map(|a| format!("{a:.2}"))
                .unwrap_or_else(|| "n/a".into()),
            format!("{:.2}", row.latency_us),
            fmt_int(row.throughput_fps),
            fmt_int(row.luts as f64),
            format!("{:.1}", row.f_mhz),
            paper,
        ]);
    }
    t.render()
}

/// Shape checks the reproduction must satisfy (who wins, by what factor).
/// Returns human-readable verdict lines; all must start with "PASS".
pub fn shape_checks(rows: &[Row]) -> Vec<String> {
    let get = |s: Strategy| rows.iter().find(|r| r.strategy == s);
    let mut out = Vec::new();
    let (Some(unfold), Some(unfold_p), Some(proposed), Some(auto)) = (
        get(Strategy::Unfold),
        get(Strategy::UnfoldPrune),
        get(Strategy::Proposed),
        get(Strategy::AutoFold),
    ) else {
        return vec!["FAIL missing strategy rows".into()];
    };

    let mut check = |name: &str, ok: bool, detail: String| {
        out.push(format!("{} {name}: {detail}", if ok { "PASS" } else { "FAIL" }));
    };

    let gain = proposed.throughput_fps / unfold.throughput_fps;
    check(
        "proposed beats dense unfold in throughput (paper 1.23x)",
        gain > 1.05,
        format!("{gain:.2}x"),
    );
    let frac = proposed.luts as f64 / unfold.luts as f64;
    check(
        "proposed uses a small fraction of unfold LUTs (paper 5.4%)",
        frac < 0.12,
        format!("{:.1}%", frac * 100.0),
    );
    check(
        "pruned unfold beats dense unfold (paper 251k vs 215k FPS)",
        unfold_p.throughput_fps >= unfold.throughput_fps,
        format!("{:.0} vs {:.0}", unfold_p.throughput_fps, unfold.throughput_fps),
    );
    check(
        "unfold+pruning slashes LUTs (paper 100.7k vs 433.2k)",
        (unfold_p.luts as f64) < unfold.luts as f64 * 0.5,
        format!("{} vs {}", unfold_p.luts, unfold.luts),
    );
    check(
        "auto folding is the small/slow point (paper 9.4k LUTs, 65.7k FPS)",
        auto.luts < proposed.luts && auto.throughput_fps < proposed.throughput_fps,
        format!("{} LUTs, {:.0} FPS", auto.luts, auto.throughput_fps),
    );
    check(
        "proposed latency comparable to unfold (paper 18.13 vs 18.18 us)",
        proposed.latency_us < unfold.latency_us * 1.8,
        format!("{:.2} vs {:.2} us", proposed.latency_us, unfold.latency_us),
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::XCU50;
    use crate::graph::builder::lenet5;

    #[test]
    fn table1_shape_reproduced_without_artifacts() {
        let g = lenet5();
        let profile = PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95);
        let rows = measure(&g, &XCU50, &profile, &Accuracies::default(), 40).unwrap();
        assert_eq!(rows.len(), 5);
        let verdicts = shape_checks(&rows);
        for v in &verdicts {
            assert!(v.starts_with("PASS"), "{}", verdicts.join("\n"));
        }
        let text = render(&rows);
        assert!(text.contains("Proposed"));
        assert!(text.contains("Rama et al. [8]"));
    }
}
