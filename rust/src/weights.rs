//! Model weight store (substrate: bridges LSTW files to the DSE/sim).
//!
//! Loads `params_*.lstw` written by the python exporter: per-layer weight
//! tensors (`<layer>.w`), biases (`<layer>.b`) and masks (`<layer>.mask`),
//! exposing them in the [fold_in, cout] layout every rust-side consumer
//! (sparsity stats, quant checks, DSE) expects.

use crate::graph::Graph;
use crate::runtime::artifact;
use crate::sparsity::magnitude::{global_masks, LayerWeights};
use crate::sparsity::{Mask, ModelSparsity};
use crate::util::error::{Error, Result};
use crate::util::lstw::{Data, Store, Tensor};
use crate::util::rng::Pcg32;
use std::path::Path;

/// One MAC layer's parameters.
#[derive(Debug, Clone)]
pub struct LayerParams {
    /// Layer name (matches the graph node).
    pub name: String,
    /// Weights, flattened to [fold_in, cout] row-major.
    pub w: Vec<f32>,
    /// Per-output-channel biases.
    pub bias: Vec<f32>,
    /// Unstructured keep-mask over the flattened weights.
    pub mask: Mask,
    /// Rows of the flattened layout (k*k*cin for conv, inputs for fc).
    pub fold_in: usize,
    /// Output channels (columns of the flattened layout).
    pub cout: usize,
}

impl LayerParams {
    /// Surviving (unpruned) weights of this layer.
    pub fn nnz(&self) -> usize {
        self.mask.nnz()
    }

    /// Masked weights (pruned entries zeroed).
    pub fn masked_w(&self) -> Vec<f32> {
        let mut w = self.w.clone();
        self.mask.apply(&mut w).expect("mask length checked at load");
        w
    }
}

/// All MAC layers of a model, stream-ordered.
#[derive(Debug, Clone, Default)]
pub struct ModelParams {
    /// Per-layer parameters in graph order.
    pub layers: Vec<LayerParams>,
}

impl ModelParams {
    /// The parameters of layer `name`, if present.
    pub fn get(&self, name: &str) -> Option<&LayerParams> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Load from an LSTW store, validating shapes against the graph.
    ///
    /// Python stores conv weights as [KH,KW,Cin,Cout] and fc as [IN,OUT];
    /// both flatten to [fold_in, cout] row-major, which is exactly the
    /// layout the engine-free packer uses (kh, kw, c patch order — see
    /// `kernels/ref.py::im2col`).
    pub fn load(store: &Store, g: &Graph) -> Result<Self> {
        let mut layers = Vec::new();
        for node in g.mac_nodes() {
            let name = &node.name;
            let wt = store.req(&format!("{name}.w"))?;
            let n_el: usize = wt.shape.iter().product();
            if n_el != node.weights() {
                return Err(Error::lstw(format!(
                    "{name}.w has {n_el} elements, graph expects {}",
                    node.weights()
                )));
            }
            let w = wt.data.to_f32();
            let bias = store.req(&format!("{name}.b"))?.data.to_f32();
            if bias.len() != node.cout {
                return Err(Error::lstw(format!(
                    "{name}.b has {} elements, graph expects {}",
                    bias.len(),
                    node.cout
                )));
            }
            let mask = match store.get(&format!("{name}.mask")) {
                Some(t) => {
                    let m = Mask::from_f32(&t.data.to_f32());
                    if m.len() != w.len() {
                        return Err(Error::lstw(format!("{name}.mask length mismatch")));
                    }
                    m
                }
                None => Mask::dense(w.len()),
            };
            layers.push(LayerParams {
                name: name.clone(),
                w,
                bias,
                mask,
                fold_in: node.fold_in(),
                cout: node.cout,
            });
        }
        Ok(ModelParams { layers })
    }

    /// Load `params_<tag>.lstw` from an artifacts directory (the file the
    /// python exporter writes and [`Self::to_store`] mirrors).
    pub fn load_artifacts(dir: impl AsRef<Path>, tag: &str, g: &Graph) -> Result<Self> {
        let store = Store::read_file(artifact::params_path(dir.as_ref(), tag))?;
        Self::load(&store, g)
    }

    /// Deterministic synthetic parameters for `g`: unit-normal weights,
    /// zero biases, dense masks. The engine-free stand-in for an exported
    /// `params_<tag>.lstw` — the single generator tests, benches and the
    /// CLI share, so kernel compiles never re-derive layer shapes ad hoc.
    pub fn synthetic(g: &Graph, seed: u64) -> Self {
        let mut rng = Pcg32::seeded(seed);
        let layers = g
            .mac_nodes()
            .map(|n| LayerParams {
                name: n.name.clone(),
                w: (0..n.weights()).map(|_| rng.normal() as f32).collect(),
                bias: vec![0.0; n.cout],
                mask: Mask::dense(n.weights()),
                fold_in: n.fold_in(),
                cout: n.cout,
            })
            .collect();
        ModelParams { layers }
    }

    /// Re-mask every layer with one global magnitude threshold (same rule
    /// as the python pruner; `layer_floor` keeps small layers connected).
    pub fn prune_global(&mut self, sparsity: f64, layer_floor: f64) -> Result<()> {
        let masks = {
            let lws: Vec<LayerWeights<'_>> = self
                .layers
                .iter()
                .map(|l| LayerWeights { name: &l.name, w: &l.w })
                .collect();
            global_masks(&lws, sparsity, layer_floor)?
        };
        for (l, (name, m)) in self.layers.iter_mut().zip(masks) {
            debug_assert_eq!(l.name, name);
            l.mask = m;
        }
        Ok(())
    }

    /// Re-mask every layer with an N:M structured mask: keep the `n`
    /// largest of every `m` consecutive input rows per output column
    /// (`sparsity::nm::nm_mask` on each layer's own [fold_in, cout]
    /// layout). Masks only — weights stay untouched, like
    /// [`Self::prune_global`].
    pub fn prune_nm(&mut self, n: usize, m: usize) -> Result<()> {
        for l in self.layers.iter_mut() {
            l.mask = crate::sparsity::nm::nm_mask(&l.w, l.fold_in, l.cout, n, m)?;
        }
        Ok(())
    }

    /// Export to an LSTW store (`<layer>.w/.b/.mask` — byte-compatible
    /// with the python exporter, so [`Self::load`] round-trips).
    pub fn to_store(&self) -> Store {
        let mut store = Store::new();
        for l in &self.layers {
            store.push(Tensor::f32(
                format!("{}.w", l.name),
                vec![l.fold_in, l.cout],
                l.w.clone(),
            ));
            store.push(Tensor::f32(format!("{}.b", l.name), vec![l.cout], l.bias.clone()));
            store.push(Tensor {
                name: format!("{}.mask", l.name),
                shape: vec![l.fold_in, l.cout],
                data: Data::U8(l.mask.keep.iter().map(|&k| k as u8).collect()),
            });
        }
        store
    }

    /// Per-layer + global sparsity statistics.
    pub fn sparsity(&self) -> ModelSparsity {
        let mut ms = ModelSparsity::default();
        for l in &self.layers {
            ms.push(l.name.clone(), l.mask.len(), l.nnz());
        }
        ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::lenet5;
    use crate::util::lstw::{Data, Store, Tensor};
    use crate::util::rng::Pcg32;

    fn fake_store(g: &Graph, with_masks: bool) -> Store {
        let mut store = Store::new();
        let mut rng = Pcg32::seeded(1);
        for node in g.mac_nodes() {
            let n = node.weights();
            store.push(Tensor::f32(
                format!("{}.w", node.name),
                vec![node.fold_in(), node.cout],
                (0..n).map(|_| rng.normal() as f32).collect(),
            ));
            store.push(Tensor::f32(
                format!("{}.b", node.name),
                vec![node.cout],
                vec![0.0; node.cout],
            ));
            if with_masks {
                store.push(Tensor {
                    name: format!("{}.mask", node.name),
                    shape: vec![node.fold_in(), node.cout],
                    data: Data::U8((0..n).map(|i| (i % 4 != 0) as u8).collect()),
                });
            }
        }
        store
    }

    #[test]
    fn load_with_masks() {
        let g = lenet5();
        let mp = ModelParams::load(&fake_store(&g, true), &g).unwrap();
        assert_eq!(mp.layers.len(), 5);
        let fc1 = mp.get("fc1").unwrap();
        assert_eq!(fc1.w.len(), 30_720);
        // 3 of 4 kept
        let s = mp.sparsity();
        assert!((s.global_sparsity() - 0.25).abs() < 0.01);
        // masked_w zeros the pruned entries
        let mw = fc1.masked_w();
        assert!(mw.iter().zip(&fc1.mask.keep).all(|(&v, &k)| k || v == 0.0));
    }

    #[test]
    fn missing_masks_default_dense() {
        let g = lenet5();
        let mp = ModelParams::load(&fake_store(&g, false), &g).unwrap();
        assert_eq!(mp.sparsity().global_sparsity(), 0.0);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let g = lenet5();
        let mut store = fake_store(&g, false);
        // Corrupt conv1.w element count.
        let idx = store.tensors.iter().position(|t| t.name == "conv1.w").unwrap();
        store.tensors[idx] = Tensor::f32("conv1.w", vec![10], vec![0.0; 10]);
        let err = ModelParams::load(&store, &g).unwrap_err();
        assert!(err.to_string().contains("conv1.w"), "{err}");
    }

    #[test]
    fn synthetic_prune_store_roundtrip() {
        let g = lenet5();
        let mut mp = ModelParams::synthetic(&g, 42);
        assert_eq!(mp.sparsity().global_sparsity(), 0.0);
        mp.prune_global(0.8, 0.05).unwrap();
        let s = mp.sparsity().global_sparsity();
        assert!((s - 0.8).abs() < 0.02, "global sparsity {s}");
        // Export and reload through the LSTW interchange: identical.
        let back = ModelParams::load(&mp.to_store(), &g).unwrap();
        for (a, b) in mp.layers.iter().zip(&back.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.w, b.w);
            assert_eq!(a.bias, b.bias);
            assert_eq!(a.mask, b.mask);
        }
    }

    #[test]
    fn prune_nm_masks_every_layer() {
        let g = lenet5();
        let mut mp = ModelParams::synthetic(&g, 13);
        mp.prune_nm(2, 4).unwrap();
        for l in &mp.layers {
            // Divisible fold_in on every LeNet-5 layer at m=4 except
            // conv1 (25): full groups keep exactly 2 of 4, the tail
            // keeps min(2, tail).
            let fit = crate::sparsity::nm::nm_fit(&l.mask.keep, l.fold_in, l.cout, 4).unwrap();
            assert_eq!(fit.n, 2, "{}", l.name);
        }
        assert!(mp.sparsity().global_sparsity() > 0.45);
        assert!(mp.prune_nm(5, 4).is_err());
    }

    #[test]
    fn load_artifacts_reads_params_file() {
        let g = lenet5();
        let mut mp = ModelParams::synthetic(&g, 7);
        mp.prune_global(0.5, 0.0).unwrap();
        let dir = std::env::temp_dir().join(format!("lstw_params_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        mp.to_store()
            .write_file(crate::runtime::artifact::params_path(&dir, "testtag"))
            .unwrap();
        let back = ModelParams::load_artifacts(&dir, "testtag", &g).unwrap();
        assert_eq!(back.sparsity().total_nnz(), mp.sparsity().total_nnz());
        assert!(ModelParams::load_artifacts(&dir, "absent", &g).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_tensor_rejected() {
        let g = lenet5();
        let mut store = fake_store(&g, false);
        store.tensors.retain(|t| t.name != "fc3.b");
        assert!(ModelParams::load(&store, &g).is_err());
    }
}
