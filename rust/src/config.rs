//! Run configuration + the `folding_config.json` interchange (S16).
//!
//! `folding_config.json` is the contract between the rust DSE (producer)
//! and the python stage-2 compile path (consumer: re-sparse fine-tune and
//! AOT of the proposed design), and between the CLI and the serving
//! coordinator (artifact selection).
//!
//! [`PolicyConfig`] is the serving control plane's operator-facing
//! configuration (DESIGN.md §11): per-tag SLOs parsed from the CLI's
//! repeatable `--slo tag=p99_ms[:weight]` plus the queue-autotune
//! toggle, with a JSON round-trip so a fleet policy can ship as a file.

use crate::coordinator::policy::{AutotuneConfig, SloSpec};
use crate::folding::{FoldingConfig, LayerFold, Style};
use crate::graph::Graph;
use crate::util::error::{Error, Result};
use crate::util::json::{self, Value};

/// Serializable DSE outcome for one strategy.
#[derive(Debug, Clone)]
pub struct FoldingConfigFile {
    /// Device name the DSE targeted.
    pub device: String,
    /// Strategy name the folding was produced by.
    pub strategy: String,
    /// Estimated clock (MHz) at the chosen configuration.
    pub f_mhz: f64,
    /// Estimated totals, recorded for provenance.
    pub est_luts: u64,
    /// Estimated throughput at the chosen configuration.
    pub est_throughput_fps: f64,
    /// Estimated single-frame latency at the chosen configuration.
    pub est_latency_us: f64,
    /// The per-layer folding decisions.
    pub folding: FoldingConfig,
}

impl FoldingConfigFile {
    /// Serialise to the `folding_config.json` shape.
    pub fn to_json(&self) -> Value {
        let layers = self
            .folding
            .layers
            .iter()
            .map(|(name, f)| {
                (
                    name.clone(),
                    json::obj(vec![
                        ("style", json::s(f.style.as_str())),
                        ("pe", json::num(f.pe as f64)),
                        ("simd", json::num(f.simd as f64)),
                        ("target_sparsity", json::num(f.sparsity)),
                    ]),
                )
            })
            .collect();
        json::obj(vec![
            ("device", json::s(self.device.clone())),
            ("strategy", json::s(self.strategy.clone())),
            ("f_mhz", json::num(self.f_mhz)),
            ("est_luts", json::num(self.est_luts as f64)),
            ("est_throughput_fps", json::num(self.est_throughput_fps)),
            ("est_latency_us", json::num(self.est_latency_us)),
            ("layers", Value::Obj(layers)),
        ])
    }

    /// Parse the `folding_config.json` shape.
    pub fn from_json(v: &Value) -> Result<Self> {
        let layers_v = v
            .req("layers")?
            .as_obj()
            .ok_or_else(|| Error::config("'layers' is not an object"))?;
        let mut folding = FoldingConfig::default();
        for (name, lv) in layers_v {
            let fold = LayerFold {
                style: Style::parse(lv.req_str("style")?)?,
                pe: lv.req_usize("pe")?,
                simd: lv.req_usize("simd")?,
                sparsity: lv.req_f64("target_sparsity")?,
            };
            folding.layers.push((name.clone(), fold));
        }
        Ok(FoldingConfigFile {
            device: v.req_str("device")?.to_string(),
            strategy: v.req_str("strategy")?.to_string(),
            f_mhz: v.req_f64("f_mhz")?,
            est_luts: v.req_f64("est_luts")? as u64,
            est_throughput_fps: v.req_f64("est_throughput_fps")?,
            est_latency_us: v.req_f64("est_latency_us")?,
            folding,
        })
    }

    /// Write `folding_config.json` to `path`.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        json::write_file(path, &self.to_json())
    }

    /// Read a `folding_config.json` from `path`.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::from_json(&json::parse_file(path)?)
    }

    /// Validate the folding against a graph (after loading).
    pub fn check(&self, g: &Graph) -> Result<()> {
        self.folding.check(g)
    }
}

/// Operator-level policy configuration for the serving control plane
/// (DESIGN.md §11): per-tag SLOs (p99 target + admission weight) and the
/// optional queue-depth autotuner.
#[derive(Debug, Clone, Default)]
pub struct PolicyConfig {
    /// `(tag, slo)` pairs, in declaration order (tags unique).
    pub slos: Vec<(String, SloSpec)>,
    /// Queue-depth autotuner bounds, when enabled.
    pub autotune: Option<AutotuneConfig>,
}

impl PolicyConfig {
    /// Parse one `--slo` argument of the form `tag=p99_ms[:weight]`
    /// (weight defaults to 1.0) and add it. Rejects malformed specs,
    /// non-positive or non-finite numbers, and duplicate tags.
    pub fn add_slo_arg(&mut self, spec: &str) -> Result<()> {
        let bad =
            || Error::config(format!("--slo wants tag=p99_ms[:weight], got '{spec}'"));
        let (tag, rest) = spec.split_once('=').ok_or_else(bad)?;
        if tag.is_empty() || rest.is_empty() {
            return Err(bad());
        }
        let (p99_s, weight_s) = match rest.split_once(':') {
            Some((p, w)) => (p, Some(w)),
            None => (rest, None),
        };
        let p99_ms: f64 = p99_s.parse().map_err(|_| bad())?;
        let weight: f64 = match weight_s {
            Some(w) => w.parse().map_err(|_| bad())?,
            None => 1.0,
        };
        let positive_finite = |x: f64| x.is_finite() && x > 0.0;
        if !(positive_finite(p99_ms) && positive_finite(weight)) {
            return Err(Error::config(format!(
                "--slo '{spec}': p99_ms and weight must be positive finite numbers"
            )));
        }
        if self.slos.iter().any(|(t, _)| t == tag) {
            return Err(Error::config(format!("--slo: duplicate tag '{tag}'")));
        }
        self.slos.push((tag.to_string(), SloSpec::new(p99_ms, weight)));
        Ok(())
    }

    /// The SLO configured for `tag`, if any.
    pub fn slo_for(&self, tag: &str) -> Option<SloSpec> {
        self.slos.iter().find(|(t, _)| t == tag).map(|(_, s)| *s)
    }

    /// Serialise to JSON (`{"slos": {tag: {p99_ms, weight}}, "autotune":
    /// {...}?}`).
    pub fn to_json(&self) -> Value {
        let slos = self
            .slos
            .iter()
            .map(|(tag, s)| {
                (
                    tag.clone(),
                    json::obj(vec![
                        ("p99_ms", json::num(s.p99_ms)),
                        ("weight", json::num(s.weight)),
                    ]),
                )
            })
            .collect();
        let mut fields = vec![("slos", Value::Obj(slos))];
        if let Some(a) = &self.autotune {
            fields.push((
                "autotune",
                json::obj(vec![
                    ("min_depth", json::num(a.min_depth as f64)),
                    ("max_depth", json::num(a.max_depth as f64)),
                    ("hysteresis_ticks", json::num(a.hysteresis_ticks as f64)),
                    ("cooldown_ticks", json::num(a.cooldown_ticks as f64)),
                    ("steal_fraction", json::num(a.steal_fraction)),
                ]),
            ));
        }
        json::obj(fields)
    }

    /// Parse the [`PolicyConfig::to_json`] shape. A policy file is
    /// untrusted operator input, so the same domain rules the CLI path
    /// enforces apply here: positive finite SLO numbers, unique tags,
    /// and autotune bounds that `QueueAutotune::new` would accept —
    /// violations return a config error instead of panicking later.
    pub fn from_json(v: &Value) -> Result<Self> {
        let positive_finite = |x: f64| x.is_finite() && x > 0.0;
        let slos_v = v
            .req("slos")?
            .as_obj()
            .ok_or_else(|| Error::config("'slos' is not an object"))?;
        let mut slos: Vec<(String, SloSpec)> = Vec::with_capacity(slos_v.len());
        for (tag, sv) in slos_v {
            let p99_ms = sv.req_f64("p99_ms")?;
            let weight = sv.req_f64("weight")?;
            if !(positive_finite(p99_ms) && positive_finite(weight)) {
                return Err(Error::config(format!(
                    "slo '{tag}': p99_ms and weight must be positive finite numbers"
                )));
            }
            if slos.iter().any(|(t, _)| t == tag) {
                return Err(Error::config(format!("slo: duplicate tag '{tag}'")));
            }
            slos.push((tag.clone(), SloSpec::new(p99_ms, weight)));
        }
        let autotune = match v.get("autotune") {
            None => None,
            Some(av) => {
                let depth = |key: &str| -> Result<usize> {
                    let x = av.req_f64(key)?;
                    if !x.is_finite() || x < 1.0 || x.fract() != 0.0 {
                        return Err(Error::config(format!(
                            "autotune.{key} must be a positive integer, got {x}"
                        )));
                    }
                    Ok(x as usize)
                };
                let ticks = |key: &str| -> Result<u32> {
                    let x = av.req_f64(key)?;
                    if !x.is_finite() || x < 0.0 || x.fract() != 0.0 {
                        return Err(Error::config(format!(
                            "autotune.{key} must be a non-negative integer, got {x}"
                        )));
                    }
                    Ok(x as u32)
                };
                let cfg = AutotuneConfig {
                    min_depth: depth("min_depth")?,
                    max_depth: depth("max_depth")?,
                    hysteresis_ticks: ticks("hysteresis_ticks")?,
                    cooldown_ticks: ticks("cooldown_ticks")?,
                    steal_fraction: av.req_f64("steal_fraction")?,
                };
                if cfg.max_depth < cfg.min_depth {
                    return Err(Error::config(format!(
                        "autotune: max_depth {} < min_depth {}",
                        cfg.max_depth, cfg.min_depth
                    )));
                }
                if !cfg.steal_fraction.is_finite() || cfg.steal_fraction < 0.0 {
                    return Err(Error::config(format!(
                        "autotune.steal_fraction must be a non-negative finite number, \
                         got {}",
                        cfg.steal_fraction
                    )));
                }
                Some(cfg)
            }
        };
        Ok(PolicyConfig { slos, autotune })
    }
}

/// Pruning profile exported by python stage 1 (the DSE's reference input):
/// per-global-sparsity rows of accuracy + per-layer achieved sparsity.
#[derive(Debug, Clone)]
pub struct PruneProfile {
    /// One row per swept global-sparsity operating point.
    pub rows: Vec<PruneRow>,
    /// The operating point the DSE treats as its accuracy reference.
    pub reference_global_sparsity: f64,
}

/// One operating point of the pruning reference sweep.
#[derive(Debug, Clone)]
pub struct PruneRow {
    /// Achieved global sparsity of this row.
    pub global_sparsity: f64,
    /// Test accuracy measured at this sparsity.
    pub accuracy: f64,
    /// (layer, achieved sparsity at this global threshold)
    pub layers: Vec<(String, f64)>,
}

impl PruneProfile {
    /// Parse the `prune_profile.json` shape the python exporter writes.
    pub fn from_json(v: &Value) -> Result<Self> {
        let rows_v = v
            .req("rows")?
            .as_arr()
            .ok_or_else(|| Error::config("'rows' is not an array"))?;
        let mut rows = Vec::with_capacity(rows_v.len());
        for rv in rows_v {
            let layers = rv
                .req("layers")?
                .as_obj()
                .ok_or_else(|| Error::config("'layers' is not an object"))?
                .iter()
                .map(|(k, s)| {
                    s.as_f64()
                        .map(|f| (k.clone(), f))
                        .ok_or_else(|| Error::config("layer sparsity not a number"))
                })
                .collect::<Result<Vec<_>>>()?;
            rows.push(PruneRow {
                global_sparsity: rv.req_f64("global_sparsity")?,
                accuracy: rv.req_f64("accuracy")?,
                layers,
            });
        }
        Ok(PruneProfile {
            rows,
            reference_global_sparsity: v
                .get("reference_global_sparsity")
                .and_then(Value::as_f64)
                .unwrap_or(0.8),
        })
    }

    /// Read a `prune_profile.json` from `path`.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::from_json(&json::parse_file(path)?)
    }

    /// Layer sparsity achievable at the reference operating point.
    pub fn layer_sparsity_at_reference(&self, layer: &str) -> Option<f64> {
        let row = self
            .rows
            .iter()
            .min_by(|a, b| {
                let da = (a.global_sparsity - self.reference_global_sparsity).abs();
                let db = (b.global_sparsity - self.reference_global_sparsity).abs();
                da.partial_cmp(&db).unwrap()
            })?;
        row.layers.iter().find(|(n, _)| n == layer).map(|(_, s)| *s)
    }

    /// A synthetic profile for tests / offline runs without artifacts:
    /// every layer prunes to `s` at every operating point.
    pub fn uniform(g: &Graph, sparsities: &[f64], accuracy: f64) -> Self {
        PruneProfile {
            reference_global_sparsity: sparsities.last().copied().unwrap_or(0.8),
            rows: sparsities
                .iter()
                .map(|&s| PruneRow {
                    global_sparsity: s,
                    accuracy,
                    layers: g.mac_nodes().map(|n| (n.name.clone(), s)).collect(),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::lenet5;

    #[test]
    fn folding_config_roundtrip() {
        let g = lenet5();
        let folding = FoldingConfig::unrolled(&g);
        let f = FoldingConfigFile {
            device: "xcu50".into(),
            strategy: "proposed".into(),
            f_mhz: 287.5,
            est_luts: 23_465,
            est_throughput_fps: 265_429.0,
            est_latency_us: 18.13,
            folding,
        };
        let text = f.to_json().to_string_pretty();
        let f2 = FoldingConfigFile::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(f.folding, f2.folding);
        assert_eq!(f2.strategy, "proposed");
        f2.check(&g).unwrap();
    }

    #[test]
    fn prune_profile_parses_python_shape() {
        let text = r#"{
            "reference_global_sparsity": 0.8,
            "rows": [
                {"global_sparsity_target": 0.5, "global_sparsity": 0.5,
                 "accuracy": 0.95, "layers": {"conv1": 0.1, "fc1": 0.6}},
                {"global_sparsity_target": 0.8, "global_sparsity": 0.8,
                 "accuracy": 0.70, "layers": {"conv1": 0.3, "fc1": 0.85}}
            ]
        }"#;
        let p = PruneProfile::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(p.rows.len(), 2);
        assert_eq!(p.layer_sparsity_at_reference("fc1"), Some(0.85));
        assert_eq!(p.layer_sparsity_at_reference("nope"), None);
    }

    #[test]
    fn uniform_profile() {
        let g = lenet5();
        let p = PruneProfile::uniform(&g, &[0.5, 0.8], 0.9);
        assert_eq!(p.layer_sparsity_at_reference("conv2"), Some(0.8));
    }

    #[test]
    fn policy_config_parses_slo_args() {
        let mut p = PolicyConfig::default();
        p.add_slo_arg("gold=20:8").unwrap();
        p.add_slo_arg("bulk=50").unwrap(); // weight defaults to 1.0
        let gold = p.slo_for("gold").unwrap();
        assert_eq!(gold.p99_ms, 20.0);
        assert_eq!(gold.weight, 8.0);
        assert_eq!(p.slo_for("bulk").unwrap().weight, 1.0);
        assert!(p.slo_for("ghost").is_none());
        // A duplicate tag is rejected, leaving the first entry intact.
        assert!(p.add_slo_arg("gold=30:2").is_err());
        assert_eq!(p.slo_for("gold").unwrap().p99_ms, 20.0);
        // Malformed / out-of-domain specs are rejected.
        for bad in [
            "gold", "=20", "gold=", "gold=abc", "gold=20:x", "gold=0:1", "gold=-5",
            "gold=20:-1", "gold=nan", "gold=20:inf",
        ] {
            let mut q = PolicyConfig::default();
            assert!(q.add_slo_arg(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn policy_config_roundtrips_through_json() {
        let mut p = PolicyConfig {
            slos: Vec::new(),
            autotune: Some(crate::coordinator::policy::AutotuneConfig::default()),
        };
        p.add_slo_arg("a=20:8").unwrap();
        p.add_slo_arg("b=100:0.5").unwrap();
        let text = p.to_json().to_string_pretty();
        let q = PolicyConfig::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(q.slos.len(), 2);
        assert_eq!(q.slo_for("a"), p.slo_for("a"));
        assert_eq!(q.slo_for("b"), p.slo_for("b"));
        assert_eq!(q.autotune, p.autotune);
        // Autotune is optional in the file.
        let bare = PolicyConfig::from_json(&json::parse(r#"{"slos": {}}"#).unwrap()).unwrap();
        assert!(bare.autotune.is_none());
        assert!(bare.slos.is_empty());
    }

    #[test]
    fn policy_config_from_json_rejects_out_of_domain_files() {
        // A policy file is untrusted input: the same domain rules as the
        // CLI path, and autotune bounds QueueAutotune::new would assert
        // on must come back as Err, never a later panic.
        for bad in [
            r#"{"slos": {"a": {"p99_ms": -1, "weight": 1}}}"#,
            r#"{"slos": {"a": {"p99_ms": 20, "weight": 0}}}"#,
            r#"{"slos": {},
                "autotune": {"min_depth": 0, "max_depth": 64,
                             "hysteresis_ticks": 2, "cooldown_ticks": 2,
                             "steal_fraction": 0.5}}"#,
            r#"{"slos": {},
                "autotune": {"min_depth": 8, "max_depth": 4,
                             "hysteresis_ticks": 2, "cooldown_ticks": 2,
                             "steal_fraction": 0.5}}"#,
            r#"{"slos": {},
                "autotune": {"min_depth": 2, "max_depth": 64,
                             "hysteresis_ticks": 2, "cooldown_ticks": 2,
                             "steal_fraction": -0.5}}"#,
            r#"{"slos": {},
                "autotune": {"min_depth": 2.5, "max_depth": 64,
                             "hysteresis_ticks": 2, "cooldown_ticks": 2,
                             "steal_fraction": 0.5}}"#,
        ] {
            let v = json::parse(bad).unwrap();
            assert!(PolicyConfig::from_json(&v).is_err(), "accepted {bad}");
        }
    }
}
