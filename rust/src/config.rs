//! Run configuration + the `folding_config.json` interchange (S16).
//!
//! `folding_config.json` is the contract between the rust DSE (producer)
//! and the python stage-2 compile path (consumer: re-sparse fine-tune and
//! AOT of the proposed design), and between the CLI and the serving
//! coordinator (artifact selection).

use crate::folding::{FoldingConfig, LayerFold, Style};
use crate::graph::Graph;
use crate::util::error::{Error, Result};
use crate::util::json::{self, Value};

/// Serializable DSE outcome for one strategy.
#[derive(Debug, Clone)]
pub struct FoldingConfigFile {
    /// Device name the DSE targeted.
    pub device: String,
    /// Strategy name the folding was produced by.
    pub strategy: String,
    /// Estimated clock (MHz) at the chosen configuration.
    pub f_mhz: f64,
    /// Estimated totals, recorded for provenance.
    pub est_luts: u64,
    /// Estimated throughput at the chosen configuration.
    pub est_throughput_fps: f64,
    /// Estimated single-frame latency at the chosen configuration.
    pub est_latency_us: f64,
    /// The per-layer folding decisions.
    pub folding: FoldingConfig,
}

impl FoldingConfigFile {
    /// Serialise to the `folding_config.json` shape.
    pub fn to_json(&self) -> Value {
        let layers = self
            .folding
            .layers
            .iter()
            .map(|(name, f)| {
                (
                    name.clone(),
                    json::obj(vec![
                        ("style", json::s(f.style.as_str())),
                        ("pe", json::num(f.pe as f64)),
                        ("simd", json::num(f.simd as f64)),
                        ("target_sparsity", json::num(f.sparsity)),
                    ]),
                )
            })
            .collect();
        json::obj(vec![
            ("device", json::s(self.device.clone())),
            ("strategy", json::s(self.strategy.clone())),
            ("f_mhz", json::num(self.f_mhz)),
            ("est_luts", json::num(self.est_luts as f64)),
            ("est_throughput_fps", json::num(self.est_throughput_fps)),
            ("est_latency_us", json::num(self.est_latency_us)),
            ("layers", Value::Obj(layers)),
        ])
    }

    /// Parse the `folding_config.json` shape.
    pub fn from_json(v: &Value) -> Result<Self> {
        let layers_v = v
            .req("layers")?
            .as_obj()
            .ok_or_else(|| Error::config("'layers' is not an object"))?;
        let mut folding = FoldingConfig::default();
        for (name, lv) in layers_v {
            let fold = LayerFold {
                style: Style::parse(lv.req_str("style")?)?,
                pe: lv.req_usize("pe")?,
                simd: lv.req_usize("simd")?,
                sparsity: lv.req_f64("target_sparsity")?,
            };
            folding.layers.push((name.clone(), fold));
        }
        Ok(FoldingConfigFile {
            device: v.req_str("device")?.to_string(),
            strategy: v.req_str("strategy")?.to_string(),
            f_mhz: v.req_f64("f_mhz")?,
            est_luts: v.req_f64("est_luts")? as u64,
            est_throughput_fps: v.req_f64("est_throughput_fps")?,
            est_latency_us: v.req_f64("est_latency_us")?,
            folding,
        })
    }

    /// Write `folding_config.json` to `path`.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        json::write_file(path, &self.to_json())
    }

    /// Read a `folding_config.json` from `path`.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::from_json(&json::parse_file(path)?)
    }

    /// Validate the folding against a graph (after loading).
    pub fn check(&self, g: &Graph) -> Result<()> {
        self.folding.check(g)
    }
}

/// Pruning profile exported by python stage 1 (the DSE's reference input):
/// per-global-sparsity rows of accuracy + per-layer achieved sparsity.
#[derive(Debug, Clone)]
pub struct PruneProfile {
    /// One row per swept global-sparsity operating point.
    pub rows: Vec<PruneRow>,
    /// The operating point the DSE treats as its accuracy reference.
    pub reference_global_sparsity: f64,
}

/// One operating point of the pruning reference sweep.
#[derive(Debug, Clone)]
pub struct PruneRow {
    /// Achieved global sparsity of this row.
    pub global_sparsity: f64,
    /// Test accuracy measured at this sparsity.
    pub accuracy: f64,
    /// (layer, achieved sparsity at this global threshold)
    pub layers: Vec<(String, f64)>,
}

impl PruneProfile {
    /// Parse the `prune_profile.json` shape the python exporter writes.
    pub fn from_json(v: &Value) -> Result<Self> {
        let rows_v = v
            .req("rows")?
            .as_arr()
            .ok_or_else(|| Error::config("'rows' is not an array"))?;
        let mut rows = Vec::with_capacity(rows_v.len());
        for rv in rows_v {
            let layers = rv
                .req("layers")?
                .as_obj()
                .ok_or_else(|| Error::config("'layers' is not an object"))?
                .iter()
                .map(|(k, s)| {
                    s.as_f64()
                        .map(|f| (k.clone(), f))
                        .ok_or_else(|| Error::config("layer sparsity not a number"))
                })
                .collect::<Result<Vec<_>>>()?;
            rows.push(PruneRow {
                global_sparsity: rv.req_f64("global_sparsity")?,
                accuracy: rv.req_f64("accuracy")?,
                layers,
            });
        }
        Ok(PruneProfile {
            rows,
            reference_global_sparsity: v
                .get("reference_global_sparsity")
                .and_then(Value::as_f64)
                .unwrap_or(0.8),
        })
    }

    /// Read a `prune_profile.json` from `path`.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::from_json(&json::parse_file(path)?)
    }

    /// Layer sparsity achievable at the reference operating point.
    pub fn layer_sparsity_at_reference(&self, layer: &str) -> Option<f64> {
        let row = self
            .rows
            .iter()
            .min_by(|a, b| {
                let da = (a.global_sparsity - self.reference_global_sparsity).abs();
                let db = (b.global_sparsity - self.reference_global_sparsity).abs();
                da.partial_cmp(&db).unwrap()
            })?;
        row.layers.iter().find(|(n, _)| n == layer).map(|(_, s)| *s)
    }

    /// A synthetic profile for tests / offline runs without artifacts:
    /// every layer prunes to `s` at every operating point.
    pub fn uniform(g: &Graph, sparsities: &[f64], accuracy: f64) -> Self {
        PruneProfile {
            reference_global_sparsity: sparsities.last().copied().unwrap_or(0.8),
            rows: sparsities
                .iter()
                .map(|&s| PruneRow {
                    global_sparsity: s,
                    accuracy,
                    layers: g.mac_nodes().map(|n| (n.name.clone(), s)).collect(),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::lenet5;

    #[test]
    fn folding_config_roundtrip() {
        let g = lenet5();
        let folding = FoldingConfig::unrolled(&g);
        let f = FoldingConfigFile {
            device: "xcu50".into(),
            strategy: "proposed".into(),
            f_mhz: 287.5,
            est_luts: 23_465,
            est_throughput_fps: 265_429.0,
            est_latency_us: 18.13,
            folding,
        };
        let text = f.to_json().to_string_pretty();
        let f2 = FoldingConfigFile::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(f.folding, f2.folding);
        assert_eq!(f2.strategy, "proposed");
        f2.check(&g).unwrap();
    }

    #[test]
    fn prune_profile_parses_python_shape() {
        let text = r#"{
            "reference_global_sparsity": 0.8,
            "rows": [
                {"global_sparsity_target": 0.5, "global_sparsity": 0.5,
                 "accuracy": 0.95, "layers": {"conv1": 0.1, "fc1": 0.6}},
                {"global_sparsity_target": 0.8, "global_sparsity": 0.8,
                 "accuracy": 0.70, "layers": {"conv1": 0.3, "fc1": 0.85}}
            ]
        }"#;
        let p = PruneProfile::from_json(&json::parse(text).unwrap()).unwrap();
        assert_eq!(p.rows.len(), 2);
        assert_eq!(p.layer_sparsity_at_reference("fc1"), Some(0.85));
        assert_eq!(p.layer_sparsity_at_reference("nope"), None);
    }

    #[test]
    fn uniform_profile() {
        let g = lenet5();
        let p = PruneProfile::uniform(&g, &[0.5, 0.8], 0.9);
        assert_eq!(p.layer_sparsity_at_reference("conv2"), Some(0.8));
    }
}
