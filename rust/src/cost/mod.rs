//! Analytic cost models of the dataflow accelerator (substrate S6) — the
//! stand-in for FINN + Vivado on the XCU50 (DESIGN.md §2, §7).
//!
//! The paper's DSE makes its decisions from *fast ONNX-graph estimates* of
//! per-layer latency and resources (Sec. III); these models implement that
//! estimate→decide loop:
//!
//! * [`luts`]   — LUT cost per layer per [`Style`]: folded MVAU, unrolled
//!   baked dense, unrolled baked **sparse** (nnz-proportional: the
//!   engine-free claim), partial sparse;
//! * [`clock`]  — achievable f_max from combinational depth (adder-tree
//!   fan-in) and routing congestion: *why pruning speeds up an unrolled
//!   design* (Table I rows 5→6);
//! * [`latency`] — initiation intervals and analytic pipeline latency (the
//!   cycle-accurate number comes from [`crate::sim`]).
//!
//! Constants are calibrated so the *shape* of Table I holds (who wins, by
//! what factor); the calibration tests in this module pin the dense-unroll
//! and auto-fold totals to the paper's order of magnitude.

pub mod clock;
pub mod latency;
pub mod luts;

use crate::device::Device;
use crate::folding::{FoldingConfig, LayerFold};
use crate::graph::{Graph, Node, Op};
use crate::util::error::Result;

/// Cost estimate for one dataflow stage.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerCost {
    /// Layer name.
    pub name: String,
    /// Initiation interval: cycles between frames in steady state.
    pub ii_cycles: u64,
    /// First-frame fill latency contribution (cycles).
    pub fill_cycles: u64,
    /// Estimated LUT usage.
    pub luts: u64,
    /// Estimated 36kb BRAM blocks.
    pub bram36: u64,
    /// Estimated DSP slices.
    pub dsps: u64,
    /// Combinational depth (levels of logic) — drives f_max.
    pub logic_depth: f64,
}

/// Whole-accelerator estimate under one folding configuration.
#[derive(Debug, Clone)]
pub struct ModelCost {
    /// Per-stage estimates, in stream order.
    pub layers: Vec<LayerCost>,
    /// Summed LUT estimate.
    pub total_luts: u64,
    /// Summed BRAM estimate.
    pub total_bram: u64,
    /// Summed DSP estimate.
    pub total_dsps: u64,
    /// Achievable clock after depth + congestion derating (MHz).
    pub f_mhz: f64,
    /// Steady-state bottleneck II (cycles/frame).
    pub max_ii: u64,
    /// Analytic first-frame latency (seconds).
    pub latency_s: f64,
    /// Steady-state throughput (frames/second).
    pub throughput_fps: f64,
}

impl ModelCost {
    /// The estimate of layer `name`, if present.
    pub fn layer(&self, name: &str) -> Option<&LayerCost> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// The stage with the largest II.
    pub fn bottleneck(&self) -> &LayerCost {
        self.layers
            .iter()
            .max_by_key(|l| l.ii_cycles)
            .expect("non-empty model")
    }

    /// True when every resource total fits the device budget.
    pub fn fits(&self, dev: &Device) -> bool {
        self.total_luts <= dev.lut_budget()
            && self.total_bram <= dev.bram_budget()
            && self.total_dsps <= dev.dsp_budget()
    }
}

/// Evaluate a folding configuration on a device.
pub fn evaluate(g: &Graph, cfg: &FoldingConfig, dev: &Device) -> Result<ModelCost> {
    cfg.check(g)?;
    let mut layers = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let lc = match node.op {
            Op::Conv | Op::Fc => {
                let fold = cfg
                    .get(&node.name)
                    .expect("checked config covers all MAC nodes");
                layer_cost(node, fold, g.weight_bits, g.act_bits)
            }
            Op::MaxPool => pool_cost(node, g.act_bits),
        };
        layers.push(lc);
    }

    let total_luts: u64 = layers.iter().map(|l| l.luts).sum();
    let total_bram: u64 = layers.iter().map(|l| l.bram36).sum();
    let total_dsps: u64 = layers.iter().map(|l| l.dsps).sum();
    let max_depth = layers.iter().map(|l| l.logic_depth).fold(0.0, f64::max);
    let f_mhz = clock::f_max_mhz(dev, max_depth, total_luts);
    let max_ii = layers.iter().map(|l| l.ii_cycles).max().unwrap_or(1).max(1);
    let latency_s = latency::pipeline_latency_s(&layers, f_mhz);
    let throughput_fps = f_mhz * 1e6 / max_ii as f64;

    Ok(ModelCost {
        layers,
        total_luts,
        total_bram,
        total_dsps,
        f_mhz,
        max_ii,
        latency_s,
        throughput_fps,
    })
}

/// Cost of one MAC stage under a folding decision.
pub fn layer_cost(node: &Node, fold: &LayerFold, wbits: usize, abits: usize) -> LayerCost {
    let ii = latency::ii_cycles(node, fold);
    LayerCost {
        name: node.name.clone(),
        ii_cycles: ii,
        fill_cycles: latency::fill_cycles(node, fold),
        luts: luts::layer_luts(node, fold, wbits, abits),
        bram36: luts::layer_bram(node, fold, wbits),
        dsps: 0, // 4-bit MACs map to LUTs in this flow (FINN-style)
        logic_depth: clock::layer_depth(node, fold),
    }
}

/// Cost of a pooling stage (pure streaming, no weights).
pub fn pool_cost(node: &Node, abits: usize) -> LayerCost {
    LayerCost {
        name: node.name.clone(),
        ii_cycles: latency::pool_ii_cycles(node),
        fill_cycles: latency::pool_fill_cycles(node),
        luts: luts::pool_luts(node, abits),
        bram36: 0,
        dsps: 0,
        logic_depth: clock::POOL_DEPTH,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::XCU50;
    use crate::folding::FoldingConfig;
    use crate::graph::builder::lenet5;

    /// Calibration: dense full unroll lands in the paper's order of
    /// magnitude (Table I: 433,249 LUTs).
    #[test]
    fn dense_unroll_lut_scale() {
        let g = lenet5();
        let cfg = FoldingConfig::unrolled(&g);
        let mc = evaluate(&g, &cfg, &XCU50).unwrap();
        assert!(
            (300_000..600_000).contains(&mc.total_luts),
            "dense unroll total {} out of calibration band",
            mc.total_luts
        );
        // It must fit the XCU50 (it did in the paper).
        assert!(mc.fits(&XCU50));
    }

    /// Calibration: fully folded is tiny and slow.
    #[test]
    fn minimal_fold_is_small_and_slow() {
        let g = lenet5();
        let cfg = FoldingConfig::minimal(&g);
        let mc = evaluate(&g, &cfg, &XCU50).unwrap();
        assert!(mc.total_luts < 20_000, "minimal fold {} LUTs", mc.total_luts);
        // conv2 is the bottleneck of the fully folded net (paper Fig. 2).
        assert_eq!(mc.bottleneck().name, "conv2");
        // Far slower than unrolled.
        let un = evaluate(&g, &FoldingConfig::unrolled(&g), &XCU50).unwrap();
        assert!(mc.throughput_fps * 20.0 < un.throughput_fps);
    }

    /// The paper's key mechanism: pruning an unrolled design *increases*
    /// throughput (shallower trees, less congestion) while slashing LUTs.
    #[test]
    fn sparse_unroll_beats_dense_unroll() {
        let g = lenet5();
        let dense = FoldingConfig::unrolled(&g);
        let mut sparse = FoldingConfig::unrolled(&g);
        for (name, f) in sparse.layers.iter_mut() {
            let node = g.node(name).unwrap();
            *f = crate::folding::LayerFold::unrolled_sparse(node, 0.8);
        }
        let d = evaluate(&g, &dense, &XCU50).unwrap();
        let s = evaluate(&g, &sparse, &XCU50).unwrap();
        assert!(s.total_luts < d.total_luts / 3, "luts {} vs {}", s.total_luts, d.total_luts);
        assert!(s.throughput_fps > d.throughput_fps, "{} vs {}", s.throughput_fps, d.throughput_fps);
        assert!(s.latency_s < d.latency_s);
    }

    #[test]
    fn unrolled_fc_ii_is_one() {
        let g = lenet5();
        let cfg = FoldingConfig::unrolled(&g);
        let mc = evaluate(&g, &cfg, &XCU50).unwrap();
        assert_eq!(mc.layer("fc1").unwrap().ii_cycles, 1);
        assert_eq!(mc.layer("conv1").unwrap().ii_cycles, 576);
    }

    #[test]
    fn pool_layers_cheap() {
        let g = lenet5();
        let cfg = FoldingConfig::unrolled(&g);
        let mc = evaluate(&g, &cfg, &XCU50).unwrap();
        let pool = mc.layer("conv1_pool").unwrap();
        assert!(pool.luts < 500);
        assert_eq!(pool.bram36, 0);
    }

    #[test]
    fn folded_uses_bram_unrolled_does_not() {
        let g = lenet5();
        let folded = evaluate(&g, &FoldingConfig::minimal(&g), &XCU50).unwrap();
        let unrolled = evaluate(&g, &FoldingConfig::unrolled(&g), &XCU50).unwrap();
        assert!(folded.total_bram > 0);
        assert_eq!(unrolled.total_bram, 0, "baked weights need no BRAM");
    }

    #[test]
    fn throughput_is_clock_over_ii() {
        let g = lenet5();
        let mc = evaluate(&g, &FoldingConfig::unrolled(&g), &XCU50).unwrap();
        let expect = mc.f_mhz * 1e6 / mc.max_ii as f64;
        assert!((mc.throughput_fps - expect).abs() < 1e-6);
    }
}
