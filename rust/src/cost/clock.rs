//! Achievable-clock model: why engine-free pruning makes unrolled designs
//! *faster*, not just smaller (Table I rows Unfold 18.18 µs → Unfold+Prune
//! 15.52 µs; Proposed beats dense Unfold by 1.23× throughput).
//!
//! Two physical effects are modelled:
//!
//! 1. **Combinational depth.** A fully unrolled neuron sums `fan_in`
//!    products through a log₂-deep adder tree; the tree's depth sets the
//!    critical path. Pruning removes leaves → shallower tree → higher
//!    f_max. Folded MVAUs are register-pipelined at a shallow depth.
//! 2. **Routing congestion.** f_max degrades as device utilisation rises
//!    (433k-LUT dense unroll routes much worse than a 23k proposed
//!    design). Modelled as a linear derate in LUT utilisation.

use crate::device::Device;
use crate::folding::{LayerFold, Style};
use crate::graph::Node;

/// Pipeline depth (levels) below which logic is "free" at f_base.
pub const D0: f64 = 6.0;
/// f_max derate per level of extra combinational depth.
pub const K_DEPTH: f64 = 0.115;
/// f_max derate per unit of LUT-budget utilisation.
pub const K_CONG: f64 = 0.30;
/// Depth of a pooling comparator stage.
pub const POOL_DEPTH: f64 = 3.0;
/// Depth of a register-pipelined folded MVAU stage.
pub const FOLDED_DEPTH: f64 = 5.0;

/// Combinational depth of one MAC stage under a folding decision.
pub fn layer_depth(node: &Node, fold: &LayerFold) -> f64 {
    match fold.style {
        Style::Folded | Style::PartialSparse => FOLDED_DEPTH,
        Style::UnrolledDense => tree_depth(node.fold_in() as f64),
        Style::UnrolledSparse | Style::NmStructured => {
            // Surviving fan-in per neuron sets the pruned tree's height.
            let fan_in = (node.fold_in() as f64) * (1.0 - fold.sparsity);
            tree_depth(fan_in)
        }
    }
}

/// Adder-tree depth for `fan_in` leaves plus the constant-multiplier level.
fn tree_depth(fan_in: f64) -> f64 {
    1.0 + fan_in.max(2.0).log2().ceil()
}

/// Achievable clock for the whole accelerator.
pub fn f_max_mhz(dev: &Device, max_depth: f64, total_luts: u64) -> f64 {
    let depth_derate = 1.0 + K_DEPTH * (max_depth - D0).max(0.0);
    let util = total_luts as f64 / dev.lut_budget() as f64;
    let cong_derate = 1.0 + K_CONG * util;
    dev.f_base_mhz / (depth_derate * cong_derate)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::XCU50;
    use crate::folding::LayerFold;
    use crate::graph::builder::lenet5;
    use crate::util::propcheck::check;

    #[test]
    fn pruning_reduces_depth() {
        let g = lenet5();
        let fc1 = g.node("fc1").unwrap(); // fan_in 256 -> depth 9
        let dense = LayerFold::unrolled(fc1);
        let sparse = LayerFold::unrolled_sparse(fc1, 0.85);
        assert!(layer_depth(fc1, &sparse) < layer_depth(fc1, &dense));
        assert_eq!(layer_depth(fc1, &dense), 1.0 + 8.0);
        // 256 * 0.15 = 38.4 -> ceil(log2) = 6
        assert_eq!(layer_depth(fc1, &sparse), 7.0);
    }

    #[test]
    fn folded_depth_constant() {
        let g = lenet5();
        let fc1 = g.node("fc1").unwrap();
        let f = LayerFold::minimal();
        assert_eq!(layer_depth(fc1, &f), FOLDED_DEPTH);
    }

    #[test]
    fn fmax_decreases_with_depth_and_util() {
        let base = f_max_mhz(&XCU50, D0, 10_000);
        assert!(f_max_mhz(&XCU50, D0 + 3.0, 10_000) < base);
        assert!(f_max_mhz(&XCU50, D0, 400_000) < base);
        // Shallow + small: essentially f_base.
        assert!((base - XCU50.f_base_mhz).abs() / XCU50.f_base_mhz < 0.01);
    }

    #[test]
    fn prop_fmax_positive_and_bounded() {
        check("f_max in (0, f_base]", 200, |g| {
            let depth = g.f64(1.0, 16.0);
            let luts = g.usize(0, 900_000) as u64;
            let f = f_max_mhz(&XCU50, depth, luts);
            assert!(f > 0.0);
            assert!(f <= XCU50.f_base_mhz + 1e-9);
        });
    }

    #[test]
    fn paper_mechanism_unfold_vs_pruned_unfold() {
        // Dense unroll (depth 9, ~433k LUTs) must clock slower than a
        // pruned unroll (depth ~7, ~100k LUTs): Table I rows 5 vs 6.
        let f_dense = f_max_mhz(&XCU50, 9.0, 433_249);
        let f_sparse = f_max_mhz(&XCU50, 7.0, 100_687);
        assert!(
            f_sparse / f_dense > 1.10,
            "pruning should buy >10% clock: {f_dense} vs {f_sparse}"
        );
    }
}
