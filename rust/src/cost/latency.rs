//! Initiation-interval and analytic latency models.
//!
//! `ii_cycles` is the steady-state cycles/frame of one stage; the chain's
//! throughput is `f_max / max_ii`. First-frame latency is the sum of stage
//! fills (SWU window buffering for convs, compute for MVAUs) — the
//! cycle-accurate value comes from [`crate::sim`], which the integration
//! tests compare against this estimate.

use crate::folding::{LayerFold, Style};
use crate::graph::{Node, Op};

use super::LayerCost;

/// Steady-state initiation interval (cycles/frame) of a MAC stage.
pub fn ii_cycles(node: &Node, fold: &LayerFold) -> u64 {
    match fold.style {
        Style::Folded | Style::UnrolledDense => fold.cycles_per_frame(node),
        Style::UnrolledSparse | Style::NmStructured => {
            // Fully unrolled: one window per cycle regardless of sparsity
            // (all surviving MACs fire in parallel; the N:M schedule only
            // changes where the survivors sit, not how many fire at once).
            node.out_pixels() as u64
        }
        Style::PartialSparse => {
            // The packed schedule skips all-zero SIMD blocks: the input
            // axis shrinks to the live fraction (rounded up to SIMD).
            let live_in = ((node.fold_in() as f64) * (1.0 - fold.sparsity)).ceil() as usize;
            let live_folds = live_in.div_ceil(fold.simd).max(1) as u64;
            let out_folds = (node.fold_out() / fold.pe) as u64;
            node.out_pixels() as u64 * live_folds * out_folds
        }
    }
}

/// First-frame fill contribution of a MAC stage.
pub fn fill_cycles(node: &Node, fold: &LayerFold) -> u64 {
    match node.op {
        Op::Conv => {
            // SWU must buffer k-1 input rows plus k pixels before the first
            // window is complete.
            let swu = ((node.k - 1) * node.ifm + node.k) as u64;
            swu + per_output_cycles(node, fold)
        }
        Op::Fc => per_output_cycles(node, fold),
        Op::MaxPool => pool_fill_cycles(node),
    }
}

/// Cycles from first input to first output element.
fn per_output_cycles(node: &Node, fold: &LayerFold) -> u64 {
    match fold.style {
        Style::Folded | Style::UnrolledDense => {
            ((node.fold_in() / fold.simd) * (node.fold_out() / fold.pe)) as u64
        }
        Style::UnrolledSparse | Style::NmStructured => 1,
        Style::PartialSparse => {
            let live_in = ((node.fold_in() as f64) * (1.0 - fold.sparsity)).ceil() as usize;
            (live_in.div_ceil(fold.simd).max(1) * (node.fold_out() / fold.pe)) as u64
        }
    }
}

/// Pooling II: one output per k² inputs, fully streaming.
pub fn pool_ii_cycles(node: &Node) -> u64 {
    (node.ofm * node.ofm) as u64
}

/// Pooling fill: cycles until the first k-by-k window is resident.
pub fn pool_fill_cycles(node: &Node) -> u64 {
    ((node.k - 1) * node.ifm + node.k) as u64
}

/// Analytic first-frame latency of the whole pipeline at `f_mhz`.
///
/// Every stage must fill before its successor starts producing, and the
/// last stage then streams its frame at its own II; the dominant stage's
/// II bounds the drain. This matches the simulator to first order.
pub fn pipeline_latency_s(layers: &[LayerCost], f_mhz: f64) -> f64 {
    let fill: u64 = layers.iter().map(|l| l.fill_cycles).sum();
    let drain = layers.iter().map(|l| l.ii_cycles).max().unwrap_or(1);
    (fill + drain) as f64 / (f_mhz * 1e6)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::folding::LayerFold;
    use crate::graph::builder::lenet5;
    use crate::util::propcheck::check;

    #[test]
    fn sparse_unroll_ii_ignores_sparsity() {
        let g = lenet5();
        let c1 = g.node("conv1").unwrap();
        for s in [0.1, 0.5, 0.9] {
            let f = LayerFold::unrolled_sparse(c1, s);
            assert_eq!(ii_cycles(c1, &f), 576);
        }
    }

    #[test]
    fn partial_sparse_skips_zero_blocks() {
        let g = lenet5();
        let fc1 = g.node("fc1").unwrap(); // fold_in 256
        let dense = LayerFold { pe: 8, simd: 16, style: Style::Folded, sparsity: 0.0 };
        let sparse = LayerFold { pe: 8, simd: 16, style: Style::PartialSparse, sparsity: 0.75 };
        // dense: (256/16)*(120/8) = 16*15 = 240 cycles
        assert_eq!(ii_cycles(fc1, &dense), 240);
        // sparse: live_in = 64 -> 4 folds * 15 = 60 cycles
        assert_eq!(ii_cycles(fc1, &sparse), 60);
    }

    #[test]
    fn prop_partial_sparse_never_slower_than_folded() {
        let g = lenet5();
        check("packed schedule <= dense schedule", 150, |gen| {
            let node = *gen.choose(&g.mac_nodes().collect::<Vec<_>>());
            let pe = gen.divisor_of(node.fold_out());
            let simd = gen.divisor_of(node.fold_in());
            let s = gen.f64(0.0, 0.95);
            let dense = LayerFold { pe, simd, style: Style::Folded, sparsity: 0.0 };
            let sparse = LayerFold { pe, simd, style: Style::PartialSparse, sparsity: s };
            assert!(ii_cycles(node, &sparse) <= ii_cycles(node, &dense));
        });
    }

    #[test]
    fn conv_fill_includes_window_buffer() {
        let g = lenet5();
        let c1 = g.node("conv1").unwrap();
        let f = LayerFold::unrolled(c1);
        // (5-1)*28 + 5 = 117 window cycles + 1-cycle unrolled MVAU... the
        // dense unrolled per-output latency is fold product = 1*1.
        assert!(fill_cycles(c1, &f) >= 117);
    }

    #[test]
    fn latency_positive_and_fill_dominated_when_deeply_folded() {
        let g = lenet5();
        let cfg = crate::folding::FoldingConfig::minimal(&g);
        let mc = crate::cost::evaluate(&g, &cfg, &crate::device::XCU50).unwrap();
        assert!(mc.latency_s > 0.0);
        let unr = crate::cost::evaluate(
            &g,
            &crate::folding::FoldingConfig::unrolled(&g),
            &crate::device::XCU50,
        )
        .unwrap();
        assert!(unr.latency_s < mc.latency_s);
    }
}
