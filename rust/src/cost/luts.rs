//! LUT / BRAM resource models per layer style (DESIGN.md §7).
//!
//! Constants follow FINN-R's published area characterisation in spirit and
//! are calibrated against Table I's absolute scale (dense unroll ≈ 433k
//! LUTs, auto-fold ≈ 9.4k on LeNet-5 W4A4); the calibration tests in
//! `cost::tests` and `experiments::tests` pin them.

use crate::folding::{LayerFold, Style};
use crate::graph::Node;

// ---- folded MVAU (weights streamed from BRAM) ----
/// LUTs per MAC lane per (weight-bit × act-bit) product.
pub const C_MAC_FOLDED: f64 = 1.15;
/// Per-PE accumulator/threshold overhead (LUTs per accumulator bit).
pub const C_PE: f64 = 3.2;
/// Fixed per-layer control (counters, stream decode).
pub const C_LAYER: f64 = 420.0;

// ---- unrolled, weights baked into logic ----
/// LUTs per baked constant-multiplier bit-product. A constant multiplier
/// is much cheaper than a generic one: only the set bits of the constant
/// survive synthesis.
pub const C_MUL_BAKED: f64 = 0.38;
/// LUTs per adder-tree node bit.
pub const C_ADD: f64 = 0.30;

// ---- sliding window unit (conv only) ----
/// SWU line-buffer LUTs per buffered bit.
pub const C_SWU_PER_BIT: f64 = 0.9;
/// SWU control overhead in LUTs, per conv layer.
pub const C_SWU_FIXED: f64 = 180.0;

// ---- pooling ----
/// Pool compare/select LUTs per channel bit.
pub const C_POOL_PER_CH_BIT: f64 = 1.1;
/// Pool control overhead in LUTs, per pool layer.
pub const C_POOL_FIXED: f64 = 60.0;

/// Accumulator width for a MAC column with `fan_in` addends.
pub fn acc_bits(wbits: usize, abits: usize, fan_in: usize) -> f64 {
    wbits as f64 + abits as f64 + (fan_in.max(2) as f64).log2().ceil()
}

/// LUTs of the MVAU implementing `node` under `fold`.
pub fn layer_luts(node: &Node, fold: &LayerFold, wbits: usize, abits: usize) -> u64 {
    let swu = if node.op == crate::graph::Op::Conv {
        // The sliding-window buffer feeds SIMD lanes; its mux network
        // scales with the window bits it must present per cycle.
        let bits = (node.k * node.k * node.cin * abits) as f64;
        bits * C_SWU_PER_BIT + C_SWU_FIXED
    } else {
        0.0
    };

    let mac = match fold.style {
        Style::Folded => folded_mac_luts(node, fold, wbits, abits),
        Style::UnrolledDense => baked_mac_luts(node, node.weights() as u64, wbits, abits),
        // N:M costs as a baked sparse unroll over its stored (padded)
        // rows: fold.sparsity for NmStructured is the *stored*-row
        // fraction, so nnz() already charges the fixed-slot padding.
        Style::UnrolledSparse | Style::NmStructured => {
            baked_mac_luts(node, fold.nnz(node), wbits, abits)
        }
        Style::PartialSparse => partial_sparse_luts(node, fold, wbits, abits),
    };

    (mac + swu).round() as u64
}

fn folded_mac_luts(node: &Node, fold: &LayerFold, wbits: usize, abits: usize) -> f64 {
    let lanes = fold.lanes() as f64;
    let acc = acc_bits(wbits, abits, node.fold_in());
    lanes * (wbits * abits) as f64 * C_MAC_FOLDED + fold.pe as f64 * acc * C_PE + C_LAYER
}

/// Fully unrolled with `nnz` surviving weights: constant multipliers plus
/// a pruned adder tree. Zero weights contribute NOTHING — the engine-free
/// mechanism. `nnz = weights` gives the dense-unrolled cost.
fn baked_mac_luts(node: &Node, nnz: u64, wbits: usize, abits: usize) -> f64 {
    let nnz = nnz as f64;
    let cout = node.fold_out() as f64;
    // Average surviving fan-in per output neuron drives the adder tree.
    let fan_in = (nnz / cout).max(1.0);
    let acc = acc_bits(wbits, abits, fan_in.ceil() as usize);
    let mults = nnz * (wbits * abits) as f64 * C_MUL_BAKED;
    // nnz - cout two-input adders in total across all trees (a tree with
    // f leaves has f-1 internal nodes).
    let adders = (nnz - cout).max(0.0) * acc * C_ADD;
    mults + adders + C_LAYER * 0.5 // unrolled layers need almost no control
}

/// Partially unrolled sparse: a folded MVAU over the *packed* (live-block)
/// input axis. Lanes cost as folded; the win is fewer cycles + less BRAM.
fn partial_sparse_luts(node: &Node, fold: &LayerFold, wbits: usize, abits: usize) -> f64 {
    let lanes = fold.lanes() as f64;
    let acc = acc_bits(wbits, abits, node.fold_in());
    // Slightly higher per-lane cost than plain folded: the packed schedule
    // needs static block-offset ROMs (tiny, but not free).
    lanes * (wbits * abits) as f64 * C_MAC_FOLDED * 1.08
        + fold.pe as f64 * acc * C_PE
        + C_LAYER
}

/// BRAM36 blocks for weight storage (folded styles only; baked = 0).
pub fn layer_bram(node: &Node, fold: &LayerFold, wbits: usize) -> u64 {
    match fold.style {
        Style::UnrolledDense | Style::UnrolledSparse | Style::NmStructured => 0,
        Style::Folded => bram_for_bits((node.weights() * wbits) as u64, fold.pe),
        Style::PartialSparse => bram_for_bits((fold.nnz(node) * wbits as u64).max(1), fold.pe),
    }
}

fn bram_for_bits(bits: u64, pe: usize) -> u64 {
    // Each PE needs an independent read port; BRAM36 = 36kb.
    let per_pe_bits = bits.div_ceil(pe as u64);
    let blocks_per_pe = per_pe_bits.div_ceil(36 * 1024).max(1);
    blocks_per_pe * pe as u64
}

/// Pooling stage LUTs: comparator tree per channel lane.
pub fn pool_luts(node: &Node, abits: usize) -> u64 {
    (node.cin as f64 * abits as f64 * C_POOL_PER_CH_BIT + C_POOL_FIXED).round() as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::folding::LayerFold;
    use crate::graph::builder::lenet5;
    use crate::util::propcheck::check;

    #[test]
    fn sparse_luts_scale_with_nnz() {
        let g = lenet5();
        let fc1 = g.node("fc1").unwrap();
        let dense = LayerFold::unrolled(fc1);
        let l_dense = layer_luts(fc1, &dense, 4, 4);
        for s in [0.5, 0.8, 0.95] {
            let f = LayerFold::unrolled_sparse(fc1, s);
            let l = layer_luts(fc1, &f, 4, 4);
            let expect_max = (l_dense as f64 * (1.0 - s) * 1.6) as u64 + 300;
            assert!(l < expect_max, "s={s}: {l} vs dense {l_dense}");
            assert!(l < l_dense);
        }
    }

    #[test]
    fn prop_sparser_never_costs_more() {
        let g = lenet5();
        check("unrolled-sparse LUTs monotone in sparsity", 150, |gen| {
            let node = *gen.choose(&g.mac_nodes().collect::<Vec<_>>());
            let s1 = gen.f64(0.0, 0.9);
            let s2 = gen.f64(s1, 0.95);
            let l1 = layer_luts(node, &LayerFold::unrolled_sparse(node, s1), 4, 4);
            let l2 = layer_luts(node, &LayerFold::unrolled_sparse(node, s2), 4, 4);
            assert!(l2 <= l1, "s {s1}->{s2}: {l1} -> {l2}");
        });
    }

    #[test]
    fn prop_folded_luts_scale_with_lanes() {
        let g = lenet5();
        check("folded LUTs grow with PE*SIMD", 150, |gen| {
            let node = *gen.choose(&g.mac_nodes().collect::<Vec<_>>());
            let pe = gen.divisor_of(node.fold_out());
            let simd = gen.divisor_of(node.fold_in());
            let f1 = LayerFold { pe, simd, style: Style::Folded, sparsity: 0.0 };
            let f2 = LayerFold {
                pe: node.fold_out(),
                simd: node.fold_in(),
                style: Style::Folded,
                sparsity: 0.0,
            };
            assert!(layer_luts(node, &f1, 4, 4) <= layer_luts(node, &f2, 4, 4));
        });
    }

    #[test]
    fn bram_port_replication() {
        // 10k weights * 4b = 40kb: 2 blocks at PE=1, but PE=8 forces 8.
        assert_eq!(bram_for_bits(40_000, 1), 2);
        assert_eq!(bram_for_bits(40_000, 8), 8);
    }

    #[test]
    fn higher_precision_costs_more() {
        let g = lenet5();
        let c2 = g.node("conv2").unwrap();
        let f = LayerFold { pe: 4, simd: 25, style: Style::Folded, sparsity: 0.0 };
        assert!(layer_luts(c2, &f, 8, 8) > layer_luts(c2, &f, 4, 4));
    }

    #[test]
    fn acc_bits_grows_with_fan_in() {
        assert!(acc_bits(4, 4, 256) > acc_bits(4, 4, 16));
        assert_eq!(acc_bits(4, 4, 2), 9.0);
    }
}
