//! [`BatchPool`]: intra-engine batch data-parallelism for baked kernels.
//!
//! One serving engine owns one pool. A batch of `n` frames is split into
//! `workers + 1` contiguous chunks; the caller executes chunk 0 inline
//! (so a pool is never slower than serial on tiny batches) while
//! persistent worker threads pull the remaining chunks from a bounded
//! [`RingQueue`] — the same first-party substrate the sharded execution
//! plane is built on (crossbeam/rayon are unavailable offline). This is
//! one of the two ways an engine spends its spare-core budget; the other
//! is the layer pipeline (`kernel::pipeline`), whose stage-group workers
//! — and, when slack remains, replicated bottleneck-group workers
//! (DESIGN.md §15) — draw from the same per-engine budget
//! (`coordinator::shard::workers_per_engine`).
//!
//! ## Identity guarantee
//!
//! Chunks are executed by [`CompiledModel::infer_batch_with`], i.e. the
//! exact serial frame loop, and reassembled in chunk order. Frames never
//! interact (the i32 MAC datapath is per-frame), so the concatenation is
//! bit-identical to a serial [`CompiledModel::infer_batch`] — asserted in
//! `tests/kernel_batch.rs` alongside the scalar/vector datapath identity.
//!
//! ## Failure semantics
//!
//! Any chunk error (only possible via length-contract violations today)
//! fails the whole batch with the lowest-indexed chunk's error, matching
//! the serial loop's first-error behaviour. A full ring never deadlocks:
//! the dispatching caller runs the chunk inline instead of waiting.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use super::{CompiledModel, Datapath};
use crate::util::error::Result;
use crate::util::ring::{PopError, PushError, RingQueue};

/// Batches below this many frames skip the pool entirely: the dispatch +
/// wakeup cost dwarfs a couple of LeNet forwards.
const MIN_PARALLEL_BATCH: usize = 4;

/// One dispatched chunk of a batch. The input is shared (`Arc`) so
/// dispatch copies the batch once, not per worker.
struct Job {
    model: Arc<CompiledModel>,
    input: Arc<Vec<f32>>,
    /// Frame range `[start, end)` of the parent batch.
    start: usize,
    end: usize,
    dp: Datapath,
    /// Chunk index + per-chunk logits, sent back to the dispatcher.
    tx: mpsc::Sender<(usize, Result<Vec<f32>>)>,
    chunk: usize,
}

impl Job {
    fn run(self) {
        let px = self.model.input_pixels();
        let x = &self.input[self.start * px..self.end * px];
        let out = self.model.infer_batch_with(x, self.end - self.start, self.dp);
        // The dispatcher may have given up on the batch (first error
        // wins); a dead receiver is not a worker error.
        let _ = self.tx.send((self.chunk, out));
    }
}

/// A persistent worker pool that fans [`CompiledModel::infer_batch`]
/// chunks across threads. `workers == 0` degenerates to the serial loop
/// (the single-core container case), so callers never special-case.
pub struct BatchPool {
    jobs: Arc<RingQueue<Job>>,
    handles: Vec<JoinHandle<()>>,
    /// Batches that actually fanned out (observability for benches).
    dispatched: AtomicUsize,
}

impl BatchPool {
    /// Spawn `workers` threads pulling from a bounded ring. Zero workers
    /// is valid and means "always serial".
    pub fn new(workers: usize) -> Self {
        // Capacity == workers: a dispatch pushes at most `workers` jobs,
        // so `Full` is impossible in steady state; the bound exists to
        // keep the inline-on-full fallback honest rather than to queue.
        let jobs: Arc<RingQueue<Job>> = Arc::new(RingQueue::new(workers.max(1)));
        let handles = (0..workers)
            .map(|i| {
                let jobs = Arc::clone(&jobs);
                std::thread::Builder::new()
                    .name(format!("batch-worker-{i}"))
                    .spawn(move || loop {
                        match jobs.pop_timeout(Duration::from_millis(50)) {
                            Ok(job) => job.run(),
                            Err(PopError::Empty) => continue,
                            Err(PopError::Closed) => break,
                        }
                    })
                    .expect("spawn batch worker")
            })
            .collect();
        BatchPool { jobs, handles, dispatched: AtomicUsize::new(0) }
    }

    /// Worker threads owned by this pool.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Batches that took the parallel path (vs the serial fallback).
    pub fn dispatched(&self) -> usize {
        self.dispatched.load(Ordering::Relaxed)
    }

    /// [`CompiledModel::infer_batch`] fanned across the pool: `n` frames
    /// packed in `x`, `n * output_len` logits out, bit-identical to the
    /// serial loop. Small batches and worker-less pools run serially.
    pub fn infer_batch(&self, model: &Arc<CompiledModel>, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let workers = self.workers();
        if workers == 0 || n < MIN_PARALLEL_BATCH || n < workers + 1 {
            return model.infer_batch(x, n);
        }
        let px = model.input_pixels();
        if x.len() != n * px {
            // Fail the contract before copying the batch; the serial
            // path produces the canonical error message.
            return model.infer_batch(x, n);
        }
        self.dispatched.fetch_add(1, Ordering::Relaxed);

        // `workers + 1` contiguous chunks, sized within one frame of each
        // other; the caller keeps chunk 0 so every core works.
        let chunks = workers + 1;
        let base = n / chunks;
        let extra = n % chunks;
        let bounds: Vec<(usize, usize)> = (0..chunks)
            .scan(0usize, |start, c| {
                let len = base + usize::from(c < extra);
                let b = (*start, *start + len);
                *start += len;
                Some(b)
            })
            .collect();

        let input = Arc::new(x.to_vec());
        let (tx, rx) = mpsc::channel();
        let mut inline = Vec::new();
        for (chunk, &(start, end)) in bounds.iter().enumerate().skip(1) {
            let job = Job {
                model: Arc::clone(model),
                input: Arc::clone(&input),
                start,
                end,
                dp: model.datapath(),
                tx: tx.clone(),
                chunk,
            };
            // Full/Closed cannot strand the batch: run the chunk on the
            // dispatching thread instead.
            if let Err(PushError::Full(job) | PushError::Closed(job)) = self.jobs.try_push(job)
            {
                inline.push(job);
            }
        }
        drop(tx);

        // Chunk 0 inline on the dispatcher, then any overflow chunks.
        let (s0, e0) = bounds[0];
        let mut parts: Vec<Option<Result<Vec<f32>>>> = (0..chunks).map(|_| None).collect();
        parts[0] = Some(model.infer_batch_with(
            &x[s0 * px..e0 * px],
            e0 - s0,
            model.datapath(),
        ));
        for job in inline {
            let chunk = job.chunk;
            let px = job.model.input_pixels();
            let out = job.model.infer_batch_with(
                &job.input[job.start * px..job.end * px],
                job.end - job.start,
                job.dp,
            );
            parts[chunk] = Some(out);
        }
        for (chunk, out) in rx {
            parts[chunk] = Some(out);
        }

        // Reassemble in chunk order; the lowest-indexed error wins so the
        // result matches what the serial loop would have reported first.
        let mut logits = Vec::with_capacity(n * model.output_len());
        for part in parts {
            logits.extend(part.expect("every chunk reports exactly once")?);
        }
        Ok(logits)
    }
}

impl Drop for BatchPool {
    fn drop(&mut self) {
        self.jobs.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::lenet5;
    use crate::kernel::KernelSpec;
    use crate::runtime::SyntheticRuntime;
    use crate::weights::ModelParams;

    fn model(seed: u64) -> Arc<CompiledModel> {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, seed);
        p.prune_global(0.7, 0.05).unwrap();
        Arc::new(CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap())
    }

    fn batch(m: &CompiledModel, n: usize) -> Vec<f32> {
        (0..n)
            .flat_map(|i| SyntheticRuntime::stripe_image(i % 10))
            .take(n * m.input_pixels())
            .collect()
    }

    #[test]
    fn pool_matches_serial_across_batch_sizes() {
        let m = model(31);
        let pool = BatchPool::new(3);
        assert_eq!(pool.workers(), 3);
        for n in [1usize, 3, 4, 5, 8, 13] {
            let x = batch(&m, n);
            let serial = m.infer_batch(&x, n).unwrap();
            let pooled = pool.infer_batch(&m, &x, n).unwrap();
            assert_eq!(pooled, serial, "batch {n} diverged");
        }
        // Batches >= MIN_PARALLEL_BATCH and >= workers + 1 fan out.
        assert!(pool.dispatched() >= 3);
    }

    #[test]
    fn zero_worker_pool_is_serial() {
        let m = model(32);
        let pool = BatchPool::new(0);
        assert_eq!(pool.workers(), 0);
        let x = batch(&m, 8);
        assert_eq!(
            pool.infer_batch(&m, &x, 8).unwrap(),
            m.infer_batch(&x, 8).unwrap()
        );
        assert_eq!(pool.dispatched(), 0, "no workers, no dispatch");
    }

    #[test]
    fn length_contract_errors_propagate() {
        let m = model(33);
        let pool = BatchPool::new(2);
        let x = batch(&m, 8);
        assert!(pool.infer_batch(&m, &x[..100], 8).is_err());
        assert!(pool.infer_batch(&m, &x, 9).is_err());
    }

    #[test]
    fn drop_joins_workers() {
        let m = model(34);
        let pool = BatchPool::new(2);
        let x = batch(&m, 8);
        pool.infer_batch(&m, &x, 8).unwrap();
        drop(pool); // must not hang: close() wakes the pop_timeout loop
    }
}
