//! Bit-packing substrate for baked kernels (S20a).
//!
//! The compile pass stores weight codes and input indices as dense
//! little-endian bitstreams — the software analogue of the paper's packed
//! on-chip layout, and the byte-exact source for size accounting (a W4
//! code costs 4 bits, an index exactly `index_bits(extent)` bits, nothing
//! more). Values are packed LSB-first; codes are two's-complement in
//! `bits` bits.

/// Bits needed to address `extent` distinct positions (>= 1).
pub fn index_bits(extent: usize) -> usize {
    if extent <= 2 {
        1
    } else {
        (usize::BITS - (extent - 1).leading_zeros()) as usize
    }
}

/// Pack `values` at `bits` bits each (1..=32), LSB-first. Values wider
/// than `bits` are truncated to the low bits.
pub fn pack_bits(values: &[u32], bits: usize) -> Vec<u8> {
    assert!((1..=32).contains(&bits), "pack width {bits} out of [1,32]");
    let total = values.len() * bits;
    let mut buf = vec![0u8; total.div_ceil(8)];
    let mut pos = 0usize;
    for &raw in values {
        let v = if bits == 32 { raw } else { raw & ((1u32 << bits) - 1) };
        let mut written = 0usize;
        while written < bits {
            let byte = (pos + written) / 8;
            let bit = (pos + written) % 8;
            let take = (8 - bit).min(bits - written);
            let chunk = ((v >> written) as u64 & ((1u64 << take) - 1)) as u8;
            buf[byte] |= chunk << bit;
            written += take;
        }
        pos += bits;
    }
    buf
}

/// Unpack `n` values of `bits` bits each from a [`pack_bits`] stream.
pub fn unpack_bits(bytes: &[u8], bits: usize, n: usize) -> Vec<u32> {
    assert!((1..=32).contains(&bits), "pack width {bits} out of [1,32]");
    let mut out = Vec::with_capacity(n);
    let mut pos = 0usize;
    for _ in 0..n {
        let mut v = 0u32;
        let mut read = 0usize;
        while read < bits {
            let byte = (pos + read) / 8;
            let bit = (pos + read) % 8;
            let take = (8 - bit).min(bits - read);
            let chunk = (bytes[byte] >> bit) & (((1u16 << take) - 1) as u8);
            v |= (chunk as u32) << read;
            read += take;
        }
        out.push(v);
        pos += bits;
    }
    out
}

/// Pack signed weight codes two's-complement at `bits` bits (2..=8).
pub fn pack_codes(codes: &[i8], bits: usize) -> Vec<u8> {
    assert!((2..=8).contains(&bits), "code width {bits} out of [2,8]");
    let vals: Vec<u32> = codes.iter().map(|&c| c as i32 as u32).collect();
    pack_bits(&vals, bits)
}

/// Unpack `n` signed codes from a [`pack_codes`] stream (sign-extending).
pub fn unpack_codes(bytes: &[u8], bits: usize, n: usize) -> Vec<i8> {
    assert!((2..=8).contains(&bits), "code width {bits} out of [2,8]");
    unpack_bits(bytes, bits, n)
        .into_iter()
        .map(|v| {
            let sign = 1u32 << (bits - 1);
            if v & sign != 0 {
                (v as i32 - (1i32 << bits)) as i8
            } else {
                v as i8
            }
        })
        .collect()
}

/// Pack index values at `index_bits(extent)` bits; returns (bytes, bits).
pub fn pack_indices(idx: &[u32], extent: usize) -> (Vec<u8>, usize) {
    let bits = index_bits(extent);
    (pack_bits(idx, bits), bits)
}

/// Pack an N:M schedule's *within-group* offsets (each in `0..m`) at
/// `index_bits(m)` bits; returns (bytes, bits). The stream is fully
/// fixed-stride: with `n` slots per group, slot `j` of group `g` of
/// channel `c` lives at bit `((c·groups + g)·n + j)·index_bits(m)` — a
/// pure-arithmetic address, no pointer array. This is the regularity win
/// an N:M schedule buys over unstructured indices: the decode needs the
/// group counter and a constant multiply, nothing stored per block.
pub fn pack_nm_indices(offsets: &[u32], m: usize) -> (Vec<u8>, usize) {
    let bits = index_bits(m);
    debug_assert!(
        offsets.iter().all(|&o| (o as usize) < m),
        "N:M offset outside its group extent {m}"
    );
    (pack_bits(offsets, bits), bits)
}

/// Decode a [`pack_nm_indices`] stream back to *absolute* input rows in
/// stream order: `cout` channels × `fold_in.div_ceil(m)` groups × `n`
/// slots per full group (a tail group of `t = fold_in % m` rows carries
/// `min(n, t)` slots), each row = `group·m + offset`. The round-trip
/// counterpart the property tests pin against the mask.
pub fn unpack_nm_rows(bytes: &[u8], fold_in: usize, n: usize, m: usize, cout: usize) -> Vec<u32> {
    let bits = index_bits(m);
    let groups = fold_in.div_ceil(m);
    let tail = fold_in % m;
    let slots_per_col: usize = (0..groups)
        .map(|g| if g + 1 == groups && tail != 0 { n.min(tail) } else { n })
        .sum();
    let offsets = unpack_bits(bytes, bits, cout * slots_per_col);
    let mut rows = Vec::with_capacity(offsets.len());
    let mut at = 0usize;
    for _ in 0..cout {
        for g in 0..groups {
            let slots = if g + 1 == groups && tail != 0 { n.min(tail) } else { n };
            for _ in 0..slots {
                rows.push((g * m) as u32 + offsets[at]);
                at += 1;
            }
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn index_width_arithmetic() {
        assert_eq!(index_bits(1), 1);
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(3), 2);
        assert_eq!(index_bits(256), 8);
        assert_eq!(index_bits(257), 9);
        assert_eq!(index_bits(25), 5);
    }

    #[test]
    fn nibble_roundtrip() {
        let codes: Vec<i8> = (-7..=7).collect();
        let packed = pack_codes(&codes, 4);
        // 15 codes * 4 bits = 60 bits -> 8 bytes.
        assert_eq!(packed.len(), 8);
        assert_eq!(unpack_codes(&packed, 4, codes.len()), codes);
    }

    #[test]
    fn unaligned_widths_roundtrip() {
        let vals = vec![5u32, 0, 7, 2, 6, 1, 3];
        for bits in [3usize, 5, 7, 11] {
            let packed = pack_bits(&vals, bits);
            assert_eq!(packed.len(), (vals.len() * bits).div_ceil(8));
            assert_eq!(unpack_bits(&packed, bits, vals.len()), vals);
        }
    }

    #[test]
    fn indices_pack_at_minimal_width() {
        let idx = vec![0u32, 24, 13, 7];
        let (bytes, bits) = pack_indices(&idx, 25);
        assert_eq!(bits, 5);
        assert_eq!(unpack_bits(&bytes, bits, idx.len()), idx);
    }

    #[test]
    fn prop_code_roundtrip_all_widths() {
        check("pack/unpack codes identity", 150, |g| {
            let bits = g.usize(2, 8);
            let qmax = (1i32 << (bits - 1)) - 1;
            let n = g.usize(0, 200);
            let mut rng = Pcg32::seeded(g.case + 3);
            let codes: Vec<i8> = (0..n)
                .map(|_| (rng.below((2 * qmax + 1) as u32) as i32 - qmax) as i8)
                .collect();
            let packed = pack_codes(&codes, bits);
            assert_eq!(packed.len(), (n * bits).div_ceil(8));
            assert_eq!(unpack_codes(&packed, bits, n), codes);
        });
    }

    #[test]
    fn nm_stream_is_fixed_stride() {
        // fold_in = 8, m = 4, n = 2, cout = 2: 2 groups x 2 slots x 2
        // channels = 8 offsets at index_bits(4) = 2 bits = exactly 2
        // bytes — the stride is arithmetic, nothing stored per group.
        let offsets = vec![0u32, 3, 1, 2, 0, 1, 2, 3];
        let (bytes, bits) = pack_nm_indices(&offsets, 4);
        assert_eq!(bits, 2);
        assert_eq!(bytes.len(), 2);
        let rows = unpack_nm_rows(&bytes, 8, 2, 4, 2);
        // row = group*m + offset, groups in order per channel.
        assert_eq!(rows, vec![0, 3, 5, 6, 0, 1, 6, 7]);
    }

    #[test]
    fn nm_tail_group_carries_fewer_slots() {
        // fold_in = 25, m = 8: groups of 8,8,8 and a tail of 1; with
        // n = 2 the tail holds min(2,1) = 1 slot -> 7 slots per channel.
        let offsets = vec![1u32, 7, 0, 2, 3, 4, 0];
        let (bytes, bits) = pack_nm_indices(&offsets, 8);
        assert_eq!(bits, 3);
        let rows = unpack_nm_rows(&bytes, 25, 2, 8, 1);
        assert_eq!(rows, vec![1, 7, 8, 10, 19, 20, 24]);
    }

    #[test]
    fn prop_bit_roundtrip() {
        check("pack/unpack bits identity", 150, |g| {
            let bits = g.usize(1, 32);
            let n = g.usize(0, 120);
            let mut rng = Pcg32::seeded(g.case + 11);
            let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
            let vals: Vec<u32> = (0..n).map(|_| rng.next_u32() & mask).collect();
            let packed = pack_bits(&vals, bits);
            assert_eq!(unpack_bits(&packed, bits, n), vals);
        });
    }
}
