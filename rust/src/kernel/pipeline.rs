//! [`StagedExecutor`]: layer-pipelined execution of a [`CompiledModel`]
//! — the serving-side realisation of the pipeline the cycle simulator
//! predicts (DESIGN.md §13).
//!
//! The model's [`Stage`] list is partitioned into contiguous,
//! cost-balanced **stage groups** (per-stage cost =
//! [`MacStage::scheduled_macs`](super::MacStage::scheduled_macs) for MAC
//! layers, window ops for pools; exact min-max linear partitioning).
//! Each group gets one persistent worker thread, and neighbouring groups
//! are connected by bounded [`RingQueue`] FIFOs carrying **activation
//! frames** — so request k's layer N runs concurrently with request
//! k+1's layer N−1, the HPIPE-style inter-request parallelism batch
//! pools cannot express. This is the third native execution mode,
//! alongside the serial walk and the data-parallel
//! [`BatchPool`](super::BatchPool)
//! ([`NativeSparseBackend::with_pipeline`](super::NativeSparseBackend::with_pipeline),
//! `serve --pipeline`).
//!
//! **Identity.** A frame is quantised once at the submit side with the
//! exact expression [`CompiledModel::forward_with`] uses, then walks the
//! same private stage entry points (`PoolStage::run`,
//! `MacStage::run_hidden` / `run_output`) in the same order — the group
//! boundaries move work between threads, never between operations, so
//! outputs are bit-identical to the serial forward on every
//! [`Datapath`] (asserted in `tests/kernel_pipeline.rs`).
//!
//! **Lossless shutdown.** [`StagedExecutor::close`] closes the submit
//! ring only; [`RingQueue`] pops keep draining after a close, so each
//! worker finishes every queued frame, then cascades the close to the
//! next ring and exits. Every frame accepted by
//! [`StagedExecutor::submit`] therefore still delivers its logits;
//! submissions after the close fail fast with
//! [`Error::QueueClosed`]. Dropping the executor closes and joins.
//!
//! **Calibration.** [`StagedExecutor::sim_specs`] exports the *same*
//! grouping as [`sim::stage::StageSpec`]s (one "cycle" per
//! MAC-equivalent op, whole frames as tokens, same FIFO depth), so a
//! [`sim::Pipeline`](crate::sim::Pipeline) built from them predicts
//! which group bottlenecks the served pipeline — and the measured
//! per-group occupancy ([`StagedExecutor::stats`]) must agree (asserted
//! in `tests/kernel_pipeline.rs`).

use super::{CompiledModel, Datapath, Stage};
use crate::sim::stage::{Kind, StageSpec};
use crate::sim::Pipeline as SimPipeline;
use crate::util::error::{Error, Result};
use crate::util::ring::{PopError, PushError, RingQueue};
use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Default inter-group FIFO capacity, in activation frames: deep enough
/// to absorb per-frame service jitter between unequal groups, shallow
/// enough that in-flight memory stays bounded (mirrors the simulator's
/// shallow-FIFO regime).
pub const DEFAULT_FIFO_DEPTH: usize = 4;

/// Idle-consumer poll period — the same drain-friendly timeout idiom the
/// batch pool and the sharded plane use.
const POLL: Duration = Duration::from_millis(50);

/// One in-flight frame between stage groups: the activation codes
/// leaving the previous group (input codes for group 0) plus the channel
/// the final group answers on. The sender rides the frame end to end, so
/// interleaved submitters can never receive each other's logits.
struct Frame {
    act: Vec<u8>,
    tx: mpsc::Sender<Vec<f32>>,
}

/// Per-group occupancy counters, written by the group's worker.
#[derive(Default)]
struct GroupMeter {
    frames: AtomicU64,
    busy_ns: AtomicU64,
}

/// Execution cost proxy of one stage, in MAC-equivalent operations —
/// the partitioning and calibration currency.
fn stage_cost(stage: &Stage) -> u64 {
    match stage {
        Stage::Mac(m) => m.scheduled_macs() as u64,
        // Max-pool: one compare per window element per output pixel per
        // channel. A compare is cheaper than a MAC + requant, but pools
        // are orders of magnitude smaller than their neighbouring MAC
        // layers, so face value keeps the proxy simple without moving
        // any partition boundary in practice.
        Stage::Pool(p) => (p.ofm * p.ofm * p.k * p.k * p.ch) as u64,
    }
}

fn stage_name(stage: &Stage) -> &str {
    match stage {
        Stage::Mac(m) => &m.name,
        Stage::Pool(p) => &p.name,
    }
}

/// Contiguous min-max partition of `costs` into at most `groups` parts
/// (classic linear partitioning, exact DP — stage lists are tiny).
/// Returns one `Range` of stage indices per group, covering `0..n`.
fn partition(costs: &[u64], groups: usize) -> Vec<Range<usize>> {
    let n = costs.len();
    let g = groups.clamp(1, n);
    let mut prefix = vec![0u64; n + 1];
    for (i, &c) in costs.iter().enumerate() {
        prefix[i + 1] = prefix[i] + c;
    }
    let seg = |a: usize, b: usize| prefix[b] - prefix[a];
    // best[k][i]: minimal achievable max-group cost splitting the first
    // i stages into k+1 groups; cut[k][i]: where the last group starts.
    let mut best = vec![vec![u64::MAX; n + 1]; g];
    let mut cut = vec![vec![0usize; n + 1]; g];
    for i in 1..=n {
        best[0][i] = seg(0, i);
    }
    for k in 1..g {
        for i in (k + 1)..=n {
            for j in k..i {
                let cand = best[k - 1][j].max(seg(j, i));
                if cand < best[k][i] {
                    best[k][i] = cand;
                    cut[k][i] = j;
                }
            }
        }
    }
    let mut bounds = vec![n];
    let (mut k, mut i) = (g - 1, n);
    while k > 0 {
        i = cut[k][i];
        bounds.push(i);
        k -= 1;
    }
    bounds.push(0);
    bounds.reverse();
    bounds.windows(2).map(|w| w[0]..w[1]).collect()
}

/// Blocking push with bounded-ring backpressure: spin briefly, then
/// sleep — the ring ahead only stays full while the downstream group is
/// the bottleneck, in which case throughput is its service rate and the
/// producer's wait is free. `Err` means the ring closed underneath the
/// producer (only possible if the consumer died); the frame is dropped
/// and its sender with it, so the submitter observes a clean
/// channel-closed error instead of a hang.
fn push_frame(q: &RingQueue<Frame>, mut f: Frame) -> std::result::Result<(), ()> {
    let mut tries = 0u32;
    loop {
        match q.try_push(f) {
            Ok(()) => return Ok(()),
            Err(PushError::Full(back)) => {
                f = back;
                tries += 1;
                if tries < 64 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(20));
                }
            }
            Err(PushError::Closed(_)) => return Err(()),
        }
    }
}

/// One stage group's worker: drain the input ring, run the group's
/// stages on each frame, hand off downstream (or answer, for the final
/// group). Exits when the input ring is closed **and** empty — the
/// drain-friendly contract [`RingQueue`] guarantees — then cascades the
/// close so the next group can wind down the same way.
#[allow(clippy::too_many_arguments)]
fn group_worker(
    model: Arc<CompiledModel>,
    dp: Datapath,
    span: Range<usize>,
    inq: Arc<RingQueue<Frame>>,
    outq: Option<Arc<RingQueue<Frame>>>,
    out_high_water: Option<Arc<AtomicUsize>>,
    meter: Arc<GroupMeter>,
) {
    let qmax = model.spec.act_qmax();
    loop {
        let frame = match inq.pop_timeout(POLL) {
            Ok(f) => f,
            Err(PopError::Empty) => continue,
            Err(PopError::Closed) => break,
        };
        let t0 = Instant::now();
        let mut act = frame.act;
        let mut logits: Option<Vec<f32>> = None;
        for stage in &model.stages()[span.clone()] {
            match stage {
                Stage::Pool(p) => act = p.run(&act),
                Stage::Mac(m) => {
                    if m.is_output {
                        logits = Some(m.run_output(&act, dp));
                    } else {
                        act = m.run_hidden(&act, qmax, dp);
                    }
                }
            }
        }
        meter
            .busy_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        meter.frames.fetch_add(1, Ordering::Relaxed);
        match (logits, &outq) {
            // The output MAC is the model's last stage, so only the
            // final group produces logits.
            (Some(v), _) => {
                // A dropped receiver (caller gave up) is not an error.
                let _ = frame.tx.send(v);
            }
            (None, Some(q)) => {
                if push_frame(q, Frame { act, tx: frame.tx }).is_ok() {
                    if let Some(hw) = &out_high_water {
                        hw.fetch_max(q.len(), Ordering::Relaxed);
                    }
                }
            }
            (None, None) => unreachable!("compile validated the graph ends in an output MAC"),
        }
    }
    if let Some(q) = outq {
        q.close();
    }
}

/// A compiled model executing as a staged layer pipeline: one worker
/// thread per cost-balanced stage group, bounded rings between groups.
/// See the module docs for the identity / shutdown / calibration
/// contracts.
pub struct StagedExecutor {
    model: Arc<CompiledModel>,
    dp: Datapath,
    spans: Vec<Range<usize>>,
    costs: Vec<u64>,
    names: Vec<String>,
    fifo_depth: usize,
    /// `fifos[g]` feeds group g; `fifos[0]` is the submit ring.
    fifos: Vec<Arc<RingQueue<Frame>>>,
    high_water: Vec<Arc<AtomicUsize>>,
    meters: Vec<Arc<GroupMeter>>,
    submitted: AtomicU64,
    started: Instant,
    workers: Vec<JoinHandle<()>>,
}

impl StagedExecutor {
    /// Pipeline `model` across (at most) `groups` stage groups with the
    /// default FIFO depth, executing the model's pinned datapath.
    /// `groups` is clamped to the stage count; `groups == 1` is the
    /// degenerate pipeline — the whole serial walk on one worker,
    /// correct but not concurrent.
    pub fn new(model: Arc<CompiledModel>, groups: usize) -> Result<Self> {
        let dp = model.datapath();
        Self::with_config(model, groups, DEFAULT_FIFO_DEPTH, dp)
    }

    /// Full-control constructor: explicit FIFO depth and [`Datapath`]
    /// override (the identity tests sweep every compiled-in datapath
    /// without recompiling the model).
    pub fn with_config(
        model: Arc<CompiledModel>,
        groups: usize,
        fifo_depth: usize,
        dp: Datapath,
    ) -> Result<Self> {
        if model.stages().is_empty() {
            return Err(Error::kernel("cannot pipeline a model with no stages"));
        }
        if groups == 0 {
            return Err(Error::config("pipeline needs >= 1 stage group"));
        }
        if fifo_depth == 0 {
            return Err(Error::config("pipeline FIFO depth must be >= 1"));
        }
        let per_stage: Vec<u64> = model.stages().iter().map(stage_cost).collect();
        let spans = partition(&per_stage, groups);
        let costs: Vec<u64> = spans
            .iter()
            .map(|s| per_stage[s.clone()].iter().sum())
            .collect();
        let names: Vec<String> = spans
            .iter()
            .map(|s| {
                model.stages()[s.clone()]
                    .iter()
                    .map(stage_name)
                    .collect::<Vec<_>>()
                    .join("+")
            })
            .collect();

        let fifos: Vec<Arc<RingQueue<Frame>>> = (0..spans.len())
            .map(|_| Arc::new(RingQueue::new(fifo_depth)))
            .collect();
        let high_water: Vec<Arc<AtomicUsize>> =
            (0..spans.len()).map(|_| Arc::new(AtomicUsize::new(0))).collect();
        let meters: Vec<Arc<GroupMeter>> =
            (0..spans.len()).map(|_| Arc::new(GroupMeter::default())).collect();

        let mut workers = Vec::with_capacity(spans.len());
        for (g, span) in spans.iter().enumerate() {
            let m = Arc::clone(&model);
            let span = span.clone();
            let inq = Arc::clone(&fifos[g]);
            let outq = fifos.get(g + 1).map(Arc::clone);
            let hw = high_water.get(g + 1).map(Arc::clone);
            let meter = Arc::clone(&meters[g]);
            workers.push(std::thread::spawn(move || {
                group_worker(m, dp, span, inq, outq, hw, meter);
            }));
        }
        Ok(StagedExecutor {
            model,
            dp,
            spans,
            costs,
            names,
            fifo_depth,
            fifos,
            high_water,
            meters,
            submitted: AtomicU64::new(0),
            started: Instant::now(),
            workers,
        })
    }

    /// The model this pipeline executes.
    pub fn model(&self) -> &Arc<CompiledModel> {
        &self.model
    }

    /// The datapath every group executes.
    pub fn datapath(&self) -> Datapath {
        self.dp
    }

    /// Number of stage groups (== worker threads).
    pub fn groups(&self) -> usize {
        self.spans.len()
    }

    /// Stage-index span of each group, in stream order.
    pub fn group_spans(&self) -> &[Range<usize>] {
        &self.spans
    }

    /// MAC-equivalent cost of each group (the partitioning input).
    pub fn group_costs(&self) -> &[u64] {
        &self.costs
    }

    /// Human-readable name of each group (member stages joined by `+`).
    pub fn group_names(&self) -> &[String] {
        &self.names
    }

    /// Inter-group FIFO capacity, in frames.
    pub fn fifo_depth(&self) -> usize {
        self.fifo_depth
    }

    /// Quantise one image and enqueue it; the receiver yields the
    /// frame's logits once it drains out of the final group. Frames
    /// flow in FIFO order end to end. Fails with [`Error::QueueClosed`]
    /// once [`StagedExecutor::close`] has run.
    pub fn submit(&self, image: &[f32]) -> Result<mpsc::Receiver<Vec<f32>>> {
        if image.len() != self.model.input_pixels() {
            return Err(Error::kernel(format!(
                "input length {} != {}",
                image.len(),
                self.model.input_pixels()
            )));
        }
        // Entry quantisation, byte for byte the forward_with expression.
        let qmax = self.model.spec.act_qmax();
        let in_scale = self.model.spec.input_scale();
        let act: Vec<u8> = image
            .iter()
            .map(|&x| ((x / in_scale).round() as i32).clamp(0, qmax) as u8)
            .collect();
        let (tx, rx) = mpsc::channel();
        push_frame(&self.fifos[0], Frame { act, tx }).map_err(|_| Error::QueueClosed)?;
        self.high_water[0].fetch_max(self.fifos[0].len(), Ordering::Relaxed);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(rx)
    }

    /// One frame through the pipeline, blocking for its logits.
    pub fn infer(&self, image: &[f32]) -> Result<Vec<f32>> {
        self.submit(image)?.recv().map_err(|_| Error::QueueClosed)
    }

    /// Stream a batch of `n` frames through the pipeline and collect the
    /// logits in submission order — same length contract and result
    /// layout as [`CompiledModel::infer_batch`], but frame k+1 enters
    /// group 0 while frame k is still in a later group. Deadlock-free by
    /// construction: results leave through unbounded channels, so the
    /// final group never blocks and the bounded rings always drain.
    pub fn infer_batch(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let px = self.model.input_pixels();
        if x.len() != n * px {
            return Err(Error::kernel(format!(
                "batch of {n} needs {} values, got {}",
                n * px,
                x.len()
            )));
        }
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            rxs.push(self.submit(&x[i * px..(i + 1) * px])?);
        }
        let mut out = Vec::with_capacity(n * self.model.output_len());
        for rx in rxs {
            out.extend(rx.recv().map_err(|_| Error::QueueClosed)?);
        }
        Ok(out)
    }

    /// Stop accepting frames and let the pipeline drain: closes the
    /// submit ring only; each worker finishes every queued frame, then
    /// cascades the close downstream and exits. Receivers returned by
    /// earlier [`StagedExecutor::submit`] calls still deliver.
    /// Idempotent; [`Drop`] calls it and joins the workers.
    pub fn close(&self) {
        self.fifos[0].close();
    }

    /// Measured per-group occupancy since start (the calibration
    /// counterpart of the simulator's per-stage utilisation).
    pub fn stats(&self) -> PipelineStats {
        PipelineStats {
            groups: (0..self.spans.len())
                .map(|g| GroupStats {
                    name: self.names[g].clone(),
                    stages: self.spans[g].clone(),
                    cost: self.costs[g],
                    frames: self.meters[g].frames.load(Ordering::Relaxed),
                    busy_s: self.meters[g].busy_ns.load(Ordering::Relaxed) as f64 / 1e9,
                })
                .collect(),
            fifo_high_water: self
                .high_water
                .iter()
                .map(|hw| hw.load(Ordering::Relaxed))
                .collect(),
            fifo_capacity: self.fifo_depth,
            submitted: self.submitted.load(Ordering::Relaxed),
            elapsed_s: self.started.elapsed().as_secs_f64(),
        }
    }

    /// The simulator's view of this exact pipeline: one [`StageSpec`]
    /// per stage group in stream order, II = the group's MAC-equivalent
    /// cost (one simulated cycle per op), whole activation frames as
    /// tokens. Feed them to [`StagedExecutor::calibration_sim`] (or
    /// [`sim::Pipeline`](crate::sim::Pipeline) directly) to predict the
    /// bottleneck group of the served pipeline.
    pub fn sim_specs(&self) -> Vec<StageSpec> {
        (0..self.spans.len())
            .map(|g| StageSpec {
                name: self.names[g].clone(),
                kind: Kind::Fc,
                tokens_per_frame: 1,
                in_tokens_per_frame: 1,
                ii_cycles_per_frame: self.costs[g].max(1),
                fill_cycles: 0,
            })
            .collect()
    }

    /// Build the calibration pipeline: the same grouping, group costs
    /// and FIFO depth as the served executor, as a cycle simulation at
    /// `f_mhz`. Its [`SimReport`](crate::sim::SimReport) must identify
    /// the same bottleneck group as [`StagedExecutor::stats`] measures.
    pub fn calibration_sim(&self, f_mhz: f64) -> SimPipeline {
        SimPipeline::new(self.sim_specs(), self.fifo_depth, f_mhz)
    }
}

impl Drop for StagedExecutor {
    fn drop(&mut self) {
        self.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Measured occupancy of one stage group.
#[derive(Debug, Clone)]
pub struct GroupStats {
    /// Member stage names joined by `+`.
    pub name: String,
    /// Stage-index span within the model's stage list.
    pub stages: Range<usize>,
    /// MAC-equivalent cost (the partitioning input).
    pub cost: u64,
    /// Frames this group finished.
    pub frames: u64,
    /// Wall time the group's worker spent executing stages, seconds.
    pub busy_s: f64,
}

/// Measured pipeline occupancy: the served-side counterpart of the
/// simulator's [`SimReport`](crate::sim::SimReport) stage utilisation.
#[derive(Debug, Clone)]
pub struct PipelineStats {
    /// Per-group occupancy, in stream order.
    pub groups: Vec<GroupStats>,
    /// High-water occupancy of each ring (`[g]` feeds group g; `[0]` is
    /// the submit ring).
    pub fifo_high_water: Vec<usize>,
    /// Ring capacity, in frames.
    pub fifo_capacity: usize,
    /// Frames accepted at the submit side.
    pub submitted: u64,
    /// Wall time since the executor started, seconds.
    pub elapsed_s: f64,
}

impl PipelineStats {
    /// Frames that drained out of the final group.
    pub fn completed(&self) -> u64 {
        self.groups.last().map_or(0, |g| g.frames)
    }

    /// Frames accepted but not (yet) completed. After a drain this must
    /// be 0 — the lossless-shutdown acceptance counter.
    pub fn in_flight(&self) -> u64 {
        self.submitted - self.completed()
    }

    /// Index of the measured bottleneck group: the one that spent the
    /// most wall time executing (all groups see the same frame stream,
    /// so busy-time order is service-time order).
    pub fn bottleneck_group(&self) -> usize {
        self.groups
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.busy_s.total_cmp(&b.1.busy_s))
            .map(|(i, _)| i)
            .expect("non-empty pipeline")
    }

    /// Per-group utilisation over the elapsed wall time (comparable to
    /// the simulator's per-stage utilisation in steady state).
    pub fn utilisation(&self) -> Vec<f64> {
        let wall = self.elapsed_s.max(1e-12);
        self.groups.iter().map(|g| g.busy_s / wall).collect()
    }

    /// `(group name, utilisation)` pairs in stream order — the measured
    /// occupancy the kernel-selection policy consumes
    /// ([`crate::kernel::Calibration::from_stats`]).
    pub fn occupancy(&self) -> Vec<(String, f64)> {
        self.groups
            .iter()
            .zip(self.utilisation())
            .map(|(g, u)| (g.name.clone(), u))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::lenet5;
    use crate::kernel::KernelSpec;
    use crate::weights::ModelParams;

    #[test]
    fn partition_balances_and_isolates_the_heavy_stage() {
        assert_eq!(partition(&[5, 5, 5, 5], 2), vec![0..2, 2..4]);
        // The dominant stage ends up alone: min-max has no better cut.
        let p = partition(&[10, 100, 10], 3);
        assert_eq!(p, vec![0..1, 1..2, 2..3]);
        // 2-way split of [10, 100, 10]: both cuts cost max 110 — assert
        // the DP achieves that optimum rather than a specific cut.
        let p = partition(&[10, 100, 10], 2);
        let worst = p
            .iter()
            .map(|s| [10u64, 100, 10][s.clone()].iter().sum::<u64>())
            .max()
            .unwrap();
        assert_eq!(worst, 110);
        // More groups than stages clamps; zero-ish inputs never panic.
        assert_eq!(partition(&[3], 5), vec![0..1]);
        assert_eq!(partition(&[1, 2, 3], 1), vec![0..3]);
    }

    #[test]
    fn partition_covers_contiguously() {
        let costs = [86_400u64, 3_456, 153_600, 1_024, 30_720, 10_080, 840];
        for g in 1..=costs.len() {
            let spans = partition(&costs, g);
            assert_eq!(spans.len(), g);
            assert_eq!(spans[0].start, 0);
            assert_eq!(spans.last().unwrap().end, costs.len());
            for w in spans.windows(2) {
                assert_eq!(w[0].end, w[1].start, "gap between groups");
            }
            for s in &spans {
                assert!(s.start < s.end, "empty group in {spans:?}");
            }
        }
    }

    #[test]
    fn pipelined_forward_is_bit_identical() {
        let g = lenet5();
        let p = ModelParams::synthetic(&g, 31);
        let model =
            Arc::new(CompiledModel::compile_dense(&g, &p, &KernelSpec::default()).unwrap());
        let exec = StagedExecutor::new(Arc::clone(&model), 3).unwrap();
        assert_eq!(exec.groups(), 3);
        for seed in 0..4u64 {
            let img = crate::runtime::SyntheticRuntime::stripe_image(seed as usize);
            assert_eq!(exec.infer(&img).unwrap(), model.forward(&img).unwrap());
        }
    }

    #[test]
    fn close_drains_then_rejects() {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, 33);
        p.prune_global(0.75, 0.05).unwrap();
        let model =
            Arc::new(CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap());
        let exec = StagedExecutor::with_config(
            Arc::clone(&model),
            4,
            2,
            model.datapath(),
        )
        .unwrap();
        let imgs: Vec<Vec<f32>> = (0..12)
            .map(crate::runtime::SyntheticRuntime::stripe_image)
            .collect();
        let rxs: Vec<_> = imgs.iter().map(|i| exec.submit(i).unwrap()).collect();
        exec.close();
        // Every accepted frame still delivers, bit-identically.
        for (img, rx) in imgs.iter().zip(rxs) {
            assert_eq!(rx.recv().unwrap(), model.forward(img).unwrap());
        }
        assert!(matches!(exec.submit(&imgs[0]), Err(Error::QueueClosed)));
        let stats = exec.stats();
        assert_eq!(stats.submitted, 12);
        assert_eq!(stats.completed(), 12);
        assert_eq!(stats.in_flight(), 0, "drain lost frames");
    }

    #[test]
    fn sim_specs_mirror_the_grouping() {
        let g = lenet5();
        let p = ModelParams::synthetic(&g, 35);
        let model =
            Arc::new(CompiledModel::compile_dense(&g, &p, &KernelSpec::default()).unwrap());
        let exec = StagedExecutor::new(Arc::clone(&model), 3).unwrap();
        let specs = exec.sim_specs();
        assert_eq!(specs.len(), exec.groups());
        for (spec, (cost, name)) in specs
            .iter()
            .zip(exec.group_costs().iter().zip(exec.group_names()))
        {
            assert_eq!(&spec.name, name);
            assert_eq!(spec.ii_cycles_per_frame, (*cost).max(1));
            assert_eq!(spec.tokens_per_frame, 1);
        }
        // The predicted bottleneck is the costliest group by definition
        // of the spec II — the serving-side agreement is asserted with
        // real measurements in tests/kernel_pipeline.rs.
        let mut sim = exec.calibration_sim(100.0);
        let rep = sim
            .try_run(&crate::sim::Workload::parse("saturated", 32).unwrap())
            .unwrap();
        let costliest = exec
            .group_costs()
            .iter()
            .enumerate()
            .max_by_key(|(_, c)| **c)
            .unwrap()
            .0;
        assert_eq!(rep.bottleneck_stage().name, exec.group_names()[costliest]);
    }
}
