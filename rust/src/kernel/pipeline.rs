//! [`StagedExecutor`]: layer-pipelined execution of a [`CompiledModel`]
//! — the serving-side realisation of the pipeline the cycle simulator
//! predicts (DESIGN.md §13, §15).
//!
//! The model's [`Stage`] list is partitioned into contiguous,
//! cost-balanced **stage groups** (per-stage cost =
//! [`MacStage::scheduled_macs`](super::MacStage::scheduled_macs) for MAC
//! layers, window ops for pools; exact min-max linear partitioning).
//! Each group gets one or more persistent worker threads — **replicas**
//! — and neighbouring groups are connected by bounded [`RingQueue`]
//! FIFOs carrying **activation frames** — so request k's layer N runs
//! concurrently with request k+1's layer N−1, the HPIPE-style
//! inter-request parallelism batch pools cannot express. This is the
//! third native execution mode, alongside the serial walk and the
//! data-parallel [`BatchPool`](super::BatchPool)
//! ([`NativeSparseBackend::with_pipeline`](super::NativeSparseBackend::with_pipeline),
//! `serve --pipeline`).
//!
//! **Replication.** One worker per group floors the served initiation
//! interval at the costliest group. When the core budget has slack,
//! [`replication_plan`] grants extra workers to the group(s) with the
//! highest *effective* cost (cost / replicas), so the bottleneck
//! group's service rate scales with R. Frames carry a submit-side
//! sequence number; dispatch into a replicated group is round-robin by
//! `seq mod R` into per-replica rings, and a reorder **boundary**
//! between neighbouring groups re-establishes sequence order before
//! round-robin dispatch into the next group — outputs stay bit-identical
//! and in order no matter how replicas race (DESIGN.md §15).
//!
//! **Identity.** A frame is quantised once at the submit side with the
//! exact expression [`CompiledModel::forward_with`] uses, then walks the
//! same private stage entry points (`PoolStage::run`,
//! `MacStage::run_hidden` / `run_output`) in the same order — the group
//! boundaries move work between threads, never between operations, so
//! outputs are bit-identical to the serial forward on every
//! [`Datapath`] and every replication shape (asserted in
//! `tests/kernel_pipeline.rs`).
//!
//! **Lossless shutdown.** [`StagedExecutor::close`] closes the submit
//! rings only; [`RingQueue`] pops keep draining after a close, so each
//! worker finishes every queued frame, and the *last* replica of a
//! group to exit cascades the close to the next group's rings. Every
//! frame accepted by [`StagedExecutor::submit`] therefore still
//! delivers its logits; submissions after the close fail fast with
//! [`Error::QueueClosed`]. Dropping the executor closes and joins.
//!
//! **Calibration.** [`StagedExecutor::sim_specs`] exports the *same*
//! grouping as [`sim::stage::StageSpec`]s (one "cycle" per
//! MAC-equivalent op, whole frames as tokens, same FIFO depth, same
//! replica counts), so a [`sim::Pipeline`](crate::sim::Pipeline) built
//! from them predicts which group bottlenecks the served pipeline — and
//! the measured per-group occupancy ([`StagedExecutor::stats`], busy
//! time normalised by replica count) must agree (asserted in
//! `tests/kernel_pipeline.rs`).

use super::{CompiledModel, Datapath, Stage};
use crate::obs::metrics::Registry;
use crate::obs::trace::{EventKind, TraceHandle, Tracer};
use crate::sim::stage::{Kind, StageSpec};
use crate::sim::Pipeline as SimPipeline;
use crate::util::error::{Error, Result};
use crate::util::ring::{PopError, PushError, RingQueue};
use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Default inter-group FIFO capacity, in activation frames: deep enough
/// to absorb per-frame service jitter between unequal groups, shallow
/// enough that in-flight memory stays bounded (mirrors the simulator's
/// shallow-FIFO regime).
pub const DEFAULT_FIFO_DEPTH: usize = 4;

/// Idle-consumer poll period — the same drain-friendly timeout idiom the
/// batch pool and the sharded plane use.
const POLL: Duration = Duration::from_millis(50);

/// Observability wiring for a staged pipeline: when a tracer is
/// attached, every group worker records `GroupEnter`/`GroupExit`
/// events (frame sequence, group, replica) on its own lock-free ring;
/// when a registry is attached, the executor registers polled gauges
/// (in-flight frames, FIFO high-water, per-group utilisation) under
/// `label`. The default is fully off and costs nothing per frame.
#[derive(Clone, Default)]
pub struct PipeObs {
    /// Event-ring tracer; `None` records nothing.
    pub tracer: Option<Arc<Tracer>>,
    /// Metrics registry; `None` registers nothing.
    pub metrics: Option<Arc<Registry>>,
    /// Name prefix for this executor's rings and gauges.
    pub label: String,
}

/// Per-worker observability context: identity of the worker plus its
/// (optional) trace ring, bundled so the worker signature stays small.
struct WorkerCtx {
    live: Arc<AtomicUsize>,
    meter: Arc<GroupMeter>,
    trace: Option<TraceHandle>,
    group: u16,
    replica: u16,
}

/// One in-flight frame between stage groups: the activation codes
/// leaving the previous group (input codes for group 0) plus the channel
/// the final group answers on. The sender rides the frame end to end, so
/// interleaved submitters can never receive each other's logits. `seq`
/// is the submit-side sequence number — accepted frames are numbered
/// contiguously from 0, which is what lets a reorder boundary detect
/// "next frame in stream order" by counting.
struct Frame {
    seq: u64,
    act: Vec<u8>,
    tx: mpsc::Sender<Vec<f32>>,
}

/// Per-replica occupancy counters, written by one worker thread.
#[derive(Default)]
struct GroupMeter {
    frames: AtomicU64,
    busy_ns: AtomicU64,
}

/// In-order recombination state between two stage groups: frames from
/// the upstream group's replicas arrive in any order; they are buffered
/// by sequence number and flushed downstream in contiguous `seq` order.
struct Reorder {
    /// The next sequence number to release downstream.
    next_seq: u64,
    /// Out-of-order frames waiting for their predecessors.
    held: BTreeMap<u64, Frame>,
}

/// The boundary between group g and group g+1: the reorder buffer plus
/// the downstream group's per-replica rings. All upstream replicas emit
/// through [`Boundary::emit`]; the flush runs under the mutex so frames
/// enter each downstream ring in strictly increasing `seq` order.
struct Boundary {
    reorder: Mutex<Reorder>,
    /// `rings[r]` feeds replica r of the downstream group.
    rings: Vec<Arc<RingQueue<Frame>>>,
    high_water: Vec<Arc<AtomicUsize>>,
}

impl Boundary {
    fn new(rings: Vec<Arc<RingQueue<Frame>>>, high_water: Vec<Arc<AtomicUsize>>) -> Self {
        Boundary {
            reorder: Mutex::new(Reorder { next_seq: 0, held: BTreeMap::new() }),
            rings,
            high_water,
        }
    }

    /// Hand one finished frame downstream, releasing every consecutive
    /// frame that is now unblocked, in order, round-robin by
    /// `seq mod R`. A blocking push under the mutex is deliberate: it
    /// stalls sibling replicas exactly when the downstream group is the
    /// bottleneck (ordinary backpressure — downstream consumers never
    /// take this lock, so the rings always drain). A closed downstream
    /// ring (consumer died) drops the frame; its sender drops with it,
    /// so the submitter observes a clean channel-closed error.
    fn emit(&self, frame: Frame) {
        let mut rd = self.reorder.lock().expect("boundary mutex poisoned");
        rd.held.insert(frame.seq, frame);
        loop {
            let seq = rd.next_seq;
            let Some(f) = rd.held.remove(&seq) else { break };
            let r = (seq % self.rings.len() as u64) as usize;
            if push_frame(&self.rings[r], f).is_ok() {
                self.high_water[r].fetch_max(self.rings[r].len(), Ordering::Relaxed);
            }
            rd.next_seq += 1;
        }
    }

    /// Close every downstream ring (the cascade step of a lossless
    /// shutdown — called by the *last* upstream replica to exit, after
    /// every upstream frame has been emitted and therefore flushed).
    fn close(&self) {
        for q in &self.rings {
            q.close();
        }
    }
}

/// Execution cost proxy of one stage, in MAC-equivalent operations —
/// the partitioning and calibration currency.
fn stage_cost(stage: &Stage) -> u64 {
    match stage {
        Stage::Mac(m) => m.scheduled_macs() as u64,
        // Max-pool: one compare per window element per output pixel per
        // channel. A compare is cheaper than a MAC + requant, but pools
        // are orders of magnitude smaller than their neighbouring MAC
        // layers, so face value keeps the proxy simple without moving
        // any partition boundary in practice.
        Stage::Pool(p) => (p.ofm * p.ofm * p.k * p.k * p.ch) as u64,
    }
}

fn stage_name(stage: &Stage) -> &str {
    match stage {
        Stage::Mac(m) => &m.name,
        Stage::Pool(p) => &p.name,
    }
}

/// Contiguous min-max partition of `costs` into at most `groups` parts
/// (classic linear partitioning, exact DP — stage lists are tiny).
/// Returns one `Range` of stage indices per group, covering `0..n`.
fn partition(costs: &[u64], groups: usize) -> Vec<Range<usize>> {
    let n = costs.len();
    let g = groups.clamp(1, n);
    let mut prefix = vec![0u64; n + 1];
    for (i, &c) in costs.iter().enumerate() {
        prefix[i + 1] = prefix[i] + c;
    }
    let seg = |a: usize, b: usize| prefix[b] - prefix[a];
    // best[k][i]: minimal achievable max-group cost splitting the first
    // i stages into k+1 groups; cut[k][i]: where the last group starts.
    let mut best = vec![vec![u64::MAX; n + 1]; g];
    let mut cut = vec![vec![0usize; n + 1]; g];
    for i in 1..=n {
        best[0][i] = seg(0, i);
    }
    for k in 1..g {
        for i in (k + 1)..=n {
            for j in k..i {
                let cand = best[k - 1][j].max(seg(j, i));
                if cand < best[k][i] {
                    best[k][i] = cand;
                    cut[k][i] = j;
                }
            }
        }
    }
    let mut bounds = vec![n];
    let (mut k, mut i) = (g - 1, n);
    while k > 0 {
        i = cut[k][i];
        bounds.push(i);
        k -= 1;
    }
    bounds.push(0);
    bounds.reverse();
    bounds.windows(2).map(|w| w[0]..w[1]).collect()
}

/// Greedy worker assignment: start every group at one replica, then
/// grant each spare worker (up to `workers` total) to the group with
/// the highest *effective* cost — cost divided by the replicas it
/// already has; earliest group wins ties. This is water-filling on the
/// served initiation interval: each grant lowers the current II floor
/// (or, once groups equalise, spreads the slack evenly).
fn replication_plan(costs: &[u64], workers: usize) -> Vec<usize> {
    let mut reps = vec![1usize; costs.len()];
    if costs.is_empty() {
        return reps;
    }
    let mut spare = workers.saturating_sub(costs.len());
    while spare > 0 {
        let mut pick = 0usize;
        for g in 1..costs.len() {
            // costs[g] / reps[g] > costs[pick] / reps[pick], exactly.
            if (costs[g] as u128 * reps[pick] as u128) > (costs[pick] as u128 * reps[g] as u128) {
                pick = g;
            }
        }
        reps[pick] += 1;
        spare -= 1;
    }
    reps
}

/// Blocking push with bounded-ring backpressure: spin briefly, then
/// sleep — the ring ahead only stays full while the downstream group is
/// the bottleneck, in which case throughput is its service rate and the
/// producer's wait is free. `Err` means the ring closed underneath the
/// producer (only possible if the consumer died); the frame is dropped
/// and its sender with it, so the submitter observes a clean
/// channel-closed error instead of a hang.
fn push_frame(q: &RingQueue<Frame>, mut f: Frame) -> std::result::Result<(), ()> {
    let mut tries = 0u32;
    loop {
        match q.try_push(f) {
            Ok(()) => return Ok(()),
            Err(PushError::Full(back)) => {
                f = back;
                tries += 1;
                if tries < 64 {
                    std::thread::yield_now();
                } else {
                    std::thread::sleep(Duration::from_micros(20));
                }
            }
            Err(PushError::Closed(_)) => return Err(()),
        }
    }
}

/// One replica of a stage group: drain its input ring, run the group's
/// stages on each frame, hand off through the downstream boundary (or
/// answer, for the final group). Exits when the input ring is closed
/// **and** empty — the drain-friendly contract [`RingQueue`] guarantees.
/// The last replica of the group to exit cascades the close through the
/// boundary so the next group can wind down the same way.
fn group_worker(
    model: Arc<CompiledModel>,
    dp: Datapath,
    span: Range<usize>,
    inq: Arc<RingQueue<Frame>>,
    boundary: Option<Arc<Boundary>>,
    ctx: WorkerCtx,
) {
    let WorkerCtx { live, meter, trace, group, replica } = ctx;
    let qmax = model.spec.act_qmax();
    loop {
        let frame = match inq.pop_timeout(POLL) {
            Ok(f) => f,
            Err(PopError::Empty) => continue,
            Err(PopError::Closed) => break,
        };
        // Group span events share the tracer's per-request sampling
        // predicate, keyed by frame sequence (positional — the plane's
        // request ids live one layer up; DESIGN.md §16).
        let traced = trace.as_ref().filter(|h| h.sampled(frame.seq));
        if let Some(h) = traced {
            h.record(EventKind::GroupEnter, frame.seq, 0, group, replica);
        }
        let t0 = Instant::now();
        let mut act = frame.act;
        let mut logits: Option<Vec<f32>> = None;
        for stage in &model.stages()[span.clone()] {
            match stage {
                Stage::Pool(p) => act = p.run(&act),
                Stage::Mac(m) => {
                    if m.is_output {
                        logits = Some(m.run_output(&act, dp));
                    } else {
                        act = m.run_hidden(&act, qmax, dp);
                    }
                }
            }
        }
        meter
            .busy_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        meter.frames.fetch_add(1, Ordering::Relaxed);
        if let Some(h) = traced {
            h.record(EventKind::GroupExit, frame.seq, 0, group, replica);
        }
        match (logits, &boundary) {
            // The output MAC is the model's last stage, so only the
            // final group produces logits. Ordering needs no boundary
            // here: the per-frame sender already routes each answer to
            // its own submitter.
            (Some(v), _) => {
                // A dropped receiver (caller gave up) is not an error.
                let _ = frame.tx.send(v);
            }
            (None, Some(b)) => b.emit(Frame { seq: frame.seq, act, tx: frame.tx }),
            (None, None) => unreachable!("compile validated the graph ends in an output MAC"),
        }
    }
    // Cascade-close: only the last replica out may close downstream —
    // sibling replicas may still hold frames for the next group.
    if live.fetch_sub(1, Ordering::AcqRel) == 1 {
        if let Some(b) = boundary {
            b.close();
        }
    }
}

/// A compiled model executing as a staged layer pipeline: one or more
/// worker threads per cost-balanced stage group, bounded rings between
/// groups, in-order recombination at every group boundary. See the
/// module docs for the identity / shutdown / calibration contracts.
pub struct StagedExecutor {
    model: Arc<CompiledModel>,
    dp: Datapath,
    spans: Vec<Range<usize>>,
    costs: Vec<u64>,
    names: Vec<String>,
    replicas: Vec<usize>,
    fifo_depth: usize,
    /// `fifos[g][r]` feeds replica r of group g; `fifos[0]` are the
    /// submit rings.
    fifos: Vec<Vec<Arc<RingQueue<Frame>>>>,
    high_water: Vec<Vec<Arc<AtomicUsize>>>,
    meters: Vec<Vec<Arc<GroupMeter>>>,
    /// Serialises sequence-number assignment with the submit-side push,
    /// so accepted frames are numbered contiguously from 0 — the gap
    /// freedom every reorder boundary relies on.
    submit_seq: Mutex<u64>,
    submitted: Arc<AtomicU64>,
    started: Instant,
    workers: Vec<JoinHandle<()>>,
}

impl StagedExecutor {
    /// Pipeline `model` across (at most) `groups` stage groups with the
    /// default FIFO depth, one worker per group, executing the model's
    /// pinned datapath. `groups` is clamped to the stage count;
    /// `groups == 1` is the degenerate pipeline — the whole serial walk
    /// on one worker, correct but not concurrent.
    pub fn new(model: Arc<CompiledModel>, groups: usize) -> Result<Self> {
        let dp = model.datapath();
        Self::with_config(model, groups, DEFAULT_FIFO_DEPTH, dp)
    }

    /// Unreplicated constructor: explicit FIFO depth and [`Datapath`]
    /// override (the identity tests sweep every compiled-in datapath
    /// without recompiling the model), one worker per group.
    pub fn with_config(
        model: Arc<CompiledModel>,
        groups: usize,
        fifo_depth: usize,
        dp: Datapath,
    ) -> Result<Self> {
        Self::build(model, groups, fifo_depth, dp, PipeObs::default(), |costs| {
            vec![1; costs.len()]
        })
    }

    /// Budgeted constructor: partition into (at most) `groups` groups,
    /// then spend up to `workers` total worker threads via
    /// [`replication_plan`] — every group gets one, and the slack goes
    /// to the costliest group(s). `workers <= groups` degenerates to
    /// [`StagedExecutor::with_config`].
    pub fn with_budget(
        model: Arc<CompiledModel>,
        groups: usize,
        workers: usize,
        fifo_depth: usize,
        dp: Datapath,
    ) -> Result<Self> {
        Self::with_budget_obs(model, groups, workers, fifo_depth, dp, PipeObs::default())
    }

    /// [`StagedExecutor::with_budget`] with observability attached: see
    /// [`PipeObs`] for what each sink records.
    pub fn with_budget_obs(
        model: Arc<CompiledModel>,
        groups: usize,
        workers: usize,
        fifo_depth: usize,
        dp: Datapath,
        obs: PipeObs,
    ) -> Result<Self> {
        Self::build(model, groups, fifo_depth, dp, obs, |costs| {
            replication_plan(costs, workers)
        })
    }

    /// Pinned-replication constructor: partition into (at most)
    /// `groups` groups and run `r` replicas on the single costliest
    /// group (1 everywhere else) — the `--pipeline NxR` shape.
    pub fn with_bottleneck_replication(
        model: Arc<CompiledModel>,
        groups: usize,
        r: usize,
        fifo_depth: usize,
        dp: Datapath,
    ) -> Result<Self> {
        Self::with_bottleneck_replication_obs(model, groups, r, fifo_depth, dp, PipeObs::default())
    }

    /// [`StagedExecutor::with_bottleneck_replication`] with
    /// observability attached: see [`PipeObs`] for what each sink
    /// records.
    pub fn with_bottleneck_replication_obs(
        model: Arc<CompiledModel>,
        groups: usize,
        r: usize,
        fifo_depth: usize,
        dp: Datapath,
        obs: PipeObs,
    ) -> Result<Self> {
        Self::build(model, groups, fifo_depth, dp, obs, |costs| {
            let mut reps = vec![1usize; costs.len()];
            if let Some((g, _)) = costs.iter().enumerate().max_by_key(|(_, c)| **c) {
                reps[g] = r.max(1);
            }
            reps
        })
    }

    /// Shared constructor core: `plan` maps the partitioned group costs
    /// to per-group replica counts (each ≥ 1).
    fn build(
        model: Arc<CompiledModel>,
        groups: usize,
        fifo_depth: usize,
        dp: Datapath,
        obs: PipeObs,
        plan: impl FnOnce(&[u64]) -> Vec<usize>,
    ) -> Result<Self> {
        if model.stages().is_empty() {
            return Err(Error::kernel("cannot pipeline a model with no stages"));
        }
        if groups == 0 {
            return Err(Error::config("pipeline needs >= 1 stage group"));
        }
        if fifo_depth == 0 {
            return Err(Error::config("pipeline FIFO depth must be >= 1"));
        }
        let per_stage: Vec<u64> = model.stages().iter().map(stage_cost).collect();
        let spans = partition(&per_stage, groups);
        let costs: Vec<u64> = spans
            .iter()
            .map(|s| per_stage[s.clone()].iter().sum())
            .collect();
        let names: Vec<String> = spans
            .iter()
            .map(|s| {
                model.stages()[s.clone()]
                    .iter()
                    .map(stage_name)
                    .collect::<Vec<_>>()
                    .join("+")
            })
            .collect();
        let replicas = plan(&costs);
        if replicas.len() != spans.len() || replicas.iter().any(|&r| r == 0) {
            return Err(Error::config(format!(
                "replication plan {replicas:?} does not cover {} groups",
                spans.len()
            )));
        }

        let fifos: Vec<Vec<Arc<RingQueue<Frame>>>> = replicas
            .iter()
            .map(|&r| (0..r).map(|_| Arc::new(RingQueue::new(fifo_depth))).collect())
            .collect();
        let high_water: Vec<Vec<Arc<AtomicUsize>>> = replicas
            .iter()
            .map(|&r| (0..r).map(|_| Arc::new(AtomicUsize::new(0))).collect())
            .collect();
        let meters: Vec<Vec<Arc<GroupMeter>>> = replicas
            .iter()
            .map(|&r| (0..r).map(|_| Arc::new(GroupMeter::default())).collect())
            .collect();
        // boundaries[g] recombines group g's output and feeds group g+1.
        let boundaries: Vec<Arc<Boundary>> = (0..spans.len().saturating_sub(1))
            .map(|g| {
                Arc::new(Boundary::new(
                    fifos[g + 1].clone(),
                    high_water[g + 1].clone(),
                ))
            })
            .collect();
        let live: Vec<Arc<AtomicUsize>> = replicas
            .iter()
            .map(|&r| Arc::new(AtomicUsize::new(r)))
            .collect();

        let submitted = Arc::new(AtomicU64::new(0));
        let started = Instant::now();
        let mut workers = Vec::with_capacity(replicas.iter().sum());
        for (g, span) in spans.iter().enumerate() {
            for r in 0..replicas[g] {
                let m = Arc::clone(&model);
                let span = span.clone();
                let inq = Arc::clone(&fifos[g][r]);
                let boundary = boundaries.get(g).map(Arc::clone);
                let ctx = WorkerCtx {
                    live: Arc::clone(&live[g]),
                    meter: Arc::clone(&meters[g][r]),
                    trace: obs
                        .tracer
                        .as_ref()
                        .map(|t| t.register(&format!("{}.g{g}r{r}", obs.label))),
                    group: g as u16,
                    replica: r as u16,
                };
                workers.push(std::thread::spawn(move || {
                    group_worker(m, dp, span, inq, boundary, ctx);
                }));
            }
        }
        if let Some(reg) = &obs.metrics {
            let label = obs.label.clone();
            // In-flight frames: accepted minus drained out of the final
            // group (both single-writer counters, read racily — a gauge,
            // not an invariant).
            let sub = Arc::clone(&submitted);
            let last: Vec<Arc<GroupMeter>> = meters.last().cloned().unwrap_or_default();
            reg.gauge_fn(&format!("{label}.in_flight"), move || {
                let done: u64 = last
                    .iter()
                    .map(|m| m.frames.load(Ordering::Relaxed))
                    .sum();
                sub.load(Ordering::Relaxed).saturating_sub(done) as f64
            });
            let hw: Vec<Arc<AtomicUsize>> = high_water.iter().flatten().cloned().collect();
            reg.gauge_fn(&format!("{label}.fifo_high_water"), move || {
                hw.iter()
                    .map(|h| h.load(Ordering::Relaxed))
                    .max()
                    .unwrap_or(0) as f64
            });
            for (g, gm) in meters.iter().enumerate() {
                let gm = gm.clone();
                let reps = gm.len().max(1) as f64;
                reg.gauge_fn(&format!("{label}.g{g}.util"), move || {
                    let busy: u64 = gm.iter().map(|m| m.busy_ns.load(Ordering::Relaxed)).sum();
                    let wall = started.elapsed().as_secs_f64().max(1e-12);
                    busy as f64 / 1e9 / (wall * reps)
                });
            }
        }
        Ok(StagedExecutor {
            model,
            dp,
            spans,
            costs,
            names,
            replicas,
            fifo_depth,
            fifos,
            high_water,
            meters,
            submit_seq: Mutex::new(0),
            submitted,
            started,
            workers,
        })
    }

    /// The model this pipeline executes.
    pub fn model(&self) -> &Arc<CompiledModel> {
        &self.model
    }

    /// The datapath every group executes.
    pub fn datapath(&self) -> Datapath {
        self.dp
    }

    /// Number of stage groups.
    pub fn groups(&self) -> usize {
        self.spans.len()
    }

    /// Stage-index span of each group, in stream order.
    pub fn group_spans(&self) -> &[Range<usize>] {
        &self.spans
    }

    /// MAC-equivalent cost of each group (the partitioning input).
    pub fn group_costs(&self) -> &[u64] {
        &self.costs
    }

    /// Human-readable name of each group (member stages joined by `+`).
    pub fn group_names(&self) -> &[String] {
        &self.names
    }

    /// Worker-thread (replica) count of each group, in stream order.
    pub fn group_replicas(&self) -> &[usize] {
        &self.replicas
    }

    /// Total worker threads across all groups (Σ replicas).
    pub fn worker_count(&self) -> usize {
        self.replicas.iter().sum()
    }

    /// Largest per-group replica count — 1 means unreplicated.
    pub fn max_replication(&self) -> usize {
        self.replicas.iter().copied().max().unwrap_or(1)
    }

    /// Inter-group FIFO capacity, in frames (per replica ring).
    pub fn fifo_depth(&self) -> usize {
        self.fifo_depth
    }

    /// Quantise one image and enqueue it; the receiver yields the
    /// frame's logits once it drains out of the final group. Frames
    /// flow in sequence order end to end (reorder boundaries
    /// re-establish it behind every replicated group). Fails with
    /// [`Error::QueueClosed`] once [`StagedExecutor::close`] has run.
    pub fn submit(&self, image: &[f32]) -> Result<mpsc::Receiver<Vec<f32>>> {
        if image.len() != self.model.input_pixels() {
            return Err(Error::kernel(format!(
                "input length {} != {}",
                image.len(),
                self.model.input_pixels()
            )));
        }
        // Entry quantisation, byte for byte the forward_with expression.
        let qmax = self.model.spec.act_qmax();
        let in_scale = self.model.spec.input_scale();
        let act: Vec<u8> = image
            .iter()
            .map(|&x| ((x / in_scale).round() as i32).clamp(0, qmax) as u8)
            .collect();
        let (tx, rx) = mpsc::channel();
        // Sequence assignment and push are one critical section, and the
        // counter only advances on success: accepted frames carry the
        // contiguous numbers 0..submitted, with no gaps for the reorder
        // boundaries to stall on — even when a concurrent close() lands
        // between two submissions.
        let mut seq_guard = self.submit_seq.lock().expect("submit mutex poisoned");
        let seq = *seq_guard;
        let r = (seq % self.fifos[0].len() as u64) as usize;
        push_frame(&self.fifos[0][r], Frame { seq, act, tx }).map_err(|_| Error::QueueClosed)?;
        *seq_guard += 1;
        drop(seq_guard);
        self.high_water[0][r].fetch_max(self.fifos[0][r].len(), Ordering::Relaxed);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(rx)
    }

    /// One frame through the pipeline, blocking for its logits.
    pub fn infer(&self, image: &[f32]) -> Result<Vec<f32>> {
        self.submit(image)?.recv().map_err(|_| Error::QueueClosed)
    }

    /// Stream a batch of `n` frames through the pipeline and collect the
    /// logits in submission order — same length contract and result
    /// layout as [`CompiledModel::infer_batch`], but frame k+1 enters
    /// group 0 while frame k is still in a later group. Deadlock-free by
    /// construction: results leave through unbounded channels, so the
    /// final group never blocks and the bounded rings always drain.
    pub fn infer_batch(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let px = self.model.input_pixels();
        if x.len() != n * px {
            return Err(Error::kernel(format!(
                "batch of {n} needs {} values, got {}",
                n * px,
                x.len()
            )));
        }
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            rxs.push(self.submit(&x[i * px..(i + 1) * px])?);
        }
        let mut out = Vec::with_capacity(n * self.model.output_len());
        for rx in rxs {
            out.extend(rx.recv().map_err(|_| Error::QueueClosed)?);
        }
        Ok(out)
    }

    /// Stop accepting frames and let the pipeline drain: closes the
    /// submit rings only; each worker finishes every queued frame, and
    /// the last replica of each group cascades the close downstream and
    /// exits. Receivers returned by earlier [`StagedExecutor::submit`]
    /// calls still deliver. Idempotent; [`Drop`] calls it and joins the
    /// workers.
    pub fn close(&self) {
        for q in &self.fifos[0] {
            q.close();
        }
    }

    /// Measured per-group occupancy since start (the calibration
    /// counterpart of the simulator's per-stage utilisation), with
    /// per-replica counters rolled up per group.
    pub fn stats(&self) -> PipelineStats {
        PipelineStats {
            groups: (0..self.spans.len())
                .map(|g| {
                    let replica_frames: Vec<u64> = self.meters[g]
                        .iter()
                        .map(|m| m.frames.load(Ordering::Relaxed))
                        .collect();
                    let replica_busy_s: Vec<f64> = self.meters[g]
                        .iter()
                        .map(|m| m.busy_ns.load(Ordering::Relaxed) as f64 / 1e9)
                        .collect();
                    GroupStats {
                        name: self.names[g].clone(),
                        stages: self.spans[g].clone(),
                        cost: self.costs[g],
                        replicas: self.replicas[g],
                        frames: replica_frames.iter().sum(),
                        busy_s: replica_busy_s.iter().sum(),
                        replica_frames,
                        replica_busy_s,
                    }
                })
                .collect(),
            fifo_high_water: self
                .high_water
                .iter()
                .map(|hws| {
                    hws.iter()
                        .map(|hw| hw.load(Ordering::Relaxed))
                        .max()
                        .unwrap_or(0)
                })
                .collect(),
            fifo_capacity: self.fifo_depth,
            submitted: self.submitted.load(Ordering::Relaxed),
            elapsed_s: self.started.elapsed().as_secs_f64(),
        }
    }

    /// The simulator's view of this exact pipeline: one [`StageSpec`]
    /// per stage group in stream order, II = the group's MAC-equivalent
    /// cost (one simulated cycle per op), whole activation frames as
    /// tokens, replica counts mirrored — the simulator models R workers
    /// as R compute units with an effective II of cost/R. Feed them to
    /// [`StagedExecutor::calibration_sim`] (or
    /// [`sim::Pipeline`](crate::sim::Pipeline) directly) to predict the
    /// bottleneck group of the served pipeline.
    pub fn sim_specs(&self) -> Vec<StageSpec> {
        (0..self.spans.len())
            .map(|g| StageSpec {
                name: self.names[g].clone(),
                kind: Kind::Fc,
                tokens_per_frame: 1,
                in_tokens_per_frame: 1,
                ii_cycles_per_frame: self.costs[g].max(1),
                fill_cycles: 0,
                replicas: self.replicas[g] as u64,
            })
            .collect()
    }

    /// Build the calibration pipeline: the same grouping, group costs,
    /// replica counts and FIFO depth as the served executor, as a cycle
    /// simulation at `f_mhz`. Its
    /// [`SimReport`](crate::sim::SimReport) must identify the same
    /// bottleneck group as [`StagedExecutor::stats`] measures.
    pub fn calibration_sim(&self, f_mhz: f64) -> SimPipeline {
        SimPipeline::new(self.sim_specs(), self.fifo_depth, f_mhz)
    }
}

impl Drop for StagedExecutor {
    fn drop(&mut self) {
        self.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Measured occupancy of one stage group.
#[derive(Debug, Clone)]
pub struct GroupStats {
    /// Member stage names joined by `+`.
    pub name: String,
    /// Stage-index span within the model's stage list.
    pub stages: Range<usize>,
    /// MAC-equivalent cost (the partitioning input).
    pub cost: u64,
    /// Worker threads serving this group.
    pub replicas: usize,
    /// Frames this group finished (summed across replicas).
    pub frames: u64,
    /// Wall time the group's workers spent executing stages, seconds
    /// (summed across replicas).
    pub busy_s: f64,
    /// Frames finished by each replica.
    pub replica_frames: Vec<u64>,
    /// Busy seconds of each replica.
    pub replica_busy_s: Vec<f64>,
}

/// Measured pipeline occupancy: the served-side counterpart of the
/// simulator's [`SimReport`](crate::sim::SimReport) stage utilisation.
#[derive(Debug, Clone)]
pub struct PipelineStats {
    /// Per-group occupancy, in stream order.
    pub groups: Vec<GroupStats>,
    /// High-water occupancy of each group's rings (`[g]` feeds group g;
    /// `[0]` are the submit rings; the max across the group's replica
    /// rings).
    pub fifo_high_water: Vec<usize>,
    /// Ring capacity, in frames (per replica ring).
    pub fifo_capacity: usize,
    /// Frames accepted at the submit side.
    pub submitted: u64,
    /// Wall time since the executor started, seconds.
    pub elapsed_s: f64,
}

impl PipelineStats {
    /// Frames that drained out of the final group.
    pub fn completed(&self) -> u64 {
        self.groups.last().map_or(0, |g| g.frames)
    }

    /// Frames accepted but not (yet) completed. After a drain this must
    /// be 0 — the lossless-shutdown acceptance counter.
    pub fn in_flight(&self) -> u64 {
        self.submitted - self.completed()
    }

    /// Index of the measured bottleneck group: the one whose *per
    /// replica* busy time is largest (all groups see the same frame
    /// stream, so normalised busy-time order is service-rate order —
    /// a group running R replicas serves frames R times faster than its
    /// summed busy time suggests).
    pub fn bottleneck_group(&self) -> usize {
        self.groups
            .iter()
            .enumerate()
            .max_by(|a, b| {
                (a.1.busy_s / a.1.replicas.max(1) as f64)
                    .total_cmp(&(b.1.busy_s / b.1.replicas.max(1) as f64))
            })
            .map(|(i, _)| i)
            .expect("non-empty pipeline")
    }

    /// Per-group utilisation over the elapsed wall time × replicas
    /// (per-worker occupancy, comparable to the simulator's per-stage
    /// utilisation in steady state).
    pub fn utilisation(&self) -> Vec<f64> {
        let wall = self.elapsed_s.max(1e-12);
        self.groups
            .iter()
            .map(|g| g.busy_s / (wall * g.replicas.max(1) as f64))
            .collect()
    }

    /// `(group name, utilisation)` pairs in stream order — the measured
    /// occupancy the kernel-selection policy consumes
    /// ([`crate::kernel::Calibration::from_stats`]).
    pub fn occupancy(&self) -> Vec<(String, f64)> {
        self.groups
            .iter()
            .zip(self.utilisation())
            .map(|(g, u)| (g.name.clone(), u))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::lenet5;
    use crate::kernel::KernelSpec;
    use crate::weights::ModelParams;

    #[test]
    fn partition_balances_and_isolates_the_heavy_stage() {
        assert_eq!(partition(&[5, 5, 5, 5], 2), vec![0..2, 2..4]);
        // The dominant stage ends up alone: min-max has no better cut.
        let p = partition(&[10, 100, 10], 3);
        assert_eq!(p, vec![0..1, 1..2, 2..3]);
        // 2-way split of [10, 100, 10]: both cuts cost max 110 — assert
        // the DP achieves that optimum rather than a specific cut.
        let p = partition(&[10, 100, 10], 2);
        let worst = p
            .iter()
            .map(|s| [10u64, 100, 10][s.clone()].iter().sum::<u64>())
            .max()
            .unwrap();
        assert_eq!(worst, 110);
        // More groups than stages clamps; zero-ish inputs never panic.
        assert_eq!(partition(&[3], 5), vec![0..1]);
        assert_eq!(partition(&[1, 2, 3], 1), vec![0..3]);
    }

    #[test]
    fn partition_covers_contiguously() {
        let costs = [86_400u64, 3_456, 153_600, 1_024, 30_720, 10_080, 840];
        for g in 1..=costs.len() {
            let spans = partition(&costs, g);
            assert_eq!(spans.len(), g);
            assert_eq!(spans[0].start, 0);
            assert_eq!(spans.last().unwrap().end, costs.len());
            for w in spans.windows(2) {
                assert_eq!(w[0].end, w[1].start, "gap between groups");
            }
            for s in &spans {
                assert!(s.start < s.end, "empty group in {spans:?}");
            }
        }
    }

    #[test]
    fn replication_plan_spends_slack_on_the_costliest() {
        // No slack: everyone gets exactly one worker.
        assert_eq!(replication_plan(&[10, 100, 10], 3), vec![1, 1, 1]);
        assert_eq!(replication_plan(&[10, 100, 10], 0), vec![1, 1, 1]);
        // Slack goes to the dominant group first…
        assert_eq!(replication_plan(&[10, 100, 10], 4), vec![1, 2, 1]);
        assert_eq!(replication_plan(&[10, 100, 10], 5), vec![1, 3, 1]);
        // …and water-fills once effective costs cross: 100/2 = 50 < 60,
        // so the fifth worker lands on the first group.
        assert_eq!(replication_plan(&[60, 100, 10], 5), vec![2, 2, 1]);
        // Ties break toward the earliest group.
        assert_eq!(replication_plan(&[50, 50], 3), vec![2, 1]);
    }

    #[test]
    fn pipelined_forward_is_bit_identical() {
        let g = lenet5();
        let p = ModelParams::synthetic(&g, 31);
        let model =
            Arc::new(CompiledModel::compile_dense(&g, &p, &KernelSpec::default()).unwrap());
        let exec = StagedExecutor::new(Arc::clone(&model), 3).unwrap();
        assert_eq!(exec.groups(), 3);
        assert_eq!(exec.group_replicas(), &[1, 1, 1]);
        for seed in 0..4u64 {
            let img = crate::runtime::SyntheticRuntime::stripe_image(seed as usize);
            assert_eq!(exec.infer(&img).unwrap(), model.forward(&img).unwrap());
        }
    }

    #[test]
    fn replicated_pipeline_is_bit_identical_and_lossless() {
        let g = lenet5();
        let p = ModelParams::synthetic(&g, 37);
        let model =
            Arc::new(CompiledModel::compile_dense(&g, &p, &KernelSpec::default()).unwrap());
        let exec = StagedExecutor::with_bottleneck_replication(
            Arc::clone(&model),
            3,
            2,
            2,
            model.datapath(),
        )
        .unwrap();
        assert_eq!(exec.groups(), 3);
        assert_eq!(exec.max_replication(), 2);
        assert_eq!(exec.worker_count(), 4);
        let imgs: Vec<Vec<f32>> = (0..10)
            .map(crate::runtime::SyntheticRuntime::stripe_image)
            .collect();
        let rxs: Vec<_> = imgs.iter().map(|i| exec.submit(i).unwrap()).collect();
        for (img, rx) in imgs.iter().zip(rxs) {
            assert_eq!(rx.recv().unwrap(), model.forward(img).unwrap());
        }
        exec.close();
        let stats = exec.stats();
        assert_eq!(stats.submitted, 10);
        assert_eq!(stats.completed(), 10);
        assert_eq!(stats.in_flight(), 0);
        // The replicated group's frames split across its two workers.
        let replicated = stats
            .groups
            .iter()
            .find(|g| g.replicas == 2)
            .expect("one group carries two replicas");
        assert_eq!(replicated.replica_frames.iter().sum::<u64>(), 10);
        assert_eq!(replicated.replica_frames.len(), 2);
    }

    #[test]
    fn close_drains_then_rejects() {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, 33);
        p.prune_global(0.75, 0.05).unwrap();
        let model =
            Arc::new(CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap());
        let exec = StagedExecutor::with_config(
            Arc::clone(&model),
            4,
            2,
            model.datapath(),
        )
        .unwrap();
        let imgs: Vec<Vec<f32>> = (0..12)
            .map(crate::runtime::SyntheticRuntime::stripe_image)
            .collect();
        let rxs: Vec<_> = imgs.iter().map(|i| exec.submit(i).unwrap()).collect();
        exec.close();
        // Every accepted frame still delivers, bit-identically.
        for (img, rx) in imgs.iter().zip(rxs) {
            assert_eq!(rx.recv().unwrap(), model.forward(img).unwrap());
        }
        assert!(matches!(exec.submit(&imgs[0]), Err(Error::QueueClosed)));
        let stats = exec.stats();
        assert_eq!(stats.submitted, 12);
        assert_eq!(stats.completed(), 12);
        assert_eq!(stats.in_flight(), 0, "drain lost frames");
    }

    #[test]
    fn sim_specs_mirror_the_grouping() {
        let g = lenet5();
        let p = ModelParams::synthetic(&g, 35);
        let model =
            Arc::new(CompiledModel::compile_dense(&g, &p, &KernelSpec::default()).unwrap());
        let exec = StagedExecutor::new(Arc::clone(&model), 3).unwrap();
        let specs = exec.sim_specs();
        assert_eq!(specs.len(), exec.groups());
        for (spec, (cost, name)) in specs
            .iter()
            .zip(exec.group_costs().iter().zip(exec.group_names()))
        {
            assert_eq!(&spec.name, name);
            assert_eq!(spec.ii_cycles_per_frame, (*cost).max(1));
            assert_eq!(spec.tokens_per_frame, 1);
            assert_eq!(spec.replicas, 1);
        }
        // The predicted bottleneck is the costliest group by definition
        // of the spec II — the serving-side agreement is asserted with
        // real measurements in tests/kernel_pipeline.rs.
        let mut sim = exec.calibration_sim(100.0);
        let rep = sim
            .try_run(&crate::sim::Workload::parse("saturated", 32).unwrap())
            .unwrap();
        let costliest = exec
            .group_costs()
            .iter()
            .enumerate()
            .max_by_key(|(_, c)| **c)
            .unwrap()
            .0;
        assert_eq!(rep.bottleneck_stage().name, exec.group_names()[costliest]);
    }

    #[test]
    fn sim_specs_mirror_replication_and_move_the_predicted_bottleneck() {
        let g = lenet5();
        let p = ModelParams::synthetic(&g, 35);
        let model =
            Arc::new(CompiledModel::compile_dense(&g, &p, &KernelSpec::default()).unwrap());
        // Enough replicas on the costliest group that its effective cost
        // drops well below the runner-up's.
        let exec = StagedExecutor::with_bottleneck_replication(
            Arc::clone(&model),
            3,
            3,
            DEFAULT_FIFO_DEPTH,
            model.datapath(),
        )
        .unwrap();
        let costliest = exec
            .group_costs()
            .iter()
            .enumerate()
            .max_by_key(|(_, c)| **c)
            .unwrap()
            .0;
        assert_eq!(exec.group_replicas()[costliest], 3);
        let specs = exec.sim_specs();
        assert_eq!(specs[costliest].replicas, 3);
        // Predicted bottleneck = argmax of cost / replicas, which is no
        // longer the costliest group.
        let mut sim = exec.calibration_sim(100.0);
        let rep = sim
            .try_run(&crate::sim::Workload::parse("saturated", 32).unwrap())
            .unwrap();
        let expected = exec
            .group_costs()
            .iter()
            .zip(exec.group_replicas())
            .enumerate()
            .max_by(|(_, (ca, ra)), (_, (cb, rb))| {
                (**ca as f64 / **ra as f64).total_cmp(&(**cb as f64 / **rb as f64))
            })
            .unwrap()
            .0;
        assert_ne!(expected, costliest, "replication should move the floor");
        assert_eq!(rep.bottleneck_stage().name, exec.group_names()[expected]);
    }
}
