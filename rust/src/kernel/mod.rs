//! Engine-free baked sparse kernels (substrate S20) — the software
//! analogue of the paper's LUT baking.
//!
//! A compile pass takes a [`crate::graph::Graph`], exported parameters
//! (weights + unstructured masks, [`crate::weights::ModelParams`]) and the
//! W4 quantisation grid ([`crate::quant::QSpec`]), and emits a
//! [`CompiledModel`]: per-layer baked kernels in which **pruned weights
//! synthesise to nothing** — the nnz-only MAC schedule simply contains no
//! entry for them, exactly as the hardware flow bakes surviving weights
//! into logic and lets zeros vanish. There is no sparse engine at run
//! time: no CSR walk, no bitmap decode, no gather unit — the schedule
//! *is* the layer.
//!
//! Kernel variants mirror [`crate::folding::Style`]:
//! * `Folded` / `UnrolledDense` → a dense MAC loop over every weight
//!   (the dense-engine baseline the bench compares against);
//! * `UnrolledSparse`          → a per-output-neuron nnz-only schedule;
//! * `PartialSparse`           → a block schedule (SIMD-lane granularity):
//!   all-zero blocks are elided, live blocks run dense;
//! * `NmStructured`            → an N:M fixed-slot schedule: every group
//!   of M consecutive input rows carries a fixed number of slots
//!   (survivors first, sum-neutral code-0 pads after), so the index
//!   stream decodes at a fixed stride ([`pack::pack_nm_indices`]).
//!
//! Flavours can be forced per model
//! ([`CompiledModel::compile_with_choice`], [`Flavour`]) or chosen per
//! layer by the cost-driven selection policy ([`KernelChoice`],
//! [`CompiledModel::compile_auto`]): each layer's candidates are scored
//! with the [`crate::cost`] latency/LUT models under a per-layer LUT
//! budget share, and the predictions ride on the compiled stages
//! (`predicted_ii` / `predicted_luts` on [`MacStage`]) so benches can put
//! predicted next to measured.
//!
//! The datapath is integer end-to-end: activations are quantised codes
//! (unsigned, ReLU clipped), MACs accumulate in `i32`, and each layer
//! requantises with a per-output-channel multiplier — floats touch only
//! the requant step, as on the accelerator. Weight codes and schedule
//! indices are additionally bit-packed ([`pack`]) so size accounting is
//! byte-exact; the packed stream round-trips to the execution tables.
//!
//! One `CompiledModel` is the single artifact every consumer shares: the
//! serving plane executes it ([`NativeSparseBackend`] behind
//! `coordinator::EngineBackend::Native`), the simulator and DSE read its
//! [`FoldingConfig`], and the experiments read its [`ModelSparsity`] /
//! compression accounting — instead of each path re-deriving layer shapes
//! from the graph independently.
//!
//! Compiling and running a tiny synthetic model end to end:
//!
//! ```
//! use logicsparse::graph::builder::mlp;
//! use logicsparse::kernel::{CompiledModel, KernelSpec};
//! use logicsparse::weights::ModelParams;
//!
//! // A small MLP (16 inputs, two 12-wide hidden fc layers, 10 logits)
//! // with synthetic weights, pruned to 50%.
//! let g = mlp(16, 12, 10);
//! let mut params = ModelParams::synthetic(&g, 7);
//! params.prune_global(0.5, 0.1).unwrap();
//!
//! // Bake the nnz-only schedules (masks are authoritative).
//! let model = CompiledModel::compile_sparse(&g, &params, &KernelSpec::default()).unwrap();
//! assert!(model.total_nnz() < model.total_weights());
//!
//! // Run one frame: integer datapath in, f32 logits out.
//! let x = vec![0.5f32; model.input_pixels()];
//! let logits = model.forward(&x).unwrap();
//! assert_eq!(logits.len(), model.output_len());
//! assert_eq!(model.output_len(), 10);
//! ```

pub mod backend;
pub mod pack;
pub mod pipeline;
pub mod pool;

use crate::device::{Device, XCU50};
use crate::folding::{FoldingConfig, LayerFold, Style};
use crate::graph::{Graph, Node, Op};
use crate::quant::{quantize_per_channel, QSpec};
use crate::sparsity::nm::{detect_nm, NmFit};
use crate::sparsity::{compression_ratio, compression_ratio_csr, ModelSparsity};
use crate::util::error::{Error, Result};
use crate::weights::{LayerParams, ModelParams};

pub use backend::NativeSparseBackend;
pub use pipeline::{PipeObs, StagedExecutor};
pub use pool::BatchPool;

/// Independent accumulator lanes the chunked datapaths use (eight i32
/// lanes: two SSE registers, one AVX2 register).
pub const LANES: usize = 8;

/// Which inner-loop implementation the MAC stages execute.
///
/// Every datapath produces **bit-identical** logits: the i32 MAC
/// accumulation is exact (wrapping two's-complement addition is
/// associative and commutative), so reassociating the sums — lane
/// chunking, multi-row fusion, pairwise `madd` — cannot change a single
/// bit of any output. Tests assert this across all kernel flavours; see
/// DESIGN.md §12.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Datapath {
    /// Reference implementation: the straightforward scalar schedule
    /// walk (one loop-carried accumulator per output channel).
    Scalar,
    /// Lane-chunked loops in stable Rust: dense rows are fused four at a
    /// time per pass over the output channels, sparse dot products run on
    /// [`LANES`] independent partial sums. The shapes are what LLVM's
    /// autovectoriser keeps in vector registers — no intrinsics, no
    /// `unsafe`, works on every target.
    Vector,
    /// Explicit `std::arch` x86_64 SSE2 intrinsics (`_mm_madd_epi16` for
    /// sparse dot products, widening `mullo` for dense rows). Only
    /// compiled behind the off-by-default `simd` cargo feature; SSE2 is
    /// part of the x86_64 baseline, so no runtime detection is needed.
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    Simd,
    /// Explicit `std::arch` x86_64 AVX2 intrinsics: the same
    /// `madd_epi16` schedule as [`Datapath::Simd`] but over 256-bit
    /// registers — 16-entry sparse chunks and 16-channel dense passes.
    /// Compiled behind the same `simd` feature, but AVX2 is *not* part
    /// of the x86_64 baseline, so selection is gated on runtime
    /// `is_x86_feature_detected!("avx2")`; pinning it on a CPU without
    /// AVX2 falls back to the SSE2 path (bit-identical anyway).
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    Avx2,
}

impl Datapath {
    /// The fastest datapath available to this build *on this CPU* —
    /// what [`CompiledModel::forward`] executes by default. With the
    /// `simd` feature on, AVX2 is picked when the CPU reports it
    /// (runtime dispatch), else the SSE2 baseline.
    pub fn best() -> Datapath {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                Datapath::Avx2
            } else {
                Datapath::Simd
            }
        }
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        {
            Datapath::Vector
        }
    }

    /// Every datapath runnable in this build on this CPU, reference
    /// first (the grid benches and bit-identity tests iterate this).
    /// AVX2 appears only when the CPU reports it, so the list is always
    /// safe to execute.
    pub fn all() -> Vec<Datapath> {
        let mut all = vec![Datapath::Scalar, Datapath::Vector];
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        {
            all.push(Datapath::Simd);
            if std::arch::is_x86_feature_detected!("avx2") {
                all.push(Datapath::Avx2);
            }
        }
        all
    }

    /// Short label for bench rows and logs.
    pub fn label(self) -> &'static str {
        match self {
            Datapath::Scalar => "scalar",
            Datapath::Vector => "vector",
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            Datapath::Simd => "simd",
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            Datapath::Avx2 => "avx2",
        }
    }
}

/// Kernel-flavour selector for [`CompiledModel::compile_with_choice`] and
/// the `serve --kernel` flag: `Auto` runs the cost-driven per-layer
/// selection policy ([`KernelChoice`]); every other value pins one style
/// on every MAC layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Flavour {
    /// Cost-model-driven per-layer selection.
    Auto,
    /// Dense full unroll everywhere ([`Style::UnrolledDense`]).
    Dense,
    /// nnz-only sparse unroll everywhere ([`Style::UnrolledSparse`]).
    Unrolled,
    /// SIMD-block schedule everywhere ([`Style::PartialSparse`]).
    Block,
    /// N:M fixed-stride schedule everywhere ([`Style::NmStructured`]).
    Nm,
}

impl Flavour {
    /// Canonical CLI name of the flavour.
    pub fn as_str(&self) -> &'static str {
        match self {
            Flavour::Auto => "auto",
            Flavour::Dense => "dense",
            Flavour::Unrolled => "unrolled",
            Flavour::Block => "block",
            Flavour::Nm => "nm",
        }
    }

    /// Parse a canonical flavour name.
    pub fn parse(s: &str) -> Result<Flavour> {
        match s {
            "auto" => Ok(Flavour::Auto),
            "dense" => Ok(Flavour::Dense),
            "unrolled" => Ok(Flavour::Unrolled),
            "block" => Ok(Flavour::Block),
            "nm" => Ok(Flavour::Nm),
            other => Err(Error::kernel(format!(
                "unknown kernel flavour '{other}' (known: auto, dense, unrolled, block, nm)"
            ))),
        }
    }
}

/// How the serving plane executes a folding style — the description the
/// DSE report's servable table and the audit logs print.
pub fn served_flavour(style: Style) -> &'static str {
    match style {
        Style::Folded => "dense loop (folded)",
        Style::UnrolledDense => "dense kernel",
        Style::UnrolledSparse => "nnz-only baked schedule",
        Style::PartialSparse => "block schedule",
        Style::NmStructured => "N:M fixed-stride schedule",
    }
}

/// Quantisation operating point of a compiled model (default: the paper's
/// W4A4 LeNet-5 point).
#[derive(Debug, Clone, Copy)]
pub struct KernelSpec {
    /// Weight quantisation grid (W4 by default).
    pub weights: QSpec,
    /// Activation code width in bits (A4 by default).
    pub act_bits: usize,
    /// Input activations are quantised on [0, input_ceil].
    pub input_ceil: f32,
    /// Hidden activations: ReLU clipped at this ceiling (ReLU6-style, the
    /// same static-threshold rule as `python/compile/quant.py`).
    pub act_ceil: f32,
}

impl Default for KernelSpec {
    fn default() -> Self {
        KernelSpec { weights: QSpec { bits: 4 }, act_bits: 4, input_ceil: 1.0, act_ceil: 6.0 }
    }
}

impl KernelSpec {
    /// Largest representable activation code (`2^act_bits - 1`).
    pub fn act_qmax(&self) -> i32 {
        (1 << self.act_bits) - 1
    }

    /// Real-valued step of one input activation code.
    pub fn input_scale(&self) -> f32 {
        self.input_ceil / self.act_qmax() as f32
    }

    /// Real-valued step of one hidden activation code.
    pub fn act_scale(&self) -> f32 {
        self.act_ceil / self.act_qmax() as f32
    }

    fn validate(&self) -> Result<()> {
        QSpec::new(self.weights.bits)?;
        if !(2..=8).contains(&self.act_bits) {
            return Err(Error::kernel(format!("act bits {} out of [2,8]", self.act_bits)));
        }
        if self.input_ceil <= 0.0 || self.act_ceil <= 0.0 {
            return Err(Error::kernel("activation ceilings must be positive"));
        }
        Ok(())
    }
}

/// The baked MAC schedule of one layer.
#[derive(Debug, Clone)]
pub enum Kernel {
    /// Dense loop: `codes` is [fold_in, cout] row-major; `rel[r]` is the
    /// input-buffer offset of schedule row `r` relative to the patch base.
    Dense { codes: Vec<i8>, rel: Vec<u32> },
    /// nnz-only schedule grouped per output channel: entries
    /// `ptr[c]..ptr[c+1]` belong to output channel `c`. For
    /// `UnrolledSparse` every entry is a surviving weight; for
    /// `PartialSparse` live blocks are stored whole (zeros included) and
    /// all-zero blocks are elided.
    Sparse {
        ptr: Vec<u32>,
        rel: Vec<u32>,
        code: Vec<i8>,
        /// Block granularity (1 = fully unrolled).
        block: usize,
        /// Live (stored) blocks across all channels.
        live_blocks: usize,
    },
}

impl Kernel {
    /// Codes physically stored by this variant (zeros in live blocks
    /// included for `PartialSparse`).
    pub fn stored(&self) -> usize {
        match self {
            Kernel::Dense { codes, .. } => codes.len(),
            Kernel::Sparse { code, .. } => code.len(),
        }
    }
}

/// One compiled MAC layer.
#[derive(Debug, Clone)]
pub struct MacStage {
    /// Layer name (matches the graph node).
    pub name: String,
    /// Layer operator (conv / fc).
    pub op: Op,
    /// Folding style the kernel was baked under.
    pub style: Style,
    /// Input channels.
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
    /// Kernel extent (conv window edge; 1 for fc).
    pub k: usize,
    /// Input feature-map edge length.
    pub ifm: usize,
    /// Output feature-map edge length.
    pub ofm: usize,
    /// Schedule rows per output pixel (`k*k*cin` for conv, the full
    /// input length for fc).
    pub fold_in: usize,
    /// Dense weight count of the layer.
    pub weights: usize,
    /// Surviving (unpruned) weights.
    pub nnz: usize,
    /// Final layer emits f32 logits instead of requantised codes.
    pub is_output: bool,
    /// Per-output-channel requant multiplier / offset: hidden layers map
    /// `acc -> round(acc*mul + add)` clamped to the activation grid; the
    /// output layer maps straight to f32 logits.
    mul: Vec<f32>,
    add: Vec<f32>,
    /// The baked MAC schedule this stage executes.
    pub kernel: Kernel,
    /// Bit-packed weight codes of the stored schedule (pack::pack_codes).
    pub packed_codes: Vec<u8>,
    /// Bit-packed schedule indices: one input offset per entry for fully
    /// unrolled schedules, one base-row index per live block for block
    /// schedules, empty for dense (positions implicit).
    pub packed_rel: Vec<u8>,
    /// Index width used by `packed_rel`.
    pub idx_bits: usize,
    /// `(N, M)` of an `NmStructured` schedule (derived from the layer's
    /// mask at compile time); `None` for every other style.
    pub nm: Option<(usize, usize)>,
    /// Cost-model predicted initiation interval (cycles/frame) under the
    /// baked fold — the prediction the bench audit columns put next to
    /// measured software cost.
    pub predicted_ii: u64,
    /// Cost-model predicted LUTs under the baked fold.
    pub predicted_luts: u64,
}

impl MacStage {
    /// Output pixels per frame (`ofm * ofm`).
    pub fn out_pixels(&self) -> usize {
        self.ofm * self.ofm
    }

    /// MACs per frame actually scheduled by this kernel variant.
    pub fn scheduled_macs(&self) -> usize {
        self.out_pixels() * self.kernel.stored()
    }

    /// Dense-equivalent MACs per frame.
    pub fn dense_macs(&self) -> usize {
        self.out_pixels() * self.weights
    }

    fn accumulate(&self, act: &[u8], base: usize, acc: &mut [i32], dp: Datapath) {
        match dp {
            Datapath::Scalar => self.accumulate_scalar(act, base, acc),
            Datapath::Vector => self.accumulate_vector(act, base, acc),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            Datapath::Simd => self.accumulate_simd(act, base, acc),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            Datapath::Avx2 => self.accumulate_avx2(act, base, acc),
        }
    }

    /// Reference scalar schedule walk (the datapath every other
    /// implementation must match bit for bit).
    fn accumulate_scalar(&self, act: &[u8], base: usize, acc: &mut [i32]) {
        match &self.kernel {
            Kernel::Dense { codes, rel } => {
                acc.fill(0);
                for (r, &off) in rel.iter().enumerate() {
                    let a = act[base + off as usize] as i32;
                    let row = &codes[r * self.cout..(r + 1) * self.cout];
                    for (c, &w) in row.iter().enumerate() {
                        acc[c] += w as i32 * a;
                    }
                }
            }
            Kernel::Sparse { ptr, rel, code, .. } => {
                for (c, slot) in acc.iter_mut().enumerate() {
                    let mut s = 0i32;
                    for j in ptr[c] as usize..ptr[c + 1] as usize {
                        s += code[j] as i32 * act[base + rel[j] as usize] as i32;
                    }
                    *slot = s;
                }
            }
        }
    }

    /// Lane-chunked stable-Rust form. Dense: four schedule rows fuse into
    /// one pass over the output channels (4× fewer `acc` traversals, four
    /// independent products per channel). Sparse: each channel's dot
    /// product runs on [`LANES`] independent partial sums, removing the
    /// loop-carried dependence of the scalar walk (the gathers stay
    /// scalar — schedule offsets are irregular by design). Sums are
    /// reassociated only, so results match scalar exactly.
    fn accumulate_vector(&self, act: &[u8], base: usize, acc: &mut [i32]) {
        match &self.kernel {
            Kernel::Dense { codes, rel } => {
                acc.fill(0);
                let cout = self.cout;
                let fused = rel.len() / 4 * 4;
                for r in (0..fused).step_by(4) {
                    let a0 = act[base + rel[r] as usize] as i32;
                    let a1 = act[base + rel[r + 1] as usize] as i32;
                    let a2 = act[base + rel[r + 2] as usize] as i32;
                    let a3 = act[base + rel[r + 3] as usize] as i32;
                    let (row0, rest) = codes[r * cout..(r + 4) * cout].split_at(cout);
                    let (row1, rest) = rest.split_at(cout);
                    let (row2, row3) = rest.split_at(cout);
                    for (c, slot) in acc.iter_mut().enumerate() {
                        *slot += row0[c] as i32 * a0
                            + row1[c] as i32 * a1
                            + row2[c] as i32 * a2
                            + row3[c] as i32 * a3;
                    }
                }
                for (r, &off) in rel.iter().enumerate().skip(fused) {
                    let a = act[base + off as usize] as i32;
                    let row = &codes[r * cout..(r + 1) * cout];
                    for (c, slot) in acc.iter_mut().enumerate() {
                        *slot += row[c] as i32 * a;
                    }
                }
            }
            Kernel::Sparse { ptr, rel, code, .. } => {
                for (c, slot) in acc.iter_mut().enumerate() {
                    let lo = ptr[c] as usize;
                    let hi = ptr[c + 1] as usize;
                    *slot = dot_sparse_lanes(&code[lo..hi], &rel[lo..hi], act, base);
                }
            }
        }
    }

    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    fn accumulate_simd(&self, act: &[u8], base: usize, acc: &mut [i32]) {
        match &self.kernel {
            Kernel::Dense { codes, rel } => {
                acc.fill(0);
                for (r, &off) in rel.iter().enumerate() {
                    let a = act[base + off as usize] as i32;
                    simd::dense_row_madd(&codes[r * self.cout..(r + 1) * self.cout], a, acc);
                }
            }
            Kernel::Sparse { ptr, rel, code, .. } => {
                for (c, slot) in acc.iter_mut().enumerate() {
                    let lo = ptr[c] as usize;
                    let hi = ptr[c + 1] as usize;
                    *slot = simd::dot_sparse(&code[lo..hi], &rel[lo..hi], act, base);
                }
            }
        }
    }

    /// AVX2 datapath: the widened twin of [`MacStage::accumulate_simd`].
    /// Soundness gate: the `avx2`-target-feature kernels may only run
    /// on a CPU that reports AVX2, so a pinned [`Datapath::Avx2`] on
    /// older silicon degrades to the SSE2 path (the detection macro
    /// caches, so the per-call check is one relaxed atomic load).
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    fn accumulate_avx2(&self, act: &[u8], base: usize, acc: &mut [i32]) {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return self.accumulate_simd(act, base, acc);
        }
        match &self.kernel {
            Kernel::Dense { codes, rel } => {
                acc.fill(0);
                for (r, &off) in rel.iter().enumerate() {
                    let a = act[base + off as usize] as i32;
                    // SAFETY: AVX2 availability checked above.
                    unsafe {
                        simd::dense_row_madd_avx2(
                            &codes[r * self.cout..(r + 1) * self.cout],
                            a,
                            acc,
                        );
                    }
                }
            }
            Kernel::Sparse { ptr, rel, code, .. } => {
                for (c, slot) in acc.iter_mut().enumerate() {
                    let lo = ptr[c] as usize;
                    let hi = ptr[c + 1] as usize;
                    // SAFETY: AVX2 availability checked above.
                    *slot =
                        unsafe { simd::dot_sparse_avx2(&code[lo..hi], &rel[lo..hi], act, base) };
                }
            }
        }
    }

    fn patch_base(&self, oh: usize, ow: usize) -> usize {
        match self.op {
            Op::Conv => (oh * self.ifm + ow) * self.cin,
            _ => 0,
        }
    }

    fn run_hidden(&self, act: &[u8], qmax: i32, dp: Datapath) -> Vec<u8> {
        let mut out = vec![0u8; self.out_pixels() * self.cout];
        let mut acc = vec![0i32; self.cout];
        for oh in 0..self.ofm {
            for ow in 0..self.ofm {
                self.accumulate(act, self.patch_base(oh, ow), &mut acc, dp);
                let o = (oh * self.ofm + ow) * self.cout;
                for c in 0..self.cout {
                    let v = (acc[c] as f32 * self.mul[c] + self.add[c]).round() as i32;
                    out[o + c] = v.clamp(0, qmax) as u8;
                }
            }
        }
        out
    }

    fn run_output(&self, act: &[u8], dp: Datapath) -> Vec<f32> {
        let mut out = vec![0f32; self.out_pixels() * self.cout];
        let mut acc = vec![0i32; self.cout];
        for oh in 0..self.ofm {
            for ow in 0..self.ofm {
                self.accumulate(act, self.patch_base(oh, ow), &mut acc, dp);
                let o = (oh * self.ofm + ow) * self.cout;
                for c in 0..self.cout {
                    out[o + c] = acc[c] as f32 * self.mul[c] + self.add[c];
                }
            }
        }
        out
    }
}

/// [`LANES`]-way chunked sparse dot product (the [`Datapath::Vector`]
/// inner loop): multiply-adds land in independent partial sums instead of
/// serialising on one accumulator. i32 addition is associative, so the
/// folded lane sums equal the scalar result exactly.
#[inline]
fn dot_sparse_lanes(code: &[i8], rel: &[u32], act: &[u8], base: usize) -> i32 {
    let mut lanes = [0i32; LANES];
    let mut code_chunks = code.chunks_exact(LANES);
    let mut rel_chunks = rel.chunks_exact(LANES);
    for (cs, rs) in (&mut code_chunks).zip(&mut rel_chunks) {
        for l in 0..LANES {
            lanes[l] += cs[l] as i32 * act[base + rs[l] as usize] as i32;
        }
    }
    let mut s: i32 = lanes.iter().sum();
    for (&w, &r) in code_chunks.remainder().iter().zip(rel_chunks.remainder()) {
        s += w as i32 * act[base + r as usize] as i32;
    }
    s
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd {
    //! Intrinsics datapaths (`simd` feature): an SSE2 tier (part of the
    //! x86_64 baseline — no runtime detection needed) and an AVX2 tier
    //! (runtime-dispatched via `is_x86_feature_detected!`). Every i16
    //! product fits: |code| ≤ 127 (W8 worst case) and activation codes
    //! ≤ 255 (A8 worst case) give |product| ≤ 32385 < 32767, and
    //! accumulation is exact in i32 — results are bit-identical to the
    //! scalar datapath on both tiers.

    use std::arch::x86_64::*;

    /// Sparse dot product over 8-entry chunks: scalar gathers fill two
    /// i16 registers, `_mm_madd_epi16` multiplies and pair-sums into
    /// four i32 lanes, which accumulate exactly; the tail runs scalar.
    pub fn dot_sparse(code: &[i8], rel: &[u32], act: &[u8], base: usize) -> i32 {
        let chunks = code.len() / 8;
        // SAFETY: SSE2 is unconditionally available on x86_64; all loads
        // and stores go through 16-byte stack arrays of exactly 8 i16 /
        // 4 i32.
        let mut s = unsafe {
            let mut acc = _mm_setzero_si128();
            for k in 0..chunks {
                let o = k * 8;
                let mut w = [0i16; 8];
                let mut a = [0i16; 8];
                for l in 0..8 {
                    w[l] = code[o + l] as i16;
                    a[l] = act[base + rel[o + l] as usize] as i16;
                }
                let wv = _mm_loadu_si128(w.as_ptr() as *const __m128i);
                let av = _mm_loadu_si128(a.as_ptr() as *const __m128i);
                acc = _mm_add_epi32(acc, _mm_madd_epi16(wv, av));
            }
            let mut out = [0i32; 4];
            _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, acc);
            out.iter().sum::<i32>()
        };
        for j in chunks * 8..code.len() {
            s += code[j] as i32 * act[base + rel[j] as usize] as i32;
        }
        s
    }

    /// Dense row update `acc[c] += row[c] * a` over 8 channels per pass:
    /// codes sign-extend i8→i16, multiply against the broadcast
    /// activation in i16 (products fit, see module docs), widen to i32
    /// with the duplicate-and-shift idiom, and accumulate in place.
    pub fn dense_row_madd(row: &[i8], a: i32, acc: &mut [i32]) {
        let cout = acc.len();
        let chunks = cout / 8;
        // SAFETY: SSE2 baseline; every pointer stays within `row` /
        // `acc` (o + 8 ≤ cout by construction) and uses unaligned ops.
        unsafe {
            let av = _mm_set1_epi16(a as i16);
            for k in 0..chunks {
                let o = k * 8;
                // 8 i8 codes → 8 sign-extended i16 lanes.
                let w8 = _mm_loadl_epi64(row.as_ptr().add(o) as *const __m128i);
                let w16 = _mm_srai_epi16(_mm_unpacklo_epi8(w8, w8), 8);
                let p = _mm_mullo_epi16(w16, av);
                // i16 products → i32 (duplicate + arithmetic shift).
                let lo = _mm_srai_epi32(_mm_unpacklo_epi16(p, p), 16);
                let hi = _mm_srai_epi32(_mm_unpackhi_epi16(p, p), 16);
                let acc_lo = acc.as_mut_ptr().add(o) as *mut __m128i;
                _mm_storeu_si128(acc_lo, _mm_add_epi32(_mm_loadu_si128(acc_lo), lo));
                let acc_hi = acc.as_mut_ptr().add(o + 4) as *mut __m128i;
                _mm_storeu_si128(acc_hi, _mm_add_epi32(_mm_loadu_si128(acc_hi), hi));
            }
        }
        for c in chunks * 8..cout {
            acc[c] += row[c] as i32 * a;
        }
    }

    /// AVX2 sparse dot product over 16-entry chunks: scalar gathers fill
    /// two 256-bit i16 registers, `_mm256_madd_epi16` multiplies and
    /// pair-sums into eight i32 lanes, which accumulate exactly; the
    /// tail runs scalar.
    ///
    /// # Safety
    /// The CPU must support AVX2 (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_sparse_avx2(code: &[i8], rel: &[u32], act: &[u8], base: usize) -> i32 {
        let chunks = code.len() / 16;
        // All loads and stores go through 32-byte stack arrays of
        // exactly 16 i16 / 8 i32, via unaligned ops.
        let mut acc = _mm256_setzero_si256();
        for k in 0..chunks {
            let o = k * 16;
            let mut w = [0i16; 16];
            let mut a = [0i16; 16];
            for l in 0..16 {
                w[l] = code[o + l] as i16;
                a[l] = act[base + rel[o + l] as usize] as i16;
            }
            let wv = _mm256_loadu_si256(w.as_ptr() as *const __m256i);
            let av = _mm256_loadu_si256(a.as_ptr() as *const __m256i);
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(wv, av));
        }
        let mut out = [0i32; 8];
        _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, acc);
        let mut s = out.iter().sum::<i32>();
        for j in chunks * 16..code.len() {
            s += code[j] as i32 * act[base + rel[j] as usize] as i32;
        }
        s
    }

    /// AVX2 dense row update `acc[c] += row[c] * a` over 16 channels per
    /// pass: 16 i8 codes sign-extend to i16 in one `vpmovsxbw`, multiply
    /// against the broadcast activation in i16 (products fit, see module
    /// docs), widen each half to i32 with `vpmovsxwd`, and accumulate in
    /// place; the tail runs scalar.
    ///
    /// # Safety
    /// The CPU must support AVX2 (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dense_row_madd_avx2(row: &[i8], a: i32, acc: &mut [i32]) {
        let cout = acc.len();
        let chunks = cout / 16;
        let av = _mm256_set1_epi16(a as i16);
        for k in 0..chunks {
            let o = k * 16;
            // 16 i8 codes → 16 sign-extended i16 lanes.
            let w8 = _mm_loadu_si128(row.as_ptr().add(o) as *const __m128i);
            let w16 = _mm256_cvtepi8_epi16(w8);
            let p = _mm256_mullo_epi16(w16, av);
            // i16 products → i32, half a register at a time (lane order
            // is preserved: elements 0..8 sit in the low 128 bits).
            let lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(p));
            let hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256(p, 1));
            let acc_lo = acc.as_mut_ptr().add(o) as *mut __m256i;
            _mm256_storeu_si256(acc_lo, _mm256_add_epi32(_mm256_loadu_si256(acc_lo), lo));
            let acc_hi = acc.as_mut_ptr().add(o + 8) as *mut __m256i;
            _mm256_storeu_si256(acc_hi, _mm256_add_epi32(_mm256_loadu_si256(acc_hi), hi));
        }
        for c in chunks * 16..cout {
            acc[c] += row[c] as i32 * a;
        }
    }
}

/// A max-pool stage (code domain: max of unsigned codes is exact because
/// requantisation is monotone).
#[derive(Debug, Clone)]
pub struct PoolStage {
    /// Layer name (matches the graph node).
    pub name: String,
    /// Channels (pooling is per-channel).
    pub ch: usize,
    /// Pool window edge length.
    pub k: usize,
    /// Input feature-map edge length.
    pub ifm: usize,
    /// Output feature-map edge length.
    pub ofm: usize,
}

impl PoolStage {
    fn run(&self, act: &[u8]) -> Vec<u8> {
        let mut out = vec![0u8; self.ofm * self.ofm * self.ch];
        for oh in 0..self.ofm {
            for ow in 0..self.ofm {
                let o = (oh * self.ofm + ow) * self.ch;
                for kh in 0..self.k {
                    for kw in 0..self.k {
                        let i = ((oh * self.k + kh) * self.ifm + ow * self.k + kw) * self.ch;
                        for c in 0..self.ch {
                            let v = act[i + c];
                            if v > out[o + c] {
                                out[o + c] = v;
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

/// One stage of the compiled chain.
#[derive(Debug, Clone)]
pub enum Stage {
    /// A baked MAC layer (conv / fc).
    Mac(MacStage),
    /// A code-domain max-pool layer.
    Pool(PoolStage),
}

/// A fully baked model: the one artifact serving, sim, DSE and the
/// experiments all consume.
#[derive(Debug, Clone)]
pub struct CompiledModel {
    /// Model name (from the graph).
    pub model: String,
    /// The quantisation operating point the kernels were baked at.
    pub spec: KernelSpec,
    /// The folding decisions the kernels were baked under (sim/DSE view).
    pub folding: FoldingConfig,
    stages: Vec<Stage>,
    input_pixels: usize,
    output_len: usize,
    datapath: Datapath,
}

impl CompiledModel {
    /// Compile `g` with `params` under the per-layer styles in `folding`
    /// (`Folded`/`UnrolledDense` → dense kernel, `UnrolledSparse` →
    /// nnz-only, `PartialSparse` → SIMD-block schedule). Masks in
    /// `params` are authoritative for which weights survive.
    pub fn compile(
        g: &Graph,
        params: &ModelParams,
        spec: &KernelSpec,
        folding: &FoldingConfig,
    ) -> Result<CompiledModel> {
        g.validate()?;
        spec.validate()?;
        folding.check(g)?;
        let last = g
            .nodes
            .iter()
            .rposition(|n| n.op.has_weights())
            .ok_or_else(|| Error::kernel("graph has no MAC layer"))?;
        if last != g.nodes.len() - 1 {
            return Err(Error::kernel(format!(
                "graph must end with a MAC layer (found trailing '{}')",
                g.nodes[last + 1].name
            )));
        }

        let mut stages = Vec::with_capacity(g.nodes.len());
        let mut cur_scale = spec.input_scale();
        for (i, node) in g.nodes.iter().enumerate() {
            if !node.op.has_weights() {
                stages.push(Stage::Pool(PoolStage {
                    name: node.name.clone(),
                    ch: node.cin,
                    k: node.k,
                    ifm: node.ifm,
                    ofm: node.ofm,
                }));
                continue;
            }
            let lp = params
                .get(&node.name)
                .ok_or_else(|| Error::kernel(format!("no params for layer '{}'", node.name)))?;
            let fold = folding
                .get(&node.name)
                .ok_or_else(|| Error::kernel(format!("no folding for layer '{}'", node.name)))?;
            let fold_in = node.fold_in();
            let cout = node.cout;
            if lp.fold_in != fold_in || lp.cout != cout {
                return Err(Error::kernel(format!(
                    "'{}': params [{}x{}] vs graph [{fold_in}x{cout}]",
                    node.name, lp.fold_in, lp.cout
                )));
            }
            let masked = lp.masked_w();
            let (codes, scales) = quantize_per_channel(&masked, fold_in, cout, spec.weights)?;

            // Relative input offset of schedule row r from the patch base:
            // weight layout is [fold_in, cout] with patch order (kh, kw, c)
            // and activations are NHWC-flat, so conv offsets collapse to
            // (kh*IFM + kw)*Cin + ci; fc is the identity.
            let rel_of = |r: usize| -> u32 {
                match node.op {
                    Op::Conv => {
                        let kh = r / (node.k * node.cin);
                        let kw = (r / node.cin) % node.k;
                        let ci = r % node.cin;
                        ((kh * node.ifm + kw) * node.cin + ci) as u32
                    }
                    _ => r as u32,
                }
            };
            let addr_space = match node.op {
                Op::Conv => node.ifm * node.ifm * node.cin,
                _ => fold_in,
            };

            // N:M layout derived from the mask: the compile pass and the
            // selection policy share `detect_nm`, so they always agree on
            // the (N, M) a given mask bakes under.
            let nm_fit = match fold.style {
                Style::NmStructured => Some(detect_nm(&lp.mask.keep, fold_in, cout)?),
                _ => None,
            };

            let (kernel, idx_stream) = match fold.style {
                Style::Folded | Style::UnrolledDense => (
                    Kernel::Dense {
                        codes: codes.clone(),
                        rel: (0..fold_in).map(rel_of).collect(),
                    },
                    Vec::new(),
                ),
                Style::UnrolledSparse => {
                    build_sparse(&codes, &lp.mask.keep, fold_in, cout, 1, rel_of)
                }
                Style::PartialSparse => {
                    build_sparse(&codes, &lp.mask.keep, fold_in, cout, fold.simd.max(1), rel_of)
                }
                Style::NmStructured => build_nm(
                    &codes,
                    &lp.mask.keep,
                    fold_in,
                    cout,
                    nm_fit.expect("fit derived above"),
                    rel_of,
                ),
            };

            let (packed_codes, packed_rel, idx_bits) = match &kernel {
                Kernel::Dense { codes, .. } => {
                    (pack::pack_codes(codes, spec.weights.bits), Vec::new(), 0)
                }
                Kernel::Sparse { rel, code, block, .. } => {
                    // Fully unrolled: one input offset per surviving entry.
                    // Block schedules: one base-row index per live block —
                    // positions inside a live block are consecutive, so a
                    // loader recomputes per-element offsets from the layer
                    // geometry (the documented packed layout, §9). N:M
                    // schedules: one within-group offset per fixed slot at
                    // index_bits(M) — slot addresses are pure arithmetic
                    // (§14), no pointer array.
                    let (bytes, bits) = if let Some(fit) = nm_fit {
                        pack::pack_nm_indices(&idx_stream, fit.m)
                    } else if *block > 1 {
                        pack::pack_indices(&idx_stream, fold_in)
                    } else {
                        pack::pack_indices(rel, addr_space)
                    };
                    (pack::pack_codes(code, spec.weights.bits), bytes, bits)
                }
            };

            // Cost-model predictions for the audit columns. An N:M fold is
            // normalised to its stored-row fraction first, so the numbers
            // charge the fixed-slot padding actually baked.
            let eff_fold = match nm_fit {
                Some(fit) => LayerFold {
                    sparsity: fit.stored_sparsity(fold_in).clamp(0.0, 0.999_999),
                    ..fold.clone()
                },
                None => fold.clone(),
            };
            let predicted_ii = crate::cost::latency::ii_cycles(node, &eff_fold);
            let predicted_luts =
                crate::cost::luts::layer_luts(node, &eff_fold, spec.weights.bits, spec.act_bits);

            let is_output = i == last;
            let in_scale = cur_scale;
            let (mul, add): (Vec<f32>, Vec<f32>) = if is_output {
                (
                    scales.iter().map(|&s| s * in_scale).collect(),
                    lp.bias.clone(),
                )
            } else {
                let out_scale = spec.act_scale();
                cur_scale = out_scale;
                (
                    scales.iter().map(|&s| s * in_scale / out_scale).collect(),
                    lp.bias.iter().map(|&b| b / out_scale).collect(),
                )
            };

            stages.push(Stage::Mac(MacStage {
                name: node.name.clone(),
                op: node.op,
                style: fold.style,
                cin: node.cin,
                cout,
                k: node.k,
                ifm: node.ifm,
                ofm: node.ofm,
                fold_in,
                weights: node.weights(),
                nnz: lp.mask.nnz(),
                is_output,
                mul,
                add,
                kernel,
                packed_codes,
                packed_rel,
                idx_bits,
                nm: nm_fit.map(|f| (f.n, f.m)),
                predicted_ii,
                predicted_luts,
            }));
        }

        let first = &g.nodes[0];
        Ok(CompiledModel {
            model: g.model.clone(),
            spec: *spec,
            folding: folding.clone(),
            stages,
            input_pixels: first.ifm * first.ifm * first.cin,
            output_len: g.nodes[last].out_elements(),
            datapath: Datapath::best(),
        })
    }

    /// Dense full unroll of every MAC layer (the dense-engine baseline).
    pub fn compile_dense(g: &Graph, params: &ModelParams, spec: &KernelSpec) -> Result<Self> {
        Self::compile(g, params, spec, &FoldingConfig::unrolled(g))
    }

    /// Engine-free sparse unroll: per-layer sparsity annotations are taken
    /// from the masks in `params` (the measured truth).
    pub fn compile_sparse(g: &Graph, params: &ModelParams, spec: &KernelSpec) -> Result<Self> {
        let mut cfg = FoldingConfig::default();
        for n in g.mac_nodes() {
            let lp = params
                .get(&n.name)
                .ok_or_else(|| Error::kernel(format!("no params for layer '{}'", n.name)))?;
            let s = lp.mask.sparsity().min(0.999_999);
            cfg.set(&n.name, LayerFold::unrolled_sparse(n, s));
        }
        Self::compile(g, params, spec, &cfg)
    }

    /// Cost-driven compile under the default [`ChoicePolicy`] (XCU50,
    /// full LUT budget, no calibration): run the per-layer selection
    /// policy and bake the winners. Returns the model plus the
    /// [`KernelChoice`] audit trail.
    pub fn compile_auto(
        g: &Graph,
        params: &ModelParams,
        spec: &KernelSpec,
    ) -> Result<(CompiledModel, KernelChoice)> {
        Self::compile_auto_with(g, params, spec, &ChoicePolicy::default())
    }

    /// [`CompiledModel::compile_auto`] under an explicit policy (target
    /// device, budget fraction, measured occupancy calibration).
    pub fn compile_auto_with(
        g: &Graph,
        params: &ModelParams,
        spec: &KernelSpec,
        policy: &ChoicePolicy,
    ) -> Result<(CompiledModel, KernelChoice)> {
        let choice = KernelChoice::choose(g, params, spec, policy)?;
        let model = Self::compile(g, params, spec, &choice.folding())?;
        Ok((model, choice))
    }

    /// Compile under a forced flavour override (the `serve --kernel`
    /// flag): `Auto` delegates to the selection policy, everything else
    /// pins one style on every MAC layer.
    pub fn compile_with_choice(
        g: &Graph,
        params: &ModelParams,
        spec: &KernelSpec,
        flavour: Flavour,
    ) -> Result<CompiledModel> {
        let layer = |n: &Node| {
            params
                .get(&n.name)
                .ok_or_else(|| Error::kernel(format!("no params for layer '{}'", n.name)))
        };
        match flavour {
            Flavour::Auto => Ok(Self::compile_auto(g, params, spec)?.0),
            Flavour::Dense => Self::compile_dense(g, params, spec),
            Flavour::Unrolled => Self::compile_sparse(g, params, spec),
            Flavour::Block => {
                let mut cfg = FoldingConfig::default();
                for n in g.mac_nodes() {
                    cfg.set(&n.name, block_fold(n, layer(n)?));
                }
                Self::compile(g, params, spec, &cfg)
            }
            Flavour::Nm => {
                let mut cfg = FoldingConfig::default();
                for n in g.mac_nodes() {
                    cfg.set(&n.name, nm_fold(n, layer(n)?)?.0);
                }
                Self::compile(g, params, spec, &cfg)
            }
        }
    }

    /// Flattened input length one frame must provide.
    pub fn input_pixels(&self) -> usize {
        self.input_pixels
    }

    /// Logits per frame.
    pub fn output_len(&self) -> usize {
        self.output_len
    }

    /// The compiled stage chain, in execution order.
    pub fn stages(&self) -> &[Stage] {
        &self.stages
    }

    /// The MAC stages only (pool stages skipped).
    pub fn mac_stages(&self) -> impl Iterator<Item = &MacStage> {
        self.stages.iter().filter_map(|s| match s {
            Stage::Mac(m) => Some(m),
            Stage::Pool(_) => None,
        })
    }

    /// Per-layer + global sparsity accounting — the same [`ModelSparsity`]
    /// shape `experiments::headline` consumes.
    pub fn sparsity(&self) -> ModelSparsity {
        let mut ms = ModelSparsity::default();
        for m in self.mac_stages() {
            ms.push(m.name.clone(), m.weights, m.nnz);
        }
        ms
    }

    /// Dense weight count across every MAC layer.
    pub fn total_weights(&self) -> usize {
        self.mac_stages().map(|m| m.weights).sum()
    }

    /// Surviving (unpruned) weights across every MAC layer.
    pub fn total_nnz(&self) -> usize {
        self.mac_stages().map(|m| m.nnz).sum()
    }

    /// Engine-free compression ratio (paper headline accounting: surviving
    /// weights at `weight_bits`, **no index storage**).
    pub fn compression(&self) -> f64 {
        compression_ratio(self.total_weights(), self.total_nnz(), self.spec.weights.bits)
    }

    /// What a CSR-style sparse engine would achieve on the same masks.
    pub fn compression_csr(&self, idx_bits: usize) -> f64 {
        compression_ratio_csr(
            self.total_weights(),
            self.total_nnz(),
            self.spec.weights.bits,
            idx_bits,
        )
    }

    /// MACs per frame the baked kernels actually schedule.
    pub fn scheduled_macs_per_frame(&self) -> usize {
        self.mac_stages().map(|m| m.scheduled_macs()).sum()
    }

    /// Dense-equivalent MACs per frame.
    pub fn dense_macs_per_frame(&self) -> usize {
        self.mac_stages().map(|m| m.dense_macs()).sum()
    }

    /// Bytes of the packed runtime image (codes + schedule indices).
    pub fn runtime_bytes(&self) -> usize {
        self.mac_stages()
            .map(|m| m.packed_codes.len() + m.packed_rel.len())
            .sum()
    }

    /// Cost-model predicted bottleneck II (cycles/frame) across the MAC
    /// stages — the predicted side of the predicted-vs-measured audit.
    pub fn predicted_max_ii(&self) -> u64 {
        self.mac_stages().map(|m| m.predicted_ii).max().unwrap_or(0)
    }

    /// Cost-model predicted LUT total across the MAC stages.
    pub fn predicted_luts(&self) -> u64 {
        self.mac_stages().map(|m| m.predicted_luts).sum()
    }

    /// One-line description for logs and backend labels.
    pub fn summary(&self) -> String {
        format!(
            "{} w{}a{} {:.1}% sparse, {} MAC layers, {} B packed",
            self.model,
            self.spec.weights.bits,
            self.spec.act_bits,
            self.sparsity().global_sparsity() * 100.0,
            self.mac_stages().count(),
            self.runtime_bytes(),
        )
    }

    /// The datapath [`CompiledModel::forward`] and
    /// [`CompiledModel::infer_batch`] execute (defaults to
    /// [`Datapath::best`]).
    pub fn datapath(&self) -> Datapath {
        self.datapath
    }

    /// Pin the default datapath (builder-style; benches and tests pin
    /// [`Datapath::Scalar`] to measure the reference, serving keeps
    /// [`Datapath::best`]). Results never change, only speed.
    pub fn with_datapath(mut self, dp: Datapath) -> Self {
        self.datapath = dp;
        self
    }

    /// Run one frame: `image` is the flattened NHWC input in
    /// [0, input_ceil]; returns `output_len` f32 logits.
    pub fn forward(&self, image: &[f32]) -> Result<Vec<f32>> {
        self.forward_with(image, self.datapath)
    }

    /// [`CompiledModel::forward`] on an explicit datapath. Bit-identical
    /// across datapaths (asserted in tests); exists so benches can put
    /// scalar and vector side by side and tests can pin the reference.
    pub fn forward_with(&self, image: &[f32], dp: Datapath) -> Result<Vec<f32>> {
        if image.len() != self.input_pixels {
            return Err(Error::kernel(format!(
                "input length {} != {}",
                image.len(),
                self.input_pixels
            )));
        }
        let qmax = self.spec.act_qmax();
        let in_scale = self.spec.input_scale();
        let mut act: Vec<u8> = image
            .iter()
            .map(|&x| ((x / in_scale).round() as i32).clamp(0, qmax) as u8)
            .collect();
        for stage in &self.stages {
            match stage {
                Stage::Pool(p) => act = p.run(&act),
                Stage::Mac(m) => {
                    if m.is_output {
                        return Ok(m.run_output(&act, dp));
                    }
                    act = m.run_hidden(&act, qmax, dp);
                }
            }
        }
        Err(Error::kernel("graph has no output layer"))
    }

    /// Argmax class of one frame.
    pub fn classify(&self, image: &[f32]) -> Result<usize> {
        let logits = self.forward(image)?;
        Ok(crate::runtime::argmax_classes(&logits)[0])
    }

    /// Run `n` frames packed into `x`; returns `n * output_len` logits.
    /// Serial frame loop — [`BatchPool::infer_batch`] fans the same
    /// computation across worker threads with bit-identical results.
    pub fn infer_batch(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        self.infer_batch_with(x, n, self.datapath)
    }

    /// [`CompiledModel::infer_batch`] on an explicit datapath.
    pub fn infer_batch_with(&self, x: &[f32], n: usize, dp: Datapath) -> Result<Vec<f32>> {
        let px = self.input_pixels;
        if x.len() != n * px {
            return Err(Error::kernel(format!(
                "batch input length {} != {n} * {px}",
                x.len()
            )));
        }
        let mut out = Vec::with_capacity(n * self.output_len);
        for i in 0..n {
            out.extend(self.forward_with(&x[i * px..(i + 1) * px], dp)?);
        }
        Ok(out)
    }
}

/// Build the per-output-channel schedule: block = 1 keeps surviving
/// entries only (fully unrolled); block > 1 stores whole live blocks
/// (partial unroll at SIMD-lane granularity) and elides all-zero blocks.
/// Also returns the base row of every live block (the per-block index
/// stream the packed layout stores for block schedules).
fn build_sparse(
    codes: &[i8],
    keep: &[bool],
    fold_in: usize,
    cout: usize,
    block: usize,
    rel_of: impl Fn(usize) -> u32,
) -> (Kernel, Vec<u32>) {
    let mut ptr = Vec::with_capacity(cout + 1);
    let mut rel = Vec::new();
    let mut code = Vec::new();
    let mut bases = Vec::new();
    let mut live_blocks = 0usize;
    ptr.push(0u32);
    for c in 0..cout {
        let mut r = 0usize;
        while r < fold_in {
            let hi = (r + block).min(fold_in);
            if (r..hi).any(|row| keep[row * cout + c]) {
                live_blocks += 1;
                bases.push(r as u32);
                for row in r..hi {
                    if block == 1 && !keep[row * cout + c] {
                        continue;
                    }
                    rel.push(rel_of(row));
                    code.push(codes[row * cout + c]);
                }
            }
            r = hi;
        }
        ptr.push(code.len() as u32);
    }
    (Kernel::Sparse { ptr, rel, code, block, live_blocks }, bases)
}

/// Build an N:M fixed-slot schedule: per output channel, every group of
/// `fit.m` consecutive input rows contributes exactly `min(fit.n, group
/// extent)` entries — surviving rows first (in row order), then
/// sum-neutral code-0 pads anchored at the group base. The padding keeps
/// the stream fully fixed-stride (slot addresses are pure arithmetic) at
/// the price of storing `fit.stored_rows` rows per channel, and
/// [`Kernel::stored`] charges the pads, keeping `scheduled_macs` honest.
/// Executes on the existing `Sparse { block: 1 }` datapath — a pad
/// multiplies by code 0, so bit-identity with the dense compile holds by
/// construction. Also returns the within-group offset of every slot, the
/// stream [`pack::pack_nm_indices`] packs at `index_bits(m)` bits.
fn build_nm(
    codes: &[i8],
    keep: &[bool],
    fold_in: usize,
    cout: usize,
    fit: NmFit,
    rel_of: impl Fn(usize) -> u32,
) -> (Kernel, Vec<u32>) {
    let (n, m) = (fit.n, fit.m);
    let mut ptr = Vec::with_capacity(cout + 1);
    let mut rel = Vec::new();
    let mut code = Vec::new();
    let mut offsets = Vec::new();
    ptr.push(0u32);
    for c in 0..cout {
        let mut base = 0usize;
        while base < fold_in {
            let hi = (base + m).min(fold_in);
            let slots = n.min(hi - base);
            let mut filled = 0usize;
            for row in base..hi {
                // `fit.n` is the worst-case survivor count over every
                // group (detect_nm on this same mask), so survivors never
                // exceed `slots`.
                if keep[row * cout + c] {
                    debug_assert!(filled < slots, "N:M fit too tight for its own mask");
                    rel.push(rel_of(row));
                    code.push(codes[row * cout + c]);
                    offsets.push((row - base) as u32);
                    filled += 1;
                }
            }
            for _ in filled..slots {
                rel.push(rel_of(base));
                code.push(0);
                offsets.push(0);
            }
            base = hi;
        }
        ptr.push(code.len() as u32);
    }
    let live_blocks = code.len();
    (Kernel::Sparse { ptr, rel, code, block: 1, live_blocks }, offsets)
}

/// Measured per-pipeline-group occupancy (the PR 7 calibration loop):
/// group names from [`StagedExecutor`] statistics paired with their
/// busy fraction. The selection policy uses it to re-weight per-layer
/// LUT-budget shares — measured-hot layers earn more area. The default
/// is the uncalibrated unit weighting.
#[derive(Debug, Clone, Default)]
pub struct Calibration {
    /// `(group name, occupancy in [0, 1])` pairs; a group name is the
    /// "+"-joined stage names of one pipeline group.
    pub occupancy: Vec<(String, f64)>,
}

impl Calibration {
    /// Build from measured pipeline statistics.
    pub fn from_stats(stats: &pipeline::PipelineStats) -> Self {
        Calibration { occupancy: stats.occupancy() }
    }

    /// Occupancy factor for `layer`: the utilisation of the pipeline
    /// group containing it (exact match against one of the group's
    /// "+"-joined stage names), floored at 0.05 so a measured-idle layer
    /// never loses its whole budget share; 1.0 when uncalibrated.
    pub fn factor(&self, layer: &str) -> f64 {
        self.occupancy
            .iter()
            .find(|(g, _)| g.split('+').any(|s| s == layer))
            .map(|(_, f)| f.max(0.05))
            .unwrap_or(1.0)
    }
}

/// Tunable inputs of the selection policy ([`KernelChoice::choose`]): the
/// target device whose LUT budget bounds per-layer feasibility, the
/// fraction of that budget this model may claim (1.0 = one model per
/// device, the serving default), and a measured occupancy calibration.
#[derive(Debug, Clone)]
pub struct ChoicePolicy {
    /// Target device.
    pub device: Device,
    /// Fraction of the device LUT budget available to this model.
    pub budget_fraction: f64,
    /// Measured occupancy re-weighting (default: unit weights).
    pub calibration: Calibration,
}

impl Default for ChoicePolicy {
    fn default() -> Self {
        ChoicePolicy { device: XCU50, budget_fraction: 1.0, calibration: Calibration::default() }
    }
}

/// One layer's audit row: the winning candidate and the numbers it won
/// with — what the bench JSON and the `serve --kernel auto` log surface.
#[derive(Debug, Clone)]
pub struct LayerChoice {
    /// Layer name.
    pub layer: String,
    /// Winning flavour (never `Auto`).
    pub flavour: Flavour,
    /// The fold the winner bakes under.
    pub fold: LayerFold,
    /// Cost-model predicted II (cycles/frame) of the winner.
    pub predicted_ii: u64,
    /// Cost-model predicted LUTs of the winner.
    pub predicted_luts: u64,
    /// Packed schedule size (bits) of the winner: codes plus index
    /// stream, from the same accounting the packer uses.
    pub packed_bits: u64,
    /// The LUT-budget share the layer was scored against.
    pub lut_share: u64,
    /// Whether the winner fit its share (`false` = every candidate was
    /// over budget and the smallest-LUT one was kept).
    pub feasible: bool,
}

/// The cost-driven selection: one [`LayerChoice`] per MAC layer in
/// stream order. Pure and deterministic — the same (graph, params, spec,
/// policy) always produces the same choice (asserted in tests), so the
/// compile pass and any later audit agree.
#[derive(Debug, Clone)]
pub struct KernelChoice {
    /// Per-layer audit rows in stream order.
    pub layers: Vec<LayerChoice>,
}

impl KernelChoice {
    /// Run the selection policy. Every layer's four candidates (dense
    /// unroll, nnz-only unroll, SIMD-block schedule, N:M fixed-stride)
    /// are scored with the [`crate::cost`] models; among candidates
    /// whose predicted LUTs fit the layer's budget share, the
    /// lexicographically smallest `(predicted II, predicted LUTs, packed
    /// bits)` wins, first in candidate order on full ties. If nothing
    /// fits, the smallest-LUT candidate wins and the row is marked
    /// infeasible. A layer's share of the policy's LUT pool is
    /// proportional to its dense weight count re-weighted by measured
    /// occupancy ([`Calibration::factor`]) — hot layers earn more area.
    pub fn choose(
        g: &Graph,
        params: &ModelParams,
        spec: &KernelSpec,
        policy: &ChoicePolicy,
    ) -> Result<KernelChoice> {
        g.validate()?;
        spec.validate()?;
        if !(policy.budget_fraction > 0.0 && policy.budget_fraction.is_finite()) {
            return Err(Error::kernel(format!(
                "budget fraction {} must be positive and finite",
                policy.budget_fraction
            )));
        }
        let mut nodes = Vec::new();
        for node in g.mac_nodes() {
            let lp = params
                .get(&node.name)
                .ok_or_else(|| Error::kernel(format!("no params for layer '{}'", node.name)))?;
            if lp.fold_in != node.fold_in() || lp.cout != node.cout {
                return Err(Error::kernel(format!(
                    "'{}': params [{}x{}] vs graph [{}x{}]",
                    node.name,
                    lp.fold_in,
                    lp.cout,
                    node.fold_in(),
                    node.cout
                )));
            }
            let w = node.weights() as f64 * policy.calibration.factor(&node.name);
            nodes.push((node, lp, w));
        }
        let pool = policy.device.lut_budget() as f64 * policy.budget_fraction;
        let total: f64 = nodes.iter().map(|(_, _, w)| w).sum();
        let mut layers = Vec::new();
        for (node, lp, w) in nodes {
            let share = (pool * w / total) as u64;
            let cands = candidates(node, lp, spec)?;
            let (win, feasible) = match cands
                .iter()
                .filter(|c| c.predicted_luts <= share)
                .min_by_key(|c| (c.predicted_ii, c.predicted_luts, c.packed_bits))
            {
                Some(c) => (c, true),
                None => (
                    cands
                        .iter()
                        .min_by_key(|c| (c.predicted_luts, c.predicted_ii, c.packed_bits))
                        .expect("four candidates per layer"),
                    false,
                ),
            };
            layers.push(LayerChoice {
                layer: node.name.clone(),
                flavour: win.flavour,
                fold: win.fold.clone(),
                predicted_ii: win.predicted_ii,
                predicted_luts: win.predicted_luts,
                packed_bits: win.packed_bits,
                lut_share: share,
                feasible,
            });
        }
        Ok(KernelChoice { layers })
    }

    /// The audit row of layer `name`, if present.
    pub fn get(&self, name: &str) -> Option<&LayerChoice> {
        self.layers.iter().find(|l| l.layer == name)
    }

    /// The chosen folds as a [`FoldingConfig`] — what
    /// [`CompiledModel::compile`] bakes.
    pub fn folding(&self) -> FoldingConfig {
        FoldingConfig {
            layers: self
                .layers
                .iter()
                .map(|l| (l.layer.clone(), l.fold.clone()))
                .collect(),
        }
    }

    /// Human-readable audit table (one row per layer).
    pub fn render(&self) -> String {
        let mut out = String::from(
            "layer        flavour    style            ii_pred    luts_pred  packed_bits  lut_share  fit\n",
        );
        for l in &self.layers {
            out.push_str(&format!(
                "{:<12} {:<10} {:<16} {:>9} {:>10} {:>12} {:>10} {:>4}\n",
                l.layer,
                l.flavour.as_str(),
                l.fold.style.as_str(),
                l.predicted_ii,
                l.predicted_luts,
                l.packed_bits,
                l.lut_share,
                if l.feasible { "yes" } else { "over" },
            ));
        }
        out
    }
}

/// One scored candidate implementation of a layer.
struct Candidate {
    flavour: Flavour,
    fold: LayerFold,
    predicted_ii: u64,
    predicted_luts: u64,
    packed_bits: u64,
}

/// The SIMD-block partial-sparse fold both the selection policy and the
/// forced `block` flavour use: one PE, the widest lane count in
/// {8, 5, 4, 2} dividing the input axis (1 otherwise), mask-measured
/// sparsity.
fn block_fold(node: &Node, lp: &LayerParams) -> LayerFold {
    let simd = [8usize, 5, 4, 2]
        .into_iter()
        .find(|s| node.fold_in() % s == 0)
        .unwrap_or(1);
    LayerFold {
        pe: 1,
        simd,
        style: Style::PartialSparse,
        sparsity: lp.mask.sparsity().min(0.999_999),
    }
}

/// The N:M full-unroll fold for `node`'s mask: [`detect_nm`] picks the
/// group size, and the fold's sparsity annotation is the fit's
/// *stored*-row fraction (padding counted), so every downstream cost
/// annotation charges the fixed slots honestly.
fn nm_fold(node: &Node, lp: &LayerParams) -> Result<(LayerFold, NmFit)> {
    let fold_in = node.fold_in();
    let fit = detect_nm(&lp.mask.keep, fold_in, node.cout)?;
    let fold = LayerFold {
        pe: node.fold_out(),
        simd: fold_in,
        style: Style::NmStructured,
        sparsity: fit.stored_sparsity(fold_in).clamp(0.0, 0.999_999),
    };
    Ok((fold, fit))
}

/// The four candidate implementations of one layer, scored with the cost
/// models. Vec order is the full-tie preference (first wins): dense
/// before the index-carrying flavours, so a dense mask lands on the
/// plain dense kernel.
fn candidates(node: &Node, lp: &LayerParams, spec: &KernelSpec) -> Result<Vec<Candidate>> {
    let wbits = spec.weights.bits as u64;
    let fold_in = node.fold_in();
    let cout = node.cout;
    let addr_space = match node.op {
        Op::Conv => node.ifm * node.ifm * node.cin,
        _ => fold_in,
    };
    let score = |flavour: Flavour, fold: LayerFold, packed_bits: u64| Candidate {
        flavour,
        predicted_ii: crate::cost::latency::ii_cycles(node, &fold),
        predicted_luts: crate::cost::luts::layer_luts(
            node,
            &fold,
            spec.weights.bits,
            spec.act_bits,
        ),
        packed_bits,
        fold,
    };

    let nnz = lp.mask.nnz() as u64;
    // Dense stores every code, no index stream; the unrolled-sparse
    // stream carries one full-width input offset per survivor.
    let dense = score(
        Flavour::Dense,
        LayerFold::unrolled(node),
        node.weights() as u64 * wbits,
    );
    let unrolled = score(
        Flavour::Unrolled,
        LayerFold::unrolled_sparse(node, lp.mask.sparsity().min(0.999_999)),
        nnz * (wbits + pack::index_bits(addr_space) as u64),
    );
    // Block: exact stored/live counts from the mask (what build_sparse
    // will bake), one base-row index per live block.
    let bfold = block_fold(node, lp);
    let (mut stored, mut live) = (0u64, 0u64);
    for c in 0..cout {
        let mut r = 0usize;
        while r < fold_in {
            let hi = (r + bfold.simd).min(fold_in);
            if (r..hi).any(|row| lp.mask.keep[row * cout + c]) {
                stored += (hi - r) as u64;
                live += 1;
            }
            r = hi;
        }
    }
    let block = score(
        Flavour::Block,
        bfold,
        stored * wbits + live * pack::index_bits(fold_in) as u64,
    );
    // N:M: fixed slots (padding included) at narrow within-group offsets.
    let (nfold, fit) = nm_fold(node, lp)?;
    let nm = score(
        Flavour::Nm,
        nfold,
        (fit.stored_rows * cout) as u64 * (wbits + pack::index_bits(fit.m) as u64),
    );
    Ok(vec![dense, unrolled, block, nm])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PruneProfile;
    use crate::device::XCU50;
    use crate::dse::{self, DseOptions, Strategy};
    use crate::experiments::headline;
    use crate::graph::builder::{lenet5, mlp};
    use crate::runtime::SyntheticRuntime;

    fn lenet_params(seed: u64, sparsity: Option<f64>) -> (Graph, ModelParams) {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, seed);
        if let Some(s) = sparsity {
            p.prune_global(s, 0.05).unwrap();
        }
        (g, p)
    }

    fn images(n: usize) -> Vec<Vec<f32>> {
        (0..n).map(SyntheticRuntime::stripe_image).collect()
    }

    #[test]
    fn dense_and_sparse_agree_on_dense_mask() {
        // With a dense mask, the nnz-only schedule contains every weight;
        // integer accumulation is order-independent, so logits must be
        // bit-exact between variants.
        let (g, p) = lenet_params(1, None);
        let spec = KernelSpec::default();
        let dense = CompiledModel::compile_dense(&g, &p, &spec).unwrap();
        let sparse = CompiledModel::compile_sparse(&g, &p, &spec).unwrap();
        assert_eq!(sparse.total_nnz(), sparse.total_weights());
        for img in images(4) {
            assert_eq!(dense.forward(&img).unwrap(), sparse.forward(&img).unwrap());
        }
    }

    #[test]
    fn sparse_schedule_equals_dense_on_masked_weights() {
        // Pruned weights quantise to code 0 in the dense kernel, so the
        // dense loop over masked codes and the nnz-only schedule compute
        // the same integer sums — baked sparsity changes cost, not values.
        let (g, p) = lenet_params(2, Some(0.8));
        let spec = KernelSpec::default();
        let dense = CompiledModel::compile_dense(&g, &p, &spec).unwrap();
        let sparse = CompiledModel::compile_sparse(&g, &p, &spec).unwrap();
        assert!(sparse.total_nnz() < sparse.total_weights());
        for img in images(4) {
            assert_eq!(dense.forward(&img).unwrap(), sparse.forward(&img).unwrap());
        }
    }

    #[test]
    fn partial_sparse_matches_unrolled_sparse() {
        let (g, p) = lenet_params(3, Some(0.7));
        let spec = KernelSpec::default();
        let sparse = CompiledModel::compile_sparse(&g, &p, &spec).unwrap();
        let mut cfg = FoldingConfig::default();
        for n in g.mac_nodes() {
            // Partial unroll at a SIMD granularity that divides fold_in.
            let simd = if n.fold_in() % 5 == 0 { 5 } else { 2 };
            cfg.set(
                &n.name,
                LayerFold { pe: 1, simd, style: Style::PartialSparse, sparsity: 0.5 },
            );
        }
        let partial = CompiledModel::compile(&g, &p, &spec, &cfg).unwrap();
        // Block schedules store zeros inside live blocks but never change
        // the integer sums.
        assert!(partial.scheduled_macs_per_frame() >= sparse.scheduled_macs_per_frame());
        assert!(partial.scheduled_macs_per_frame() <= partial.dense_macs_per_frame());
        for img in images(3) {
            assert_eq!(partial.forward(&img).unwrap(), sparse.forward(&img).unwrap());
        }
        // The packed layout charges exactly one base-row index per live
        // block (positions inside a block are implicit).
        for mac in partial.mac_stages() {
            let Kernel::Sparse { live_blocks, .. } = &mac.kernel else {
                panic!("partial compile produced a dense kernel");
            };
            assert_eq!(mac.packed_rel.len(), (live_blocks * mac.idx_bits).div_ceil(8));
            assert_eq!(mac.idx_bits, pack::index_bits(mac.fold_in));
        }
    }

    #[test]
    fn forward_is_deterministic_and_shaped() {
        let (g, p) = lenet_params(4, Some(0.75));
        let m = CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap();
        assert_eq!(m.input_pixels(), 28 * 28);
        assert_eq!(m.output_len(), 10);
        let img = SyntheticRuntime::stripe_image(3);
        let a = m.forward(&img).unwrap();
        let b = m.forward(&img).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 10);
        assert!(a.iter().all(|v| v.is_finite()));
        // Batch path concatenates per-frame logits.
        let two: Vec<f32> = [img.clone(), img].concat();
        let batch = m.infer_batch(&two, 2).unwrap();
        assert_eq!(&batch[..10], &a[..]);
        assert_eq!(&batch[10..], &a[..]);
        assert!(m.forward(&[0.0; 3]).is_err());
        assert!(m.infer_batch(&two, 3).is_err());
    }

    #[test]
    fn sparsity_and_compression_match_headline_accounting() {
        let (g, p) = lenet_params(5, Some(0.845));
        let m = CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap();
        let ms = m.sparsity();
        assert_eq!(ms.total_weights(), 44_190);
        assert_eq!(ms.total_nnz(), p.sparsity().total_nnz());
        // The compiled model's self-reported compression must be the same
        // number experiments::headline derives from the same accounting
        // (acceptance criterion: within 1%; it is exact by construction).
        let (free, csr) = headline::compression_from_sparsity(&ms, m.spec.weights.bits);
        assert!((m.compression() - free).abs() / free < 1e-9);
        assert!((m.compression_csr(16) - csr).abs() / csr < 1e-9);
        assert!(m.compression() > m.compression_csr(16));
    }

    #[test]
    fn nnz_macs_shrink_with_sparsity() {
        let (g, dense_p) = lenet_params(6, None);
        let (_, sparse_p) = lenet_params(6, Some(0.75));
        let spec = KernelSpec::default();
        let dense = CompiledModel::compile_dense(&g, &dense_p, &spec).unwrap();
        let sparse = CompiledModel::compile_sparse(&g, &sparse_p, &spec).unwrap();
        assert_eq!(dense.dense_macs_per_frame(), 281_640);
        assert_eq!(dense.scheduled_macs_per_frame(), 281_640);
        let ratio =
            sparse.scheduled_macs_per_frame() as f64 / dense.scheduled_macs_per_frame() as f64;
        assert!(ratio < 0.35, "75% pruning must cut scheduled MACs: {ratio}");
    }

    #[test]
    fn packed_streams_roundtrip_to_schedules() {
        let (g, p) = lenet_params(7, Some(0.6));
        let m = CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap();
        for mac in m.mac_stages() {
            let Kernel::Sparse { rel, code, .. } = &mac.kernel else {
                panic!("sparse compile produced a dense kernel");
            };
            assert_eq!(
                &pack::unpack_codes(&mac.packed_codes, m.spec.weights.bits, code.len()),
                code
            );
            assert_eq!(&pack::unpack_bits(&mac.packed_rel, mac.idx_bits, rel.len()), rel);
            // W4 + minimal-width indices beat the unpacked tables.
            assert!(mac.packed_codes.len() < code.len());
        }
        assert!(m.runtime_bytes() > 0);
    }

    #[test]
    fn datapaths_are_bit_identical_across_flavours() {
        // The tentpole identity guarantee: every compiled-in datapath
        // (scalar reference, lane-chunked vector, intrinsics when the
        // `simd` feature is on) produces bit-identical logits on every
        // kernel flavour. LeNet-5 shapes exercise the lane remainders:
        // cout 6 is no multiple of the dense 4-row fuse width, and
        // per-channel nnz counts are arbitrary relative to LANES.
        let (g, p) = lenet_params(12, Some(0.7));
        let spec = KernelSpec::default();
        let mut cfg = FoldingConfig::default();
        for n in g.mac_nodes() {
            // Largest lane granularity dividing fold_in (folding checks
            // divisibility; the datapaths themselves need no alignment).
            let simd = [8usize, 5, 4, 2]
                .into_iter()
                .find(|s| n.fold_in() % s == 0)
                .unwrap_or(1);
            cfg.set(
                &n.name,
                LayerFold { pe: 1, simd, style: Style::PartialSparse, sparsity: 0.5 },
            );
        }
        let models = [
            CompiledModel::compile_dense(&g, &p, &spec).unwrap(),
            CompiledModel::compile_sparse(&g, &p, &spec).unwrap(),
            CompiledModel::compile(&g, &p, &spec, &cfg).unwrap(),
        ];
        for m in &models {
            for img in images(3) {
                let reference = m.forward_with(&img, Datapath::Scalar).unwrap();
                for dp in Datapath::all() {
                    assert_eq!(
                        m.forward_with(&img, dp).unwrap(),
                        reference,
                        "{} datapath diverged on {}",
                        dp.label(),
                        m.model
                    );
                }
            }
        }
    }

    #[test]
    fn datapath_selection_and_labels() {
        let all = Datapath::all();
        assert_eq!(all[0], Datapath::Scalar);
        assert!(all.contains(&Datapath::best()));
        assert_eq!(Datapath::Scalar.label(), "scalar");
        assert_eq!(Datapath::Vector.label(), "vector");
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        {
            assert_eq!(Datapath::Simd.label(), "simd");
            assert_eq!(Datapath::Avx2.label(), "avx2");
            // AVX2 is runtime-dispatched: it is listed (and wins best())
            // exactly when the CPU reports it, so `all()` never hands a
            // test or bench a datapath it cannot execute.
            if std::arch::is_x86_feature_detected!("avx2") {
                assert_eq!(Datapath::best(), Datapath::Avx2);
                assert!(all.contains(&Datapath::Avx2));
            } else {
                assert_eq!(Datapath::best(), Datapath::Simd);
                assert!(!all.contains(&Datapath::Avx2));
            }
        }
        // A compiled model defaults to the best datapath and can be
        // pinned without changing results.
        let (g, p) = lenet_params(13, Some(0.6));
        let m = CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap();
        assert_eq!(m.datapath(), Datapath::best());
        let img = SyntheticRuntime::stripe_image(5);
        let fast = m.forward(&img).unwrap();
        let pinned = m.clone().with_datapath(Datapath::Scalar);
        assert_eq!(pinned.datapath(), Datapath::Scalar);
        assert_eq!(pinned.forward(&img).unwrap(), fast);
    }

    #[test]
    fn vector_datapath_handles_non_lane_multiple_mlp_shapes() {
        // fold_in 19 / 13 and cout 13 / 10: nothing is a multiple of the
        // 4-row dense fuse width or the 8-wide sparse lanes, so every
        // remainder loop runs.
        let g = mlp(19, 13, 10);
        let mut p = ModelParams::synthetic(&g, 14);
        p.prune_global(0.4, 0.1).unwrap();
        let spec = KernelSpec::default();
        for m in [
            CompiledModel::compile_dense(&g, &p, &spec).unwrap(),
            CompiledModel::compile_sparse(&g, &p, &spec).unwrap(),
        ] {
            let x: Vec<f32> = (0..19).map(|i| (i % 5) as f32 / 5.0).collect();
            let reference = m.forward_with(&x, Datapath::Scalar).unwrap();
            for dp in Datapath::all() {
                assert_eq!(m.forward_with(&x, dp).unwrap(), reference, "{}", dp.label());
            }
        }
    }

    #[test]
    fn mlp_chain_compiles_and_runs() {
        let g = mlp(64, 32, 10);
        let mut p = ModelParams::synthetic(&g, 8);
        p.prune_global(0.5, 0.1).unwrap();
        let m = CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap();
        assert_eq!(m.input_pixels(), 64);
        let x: Vec<f32> = (0..64).map(|i| (i % 7) as f32 / 7.0).collect();
        assert_eq!(m.forward(&x).unwrap().len(), 10);
    }

    #[test]
    fn compiles_from_dse_folding() {
        // The DSE's chosen styles drive kernel selection directly: one
        // FoldingConfig is shared by cost model, simulator and kernels.
        let g = lenet5();
        let profile = PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95);
        let r = dse::run(Strategy::Proposed, &g, &XCU50, &profile, &DseOptions::default())
            .unwrap();
        let mut p = ModelParams::synthetic(&g, 9);
        p.prune_global(0.7, 0.05).unwrap();
        let m = CompiledModel::compile(&g, &p, &KernelSpec::default(), &r.folding).unwrap();
        assert_eq!(m.folding, r.folding);
        let img = SyntheticRuntime::stripe_image(1);
        assert_eq!(m.forward(&img).unwrap().len(), 10);
    }

    #[test]
    fn rejects_bad_specs_and_graphs() {
        let (g, p) = lenet_params(10, None);
        let spec = KernelSpec { act_bits: 1, ..KernelSpec::default() };
        assert!(CompiledModel::compile_dense(&g, &p, &spec).is_err());
        let spec = KernelSpec { act_ceil: 0.0, ..KernelSpec::default() };
        assert!(CompiledModel::compile_dense(&g, &p, &spec).is_err());
        // Params missing a layer.
        let g2 = lenet5();
        let mut p2 = ModelParams::synthetic(&g2, 11);
        p2.layers.retain(|l| l.name != "fc2");
        assert!(CompiledModel::compile_dense(&g2, &p2, &KernelSpec::default()).is_err());
    }

    #[test]
    fn flavour_roundtrip_and_forced_compiles_are_bit_identical() {
        for f in [Flavour::Auto, Flavour::Dense, Flavour::Unrolled, Flavour::Block, Flavour::Nm]
        {
            assert_eq!(Flavour::parse(f.as_str()).unwrap(), f);
        }
        assert!(Flavour::parse("bespoke").is_err());
        // Every forced flavour computes the same logits as the dense
        // compile of the same masked params — the PR 2 invariant extended
        // to N:M and auto.
        let (g, p) = lenet_params(25, Some(0.6));
        let spec = KernelSpec::default();
        let reference = CompiledModel::compile_dense(&g, &p, &spec).unwrap();
        let img = SyntheticRuntime::stripe_image(2);
        let want = reference.forward(&img).unwrap();
        for f in [Flavour::Auto, Flavour::Unrolled, Flavour::Block, Flavour::Nm] {
            let m = CompiledModel::compile_with_choice(&g, &p, &spec, f).unwrap();
            assert_eq!(m.forward(&img).unwrap(), want, "flavour {}", f.as_str());
        }
    }

    #[test]
    fn nm_compile_is_bit_identical_and_fixed_stride() {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, 24);
        p.prune_nm(2, 8).unwrap();
        let spec = KernelSpec::default();
        let nm = CompiledModel::compile_with_choice(&g, &p, &spec, Flavour::Nm).unwrap();
        let dense = CompiledModel::compile_dense(&g, &p, &spec).unwrap();
        for img in images(3) {
            for dp in Datapath::all() {
                assert_eq!(
                    nm.forward_with(&img, dp).unwrap(),
                    dense.forward_with(&img, dp).unwrap(),
                    "{} datapath diverged on N:M",
                    dp.label()
                );
            }
        }
        for mac in nm.mac_stages() {
            assert_eq!(mac.style, Style::NmStructured);
            let (n, m) = mac.nm.expect("N:M stage must record its fit");
            assert!(n <= m && m <= 16, "{}: {n}:{m}", mac.name);
            let Kernel::Sparse { rel, code, block, .. } = &mac.kernel else {
                panic!("N:M must bake a sparse schedule");
            };
            assert_eq!(*block, 1);
            // Fixed-stride stream: narrow within-group offsets, length a
            // pure function of the layer geometry and the fit.
            assert_eq!(mac.idx_bits, pack::index_bits(m));
            assert_eq!(mac.packed_rel.len(), (code.len() * mac.idx_bits).div_ceil(8));
            let rows = pack::unpack_nm_rows(&mac.packed_rel, mac.fold_in, n, m, mac.cout);
            assert_eq!(rows.len(), code.len());
            if mac.op == Op::Fc {
                // fc offsets are absolute rows: the decode must rebuild
                // the execution table exactly.
                assert_eq!(&rows, rel);
            }
            // The schedule stores fixed slots: at least the survivors,
            // never more than the dense axis.
            assert!(code.len() >= mac.nnz && code.len() <= mac.weights);
        }
    }

    #[test]
    fn auto_selection_is_pure_and_audited() {
        let (g, p) = lenet_params(20, Some(0.75));
        let spec = KernelSpec::default();
        let (m1, c1) = CompiledModel::compile_auto(&g, &p, &spec).unwrap();
        let (m2, c2) = CompiledModel::compile_auto(&g, &p, &spec).unwrap();
        // Purity: identical inputs, identical choice and model folding.
        assert_eq!(c1.folding(), c2.folding());
        assert_eq!(m1.folding, m2.folding);
        // Audit rows cover every MAC layer in stream order, and the
        // predictions on the compiled stages match the rows the policy
        // scored (both sides call the same cost models).
        assert_eq!(c1.layers.len(), 5);
        for (l, mac) in c1.layers.iter().zip(m1.mac_stages()) {
            assert_eq!(l.layer, mac.name);
            assert_eq!(l.fold.style, mac.style);
            assert_eq!(l.predicted_ii, mac.predicted_ii);
            assert_eq!(l.predicted_luts, mac.predicted_luts);
            assert!(l.feasible, "{} over budget on a full XCU50", l.layer);
        }
        assert_eq!(c1.get("conv1").unwrap().layer, "conv1");
        assert!(c1.render().contains("conv1"));
        assert!(m1.predicted_max_ii() > 0);
        assert!(m1.predicted_luts() > 0);
    }

    #[test]
    fn auto_picks_dense_for_dense_masks_and_sparse_for_pruned() {
        let g = lenet5();
        let spec = KernelSpec::default();
        let dense_p = ModelParams::synthetic(&g, 21);
        let (_, choice) = CompiledModel::compile_auto(&g, &dense_p, &spec).unwrap();
        // Dense masks: the index-free dense kernel wins on packed bits.
        for l in &choice.layers {
            assert_eq!(l.flavour, Flavour::Dense, "{}\n{}", l.layer, choice.render());
        }
        // Unstructured 75% pruning: the nnz-only unroll ties dense on
        // predicted II and wins on LUTs.
        let (_, p75) = lenet_params(21, Some(0.75));
        let (m, choice) = CompiledModel::compile_auto(&g, &p75, &spec).unwrap();
        for l in &choice.layers {
            assert_eq!(l.flavour, Flavour::Unrolled, "{}\n{}", l.layer, choice.render());
        }
        assert!(m.total_nnz() < m.total_weights());
    }

    #[test]
    fn auto_prefers_nm_on_structured_masks() {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, 22);
        p.prune_nm(2, 4).unwrap();
        let spec = KernelSpec::default();
        let (m, choice) = CompiledModel::compile_auto(&g, &p, &spec).unwrap();
        // A genuinely 2:4 mask stores no padding, so the N:M candidate
        // ties the nnz-only unroll on predicted cost and wins on packed
        // bits (2-bit offsets vs full-width input indices).
        for l in &choice.layers {
            assert_eq!(l.flavour, Flavour::Nm, "{}\n{}", l.layer, choice.render());
        }
        // No padding waste: the N:M schedule runs exactly the survivors.
        let sparse = CompiledModel::compile_sparse(&g, &p, &spec).unwrap();
        assert_eq!(m.scheduled_macs_per_frame(), sparse.scheduled_macs_per_frame());
        let dense = CompiledModel::compile_dense(&g, &p, &spec).unwrap();
        for img in images(2) {
            assert_eq!(m.forward(&img).unwrap(), dense.forward(&img).unwrap());
        }
    }

    #[test]
    fn choice_is_monotone_in_sparsity() {
        // Satellite invariant: raising sparsity never flips a layer from
        // a sparse flavour back to dense. A tiny device + half budget
        // forces the block/fallback arms so the invariant is exercised
        // where it could actually break.
        let g = lenet5();
        let spec = KernelSpec::default();
        let policy = ChoicePolicy {
            device: crate::device::TINY,
            budget_fraction: 0.5,
            ..Default::default()
        };
        let mut prev: Vec<Flavour> = Vec::new();
        for s in [0.3, 0.5, 0.7, 0.85, 0.95] {
            let mut p = ModelParams::synthetic(&g, 23);
            p.prune_global(s, 0.05).unwrap();
            let choice = KernelChoice::choose(&g, &p, &spec, &policy).unwrap();
            choice.folding().check(&g).unwrap();
            let flavs: Vec<Flavour> = choice.layers.iter().map(|l| l.flavour).collect();
            if !prev.is_empty() {
                for (i, (&now, &before)) in flavs.iter().zip(&prev).enumerate() {
                    if before != Flavour::Dense {
                        assert_ne!(
                            now,
                            Flavour::Dense,
                            "{} flipped sparse->dense when sparsity rose to {s}\n{}",
                            choice.layers[i].layer,
                            choice.render()
                        );
                    }
                }
            }
            prev = flavs;
        }
        // The constrained policy really exercised the block schedule.
        assert!(prev.iter().any(|&f| f == Flavour::Block || f == Flavour::Unrolled));
    }

    #[test]
    fn calibration_reweights_budget_shares() {
        let mut cal = Calibration::default();
        assert_eq!(cal.factor("conv1"), 1.0);
        cal.occupancy = vec![("conv1+pool1".to_string(), 0.9), ("fc1".to_string(), 0.2)];
        assert!((cal.factor("conv1") - 0.9).abs() < 1e-12);
        assert!((cal.factor("fc1") - 0.2).abs() < 1e-12);
        assert_eq!(cal.factor("fc3"), 1.0);
        // The floor keeps a measured-idle layer from losing its whole
        // share.
        cal.occupancy.push(("fc2".to_string(), 0.0));
        assert!(cal.factor("fc2") >= 0.05);
        // A calibrated policy still yields a valid, pure choice.
        let (g, p) = lenet_params(26, Some(0.75));
        let spec = KernelSpec::default();
        let policy = ChoicePolicy { calibration: cal, ..Default::default() };
        let a = KernelChoice::choose(&g, &p, &spec, &policy).unwrap();
        let b = KernelChoice::choose(&g, &p, &spec, &policy).unwrap();
        assert_eq!(a.folding(), b.folding());
        a.folding().check(&g).unwrap();
        // Bad policies are rejected.
        let bad = ChoicePolicy { budget_fraction: 0.0, ..Default::default() };
        assert!(KernelChoice::choose(&g, &p, &spec, &bad).is_err());
    }

    #[test]
    fn served_flavour_names_every_style() {
        for st in [
            Style::Folded,
            Style::UnrolledDense,
            Style::UnrolledSparse,
            Style::PartialSparse,
            Style::NmStructured,
        ] {
            assert!(!served_flavour(st).is_empty());
        }
        assert_eq!(served_flavour(Style::NmStructured), "N:M fixed-stride schedule");
    }
}
