//! [`NativeSparseBackend`]: baked kernels behind the serving plane's
//! [`InferenceBackend`] seam — LeNet-shaped inference with **no engine**:
//! no PJRT, no artifacts, no sleep stand-in; every MAC the sharded plane
//! executes comes out of the compiled nnz-only schedules.

use std::sync::Arc;

use super::CompiledModel;
use crate::runtime::{InferenceBackend, IMG, NUM_CLASSES};
use crate::util::error::{Error, Result};

/// Serving adapter for a [`CompiledModel`]. The model is immutable shared
/// state, so engine replicas clone one `Arc` instead of re-compiling.
pub struct NativeSparseBackend {
    model: Arc<CompiledModel>,
}

impl NativeSparseBackend {
    /// Wrap `model` for the request path; rejects models whose shape does
    /// not match the serving contract (28x28 in, 10 logits out).
    pub fn new(model: Arc<CompiledModel>) -> Result<Self> {
        if model.input_pixels() != IMG * IMG {
            return Err(Error::kernel(format!(
                "model takes {} inputs, serving needs {}",
                model.input_pixels(),
                IMG * IMG
            )));
        }
        if model.output_len() != NUM_CLASSES {
            return Err(Error::kernel(format!(
                "model emits {} logits, serving needs {NUM_CLASSES}",
                model.output_len()
            )));
        }
        Ok(NativeSparseBackend { model })
    }

    /// The compiled model this backend serves.
    pub fn model(&self) -> &CompiledModel {
        &self.model
    }
}

impl InferenceBackend for NativeSparseBackend {
    fn infer_padded(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        self.model.infer_batch(x, n)
    }

    fn label(&self) -> String {
        format!("native/{}", self.model.summary())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::{convnet, lenet5};
    use crate::kernel::KernelSpec;
    use crate::runtime::SyntheticRuntime;
    use crate::weights::ModelParams;

    #[test]
    fn backend_matches_direct_forward() {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, 21);
        p.prune_global(0.75, 0.05).unwrap();
        let model =
            Arc::new(CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap());
        let be = NativeSparseBackend::new(Arc::clone(&model)).unwrap();
        let a = SyntheticRuntime::stripe_image(2);
        let b = SyntheticRuntime::stripe_image(7);
        let x: Vec<f32> = [a.clone(), b.clone()].concat();
        let logits = be.infer_padded(&x, 2).unwrap();
        assert_eq!(logits.len(), 2 * NUM_CLASSES);
        assert_eq!(&logits[..10], &model.forward(&a).unwrap()[..]);
        assert_eq!(&logits[10..], &model.forward(&b).unwrap()[..]);
        assert!(be.label().starts_with("native/"));
        assert!(be.infer_padded(&x, 3).is_err());
    }

    #[test]
    fn non_serving_shapes_are_rejected() {
        let g = convnet(2, 8, 32, 10);
        let p = ModelParams::synthetic(&g, 22);
        let model =
            Arc::new(CompiledModel::compile_dense(&g, &p, &KernelSpec::default()).unwrap());
        assert!(NativeSparseBackend::new(model).is_err());
    }
}
