//! [`NativeSparseBackend`]: baked kernels behind the serving plane's
//! [`InferenceBackend`] seam — LeNet-shaped inference with **no engine**:
//! no PJRT, no artifacts, no sleep stand-in; every MAC the sharded plane
//! executes comes out of the compiled nnz-only schedules.

use std::sync::Arc;

use super::{BatchPool, CompiledModel, StagedExecutor};
use crate::runtime::{InferenceBackend, IMG, NUM_CLASSES};
use crate::util::error::{Error, Result};

/// Serving adapter for a [`CompiledModel`]. The model is immutable shared
/// state, so engine replicas clone one `Arc` instead of re-compiling.
/// Three execution modes, all bit-identical to the serial stage walk:
/// plain serial ([`NativeSparseBackend::new`]), data-parallel batches
/// over a [`BatchPool`] ([`NativeSparseBackend::with_workers`]), or
/// layer-pipelined over a [`StagedExecutor`]
/// ([`NativeSparseBackend::with_pipeline`]) — request k's layer N
/// concurrent with request k+1's layer N−1.
pub struct NativeSparseBackend {
    model: Arc<CompiledModel>,
    pool: Option<BatchPool>,
    pipeline: Option<StagedExecutor>,
}

impl NativeSparseBackend {
    /// Wrap `model` for the request path; rejects models whose shape does
    /// not match the serving contract (28x28 in, 10 logits out). Batches
    /// run serially — see [`NativeSparseBackend::with_workers`].
    pub fn new(model: Arc<CompiledModel>) -> Result<Self> {
        Self::with_workers(model, 0)
    }

    /// Like [`NativeSparseBackend::new`] but with `workers` pool threads
    /// fanning each batch (the coordinator sizes this from the host core
    /// count via `shard::workers_per_engine`). `workers == 0` keeps the
    /// serial path with no pool threads at all.
    pub fn with_workers(model: Arc<CompiledModel>, workers: usize) -> Result<Self> {
        Self::validate(&model)?;
        let pool = (workers > 0).then(|| BatchPool::new(workers));
        Ok(NativeSparseBackend { model, pool, pipeline: None })
    }

    /// Layer-pipelined mode: execute stages across (at most) `groups`
    /// cost-balanced stage groups, one persistent worker each, bounded
    /// rings between them (see [`StagedExecutor`]). Same shape contract
    /// as [`NativeSparseBackend::new`]; the coordinator budgets `groups`
    /// from the host core count via `shard::pipeline_groups_per_engine`.
    /// `groups == 1` degenerates to the serial walk on one worker.
    pub fn with_pipeline(model: Arc<CompiledModel>, groups: usize) -> Result<Self> {
        Self::validate(&model)?;
        let pipeline = Some(StagedExecutor::new(Arc::clone(&model), groups)?);
        Ok(NativeSparseBackend { model, pool: None, pipeline })
    }

    /// Layer-pipelined mode with a worker budget: like
    /// [`NativeSparseBackend::with_pipeline`], but up to `workers`
    /// total threads are spent across the groups — every group gets
    /// one, and the slack replicates the costliest group(s)
    /// (`StagedExecutor::with_budget`). The coordinator budgets
    /// `workers` from the host core count via
    /// `shard::pipeline_workers_per_engine`.
    pub fn with_pipeline_budget(
        model: Arc<CompiledModel>,
        groups: usize,
        workers: usize,
    ) -> Result<Self> {
        Self::with_pipeline_budget_obs(model, groups, workers, super::PipeObs::default())
    }

    /// [`NativeSparseBackend::with_pipeline_budget`] with observability
    /// attached: the executor's group workers record trace events and
    /// the executor registers occupancy gauges (see
    /// [`PipeObs`](super::PipeObs)).
    pub fn with_pipeline_budget_obs(
        model: Arc<CompiledModel>,
        groups: usize,
        workers: usize,
        obs: super::PipeObs,
    ) -> Result<Self> {
        Self::validate(&model)?;
        let dp = model.datapath();
        let pipeline = Some(StagedExecutor::with_budget_obs(
            Arc::clone(&model),
            groups,
            workers,
            super::pipeline::DEFAULT_FIFO_DEPTH,
            dp,
            obs,
        )?);
        Ok(NativeSparseBackend { model, pool: None, pipeline })
    }

    /// Layer-pipelined mode with pinned bottleneck replication: `r`
    /// worker threads on the single costliest group, one everywhere
    /// else (`serve --pipeline NxR`).
    pub fn with_pipeline_replicated(
        model: Arc<CompiledModel>,
        groups: usize,
        r: usize,
    ) -> Result<Self> {
        Self::with_pipeline_replicated_obs(model, groups, r, super::PipeObs::default())
    }

    /// [`NativeSparseBackend::with_pipeline_replicated`] with
    /// observability attached (see [`PipeObs`](super::PipeObs)).
    pub fn with_pipeline_replicated_obs(
        model: Arc<CompiledModel>,
        groups: usize,
        r: usize,
        obs: super::PipeObs,
    ) -> Result<Self> {
        Self::validate(&model)?;
        let dp = model.datapath();
        let pipeline = Some(StagedExecutor::with_bottleneck_replication_obs(
            Arc::clone(&model),
            groups,
            r,
            super::pipeline::DEFAULT_FIFO_DEPTH,
            dp,
            obs,
        )?);
        Ok(NativeSparseBackend { model, pool: None, pipeline })
    }

    fn validate(model: &CompiledModel) -> Result<()> {
        if model.input_pixels() != IMG * IMG {
            return Err(Error::kernel(format!(
                "model takes {} inputs, serving needs {}",
                model.input_pixels(),
                IMG * IMG
            )));
        }
        if model.output_len() != NUM_CLASSES {
            return Err(Error::kernel(format!(
                "model emits {} logits, serving needs {NUM_CLASSES}",
                model.output_len()
            )));
        }
        Ok(())
    }

    /// The compiled model this backend serves.
    pub fn model(&self) -> &CompiledModel {
        &self.model
    }

    /// Pool worker threads fanning batches (0 = serial).
    pub fn workers(&self) -> usize {
        self.pool.as_ref().map_or(0, BatchPool::workers)
    }

    /// Stage groups when pipelined (0 = not in pipeline mode).
    pub fn stage_groups(&self) -> usize {
        self.pipeline.as_ref().map_or(0, StagedExecutor::groups)
    }

    /// Largest per-group replica count when pipelined (1 = unreplicated
    /// or not in pipeline mode).
    pub fn pipeline_replication(&self) -> usize {
        self.pipeline.as_ref().map_or(1, StagedExecutor::max_replication)
    }

    /// The staged executor, when running in pipeline mode (occupancy
    /// stats and the calibration sim hang off it).
    pub fn pipeline(&self) -> Option<&StagedExecutor> {
        self.pipeline.as_ref()
    }

    /// Measured per-group occupancy as a [`Calibration`] the kernel
    /// selection policy can consume, when running in pipeline mode.
    /// Serial and pooled backends have no stage groups to measure and
    /// return `None` — callers fall back to `Calibration::default()`.
    pub fn measured_calibration(&self) -> Option<super::Calibration> {
        self.pipeline.as_ref().map(|p| super::Calibration::from_stats(&p.stats()))
    }
}

impl InferenceBackend for NativeSparseBackend {
    fn infer_padded(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        if let Some(pipe) = &self.pipeline {
            return pipe.infer_batch(x, n);
        }
        match &self.pool {
            Some(pool) => pool.infer_batch(&self.model, x, n),
            None => self.model.infer_batch(x, n),
        }
    }

    fn label(&self) -> String {
        if let Some(pipe) = &self.pipeline {
            // Replication shows as `pipe3x2` (3 groups, bottleneck x2);
            // the unreplicated label keeps the PR 7 `pipe3` shape.
            let rep = match pipe.max_replication() {
                1 => String::new(),
                r => format!("x{r}"),
            };
            return format!("native+pipe{}{rep}/{}", pipe.groups(), self.model.summary());
        }
        match self.workers() {
            0 => format!("native/{}", self.model.summary()),
            w => format!("native+{w}w/{}", self.model.summary()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::{convnet, lenet5};
    use crate::kernel::KernelSpec;
    use crate::runtime::SyntheticRuntime;
    use crate::weights::ModelParams;

    #[test]
    fn backend_matches_direct_forward() {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, 21);
        p.prune_global(0.75, 0.05).unwrap();
        let model =
            Arc::new(CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap());
        let be = NativeSparseBackend::new(Arc::clone(&model)).unwrap();
        let a = SyntheticRuntime::stripe_image(2);
        let b = SyntheticRuntime::stripe_image(7);
        let x: Vec<f32> = [a.clone(), b.clone()].concat();
        let logits = be.infer_padded(&x, 2).unwrap();
        assert_eq!(logits.len(), 2 * NUM_CLASSES);
        assert_eq!(&logits[..10], &model.forward(&a).unwrap()[..]);
        assert_eq!(&logits[10..], &model.forward(&b).unwrap()[..]);
        assert!(be.label().starts_with("native/"));
        assert!(be.infer_padded(&x, 3).is_err());
    }

    #[test]
    fn pooled_backend_matches_serial_backend() {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, 23);
        p.prune_global(0.7, 0.05).unwrap();
        let model =
            Arc::new(CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap());
        let serial = NativeSparseBackend::new(Arc::clone(&model)).unwrap();
        let pooled = NativeSparseBackend::with_workers(Arc::clone(&model), 3).unwrap();
        assert_eq!(serial.workers(), 0);
        assert_eq!(pooled.workers(), 3);
        assert!(pooled.label().starts_with("native+3w/"));
        for n in [1usize, 2, 8, 11] {
            let x: Vec<f32> = (0..n).flat_map(SyntheticRuntime::stripe_image).collect();
            assert_eq!(
                pooled.infer_padded(&x, n).unwrap(),
                serial.infer_padded(&x, n).unwrap(),
                "batch {n} diverged between pooled and serial backends"
            );
        }
    }

    #[test]
    fn pipelined_backend_matches_serial_backend() {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, 29);
        p.prune_global(0.7, 0.05).unwrap();
        let model =
            Arc::new(CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap());
        let serial = NativeSparseBackend::new(Arc::clone(&model)).unwrap();
        let piped = NativeSparseBackend::with_pipeline(Arc::clone(&model), 3).unwrap();
        assert_eq!(piped.stage_groups(), 3);
        assert_eq!(piped.workers(), 0);
        assert!(piped.label().starts_with("native+pipe3/"));
        for n in [1usize, 2, 8, 11] {
            let x: Vec<f32> = (0..n).flat_map(SyntheticRuntime::stripe_image).collect();
            assert_eq!(
                piped.infer_padded(&x, n).unwrap(),
                serial.infer_padded(&x, n).unwrap(),
                "batch {n} diverged between pipelined and serial backends"
            );
        }
        assert!(piped.infer_padded(&[0.0; 10], 1).is_err());
        // Only the pipelined mode has stage groups to measure, and
        // every measured factor is positive once frames have flowed.
        assert!(serial.measured_calibration().is_none());
        let cal = piped.measured_calibration().unwrap();
        assert_eq!(cal.occupancy.len(), 3);
        assert!(cal.occupancy.iter().all(|(_, f)| *f >= 0.0));
    }

    #[test]
    fn replicated_pipeline_backend_matches_serial_and_labels_replication() {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, 41);
        p.prune_global(0.7, 0.05).unwrap();
        let model =
            Arc::new(CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap());
        let serial = NativeSparseBackend::new(Arc::clone(&model)).unwrap();
        // Pinned bottleneck replication: 3 groups, x2 on the costliest.
        let pinned = NativeSparseBackend::with_pipeline_replicated(Arc::clone(&model), 3, 2)
            .unwrap();
        assert_eq!(pinned.stage_groups(), 3);
        assert_eq!(pinned.pipeline_replication(), 2);
        assert!(pinned.label().starts_with("native+pipe3x2/"));
        // Budgeted: 3 groups + 2 spare workers also replicate.
        let budgeted =
            NativeSparseBackend::with_pipeline_budget(Arc::clone(&model), 3, 5).unwrap();
        assert_eq!(budgeted.stage_groups(), 3);
        assert!(budgeted.pipeline_replication() >= 2);
        // A budget with no slack stays unreplicated and keeps the PR 7
        // label shape.
        let flat = NativeSparseBackend::with_pipeline_budget(Arc::clone(&model), 3, 3).unwrap();
        assert_eq!(flat.pipeline_replication(), 1);
        assert!(flat.label().starts_with("native+pipe3/"));
        for n in [1usize, 2, 8, 11] {
            let x: Vec<f32> = (0..n).flat_map(SyntheticRuntime::stripe_image).collect();
            let want = serial.infer_padded(&x, n).unwrap();
            assert_eq!(pinned.infer_padded(&x, n).unwrap(), want, "pinned batch {n}");
            assert_eq!(budgeted.infer_padded(&x, n).unwrap(), want, "budgeted batch {n}");
        }
    }

    #[test]
    fn non_serving_shapes_are_rejected() {
        let g = convnet(2, 8, 32, 10);
        let p = ModelParams::synthetic(&g, 22);
        let model =
            Arc::new(CompiledModel::compile_dense(&g, &p, &KernelSpec::default()).unwrap());
        assert!(NativeSparseBackend::new(model).is_err());
    }
}
