//! [`NativeSparseBackend`]: baked kernels behind the serving plane's
//! [`InferenceBackend`] seam — LeNet-shaped inference with **no engine**:
//! no PJRT, no artifacts, no sleep stand-in; every MAC the sharded plane
//! executes comes out of the compiled nnz-only schedules.

use std::sync::Arc;

use super::{BatchPool, CompiledModel};
use crate::runtime::{InferenceBackend, IMG, NUM_CLASSES};
use crate::util::error::{Error, Result};

/// Serving adapter for a [`CompiledModel`]. The model is immutable shared
/// state, so engine replicas clone one `Arc` instead of re-compiling.
/// With a [`BatchPool`] attached ([`NativeSparseBackend::with_workers`])
/// batched requests fan across the pool's worker threads — bit-identical
/// to the serial loop, just faster on multi-core hosts.
pub struct NativeSparseBackend {
    model: Arc<CompiledModel>,
    pool: Option<BatchPool>,
}

impl NativeSparseBackend {
    /// Wrap `model` for the request path; rejects models whose shape does
    /// not match the serving contract (28x28 in, 10 logits out). Batches
    /// run serially — see [`NativeSparseBackend::with_workers`].
    pub fn new(model: Arc<CompiledModel>) -> Result<Self> {
        Self::with_workers(model, 0)
    }

    /// Like [`NativeSparseBackend::new`] but with `workers` pool threads
    /// fanning each batch (the coordinator sizes this from the host core
    /// count via `shard::workers_per_engine`). `workers == 0` keeps the
    /// serial path with no pool threads at all.
    pub fn with_workers(model: Arc<CompiledModel>, workers: usize) -> Result<Self> {
        if model.input_pixels() != IMG * IMG {
            return Err(Error::kernel(format!(
                "model takes {} inputs, serving needs {}",
                model.input_pixels(),
                IMG * IMG
            )));
        }
        if model.output_len() != NUM_CLASSES {
            return Err(Error::kernel(format!(
                "model emits {} logits, serving needs {NUM_CLASSES}",
                model.output_len()
            )));
        }
        let pool = (workers > 0).then(|| BatchPool::new(workers));
        Ok(NativeSparseBackend { model, pool })
    }

    /// The compiled model this backend serves.
    pub fn model(&self) -> &CompiledModel {
        &self.model
    }

    /// Pool worker threads fanning batches (0 = serial).
    pub fn workers(&self) -> usize {
        self.pool.as_ref().map_or(0, BatchPool::workers)
    }
}

impl InferenceBackend for NativeSparseBackend {
    fn infer_padded(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        match &self.pool {
            Some(pool) => pool.infer_batch(&self.model, x, n),
            None => self.model.infer_batch(x, n),
        }
    }

    fn label(&self) -> String {
        match self.workers() {
            0 => format!("native/{}", self.model.summary()),
            w => format!("native+{w}w/{}", self.model.summary()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::{convnet, lenet5};
    use crate::kernel::KernelSpec;
    use crate::runtime::SyntheticRuntime;
    use crate::weights::ModelParams;

    #[test]
    fn backend_matches_direct_forward() {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, 21);
        p.prune_global(0.75, 0.05).unwrap();
        let model =
            Arc::new(CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap());
        let be = NativeSparseBackend::new(Arc::clone(&model)).unwrap();
        let a = SyntheticRuntime::stripe_image(2);
        let b = SyntheticRuntime::stripe_image(7);
        let x: Vec<f32> = [a.clone(), b.clone()].concat();
        let logits = be.infer_padded(&x, 2).unwrap();
        assert_eq!(logits.len(), 2 * NUM_CLASSES);
        assert_eq!(&logits[..10], &model.forward(&a).unwrap()[..]);
        assert_eq!(&logits[10..], &model.forward(&b).unwrap()[..]);
        assert!(be.label().starts_with("native/"));
        assert!(be.infer_padded(&x, 3).is_err());
    }

    #[test]
    fn pooled_backend_matches_serial_backend() {
        let g = lenet5();
        let mut p = ModelParams::synthetic(&g, 23);
        p.prune_global(0.7, 0.05).unwrap();
        let model =
            Arc::new(CompiledModel::compile_sparse(&g, &p, &KernelSpec::default()).unwrap());
        let serial = NativeSparseBackend::new(Arc::clone(&model)).unwrap();
        let pooled = NativeSparseBackend::with_workers(Arc::clone(&model), 3).unwrap();
        assert_eq!(serial.workers(), 0);
        assert_eq!(pooled.workers(), 3);
        assert!(pooled.label().starts_with("native+3w/"));
        for n in [1usize, 2, 8, 11] {
            let x: Vec<f32> = (0..n).flat_map(SyntheticRuntime::stripe_image).collect();
            assert_eq!(
                pooled.infer_padded(&x, n).unwrap(),
                serial.infer_padded(&x, n).unwrap(),
                "batch {n} diverged between pooled and serial backends"
            );
        }
    }

    #[test]
    fn non_serving_shapes_are_rejected() {
        let g = convnet(2, 8, 32, 10);
        let p = ModelParams::synthetic(&g, 22);
        let model =
            Arc::new(CompiledModel::compile_dense(&g, &p, &KernelSpec::default()).unwrap());
        assert!(NativeSparseBackend::new(model).is_err());
    }
}
