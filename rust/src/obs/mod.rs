//! First-party observability plane: tracing + metrics, zero dependencies.
//!
//! Two layers, both built so the serving hot path never blocks on an
//! observer:
//!
//! - [`trace`] — per-request lifecycle events (admitted / shed /
//!   enqueued / dispatched / stolen / pipeline group enter+exit /
//!   completed) recorded into bounded lock-free event rings
//!   (drop-oldest, with an explicit dropped-event count), assembled
//!   post-hoc into spans and exported as Chrome trace-event JSON plus a
//!   compact arrival-schedule capture that round-trips through
//!   [`crate::traffic::Traffic::replay`].
//! - [`metrics`] — an atomics-only registry of counters, polled gauges
//!   and log-bucketed histograms that the serving-plane stats structs
//!   plumb onto, so one scrape covers sheds, ring depth/backoffs/steals,
//!   pipeline occupancy and latency in a single snapshot.
//!
//! [`ObsConfig`] bundles both behind `Option`s: the default config is
//! fully off and costs nothing on any path.

pub mod metrics;
pub mod trace;

use std::sync::Arc;

/// Observability wiring for a serving plane: both members optional,
/// default fully off. Cloning shares the underlying sinks.
#[derive(Clone, Default)]
pub struct ObsConfig {
    /// Event-ring tracer; `None` disables all event recording.
    pub tracer: Option<Arc<trace::Tracer>>,
    /// Metrics registry; `None` leaves stats on private atomics.
    pub metrics: Option<Arc<metrics::Registry>>,
}

impl ObsConfig {
    /// True when neither a tracer nor a registry is attached.
    pub fn is_off(&self) -> bool {
        self.tracer.is_none() && self.metrics.is_none()
    }
}

impl std::fmt::Debug for ObsConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ObsConfig")
            .field("tracer", &self.tracer.is_some())
            .field("metrics", &self.metrics.is_some())
            .finish()
    }
}
