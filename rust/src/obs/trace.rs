//! Per-request lifecycle tracing on bounded lock-free event rings.
//!
//! Recording is three relaxed atomic stores plus one `fetch_add` — no
//! locks, no allocation, no branches on ring state. Each ring is a
//! fixed-capacity array of 3-word slots claimed by a monotone head
//! counter; when the ring wraps, the oldest events are overwritten and
//! counted in [`EventRing::dropped_events`]. The hot path therefore
//! never blocks and never grows memory, at the price of best-effort
//! retention under overload (drops are explicit, never silent).
//!
//! Assembly is strictly post-hoc: [`Tracer::events`] decodes every
//! ring after the serving threads have quiesced (join = happens-before,
//! so no torn reads on live slots), [`Tracer::chrome_trace`] turns the
//! decoded stream into Chrome trace-event JSON, and
//! [`Tracer::arrival_schedule`] projects the admitted events into the
//! per-tag offset vectors that [`crate::traffic::Traffic::replay`]
//! consumes — live capture → deterministic replay.
//!
//! Sampling is a pure function of the request id (a multiplicative
//! hash modulo 1000 against the configured permille), so every ring
//! makes the same keep/drop decision for a request without shared
//! state. Shed events are always recorded regardless of the sample
//! rate: overload is precisely when observability matters most.

use crate::util::error::Result;
use crate::util::json::{self, Value};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Validation marker stored in the top byte of a slot's packed word.
const MARKER: u64 = 0xA5;

/// What happened to a request (or pipeline frame) at one instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Admission gate accepted the request.
    Admitted = 1,
    /// Admission gate shed the request at the shared host bound.
    ShedHost = 2,
    /// Admission gate shed the request at its per-tag budget.
    ShedBudget = 3,
    /// Batcher pulled the request off the submit channel.
    Enqueued = 4,
    /// Batcher flushed the request to an engine work ring.
    Dispatched = 5,
    /// An idle engine stole the batch holding this request.
    Stolen = 6,
    /// A pipeline-group worker started a frame (group/replica set).
    GroupEnter = 7,
    /// A pipeline-group worker finished a frame (group/replica set).
    GroupExit = 8,
    /// Response delivered back to the client.
    Completed = 9,
    /// Engine failed the batch holding this request.
    Failed = 10,
}

impl EventKind {
    fn from_u8(v: u8) -> Option<EventKind> {
        Some(match v {
            1 => EventKind::Admitted,
            2 => EventKind::ShedHost,
            3 => EventKind::ShedBudget,
            4 => EventKind::Enqueued,
            5 => EventKind::Dispatched,
            6 => EventKind::Stolen,
            7 => EventKind::GroupEnter,
            8 => EventKind::GroupExit,
            9 => EventKind::Completed,
            10 => EventKind::Failed,
            _ => return None,
        })
    }

    /// Short lowercase name used in exports.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Admitted => "admitted",
            EventKind::ShedHost => "shed_host",
            EventKind::ShedBudget => "shed_budget",
            EventKind::Enqueued => "enqueued",
            EventKind::Dispatched => "dispatched",
            EventKind::Stolen => "stolen",
            EventKind::GroupEnter => "group_enter",
            EventKind::GroupExit => "group_exit",
            EventKind::Completed => "completed",
            EventKind::Failed => "failed",
        }
    }
}

/// Bounded lock-free MPSC event ring: 3 `u64` words per slot
/// (request id, timestamp in µs from the tracer origin, packed
/// marker/kind/tag/group/replica), drop-oldest on wrap.
pub struct EventRing {
    words: Vec<AtomicU64>,
    head: AtomicU64,
    capacity: u64,
}

impl EventRing {
    fn new(capacity: usize) -> EventRing {
        let capacity = capacity.max(16);
        let mut words = Vec::with_capacity(capacity * 3);
        for _ in 0..capacity * 3 {
            words.push(AtomicU64::new(0));
        }
        EventRing { words, head: AtomicU64::new(0), capacity: capacity as u64 }
    }

    /// Record one event. Never blocks: a full ring overwrites its
    /// oldest slot and the loss shows up in [`EventRing::dropped_events`].
    pub fn record(&self, kind: EventKind, req_id: u64, ts_us: u64, tag: u16, group: u16, replica: u16) {
        let idx = self.head.fetch_add(1, Ordering::Relaxed) % self.capacity;
        let base = (idx * 3) as usize;
        let packed = (MARKER << 56)
            | ((kind as u64) << 48)
            | ((tag as u64) << 32)
            | ((group as u64) << 16)
            | replica as u64;
        self.words[base].store(req_id, Ordering::Relaxed);
        self.words[base + 1].store(ts_us, Ordering::Relaxed);
        self.words[base + 2].store(packed, Ordering::Relaxed);
    }

    /// Events recorded over the ring's lifetime (including dropped).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Events lost to drop-oldest overwrite.
    pub fn dropped_events(&self) -> u64 {
        self.recorded().saturating_sub(self.capacity)
    }

    /// Decode the retained slots. Call only after the writing threads
    /// have quiesced: during a wrap two writers may interleave on one
    /// slot, so the decoder validates the marker byte and kind and
    /// skips anything implausible rather than trusting every word.
    fn decode(&self, out: &mut Vec<RawEvent>, ring: usize) {
        let head = self.recorded();
        let live = head.min(self.capacity);
        for i in 0..live {
            let base = (i * 3) as usize;
            let packed = self.words[base + 2].load(Ordering::Relaxed);
            if packed >> 56 != MARKER {
                continue;
            }
            let Some(kind) = EventKind::from_u8((packed >> 48) as u8) else {
                continue;
            };
            out.push(RawEvent {
                ring,
                req_id: self.words[base].load(Ordering::Relaxed),
                ts_us: self.words[base + 1].load(Ordering::Relaxed),
                kind,
                tag: (packed >> 32) as u16,
                group: (packed >> 16) as u16,
                replica: packed as u16,
            });
        }
    }
}

/// One decoded event, with the index of the ring that recorded it.
#[derive(Clone, Copy, Debug)]
pub struct RawEvent {
    /// Index of the recording ring in registration order.
    pub ring: usize,
    /// Request id (or pipeline frame sequence for group events).
    pub req_id: u64,
    /// Microseconds since the tracer origin.
    pub ts_us: u64,
    /// What happened.
    pub kind: EventKind,
    /// Interned model-tag id ([`Tracer::tag_name`] resolves it).
    pub tag: u16,
    /// Pipeline group index (group events only).
    pub group: u16,
    /// Pipeline replica index (group events only).
    pub replica: u16,
}

/// Cloneable recording endpoint bound to one ring. Cheap to clone and
/// to pass into worker threads; all clones share the ring.
#[derive(Clone)]
pub struct TraceHandle {
    ring: Arc<EventRing>,
    origin: Instant,
    sample_permille: u32,
}

impl TraceHandle {
    /// Deterministic sampling predicate: same answer for the same id on
    /// every ring, no shared state. 1000 permille keeps everything.
    pub fn sampled(&self, req_id: u64) -> bool {
        if self.sample_permille >= 1000 {
            return true;
        }
        // Multiplicative hash so consecutive ids spread uniformly.
        let h = req_id.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32;
        (h % 1000) as u32 < self.sample_permille
    }

    /// Record a full event now (timestamps itself).
    pub fn record(&self, kind: EventKind, req_id: u64, tag: u16, group: u16, replica: u16) {
        let ts = self.origin.elapsed().as_micros() as u64;
        self.ring.record(kind, req_id, ts, tag, group, replica);
    }

    /// Record a request-lifecycle event if the request is sampled.
    /// Sheds are always recorded: overload is when traces matter.
    pub fn request(&self, kind: EventKind, req_id: u64, tag: u16) {
        let always = matches!(kind, EventKind::ShedHost | EventKind::ShedBudget);
        if always || self.sampled(req_id) {
            self.record(kind, req_id, tag, 0, 0);
        }
    }
}

/// Trace collector: owns the rings, the tag interner and the export
/// logic. Create one per `serve` run and share it via `Arc`.
pub struct Tracer {
    origin: Instant,
    sample_permille: u32,
    ring_capacity: usize,
    rings: Mutex<Vec<(String, Arc<EventRing>)>>,
    tags: Mutex<Vec<String>>,
}

/// Default per-ring capacity in events (3 words each).
pub const DEFAULT_RING_CAPACITY: usize = 65_536;

impl Tracer {
    /// Tracer keeping `sample_rate` (0.0..=1.0) of requests, with the
    /// default per-ring capacity.
    pub fn new(sample_rate: f64) -> Arc<Tracer> {
        Tracer::with_capacity(sample_rate, DEFAULT_RING_CAPACITY)
    }

    /// Tracer with an explicit per-ring event capacity (min 16).
    pub fn with_capacity(sample_rate: f64, ring_capacity: usize) -> Arc<Tracer> {
        let permille = (sample_rate.clamp(0.0, 1.0) * 1000.0).round() as u32;
        Arc::new(Tracer {
            origin: Instant::now(),
            sample_permille: permille,
            ring_capacity: ring_capacity.max(16),
            rings: Mutex::new(Vec::new()),
            tags: Mutex::new(Vec::new()),
        })
    }

    /// The configured sample rate, as a fraction.
    pub fn sample_rate(&self) -> f64 {
        self.sample_permille as f64 / 1000.0
    }

    /// Register a new ring (one per recording thread or shared MPSC
    /// point). Registration takes a lock — do it at wiring time, not on
    /// the hot path; recording through the returned handle is lock-free.
    pub fn register(&self, label: &str) -> TraceHandle {
        let ring = Arc::new(EventRing::new(self.ring_capacity));
        self.rings.lock().unwrap().push((label.to_string(), Arc::clone(&ring)));
        TraceHandle { ring, origin: self.origin, sample_permille: self.sample_permille }
    }

    /// Intern a model tag, returning its compact id for event words.
    pub fn intern(&self, tag: &str) -> u16 {
        let mut tags = self.tags.lock().unwrap();
        if let Some(i) = tags.iter().position(|t| t == tag) {
            return i as u16;
        }
        tags.push(tag.to_string());
        (tags.len() - 1) as u16
    }

    /// Resolve an interned tag id back to its name.
    pub fn tag_name(&self, id: u16) -> String {
        self.tags
            .lock()
            .unwrap()
            .get(id as usize)
            .cloned()
            .unwrap_or_else(|| format!("tag{id}"))
    }

    /// Total events lost to drop-oldest overwrite across all rings.
    pub fn dropped_events(&self) -> u64 {
        self.rings.lock().unwrap().iter().map(|(_, r)| r.dropped_events()).sum()
    }

    /// Total events recorded across all rings (including dropped).
    pub fn recorded_events(&self) -> u64 {
        self.rings.lock().unwrap().iter().map(|(_, r)| r.recorded()).sum()
    }

    /// Decode every ring into one time-sorted event stream. Post-hoc
    /// only: call after the serving plane has shut down.
    pub fn events(&self) -> Vec<RawEvent> {
        let rings = self.rings.lock().unwrap();
        let mut out = Vec::new();
        for (i, (_, ring)) in rings.iter().enumerate() {
            ring.decode(&mut out, i);
        }
        out.sort_by_key(|e| (e.ts_us, e.ring, e.req_id));
        out
    }

    /// Per-tag arrival schedule captured from the admitted events:
    /// `(tag, offsets_s)` with offsets relative to the first admission
    /// overall (so inter-tag phasing survives the round trip). Feed
    /// each vector to [`crate::traffic::Traffic::replay`].
    pub fn arrival_schedule(&self) -> Vec<(String, Vec<f64>)> {
        let events = self.events();
        let t0 = events
            .iter()
            .filter(|e| e.kind == EventKind::Admitted)
            .map(|e| e.ts_us)
            .min()
            .unwrap_or(0);
        let mut per_tag: Vec<(u16, Vec<f64>)> = Vec::new();
        for e in &events {
            if e.kind != EventKind::Admitted {
                continue;
            }
            let off = (e.ts_us - t0) as f64 / 1e6;
            match per_tag.iter_mut().find(|(t, _)| *t == e.tag) {
                Some((_, v)) => v.push(off),
                None => per_tag.push((e.tag, vec![off])),
            }
        }
        per_tag
            .into_iter()
            .map(|(t, mut v)| {
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                (self.tag_name(t), v)
            })
            .collect()
    }

    /// Latency breakdown over the sampled requests that completed:
    /// mean queue (enqueued→dispatched), exec (dispatched→completed)
    /// and total (admitted→completed) in µs, plus the span count.
    pub fn stage_breakdown(&self) -> StageBreakdown {
        let mut spans: Vec<(u64, Span)> = Vec::new();
        for e in self.events() {
            let span = match spans.iter_mut().find(|(id, _)| *id == e.req_id) {
                Some((_, s)) => s,
                None => {
                    spans.push((e.req_id, Span::default()));
                    &mut spans.last_mut().unwrap().1
                }
            };
            match e.kind {
                EventKind::Admitted => span.admitted = Some(e.ts_us),
                EventKind::Enqueued => span.enqueued = Some(e.ts_us),
                EventKind::Dispatched => span.dispatched = Some(e.ts_us),
                EventKind::Completed => span.completed = Some(e.ts_us),
                _ => {}
            }
        }
        let mut b = StageBreakdown::default();
        for (_, s) in &spans {
            let (Some(a), Some(c)) = (s.admitted, s.completed) else { continue };
            b.spans += 1;
            b.total_us += c.saturating_sub(a) as f64;
            if let (Some(e), Some(d)) = (s.enqueued, s.dispatched) {
                b.queue_us += d.saturating_sub(e) as f64;
            }
            if let Some(d) = s.dispatched {
                b.exec_us += c.saturating_sub(d) as f64;
            }
        }
        if b.spans > 0 {
            let n = b.spans as f64;
            b.queue_us /= n;
            b.exec_us /= n;
            b.total_us /= n;
        }
        b
    }

    /// Build the Chrome trace-event document (`chrome://tracing` /
    /// Perfetto "JSON object format"): per-request `X` spans for
    /// request/queue/exec on per-request lanes, `i` instants for sheds
    /// and steals, pipeline group/replica `X` spans on the recording
    /// worker's lane, and ring accounting under `otherData`
    /// (including `dropped_events` and the arrival capture).
    pub fn chrome_trace(&self) -> Value {
        let events = self.events();
        let rings = self.rings.lock().unwrap();
        // Lane map: 0..n_rings are the recording threads (pipeline +
        // instant events), REQ_LANES lanes above that carry request
        // spans so concurrent requests don't visually overlap.
        const REQ_BASE: u64 = 1000;
        const REQ_LANES: u64 = 32;
        let mut out: Vec<Value> = Vec::new();
        for (i, (label, _)) in rings.iter().enumerate() {
            push_meta(&mut out, i as u64, format!("ring:{label}"));
        }
        for lane in 0..REQ_LANES {
            push_meta(&mut out, REQ_BASE + lane, format!("requests[{lane}]"));
        }
        drop(rings);

        // Request spans: one pass groups the lifecycle per req id.
        let mut spans: Vec<(u64, u16, Span)> = Vec::new();
        // Pipeline group spans: keyed by (ring, seq, group, replica);
        // enter/exit pair up in ring order.
        let mut opens: Vec<(usize, u64, u16, u16, u64)> = Vec::new();
        for e in &events {
            match e.kind {
                EventKind::Admitted
                | EventKind::Enqueued
                | EventKind::Dispatched
                | EventKind::Completed
                | EventKind::Failed => {
                    let s = match spans.iter_mut().find(|(id, _, _)| *id == e.req_id) {
                        Some((_, _, s)) => s,
                        None => {
                            spans.push((e.req_id, e.tag, Span::default()));
                            &mut spans.last_mut().unwrap().2
                        }
                    };
                    match e.kind {
                        EventKind::Admitted => s.admitted = Some(e.ts_us),
                        EventKind::Enqueued => s.enqueued = Some(e.ts_us),
                        EventKind::Dispatched => s.dispatched = Some(e.ts_us),
                        EventKind::Completed => s.completed = Some(e.ts_us),
                        EventKind::Failed => s.failed = true,
                        _ => unreachable!(),
                    }
                }
                EventKind::ShedHost | EventKind::ShedBudget | EventKind::Stolen => {
                    out.push(json::obj(vec![
                        ("name", json::s(e.kind.name())),
                        ("cat", json::s("overload")),
                        ("ph", json::s("i")),
                        ("s", json::s("t")),
                        ("ts", Value::Num(e.ts_us as f64)),
                        ("pid", Value::Num(0.0)),
                        ("tid", Value::Num(e.ring as f64)),
                        (
                            "args",
                            json::obj(vec![
                                ("req", Value::Num(e.req_id as f64)),
                                ("tag", json::s(self.tag_name(e.tag))),
                            ]),
                        ),
                    ]));
                }
                EventKind::GroupEnter => {
                    opens.push((e.ring, e.req_id, e.group, e.replica, e.ts_us));
                }
                EventKind::GroupExit => {
                    if let Some(i) = opens.iter().position(|&(r, s, g, rep, _)| {
                        r == e.ring && s == e.req_id && g == e.group && rep == e.replica
                    }) {
                        let (_, seq, g, rep, t0) = opens.remove(i);
                        push_x(
                            &mut out,
                            format!("g{g}/r{rep}"),
                            "pipeline",
                            e.ring as u64,
                            t0,
                            e.ts_us,
                            vec![
                                ("frame", Value::Num(seq as f64)),
                                ("group", Value::Num(g as f64)),
                                ("replica", Value::Num(rep as f64)),
                            ],
                        );
                    }
                }
            }
        }
        for (id, tag, s) in &spans {
            let Some(t_adm) = s.admitted else { continue };
            let Some(t_done) = s.completed else { continue };
            let lane = REQ_BASE + id % REQ_LANES;
            let tag = self.tag_name(*tag);
            push_x(
                &mut out,
                format!("request {tag}#{id}"),
                if s.failed { "request-failed" } else { "request" },
                lane,
                t_adm,
                t_done,
                vec![("tag", json::s(&*tag))],
            );
            if let (Some(e), Some(d)) = (s.enqueued, s.dispatched) {
                push_x(&mut out, "queue".to_string(), "stage", lane, e, d, vec![]);
            }
            if let Some(d) = s.dispatched {
                push_x(&mut out, "exec".to_string(), "stage", lane, d, t_done, vec![]);
            }
        }
        // chrome://tracing tolerates any order, but the CI validator
        // (and humans reading the file) want per-lane monotone time.
        out.sort_by(|a, b| {
            let key = |v: &Value| {
                let tid = v.get("tid").and_then(Value::as_f64).unwrap_or(0.0) as u64;
                let ts = v.get("ts").and_then(Value::as_f64).unwrap_or(0.0) as u64;
                (tid, ts)
            };
            key(a).cmp(&key(b))
        });

        let rings = self.rings.lock().unwrap();
        let ring_info: Vec<Value> = rings
            .iter()
            .map(|(label, r)| {
                json::obj(vec![
                    ("label", json::s(label.as_str())),
                    ("recorded", Value::Num(r.recorded() as f64)),
                    ("dropped", Value::Num(r.dropped_events() as f64)),
                ])
            })
            .collect();
        drop(rings);
        let arrivals: Vec<(String, Value)> = self
            .arrival_schedule()
            .into_iter()
            .map(|(tag, offs)| (tag, Value::Arr(offs.into_iter().map(Value::Num).collect())))
            .collect();
        json::obj(vec![
            ("traceEvents", Value::Arr(out)),
            ("displayTimeUnit", json::s("ms")),
            (
                "otherData",
                json::obj(vec![
                    ("dropped_events", Value::Num(self.dropped_events() as f64)),
                    ("sample_rate", Value::Num(self.sample_rate())),
                    ("rings", Value::Arr(ring_info)),
                    ("arrivals", Value::Obj(arrivals)),
                ]),
            ),
        ])
    }

    /// Write the Chrome trace-event document to `path`.
    pub fn write_chrome(&self, path: &str) -> Result<()> {
        json::write_file(path, &self.chrome_trace())
    }
}

/// Append a Chrome `M` thread-name metadata event.
fn push_meta(out: &mut Vec<Value>, tid: u64, name: String) {
    out.push(json::obj(vec![
        ("name", json::s("thread_name")),
        ("ph", json::s("M")),
        ("pid", Value::Num(0.0)),
        ("tid", Value::Num(tid as f64)),
        ("args", json::obj(vec![("name", json::s(name))])),
    ]));
}

/// Append a Chrome `X` complete event spanning `t0..t1` µs.
fn push_x(
    out: &mut Vec<Value>,
    name: String,
    cat: &str,
    tid: u64,
    t0: u64,
    t1: u64,
    args: Vec<(&str, Value)>,
) {
    out.push(json::obj(vec![
        ("name", json::s(name)),
        ("cat", json::s(cat)),
        ("ph", json::s("X")),
        ("ts", Value::Num(t0 as f64)),
        ("dur", Value::Num(t1.saturating_sub(t0) as f64)),
        ("pid", Value::Num(0.0)),
        ("tid", Value::Num(tid as f64)),
        ("args", json::obj(args)),
    ]));
}

/// Per-request lifecycle timestamps assembled from the event stream.
#[derive(Clone, Copy, Default)]
struct Span {
    admitted: Option<u64>,
    enqueued: Option<u64>,
    dispatched: Option<u64>,
    completed: Option<u64>,
    failed: bool,
}

/// Mean per-stage latency over the completed sampled requests.
#[derive(Clone, Copy, Debug, Default)]
pub struct StageBreakdown {
    /// Completed request spans the means were taken over.
    pub spans: usize,
    /// Mean enqueued→dispatched wait in the batcher, µs.
    pub queue_us: f64,
    /// Mean dispatched→completed engine time, µs.
    pub exec_us: f64,
    /// Mean admitted→completed end-to-end latency, µs.
    pub total_us: f64,
}
