//! Atomics-only metrics registry: counters, polled gauges and
//! log-bucketed histograms behind one scrape.
//!
//! The design keeps the hot path free of observer cost:
//!
//! - **Counters** are plain `Arc<AtomicU64>`s handed out by
//!   [`Registry::counter`]. The serving-plane stats structs hold the
//!   same `Arc` they always incremented — registering a counter adds a
//!   name to the scrape, not a write to the hot path.
//! - **Gauges** are closures evaluated at scrape time
//!   ([`Registry::gauge_fn`]): in-flight, ring depth, pipeline
//!   occupancy etc. are *read* when somebody asks, never *pushed*.
//! - **Histograms** are fixed arrays of power-of-two latency buckets
//!   ([`Histogram`]): one `fetch_add` per observation, no locks, no
//!   allocation, quantiles reconstructed from bucket upper bounds.
//! - **Labels** are static strings (kernel flavour per layer, datapath
//!   tier) attached once at wiring time.
//!
//! [`Registry::snapshot`] freezes everything into a
//! [`MetricsSnapshot`] that renders as sorted `name value` text or as
//! a JSON object — the single scrape surface the CLI's
//! `--metrics-interval` thread prints.

use crate::util::json::{self, Value};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Number of power-of-two histogram buckets: bucket `i` holds
/// observations with `value < 2^i` µs (cap ~ 2^39 µs ≈ 9 days).
pub const HIST_BUCKETS: usize = 40;

/// Lock-free log₂-bucketed histogram of `u64` observations (µs by
/// convention). One `fetch_add` per record.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
}

impl Histogram {
    fn new() -> Histogram {
        let mut buckets = Vec::with_capacity(HIST_BUCKETS);
        for _ in 0..HIST_BUCKETS {
            buckets.push(AtomicU64::new(0));
        }
        Histogram { buckets, count: AtomicU64::new(0), sum: AtomicU64::new(0) }
    }

    /// Record one observation.
    pub fn record(&self, value: u64) {
        let idx = (64 - value.leading_zeros() as usize).min(HIST_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
    }

    /// Freeze the current bucket counts.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// Immutable view of a [`Histogram`] at one instant.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    /// Per-bucket counts; bucket `i` holds values `< 2^i`.
    pub buckets: Vec<u64>,
    /// Total observations.
    pub count: u64,
    /// Sum of all observed values.
    pub sum: u64,
}

impl HistogramSnapshot {
    /// Mean observed value (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate quantile: the upper bound of the bucket holding the
    /// q-th observation. Resolution is one power of two — good enough
    /// to spot order-of-magnitude latency shifts from a scrape.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= rank {
                return (1u64 << i.min(63)) as f64;
            }
        }
        (1u64 << (HIST_BUCKETS - 1)) as f64
    }
}

/// Polled gauge: evaluated at scrape time, zero hot-path cost.
type GaugeFn = Arc<dyn Fn() -> f64 + Send + Sync>;

#[derive(Default)]
struct RegistryInner {
    counters: Vec<(String, Arc<AtomicU64>)>,
    gauges: Vec<(String, GaugeFn)>,
    hists: Vec<(String, Arc<Histogram>)>,
    labels: Vec<(String, String)>,
}

/// Unified metrics registry. Registration (get-or-create by name)
/// takes a lock — wiring time only; all recording afterwards is
/// straight atomics on the handed-out `Arc`s.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<RegistryInner>,
}

impl Registry {
    /// Fresh empty registry.
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry::default())
    }

    /// Get or create the counter `name`. The returned `Arc` is the
    /// live cell: incrementing it is the single write path, the scrape
    /// reads the same atomic.
    pub fn counter(&self, name: &str) -> Arc<AtomicU64> {
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, c)) = inner.counters.iter().find(|(n, _)| n == name) {
            return Arc::clone(c);
        }
        let c = Arc::new(AtomicU64::new(0));
        inner.counters.push((name.to_string(), Arc::clone(&c)));
        c
    }

    /// Register (or replace) the polled gauge `name`. The closure runs
    /// on the scraping thread at snapshot time.
    pub fn gauge_fn(&self, name: &str, f: impl Fn() -> f64 + Send + Sync + 'static) {
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, g)) = inner.gauges.iter_mut().find(|(n, _)| n == name) {
            *g = Arc::new(f);
            return;
        }
        inner.gauges.push((name.to_string(), Arc::new(f)));
    }

    /// Get or create the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, h)) = inner.hists.iter().find(|(n, _)| n == name) {
            return Arc::clone(h);
        }
        let h = Arc::new(Histogram::new());
        inner.hists.push((name.to_string(), Arc::clone(&h)));
        h
    }

    /// Attach (or overwrite) a static text label, e.g. the kernel
    /// flavour chosen for a layer or the active datapath tier.
    pub fn label(&self, name: &str, value: &str) {
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, v)) = inner.labels.iter_mut().find(|(n, _)| n == name) {
            *v = value.to_string();
            return;
        }
        inner.labels.push((name.to_string(), value.to_string()));
    }

    /// One scrape: counters and labels copied, gauges evaluated,
    /// histograms frozen. Sorted by name for stable output.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        let mut counters: Vec<(String, u64)> = inner
            .counters
            .iter()
            .map(|(n, c)| (n.clone(), c.load(Ordering::Relaxed)))
            .collect();
        let mut gauges: Vec<(String, f64)> =
            inner.gauges.iter().map(|(n, g)| (n.clone(), g())).collect();
        let mut hists: Vec<(String, HistogramSnapshot)> =
            inner.hists.iter().map(|(n, h)| (n.clone(), h.snapshot())).collect();
        let mut labels: Vec<(String, String)> = inner.labels.clone();
        drop(inner);
        counters.sort_by(|a, b| a.0.cmp(&b.0));
        gauges.sort_by(|a, b| a.0.cmp(&b.0));
        hists.sort_by(|a, b| a.0.cmp(&b.0));
        labels.sort_by(|a, b| a.0.cmp(&b.0));
        MetricsSnapshot { counters, gauges, hists, labels }
    }
}

/// Frozen scrape of a [`Registry`].
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    /// Counter values, name-sorted.
    pub counters: Vec<(String, u64)>,
    /// Gauge values (closures evaluated at snapshot), name-sorted.
    pub gauges: Vec<(String, f64)>,
    /// Histogram snapshots, name-sorted.
    pub hists: Vec<(String, HistogramSnapshot)>,
    /// Static labels, name-sorted.
    pub labels: Vec<(String, String)>,
}

impl MetricsSnapshot {
    /// Plain-text scrape: one `name value` line per series, suitable
    /// for the `--metrics-interval` console feed.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (n, v) in &self.counters {
            out.push_str(&format!("{n} {v}\n"));
        }
        for (n, v) in &self.gauges {
            out.push_str(&format!("{n} {v:.3}\n"));
        }
        for (n, h) in &self.hists {
            out.push_str(&format!(
                "{n}_count {} | mean {:.0} | p50 {:.0} | p99 {:.0}\n",
                h.count,
                h.mean(),
                h.quantile(0.5),
                h.quantile(0.99)
            ));
        }
        for (n, v) in &self.labels {
            out.push_str(&format!("{n} {v}\n"));
        }
        out
    }

    /// JSON scrape mirroring [`MetricsSnapshot::render`].
    pub fn to_json(&self) -> Value {
        let counters: Vec<(String, Value)> =
            self.counters.iter().map(|(n, v)| (n.clone(), Value::Num(*v as f64))).collect();
        let gauges: Vec<(String, Value)> =
            self.gauges.iter().map(|(n, v)| (n.clone(), Value::Num(*v))).collect();
        let hists: Vec<(String, Value)> = self
            .hists
            .iter()
            .map(|(n, h)| {
                (
                    n.clone(),
                    json::obj(vec![
                        ("count", Value::Num(h.count as f64)),
                        ("mean", Value::Num(h.mean())),
                        ("p50", Value::Num(h.quantile(0.5))),
                        ("p99", Value::Num(h.quantile(0.99))),
                    ]),
                )
            })
            .collect();
        let labels: Vec<(String, Value)> =
            self.labels.iter().map(|(n, v)| (n.clone(), json::s(v.as_str()))).collect();
        json::obj(vec![
            ("counters", Value::Obj(counters)),
            ("gauges", Value::Obj(gauges)),
            ("hists", Value::Obj(hists)),
            ("labels", Value::Obj(labels)),
        ])
    }

    /// Look up a counter by exact name.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    /// Look up a gauge by exact name.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }
}
