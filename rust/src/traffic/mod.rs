//! Unified traffic model: one source of truth for arrival processes,
//! consumed by **both** the cycle-level simulator (`sim`) and the serving
//! load generator (`coordinator::loadgen`). Before this module existed the
//! simulator's `Workload` and the server's ad-hoc client loops were
//! separate worlds, so Table-I-style *measured* claims and served-traffic
//! claims could never be compared under the same arrivals.
//!
//! Two layers:
//!
//! * [`Traffic`] — the shared model. Shapes are parameterised in
//!   **seconds** ([`Shape`]); [`Traffic::schedule`] yields monotone arrival
//!   offsets that the load generator replays against the wall clock and
//!   the simulator converts to cycles via its pipeline clock
//!   ([`Traffic::to_cycles`]).
//! * [`Workload`] — the simulator-facing cycle-domain wrapper (previously
//!   defined in `sim::pipeline`, extracted here). Its variants keep their
//!   historical cycle/fps parameters; arrival generation delegates to
//!   [`Traffic`], so both consumers sample the identical process.

use crate::util::error::{Error, Result};
use crate::util::rng::Pcg32;

/// Arrival-process shape, parameterised in seconds.
#[derive(Debug, Clone)]
pub enum Shape {
    /// Every event available at t=0: back-to-back input, the saturated
    /// throughput measurement (Table I).
    Saturated,
    /// Fixed inter-arrival interval.
    Periodic { interval_s: f64 },
    /// Memoryless arrivals at `rate_eps` events per second.
    Poisson { rate_eps: f64, seed: u64 },
    /// Bursts of `size` back-to-back events separated by exponentially
    /// distributed gaps with mean `gap_s` (bursty open-loop clients).
    Burst { size: u64, gap_s: f64, seed: u64 },
    /// Replay a recorded trace of absolute offsets in seconds (sorted
    /// internally; the event count is the trace length).
    Replay { times_s: Vec<f64> },
}

/// A finite arrival process: `events` arrivals drawn from `shape`.
#[derive(Debug, Clone)]
pub struct Traffic {
    /// Number of arrivals to generate (capped by the trace length for
    /// [`Shape::Replay`]).
    pub events: u64,
    /// The arrival-process shape.
    pub shape: Shape,
}

impl Traffic {
    /// All `events` arrivals at t=0 (saturated throughput measurement).
    pub fn saturated(events: u64) -> Traffic {
        Traffic { events, shape: Shape::Saturated }
    }

    /// Fixed inter-arrival interval of `interval_s` seconds.
    pub fn periodic(events: u64, interval_s: f64) -> Traffic {
        Traffic { events, shape: Shape::Periodic { interval_s } }
    }

    /// Memoryless arrivals at `rate_eps` events/second (deterministic
    /// given `seed`).
    pub fn poisson(events: u64, rate_eps: f64, seed: u64) -> Traffic {
        Traffic { events, shape: Shape::Poisson { rate_eps, seed } }
    }

    /// Bursts of `size` back-to-back events, mean `gap_s` seconds apart.
    pub fn bursty(events: u64, size: u64, gap_s: f64, seed: u64) -> Traffic {
        Traffic { events, shape: Shape::Burst { size, gap_s, seed } }
    }

    /// Replay a recorded trace of absolute offsets in seconds.
    pub fn replay(times_s: Vec<f64>) -> Traffic {
        Traffic { events: times_s.len() as u64, shape: Shape::Replay { times_s } }
    }

    /// Number of arrivals this model will generate.
    pub fn events(&self) -> u64 {
        match &self.shape {
            Shape::Replay { times_s } => self.events.min(times_s.len() as u64),
            _ => self.events,
        }
    }

    /// Monotone non-decreasing arrival offsets in seconds, starting at or
    /// after 0. Deterministic given the shape (seeds included).
    pub fn schedule(&self) -> Vec<f64> {
        let n = self.events();
        match &self.shape {
            Shape::Saturated => vec![0.0; n as usize],
            Shape::Periodic { interval_s } => {
                (0..n).map(|k| k as f64 * interval_s).collect()
            }
            Shape::Poisson { rate_eps, seed } => {
                assert!(*rate_eps > 0.0, "poisson rate must be > 0");
                let mut rng = Pcg32::seeded(*seed);
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        t += rng.exp(*rate_eps);
                        t
                    })
                    .collect()
            }
            Shape::Burst { size, gap_s, seed } => {
                assert!(*size >= 1, "burst size must be >= 1");
                let mut rng = Pcg32::seeded(*seed);
                let mut t = 0.0;
                (0..n)
                    .map(|k| {
                        if k > 0 && k % size == 0 {
                            t += if *gap_s > 0.0 { rng.exp(1.0 / gap_s) } else { 0.0 };
                        }
                        t
                    })
                    .collect()
            }
            Shape::Replay { times_s } => {
                let mut ts: Vec<f64> = times_s[..n as usize].to_vec();
                ts.sort_by(|a, b| a.partial_cmp(b).expect("NaN in replay trace"));
                ts
            }
        }
    }

    /// The schedule in cycles of a clock running at `f_mhz` MHz — what the
    /// cycle simulator feeds its source actor.
    pub fn to_cycles(&self, f_mhz: f64) -> Vec<u64> {
        let hz = f_mhz * 1e6;
        self.schedule().iter().map(|&t| (t * hz).round().max(0.0) as u64).collect()
    }
}

/// One arrival of a merged multi-stream schedule ([`Mix::schedule`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MixArrival {
    /// Arrival offset in seconds (monotone across the merged schedule).
    pub at_s: f64,
    /// Index of the stream (mix order) this arrival belongs to.
    pub stream: usize,
}

/// One stream of a [`Mix`]: a tagged arrival process plus the wall-clock
/// offset it phases in at. `start_s > 0` models a tag that **joins
/// mid-run** (e.g. a model registered on a live host): its arrivals are
/// the underlying [`Traffic`] schedule shifted wholesale by the offset.
#[derive(Debug, Clone)]
pub struct MixStream {
    /// The model tag this stream submits against.
    pub tag: String,
    /// The stream's arrival process.
    pub traffic: Traffic,
    /// Offset (seconds) added to every arrival of this stream.
    pub start_s: f64,
}

/// A heterogeneous traffic mix: one named arrival process per stream
/// (model tag), merged into a single monotone wall-clock schedule — what
/// the multi-model load generator
/// (`coordinator::loadgen::run_open_loop_mix`) replays against a serving
/// fleet, so per-tag offered load stays exactly the per-stream [`Traffic`]
/// while the host sees the interleaved aggregate. Streams may be
/// phase-shifted ([`Mix::stream_at`]) to model tags joining mid-run.
#[derive(Debug, Clone, Default)]
pub struct Mix {
    streams: Vec<MixStream>,
}

impl Mix {
    /// An empty mix; add streams with [`Mix::stream`] /
    /// [`Mix::stream_at`].
    pub fn new() -> Mix {
        Mix::default()
    }

    /// Add one `(tag, traffic)` stream starting at t=0 (builder-style).
    pub fn stream(mut self, tag: impl Into<String>, traffic: Traffic) -> Mix {
        self.streams.push(MixStream { tag: tag.into(), traffic, start_s: 0.0 });
        self
    }

    /// Add one stream whose arrivals are phase-shifted by `start_s`
    /// seconds — the tag joins the run at that offset (builder-style).
    pub fn stream_at(
        mut self,
        tag: impl Into<String>,
        traffic: Traffic,
        start_s: f64,
    ) -> Mix {
        assert!(start_s >= 0.0, "stream offset must be >= 0");
        self.streams.push(MixStream { tag: tag.into(), traffic, start_s });
        self
    }

    /// The streams, in insertion order.
    pub fn streams(&self) -> &[MixStream] {
        &self.streams
    }

    /// Total arrivals across all streams.
    pub fn events(&self) -> u64 {
        self.streams.iter().map(|s| s.traffic.events()).sum()
    }

    /// The merged schedule: every stream's [`Traffic::schedule`]
    /// (shifted by its `start_s`) interleaved into one monotone-by-time
    /// sequence. Ties break by stream order (stable), so the merge is
    /// deterministic.
    pub fn schedule(&self) -> Vec<MixArrival> {
        let per_stream: Vec<Vec<f64>> = self
            .streams
            .iter()
            .map(|s| {
                let mut ts = s.traffic.schedule();
                if s.start_s > 0.0 {
                    for t in &mut ts {
                        *t += s.start_s;
                    }
                }
                ts
            })
            .collect();
        let mut cursor = vec![0usize; per_stream.len()];
        let total: usize = per_stream.iter().map(|s| s.len()).sum();
        let mut merged = Vec::with_capacity(total);
        for _ in 0..total {
            let mut best: Option<(usize, f64)> = None;
            for (k, s) in per_stream.iter().enumerate() {
                if let Some(&at) = s.get(cursor[k]) {
                    if best.map(|(_, b)| at < b).unwrap_or(true) {
                        best = Some((k, at));
                    }
                }
            }
            let (k, at_s) = best.expect("cursor accounting broke");
            cursor[k] += 1;
            merged.push(MixArrival { at_s, stream: k });
        }
        merged
    }
}

/// Cycle-domain workload for the simulator. Extracted from `sim::pipeline`
/// and re-exported there; arrival generation is shared with the serving
/// load generator through [`Traffic`].
#[derive(Debug, Clone)]
pub enum Workload {
    /// Back-to-back frames (throughput measurement — Table I).
    Saturated { frames: u64 },
    /// Fixed inter-arrival interval in cycles.
    Periodic { frames: u64, interval_cycles: u64 },
    /// Poisson arrivals at `rate_fps` given the pipeline clock.
    Poisson { frames: u64, rate_fps: f64, seed: u64 },
    /// Bursts of `burst` back-to-back frames, mean `gap_cycles` apart.
    Burst { frames: u64, burst: u64, gap_cycles: u64, seed: u64 },
    /// Replay a recorded arrival trace (cycles, sorted internally).
    Replay { arrival_cycles: Vec<u64> },
}

impl Workload {
    /// Number of frames this workload will generate.
    pub fn frames(&self) -> u64 {
        match self {
            Workload::Saturated { frames }
            | Workload::Periodic { frames, .. }
            | Workload::Poisson { frames, .. }
            | Workload::Burst { frames, .. } => *frames,
            Workload::Replay { arrival_cycles } => arrival_cycles.len() as u64,
        }
    }

    /// The equivalent time-domain [`Traffic`] under a clock of `f_mhz`.
    pub fn traffic(&self, f_mhz: f64) -> Traffic {
        let hz = f_mhz * 1e6;
        match self {
            Workload::Saturated { frames } => Traffic::saturated(*frames),
            Workload::Periodic { frames, interval_cycles } => {
                Traffic::periodic(*frames, *interval_cycles as f64 / hz)
            }
            Workload::Poisson { frames, rate_fps, seed } => {
                Traffic::poisson(*frames, *rate_fps, *seed)
            }
            Workload::Burst { frames, burst, gap_cycles, seed } => {
                Traffic::bursty(*frames, *burst, *gap_cycles as f64 / hz, *seed)
            }
            Workload::Replay { arrival_cycles } => {
                Traffic::replay(arrival_cycles.iter().map(|&c| c as f64 / hz).collect())
            }
        }
    }

    /// Arrival times in cycles (what `sim::Pipeline` consumes).
    pub fn arrivals(&self, f_mhz: f64) -> Vec<u64> {
        self.traffic(f_mhz).to_cycles(f_mhz)
    }

    /// Parse a CLI traffic spec:
    /// `saturated` | `poisson:<fps>` | `periodic:<cycles>` |
    /// `burst:<size>:<gap_cycles>`.
    pub fn parse(spec: &str, frames: u64) -> Result<Workload> {
        if spec == "saturated" {
            return Ok(Workload::Saturated { frames });
        }
        if let Some(fps) = spec.strip_prefix("poisson:") {
            let rate_fps: f64 = fps
                .parse()
                .map_err(|_| Error::config(format!("bad poisson rate '{fps}'")))?;
            if !rate_fps.is_finite() || rate_fps <= 0.0 {
                return Err(Error::config(format!(
                    "poisson rate must be a positive finite fps, got '{fps}'"
                )));
            }
            return Ok(Workload::Poisson { frames, rate_fps, seed: 7 });
        }
        if let Some(cyc) = spec.strip_prefix("periodic:") {
            let interval_cycles = cyc
                .parse()
                .map_err(|_| Error::config(format!("bad period '{cyc}'")))?;
            return Ok(Workload::Periodic { frames, interval_cycles });
        }
        if let Some(rest) = spec.strip_prefix("burst:") {
            let (size, gap) = rest
                .split_once(':')
                .ok_or_else(|| Error::config(format!("burst spec '{rest}' wants <size>:<gap_cycles>")))?;
            let burst: u64 = size
                .parse()
                .map_err(|_| Error::config(format!("bad burst size '{size}'")))?;
            if burst == 0 {
                return Err(Error::config("burst size must be >= 1"));
            }
            let gap_cycles = gap
                .parse()
                .map_err(|_| Error::config(format!("bad burst gap '{gap}'")))?;
            return Ok(Workload::Burst { frames, burst, gap_cycles, seed: 7 });
        }
        Err(Error::config(format!(
            "unknown traffic '{spec}' (saturated|poisson:<fps>|periodic:<cycles>|burst:<size>:<gap_cycles>)"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn saturated_is_all_zero() {
        let t = Traffic::saturated(5);
        assert_eq!(t.schedule(), vec![0.0; 5]);
        assert_eq!(t.to_cycles(200.0), vec![0; 5]);
    }

    #[test]
    fn periodic_cycles_roundtrip_exactly() {
        // Workload::Periodic{interval_cycles} -> seconds -> cycles must
        // land back on exact multiples of the interval.
        let wl = Workload::Periodic { frames: 100, interval_cycles: 2357 };
        let arr = wl.arrivals(212.5);
        assert_eq!(arr.len(), 100);
        for (k, &a) in arr.iter().enumerate() {
            assert_eq!(a, k as u64 * 2357, "frame {k}");
        }
    }

    #[test]
    fn poisson_is_monotone_deterministic_and_rate_accurate() {
        let t = Traffic::poisson(4000, 1000.0, 11);
        let s1 = t.schedule();
        let s2 = t.schedule();
        assert_eq!(s1, s2, "same seed must replay identically");
        assert!(s1.windows(2).all(|w| w[0] <= w[1]));
        // Mean inter-arrival ~ 1/rate.
        let mean = s1.last().unwrap() / s1.len() as f64;
        assert!((mean - 1e-3).abs() < 1e-4, "mean inter-arrival {mean}");
    }

    #[test]
    fn poisson_seeds_differ() {
        let a = Traffic::poisson(50, 1000.0, 1).schedule();
        let b = Traffic::poisson(50, 1000.0, 2).schedule();
        assert_ne!(a, b);
    }

    #[test]
    fn burst_groups_share_arrival_time() {
        let t = Traffic::bursty(12, 4, 0.01, 3);
        let s = t.schedule();
        assert_eq!(s.len(), 12);
        for chunk in s.chunks(4) {
            assert!(chunk.iter().all(|&x| x == chunk[0]), "burst not aligned");
        }
        // Gaps strictly positive between bursts.
        assert!(s[4] > s[3] && s[8] > s[7]);
        assert!(s.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn replay_sorts_and_bounds_events() {
        let t = Traffic::replay(vec![0.3, 0.1, 0.2]);
        assert_eq!(t.events(), 3);
        assert_eq!(t.schedule(), vec![0.1, 0.2, 0.3]);
        let wl = Workload::Replay { arrival_cycles: vec![300, 100, 200] };
        assert_eq!(wl.frames(), 3);
        assert_eq!(wl.arrivals(200.0), vec![100, 200, 300]);
    }

    #[test]
    fn workload_and_traffic_sample_identical_processes() {
        // The cycle-domain wrapper and the time-domain model must produce
        // the same Poisson process (same seed, same rate) up to the cycle
        // rounding — the whole point of the shared module.
        let f_mhz = 200.0;
        let wl = Workload::Poisson { frames: 64, rate_fps: 50_000.0, seed: 9 };
        let direct = Traffic::poisson(64, 50_000.0, 9).to_cycles(f_mhz);
        assert_eq!(wl.arrivals(f_mhz), direct);
    }

    #[test]
    fn mix_merges_streams_monotone_and_complete() {
        let mix = Mix::new()
            .stream("a", Traffic::periodic(5, 0.010))
            .stream("b", Traffic::poisson(20, 500.0, 3));
        assert_eq!(mix.events(), 25);
        assert_eq!(mix.streams().len(), 2);
        let sched = mix.schedule();
        assert_eq!(sched.len(), 25);
        assert!(sched.windows(2).all(|w| w[0].at_s <= w[1].at_s), "not monotone");
        // Per-stream arrivals survive the merge exactly.
        let a: Vec<f64> = sched.iter().filter(|x| x.stream == 0).map(|x| x.at_s).collect();
        let b: Vec<f64> = sched.iter().filter(|x| x.stream == 1).map(|x| x.at_s).collect();
        assert_eq!(a, Traffic::periodic(5, 0.010).schedule());
        assert_eq!(b, Traffic::poisson(20, 500.0, 3).schedule());
    }

    #[test]
    fn mix_stream_at_phase_shifts_one_stream() {
        // The phase-shift scenario: tag "late" joins 50ms into the run.
        let mix = Mix::new()
            .stream("base", Traffic::periodic(5, 0.010))
            .stream_at("late", Traffic::periodic(3, 0.010), 0.050);
        assert_eq!(mix.events(), 8);
        assert_eq!(mix.streams()[1].start_s, 0.050);
        let sched = mix.schedule();
        assert!(sched.windows(2).all(|w| w[0].at_s <= w[1].at_s), "not monotone");
        let late: Vec<f64> =
            sched.iter().filter(|a| a.stream == 1).map(|a| a.at_s).collect();
        // Same float ops as the mix applies, so the match is exact.
        let expect: Vec<f64> = Traffic::periodic(3, 0.010)
            .schedule()
            .iter()
            .map(|t| t + 0.050)
            .collect();
        assert_eq!(late, expect);
        // The base stream is untouched by the neighbour's offset.
        let base: Vec<f64> =
            sched.iter().filter(|a| a.stream == 0).map(|a| a.at_s).collect();
        assert_eq!(base, Traffic::periodic(5, 0.010).schedule());
        // Nothing of "late" arrives before its join offset.
        assert!(sched
            .iter()
            .filter(|a| a.stream == 1)
            .all(|a| a.at_s >= 0.050));
    }

    #[test]
    fn mix_ties_break_by_stream_order() {
        // Two saturated streams: every arrival ties at t=0; the merge must
        // be deterministic with stream 0 first at each step.
        let mix = Mix::new()
            .stream("x", Traffic::saturated(2))
            .stream("y", Traffic::saturated(2));
        let sched = mix.schedule();
        let order: Vec<usize> = sched.iter().map(|a| a.stream).collect();
        assert_eq!(order, vec![0, 0, 1, 1]);
    }

    #[test]
    fn parse_specs() {
        assert!(matches!(
            Workload::parse("saturated", 10),
            Ok(Workload::Saturated { frames: 10 })
        ));
        assert!(matches!(
            Workload::parse("poisson:5000", 10),
            Ok(Workload::Poisson { frames: 10, .. })
        ));
        assert!(matches!(
            Workload::parse("periodic:2000", 10),
            Ok(Workload::Periodic { frames: 10, interval_cycles: 2000 })
        ));
        assert!(matches!(
            Workload::parse("burst:8:1000", 10),
            Ok(Workload::Burst { frames: 10, burst: 8, gap_cycles: 1000, .. })
        ));
        assert!(Workload::parse("nope", 10).is_err());
        assert!(Workload::parse("burst:8", 10).is_err());
        // Value validation: syntactically fine specs with values that
        // would panic downstream must fail here instead.
        assert!(Workload::parse("poisson:0", 10).is_err());
        assert!(Workload::parse("poisson:-5", 10).is_err());
        assert!(Workload::parse("poisson:nan", 10).is_err());
        assert!(Workload::parse("burst:0:1000", 10).is_err());
    }
}
