//! Serving statistics: counters + latency reservoir, lock-light.
//!
//! Counters are atomics (hot path); latencies go into a bounded reservoir
//! behind a mutex taken once per completed request — profiled as noise at
//! LeNet batch rates (see EXPERIMENTS.md §Perf).
//!
//! One [`ServerStats`] instance belongs to one serving plane: the
//! single-model [`crate::coordinator::Server`] owns exactly one, a
//! [`crate::coordinator::Fleet`] owns one per model tag and rolls them up
//! into a [`crate::coordinator::FleetSnapshot`]. Admission sheds are
//! therefore counted twice on purpose: per plane here (`shed`, attributed
//! to the tag whose submit was rejected) and fleet-wide on the shared
//! [`crate::coordinator::AdmissionGate`]; the two views must sum to the
//! same total (asserted in `tests/serving.rs`). Sheds caused by a tag's
//! **own** budget (DESIGN.md §11) are a separate counter (`shed_budget`)
//! precisely so that reconciliation keeps holding once per-tag budgets
//! are active: the host gate never sees a budget shed.
//!
//! A handful of snapshot fields (`in_flight`, `budget_capacity`,
//! `ring_depth`, `slo_p99_ms`) describe plane state the counters cannot
//! see; `ServerStats::snapshot` leaves them at their inert defaults and
//! the owning plane fills them in.

use crate::obs::metrics::{Histogram, Registry};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Live server statistics.
///
/// Counters are `Arc<AtomicU64>` cells so a plane built with an
/// [`crate::obs::metrics::Registry`] attached shares the *same* atomics
/// with the metrics scrape ([`ServerStats::new_in`]): incrementing here
/// is the single write path, registration only names the cell. A
/// detached plane ([`ServerStats::new`]) pays one pointer indirection
/// and nothing else.
pub struct ServerStats {
    started: Instant,
    submitted: Arc<AtomicU64>,
    dispatched_batches: Arc<AtomicU64>,
    dispatched_requests: Arc<AtomicU64>,
    completed: Arc<AtomicU64>,
    errors: Arc<AtomicU64>,
    /// Batches an idle engine stole from a neighbour's work ring.
    steals: Arc<AtomicU64>,
    /// Requests admission control rejected at this plane's submit path.
    shed: Arc<AtomicU64>,
    /// Requests rejected by this plane's **own** tag budget (DESIGN.md
    /// §11) — never counted on the shared host gate.
    shed_budget: Arc<AtomicU64>,
    exec_time_us: Arc<AtomicU64>,
    latencies: Mutex<LatencyBuf>,
    /// Scrape-visible latency histogram (µs), fed alongside the
    /// reservoir on the same once-per-completion path.
    latency_hist: Option<Arc<Histogram>>,
}

const RESERVOIR: usize = 100_000;

/// Recent-completions window behind [`ServerStats::snapshot_sampled`]:
/// the per-tick percentile cost is one clone + sort of at most this many
/// values, regardless of how many requests the plane has ever served.
pub(crate) const WINDOW: usize = 512;

/// Latency samples, two views under one lock: the bounded first-N
/// `reservoir` (full-run percentiles for final reports) and a sliding
/// `window` ring of the most recent completions (bounded-cost percentiles
/// for the policy control plane's telemetry cadence).
#[derive(Default)]
struct LatencyBuf {
    reservoir: Vec<u64>,
    window: Vec<u64>,
    /// Next write slot in `window` once it has filled.
    next: usize,
}

impl LatencyBuf {
    fn record(&mut self, us: u64) {
        if self.reservoir.len() < RESERVOIR {
            self.reservoir.push(us);
        }
        if self.window.len() < WINDOW {
            self.window.push(us);
        } else {
            self.window[self.next] = us;
            self.next = (self.next + 1) % WINDOW;
        }
    }
}

/// Which latency view a snapshot pays for.
enum LatencySource {
    /// Clone + sort the full reservoir (final reports).
    Full,
    /// Clone + sort the recent-completions window (control cadence).
    Window,
    /// Neither — percentile fields stay 0.0 (counters-only control).
    None,
}

impl ServerStats {
    /// Fresh counters; the wall-clock epoch for throughput starts now.
    pub fn new() -> Self {
        let cell = || Arc::new(AtomicU64::new(0));
        ServerStats {
            started: Instant::now(),
            submitted: cell(),
            dispatched_batches: cell(),
            dispatched_requests: cell(),
            completed: cell(),
            errors: cell(),
            steals: cell(),
            shed: cell(),
            shed_budget: cell(),
            exec_time_us: cell(),
            latencies: Mutex::new(LatencyBuf::default()),
            latency_hist: None,
        }
    }

    /// Fresh counters registered in `registry` under `prefix` (e.g.
    /// `"serve.a."`): the registry scrape and the hot path share the
    /// same atomic cells, so re-plumbing adds no second write path. The
    /// latency reservoir additionally feeds a `{prefix}latency_us`
    /// histogram on the existing once-per-completion lock.
    pub fn new_in(registry: &Registry, prefix: &str) -> Self {
        let c = |name: &str| registry.counter(&format!("{prefix}{name}"));
        ServerStats {
            started: Instant::now(),
            submitted: c("submitted"),
            dispatched_batches: c("dispatched_batches"),
            dispatched_requests: c("dispatched_requests"),
            completed: c("completed"),
            errors: c("errors"),
            steals: c("steals"),
            shed: c("shed_host"),
            shed_budget: c("shed_budget"),
            exec_time_us: c("exec_time_us"),
            latencies: Mutex::new(LatencyBuf::default()),
            latency_hist: Some(registry.histogram(&format!("{prefix}latency_us"))),
        }
    }

    /// Count one admitted submission.
    pub fn on_submit(&self) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one formed batch of `n` requests handed to the plane.
    pub fn on_dispatch(&self, n: usize) {
        self.dispatched_batches.fetch_add(1, Ordering::Relaxed);
        self.dispatched_requests.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Account one executed batch's engine time.
    pub fn on_batch(&self, _n: usize, exec_s: f64) {
        self.exec_time_us
            .fetch_add((exec_s * 1e6) as u64, Ordering::Relaxed);
    }

    /// Count one successfully served request and sample its latency
    /// (into both the full-run reservoir and the recent window).
    pub fn on_complete(&self, latency_s: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let us = (latency_s * 1e6) as u64;
        if let Some(h) = &self.latency_hist {
            h.record(us);
        }
        self.latencies.lock().expect("stats poisoned").record(us);
    }

    /// Count one request answered with an engine error.
    pub fn on_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one batch executed by a neighbour engine (work stealing).
    pub fn on_steal(&self) {
        self.steals.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one submission rejected by admission control at this plane.
    pub fn on_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one submission rejected by this plane's own tag budget.
    pub fn on_shed_budget(&self) {
        self.shed_budget.fetch_add(1, Ordering::Relaxed);
    }

    /// Materialise an immutable [`StatsSnapshot`] of the live counters,
    /// including latency percentiles (clones and sorts the bounded
    /// reservoir — fine for reporting, wasteful on a control cadence;
    /// see [`ServerStats::snapshot_counters`]).
    pub fn snapshot(&self) -> StatsSnapshot {
        self.snapshot_impl(LatencySource::Full)
    }

    /// Counters-only snapshot for the policy control plane: identical to
    /// [`ServerStats::snapshot`] except no latency view is cloned or
    /// sorted — every percentile field is 0.0, so
    /// [`StatsSnapshot::slo_met`] must not be read off this variant.
    /// Counter-driven control ticks stay O(1) in completed-request
    /// history; latency-aware policies use
    /// [`ServerStats::snapshot_sampled`] instead.
    pub fn snapshot_counters(&self) -> StatsSnapshot {
        self.snapshot_impl(LatencySource::None)
    }

    /// Bounded-cost latency-aware snapshot for the policy control plane:
    /// percentiles come from the sliding window of the most recent
    /// [`WINDOW`] completions, so each tick pays one clone + sort of at
    /// most that many values no matter how long the plane has served —
    /// and the reported p99 tracks *current* behaviour rather than the
    /// whole run (what an SLO policy actually wants to act on).
    pub fn snapshot_sampled(&self) -> StatsSnapshot {
        self.snapshot_impl(LatencySource::Window)
    }

    fn snapshot_impl(&self, source: LatencySource) -> StatsSnapshot {
        let lat = match source {
            LatencySource::None => Vec::new(),
            LatencySource::Full | LatencySource::Window => {
                let buf = self.latencies.lock().expect("stats poisoned");
                let mut lat = match source {
                    LatencySource::Full => buf.reservoir.clone(),
                    _ => buf.window.clone(),
                };
                drop(buf);
                lat.sort_unstable();
                lat
            }
        };
        let pct = |q: f64| -> f64 {
            if lat.is_empty() {
                return 0.0;
            }
            let idx = ((lat.len() - 1) as f64 * q).round() as usize;
            lat[idx] as f64 / 1e6
        };
        let completed = self.completed.load(Ordering::Relaxed);
        let batches = self.dispatched_batches.load(Ordering::Relaxed);
        let elapsed = self.started.elapsed().as_secs_f64();
        StatsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            errors: self.errors.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            shed_budget: self.shed_budget.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches > 0 {
                self.dispatched_requests.load(Ordering::Relaxed) as f64 / batches as f64
            } else {
                0.0
            },
            throughput_rps: if elapsed > 0.0 { completed as f64 / elapsed } else { 0.0 },
            exec_time_s: self.exec_time_us.load(Ordering::Relaxed) as f64 / 1e6,
            p50_latency_s: pct(0.5),
            p95_latency_s: pct(0.95),
            p99_latency_s: pct(0.99),
            elapsed_s: elapsed,
            in_flight: 0,
            budget_capacity: None,
            ring_depth: 0,
            ring_full_backoffs: 0,
            slo_p99_ms: None,
        }
    }
}

impl Default for ServerStats {
    fn default() -> Self {
        Self::new()
    }
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    /// Requests admitted past the gate and queued for batching.
    pub submitted: u64,
    /// Requests answered successfully (errors excluded).
    pub completed: u64,
    /// Requests answered with an engine failure (NaN logits).
    pub errors: u64,
    /// Batches executed by an engine other than the one they were
    /// dispatched to (work stealing).
    pub steals: u64,
    /// Requests fast-rejected by admission control (never queued),
    /// attributed to this plane's submit path. Counts **host-gate**
    /// sheds only; budget sheds are [`StatsSnapshot::shed_budget`].
    pub shed: u64,
    /// Requests fast-rejected by this plane's own tag budget (DESIGN.md
    /// §11) — disjoint from [`StatsSnapshot::shed`], so the host gate's
    /// total still equals the per-tag `shed` sum.
    pub shed_budget: u64,
    /// Batches formed and dispatched to the execution plane.
    pub batches: u64,
    /// Dispatched requests per dispatched batch.
    pub mean_batch_size: f64,
    /// Completed requests per second of elapsed wall time.
    pub throughput_rps: f64,
    /// Total engine execute time (batch-level, summed across engines).
    pub exec_time_s: f64,
    /// Median request latency (queue + batch + execute), seconds.
    pub p50_latency_s: f64,
    /// 95th-percentile request latency, seconds.
    pub p95_latency_s: f64,
    /// 99th-percentile request latency, seconds.
    pub p99_latency_s: f64,
    /// Wall time since the stats epoch (server start), seconds.
    pub elapsed_s: f64,
    /// Requests of this plane currently in flight (budget occupancy at
    /// snapshot time). Filled by the owning plane.
    pub in_flight: usize,
    /// This plane's tag-budget cap, `None` when unlimited. Filled by the
    /// owning plane.
    pub budget_capacity: Option<usize>,
    /// Current per-engine work-ring capacity, in batches (the knob queue
    /// autotuning turns). Filled by the owning plane; 0 when unknown.
    pub ring_depth: usize,
    /// Times this plane's dispatcher found every ring full and backed
    /// off — the queue-pressure signal autotuning grows depth on
    /// (admission sheds happen upstream of the rings and cannot be
    /// relieved by deeper rings). Filled by the owning plane.
    pub ring_full_backoffs: u64,
    /// The tag's SLO p99 target in milliseconds, when one is configured.
    /// Filled by the owning plane.
    pub slo_p99_ms: Option<f64>,
}

impl StatsSnapshot {
    /// Total submissions rejected with `Error::Overloaded`, both scopes
    /// (host gate + own budget).
    pub fn shed_total(&self) -> u64 {
        self.shed + self.shed_budget
    }

    /// True when an SLO p99 target is configured and the measured p99
    /// meets it. `None` when no SLO is set **or** nothing completed yet
    /// — an empty latency reservoir reads as p99 = 0, which must not
    /// count as conformance (a fully-starved tag serves nothing and
    /// meets nothing).
    pub fn slo_met(&self) -> Option<bool> {
        if self.completed == 0 {
            return None;
        }
        self.slo_p99_ms.map(|t| self.p99_latency_s * 1e3 <= t)
    }

    /// One-line human-readable summary of the snapshot.
    pub fn render(&self) -> String {
        let mut s = format!(
            "served {}/{} ({} errors, {} shed, {} budget-shed, {} steals) in {:.2}s \
             | {:.0} req/s | batches {} (mean {:.1}) | latency p50 {:.2}ms \
             p95 {:.2}ms p99 {:.2}ms",
            self.completed,
            self.submitted,
            self.errors,
            self.shed,
            self.shed_budget,
            self.steals,
            self.elapsed_s,
            self.throughput_rps,
            self.batches,
            self.mean_batch_size,
            self.p50_latency_s * 1e3,
            self.p95_latency_s * 1e3,
            self.p99_latency_s * 1e3,
        );
        if self.ring_depth > 0 {
            s.push_str(&format!(" | ring {}b", self.ring_depth));
        }
        if let Some(cap) = self.budget_capacity {
            s.push_str(&format!(" | budget {}/{}", self.in_flight, cap));
        }
        if let Some(target) = self.slo_p99_ms {
            let verdict = match self.slo_met() {
                Some(true) => "met",
                Some(false) => "MISSED",
                None => "no served requests",
            };
            s.push_str(&format!(" | slo p99<={target:.1}ms {verdict}"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_flow() {
        let s = ServerStats::new();
        for _ in 0..10 {
            s.on_submit();
        }
        s.on_dispatch(6);
        s.on_dispatch(4);
        s.on_batch(6, 0.001);
        s.on_batch(4, 0.002);
        for i in 0..10 {
            s.on_complete(0.001 * (i + 1) as f64);
        }
        s.on_error();
        s.on_shed();
        s.on_shed();
        s.on_shed_budget();
        let snap = s.snapshot();
        assert_eq!(snap.submitted, 10);
        assert_eq!(snap.completed, 10);
        assert_eq!(snap.errors, 1);
        assert_eq!(snap.shed, 2);
        assert_eq!(snap.shed_budget, 1);
        assert_eq!(snap.shed_total(), 3);
        assert_eq!(snap.batches, 2);
        assert!((snap.mean_batch_size - 5.0).abs() < 1e-9);
        assert!(snap.p50_latency_s > 0.0);
        assert!(snap.p50_latency_s <= snap.p99_latency_s);
        assert!((snap.exec_time_s - 0.003).abs() < 1e-6);
        assert!(snap.render().contains("served 10/10"));
    }

    #[test]
    fn counters_snapshot_skips_latency_work() {
        let s = ServerStats::new();
        for _ in 0..4 {
            s.on_submit();
            s.on_complete(0.002);
        }
        s.on_shed();
        let c = s.snapshot_counters();
        assert_eq!(c.completed, 4);
        assert_eq!(c.shed, 1);
        assert_eq!(c.p99_latency_s, 0.0, "counters variant must skip percentiles");
        // The full snapshot still reports them.
        assert!(s.snapshot().p99_latency_s > 0.0);
    }

    #[test]
    fn sampled_snapshot_tracks_recent_completions() {
        let s = ServerStats::new();
        // Fill well past the window with slow completions, then overwrite
        // the whole window with fast ones: the sampled view must follow
        // the recent behaviour while the full reservoir keeps the past.
        for _ in 0..(WINDOW * 2) {
            s.on_complete(0.100);
        }
        for _ in 0..WINDOW {
            s.on_complete(0.001);
        }
        let sampled = s.snapshot_sampled();
        let full = s.snapshot();
        assert!(
            (sampled.p99_latency_s - 0.001).abs() < 1e-4,
            "window p99 {} should track the recent fast completions",
            sampled.p99_latency_s
        );
        assert!(
            full.p99_latency_s > 0.05,
            "reservoir p99 {} should still see the slow past",
            full.p99_latency_s
        );
        // Same counters either way.
        assert_eq!(sampled.completed, full.completed);
        // And the counters-only variant still skips the work entirely.
        assert_eq!(s.snapshot_counters().p99_latency_s, 0.0);
    }

    #[test]
    fn registry_backed_counters_share_cells() {
        let reg = Registry::new();
        let s = ServerStats::new_in(&reg, "t.");
        s.on_submit();
        s.on_complete(0.002);
        s.on_shed();
        s.on_shed_budget();
        // The scrape reads the very cells the hot path incremented.
        let snap = reg.snapshot();
        assert_eq!(snap.counter("t.submitted"), Some(1));
        assert_eq!(snap.counter("t.completed"), Some(1));
        assert_eq!(snap.counter("t.shed_host"), Some(1));
        assert_eq!(snap.counter("t.shed_budget"), Some(1));
        let (_, h) = snap.hists.iter().find(|(n, _)| n == "t.latency_us").unwrap();
        assert_eq!(h.count, 1);
        assert!((h.mean() - 2000.0).abs() < 1.0, "mean is exact: {}", h.mean());
        assert!(h.quantile(0.99) >= 2000.0, "bucket upper bound covers the obs");
        // And the plane's own snapshot agrees.
        assert_eq!(s.snapshot().submitted, 1);
    }

    #[test]
    fn empty_snapshot_safe() {
        let snap = ServerStats::new().snapshot();
        assert_eq!(snap.p99_latency_s, 0.0);
        assert_eq!(snap.mean_batch_size, 0.0);
        assert_eq!(snap.budget_capacity, None);
        assert_eq!(snap.slo_met(), None);
    }

    #[test]
    fn render_surfaces_plane_state_and_slo_verdict() {
        let mut snap = ServerStats::new().snapshot();
        // Inert defaults render no plane-state suffixes.
        let plain = snap.render();
        assert!(!plain.contains("slo"));
        assert!(!plain.contains("budget "));
        snap.ring_depth = 24;
        snap.in_flight = 3;
        snap.budget_capacity = Some(56);
        snap.slo_p99_ms = Some(20.0);
        snap.p99_latency_s = 0.005;
        // Nothing completed: an empty reservoir must not read as
        // conformance.
        assert_eq!(snap.slo_met(), None);
        assert!(snap.render().contains("no served requests"));
        snap.completed = 10;
        let s = snap.render();
        assert!(s.contains("ring 24b"), "{s}");
        assert!(s.contains("budget 3/56"), "{s}");
        assert!(s.contains("slo p99<=20.0ms met"), "{s}");
        snap.p99_latency_s = 0.050;
        assert!(snap.render().contains("MISSED"));
    }
}
