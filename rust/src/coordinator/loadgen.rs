//! Open-loop load generator driven by the shared [`crate::traffic`]
//! model — the serving-side twin of the simulator's arrival processes, so
//! simulated ("Table-I measured") and served throughput are produced under
//! *identical* traffic.
//!
//! Open-loop means arrivals are scheduled by the traffic model, not by
//! response completion: the generator replays the schedule against the
//! wall clock and submits regardless of how the server is keeping up.
//! Under overload the admission gate sheds ([`ShedMode`] decides whether a
//! shed arrival is dropped — honest open-loop — or retried until admitted,
//! which is the right shape for saturated capacity measurements).
//! Responses are collected on a separate thread so waiting never distorts
//! the arrival process.
//!
//! The generator drives anything that implements [`Submit`]: a
//! single-model [`Server`] or one tag of a [`Fleet`] (via
//! [`TagHandle`]). [`run_open_loop_mix`] replays a heterogeneous
//! [`Mix`] — one arrival process per model tag, merged into a single
//! wall-clock schedule — against a whole fleet and reports per tag.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::fleet::{Fleet, ModelSpec, TagHandle};
use super::{Response, Server};
use crate::traffic::{Mix, Traffic};
use crate::util::error::{Error, Result};

/// What to do when admission control sheds an arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedMode {
    /// Count it and move on (open-loop honesty: latency percentiles stay
    /// meaningful under overload).
    Drop,
    /// Retry until admitted (saturated-throughput measurements: every
    /// arrival eventually executes).
    Retry,
}

/// A submit target the open-loop generator can drive: the single-model
/// [`Server`], or one tag of a [`Fleet`] through a pre-resolved
/// [`TagHandle`].
pub trait Submit {
    /// Submit one image; same contract as [`Server::submit`]
    /// ([`Error::Overloaded`] on shed, [`Error::QueueClosed`] once
    /// shutdown began, nothing queued on either).
    fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>>;
}

impl Submit for Server {
    fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        Server::submit(self, image)
    }
}

impl Submit for TagHandle<'_> {
    fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        TagHandle::submit(self, image)
    }
}

/// Outcome of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Arrivals the traffic model generated.
    pub offered: u64,
    /// Arrivals admitted by the server.
    pub accepted: u64,
    /// Arrivals shed by admission control (Drop mode only).
    pub shed: u64,
    /// Accepted requests that completed successfully.
    pub completed: u64,
    /// Accepted requests answered with an engine error.
    pub errors: u64,
    /// Accepted requests whose response channel died unanswered — must be
    /// zero if the serving plane keeps its no-loss guarantee.
    pub lost: u64,
    /// Wall time from first submission to last response.
    pub wall_s: f64,
    /// Completed requests per second of wall time.
    pub achieved_rps: f64,
    /// Per-request latencies (seconds) of successful completions, sorted
    /// ascending (`run_open_loop` sorts once so percentile queries are
    /// O(1)).
    pub latencies_s: Vec<f64>,
}

impl LoadReport {
    /// Latency percentile over successful completions (0.0 ..= 1.0).
    pub fn latency_pct_s(&self, q: f64) -> f64 {
        if self.latencies_s.is_empty() {
            return 0.0;
        }
        let idx = ((self.latencies_s.len() - 1) as f64 * q).round() as usize;
        self.latencies_s[idx]
    }

    /// One-line human-readable summary of the run.
    pub fn render(&self) -> String {
        format!(
            "offered {} | accepted {} (shed {}) | completed {} ({} errors, {} lost) \
             | {:.2}s wall | {:.0} req/s | p50 {:.2}ms p99 {:.2}ms",
            self.offered,
            self.accepted,
            self.shed,
            self.completed,
            self.errors,
            self.lost,
            self.wall_s,
            self.achieved_rps,
            self.latency_pct_s(0.5) * 1e3,
            self.latency_pct_s(0.99) * 1e3,
        )
    }
}

/// Per-tag outcome of one mixed-traffic fleet run
/// ([`run_open_loop_mix`]). All tags share one wall clock, so the
/// per-tag `achieved_rps` figures sum to the fleet aggregate.
#[derive(Debug, Clone)]
pub struct MixReport {
    /// `(tag, report)` per mix stream, in mix order.
    pub per_tag: Vec<(String, LoadReport)>,
    /// Wall time of the whole mixed run (first submission to last
    /// response, any tag).
    pub wall_s: f64,
}

impl MixReport {
    /// The report of one tag, if present in the mix.
    pub fn get(&self, tag: &str) -> Option<&LoadReport> {
        self.per_tag.iter().find(|(t, _)| t == tag).map(|(_, r)| r)
    }

    /// Total arrivals offered across all tags.
    pub fn offered(&self) -> u64 {
        self.per_tag.iter().map(|(_, r)| r.offered).sum()
    }

    /// Total successful completions across all tags.
    pub fn completed(&self) -> u64 {
        self.per_tag.iter().map(|(_, r)| r.completed).sum()
    }

    /// Total responses lost across all tags (must stay zero — the
    /// serving plane's no-loss guarantee, per tag).
    pub fn lost(&self) -> u64 {
        self.per_tag.iter().map(|(_, r)| r.lost).sum()
    }

    /// Total arrivals shed across all tags (Drop mode only).
    pub fn shed(&self) -> u64 {
        self.per_tag.iter().map(|(_, r)| r.shed).sum()
    }

    /// Fleet-aggregate throughput: total completions over the shared
    /// wall time.
    pub fn aggregate_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.completed() as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Aggregate summary line plus one indented line per tag.
    pub fn render(&self) -> String {
        let mut s = format!(
            "mix: {} tags | offered {} | completed {} (lost {}, shed {}) | \
             {:.2}s wall | {:.0} req/s aggregate",
            self.per_tag.len(),
            self.offered(),
            self.completed(),
            self.lost(),
            self.shed(),
            self.wall_s,
            self.aggregate_rps(),
        );
        for (tag, rep) in &self.per_tag {
            s.push_str(&format!("\n  [{tag}] {}", rep.render()));
        }
        s
    }
}

/// Sleep up to (not past) offset `at` seconds after `t0`, finishing with
/// a short spin so bursts stay sharp.
fn wait_until(t0: Instant, at: f64) {
    loop {
        let now = t0.elapsed().as_secs_f64();
        if now >= at {
            break;
        }
        let dt = at - now;
        if dt > 500e-6 {
            std::thread::sleep(Duration::from_secs_f64(dt - 200e-6));
        } else {
            std::hint::spin_loop();
        }
    }
}

/// Replay `traffic` against `server`, drawing the image for arrival `i`
/// from `image_of`. Blocks until every accepted request has been answered
/// (or its channel died), so the report is complete.
pub fn run_open_loop(
    server: &impl Submit,
    traffic: &Traffic,
    image_of: impl Fn(u64) -> Vec<f32>,
    shed_mode: ShedMode,
) -> LoadReport {
    let schedule = traffic.schedule();
    let mut offered = 0u64;
    let mut accepted = 0u64;
    let mut shed = 0u64;

    let (pending_tx, pending_rx) = mpsc::channel::<mpsc::Receiver<Response>>();
    let (t0, collected) = std::thread::scope(|s| {
        let collector = s.spawn(move || {
            let mut completed = 0u64;
            let mut errors = 0u64;
            let mut lost = 0u64;
            let mut latencies_s = Vec::new();
            while let Ok(rx) = pending_rx.recv() {
                match rx.recv() {
                    Ok(resp) => {
                        if resp.is_error() {
                            errors += 1;
                        } else {
                            completed += 1;
                            latencies_s.push(resp.latency_s);
                        }
                    }
                    Err(_) => lost += 1,
                }
            }
            (completed, errors, lost, latencies_s)
        });

        let t0 = Instant::now();
        'arrivals: for (i, &at) in schedule.iter().enumerate() {
            wait_until(t0, at);
            offered += 1;
            loop {
                match server.submit(image_of(i as u64)) {
                    Ok(rx) => {
                        accepted += 1;
                        if pending_tx.send(rx).is_err() {
                            break 'arrivals; // collector died (panic)
                        }
                        break;
                    }
                    Err(Error::Overloaded) => match shed_mode {
                        ShedMode::Drop => {
                            shed += 1;
                            break;
                        }
                        ShedMode::Retry => std::thread::yield_now(),
                    },
                    Err(_) => break 'arrivals, // server shutting down
                }
            }
        }
        drop(pending_tx);
        (t0, collector.join().expect("collector panicked"))
    });

    let (completed, errors, lost, mut latencies_s) = collected;
    latencies_s.sort_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
    let wall_s = t0.elapsed().as_secs_f64();
    LoadReport {
        offered,
        accepted,
        shed,
        completed,
        errors,
        lost,
        wall_s,
        achieved_rps: if wall_s > 0.0 { completed as f64 / wall_s } else { 0.0 },
        latencies_s,
    }
}

/// Replay a heterogeneous [`Mix`] — one arrival process per model tag,
/// merged into a single wall-clock schedule — against `fleet`. Every tag
/// in the mix is resolved to its plane **once** up front
/// ([`Error::UnknownModel`] if any is missing); the hot loop then submits
/// by plane index. `image_of(stream, i)` draws the image for arrival `i`
/// of mix stream `stream` (mix order). Blocks until every accepted
/// request has been answered, so the per-tag reports are complete.
pub fn run_open_loop_mix(
    fleet: &Fleet,
    mix: &Mix,
    image_of: impl Fn(usize, u64) -> Vec<f32>,
    shed_mode: ShedMode,
) -> Result<MixReport> {
    let n_streams = mix.streams().len();
    let mut plane_of = Vec::with_capacity(n_streams);
    for s in mix.streams() {
        plane_of.push(fleet.resolve(&s.tag)?);
    }
    let schedule = mix.schedule();
    let mut offered = vec![0u64; n_streams];
    let mut accepted = vec![0u64; n_streams];
    let mut shed = vec![0u64; n_streams];
    let mut seq = vec![0u64; n_streams];

    let (pending_tx, pending_rx) =
        mpsc::channel::<(usize, mpsc::Receiver<Response>)>();
    let (t0, collected) = std::thread::scope(|s| {
        let collector = s.spawn(move || {
            let mut completed = vec![0u64; n_streams];
            let mut errors = vec![0u64; n_streams];
            let mut lost = vec![0u64; n_streams];
            let mut latencies_s: Vec<Vec<f64>> = vec![Vec::new(); n_streams];
            while let Ok((k, rx)) = pending_rx.recv() {
                match rx.recv() {
                    Ok(resp) => {
                        if resp.is_error() {
                            errors[k] += 1;
                        } else {
                            completed[k] += 1;
                            latencies_s[k].push(resp.latency_s);
                        }
                    }
                    Err(_) => lost[k] += 1,
                }
            }
            (completed, errors, lost, latencies_s)
        });

        let t0 = Instant::now();
        'arrivals: for a in &schedule {
            wait_until(t0, a.at_s);
            let k = a.stream;
            offered[k] += 1;
            let i = seq[k];
            seq[k] += 1;
            loop {
                match fleet.submit_at(plane_of[k], image_of(k, i)) {
                    Ok(rx) => {
                        accepted[k] += 1;
                        if pending_tx.send((k, rx)).is_err() {
                            break 'arrivals; // collector died (panic)
                        }
                        break;
                    }
                    Err(Error::Overloaded) => match shed_mode {
                        ShedMode::Drop => {
                            shed[k] += 1;
                            break;
                        }
                        ShedMode::Retry => std::thread::yield_now(),
                    },
                    Err(_) => break 'arrivals, // fleet shutting down
                }
            }
        }
        drop(pending_tx);
        (t0, collector.join().expect("collector panicked"))
    });

    let (completed, errors, lost, lats) = collected;
    let wall_s = t0.elapsed().as_secs_f64();
    let mut per_tag = Vec::with_capacity(n_streams);
    for (k, (stream, mut latencies_s)) in
        mix.streams().iter().zip(lats).enumerate()
    {
        latencies_s.sort_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
        per_tag.push((
            stream.tag.clone(),
            LoadReport {
                offered: offered[k],
                accepted: accepted[k],
                shed: shed[k],
                completed: completed[k],
                errors: errors[k],
                lost: lost[k],
                wall_s,
                achieved_rps: if wall_s > 0.0 {
                    completed[k] as f64 / wall_s
                } else {
                    0.0
                },
                latencies_s,
            },
        ));
    }
    Ok(MixReport { per_tag, wall_s })
}

/// One phase of a membership-churning load run ([`run_phases`]):
/// membership actions applied up front, then a [`Mix`] replayed against
/// the resulting fleet. A tag that joins partway through the phase is
/// modelled with [`Mix::stream_at`] (register it here, phase-shift its
/// stream).
#[derive(Clone, Default)]
pub struct Phase {
    /// Tags to retire (lossless drain) before this phase's traffic.
    pub retire: Vec<String>,
    /// Models to register before this phase's traffic.
    pub register: Vec<ModelSpec>,
    /// The traffic replayed during this phase.
    pub mix: Mix,
}

/// Replay a sequence of [`Phase`]s against a fleet: each phase first
/// retires / registers its tags (both are lossless for in-flight work —
/// responses of earlier phases keep arriving on their channels, and both
/// run a control-loop tick internally so budgets reflect the new
/// membership), then replays its mix open-loop and reports per tag.
/// This is the phase-shift scenario from DESIGN.md §11: a tag joining
/// (or leaving) a running host mid-run, driven by the same traffic model
/// everything else uses.
pub fn run_phases(
    fleet: &Fleet,
    phases: &[Phase],
    image_of: impl Fn(usize, u64) -> Vec<f32>,
    shed_mode: ShedMode,
) -> Result<Vec<MixReport>> {
    let mut reports = Vec::with_capacity(phases.len());
    for phase in phases {
        for tag in &phase.retire {
            fleet.retire(tag)?;
        }
        for spec in &phase.register {
            fleet.register(spec.clone())?;
        }
        reports.push(run_open_loop_mix(fleet, &phase.mix, &image_of, shed_mode)?);
    }
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(completed: u64, shed: u64) -> LoadReport {
        LoadReport {
            offered: completed + shed,
            accepted: completed,
            shed,
            completed,
            errors: 0,
            lost: 0,
            wall_s: 2.0,
            achieved_rps: completed as f64 / 2.0,
            latencies_s: vec![0.001; completed as usize],
        }
    }

    #[test]
    fn report_percentiles_and_render() {
        let rep = LoadReport {
            offered: 10,
            accepted: 9,
            shed: 1,
            completed: 8,
            errors: 1,
            lost: 0,
            wall_s: 2.0,
            achieved_rps: 4.0,
            latencies_s: vec![0.001, 0.002, 0.003, 0.004],
        };
        assert!(rep.latency_pct_s(0.0) <= rep.latency_pct_s(0.5));
        assert!(rep.latency_pct_s(0.5) <= rep.latency_pct_s(1.0));
        assert_eq!(rep.latency_pct_s(1.0), 0.004);
        let s = rep.render();
        assert!(s.contains("offered 10"));
        assert!(s.contains("shed 1"));
    }

    #[test]
    fn empty_report_is_safe() {
        let rep = LoadReport {
            offered: 0,
            accepted: 0,
            shed: 0,
            completed: 0,
            errors: 0,
            lost: 0,
            wall_s: 0.0,
            achieved_rps: 0.0,
            latencies_s: Vec::new(),
        };
        assert_eq!(rep.latency_pct_s(0.99), 0.0);
    }

    #[test]
    fn mix_report_aggregates_across_tags() {
        let mix = MixReport {
            per_tag: vec![
                ("a".to_string(), report(6, 2)),
                ("b".to_string(), report(4, 0)),
            ],
            wall_s: 2.0,
        };
        assert_eq!(mix.offered(), 12);
        assert_eq!(mix.completed(), 10);
        assert_eq!(mix.shed(), 2);
        assert_eq!(mix.lost(), 0);
        assert!((mix.aggregate_rps() - 5.0).abs() < 1e-9);
        assert_eq!(mix.get("b").unwrap().completed, 4);
        assert!(mix.get("c").is_none());
        let s = mix.render();
        assert!(s.contains("mix: 2 tags"));
        assert!(s.contains("[a]"));
    }
}
