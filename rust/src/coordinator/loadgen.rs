//! Open-loop load generator driven by the shared [`crate::traffic`]
//! model — the serving-side twin of the simulator's arrival processes, so
//! simulated ("Table-I measured") and served throughput are produced under
//! *identical* traffic.
//!
//! Open-loop means arrivals are scheduled by the traffic model, not by
//! response completion: the generator replays the schedule against the
//! wall clock and submits regardless of how the server is keeping up.
//! Under overload the admission gate sheds ([`ShedMode`] decides whether a
//! shed arrival is dropped — honest open-loop — or retried until admitted,
//! which is the right shape for saturated capacity measurements).
//! Responses are collected on a separate thread so waiting never distorts
//! the arrival process.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::{Response, Server};
use crate::traffic::Traffic;
use crate::util::error::Error;

/// What to do when admission control sheds an arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedMode {
    /// Count it and move on (open-loop honesty: latency percentiles stay
    /// meaningful under overload).
    Drop,
    /// Retry until admitted (saturated-throughput measurements: every
    /// arrival eventually executes).
    Retry,
}

/// Outcome of one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Arrivals the traffic model generated.
    pub offered: u64,
    /// Arrivals admitted by the server.
    pub accepted: u64,
    /// Arrivals shed by admission control (Drop mode only).
    pub shed: u64,
    /// Accepted requests that completed successfully.
    pub completed: u64,
    /// Accepted requests answered with an engine error.
    pub errors: u64,
    /// Accepted requests whose response channel died unanswered — must be
    /// zero if the serving plane keeps its no-loss guarantee.
    pub lost: u64,
    /// Wall time from first submission to last response.
    pub wall_s: f64,
    /// Completed requests per second of wall time.
    pub achieved_rps: f64,
    /// Per-request latencies (seconds) of successful completions, sorted
    /// ascending (`run_open_loop` sorts once so percentile queries are
    /// O(1)).
    pub latencies_s: Vec<f64>,
}

impl LoadReport {
    /// Latency percentile over successful completions (0.0 ..= 1.0).
    pub fn latency_pct_s(&self, q: f64) -> f64 {
        if self.latencies_s.is_empty() {
            return 0.0;
        }
        let idx = ((self.latencies_s.len() - 1) as f64 * q).round() as usize;
        self.latencies_s[idx]
    }

    pub fn render(&self) -> String {
        format!(
            "offered {} | accepted {} (shed {}) | completed {} ({} errors, {} lost) \
             | {:.2}s wall | {:.0} req/s | p50 {:.2}ms p99 {:.2}ms",
            self.offered,
            self.accepted,
            self.shed,
            self.completed,
            self.errors,
            self.lost,
            self.wall_s,
            self.achieved_rps,
            self.latency_pct_s(0.5) * 1e3,
            self.latency_pct_s(0.99) * 1e3,
        )
    }
}

/// Replay `traffic` against `server`, drawing the image for arrival `i`
/// from `image_of`. Blocks until every accepted request has been answered
/// (or its channel died), so the report is complete.
pub fn run_open_loop(
    server: &Server,
    traffic: &Traffic,
    image_of: impl Fn(u64) -> Vec<f32>,
    shed_mode: ShedMode,
) -> LoadReport {
    let schedule = traffic.schedule();
    let mut offered = 0u64;
    let mut accepted = 0u64;
    let mut shed = 0u64;

    let (pending_tx, pending_rx) = mpsc::channel::<mpsc::Receiver<Response>>();
    let (t0, collected) = std::thread::scope(|s| {
        let collector = s.spawn(move || {
            let mut completed = 0u64;
            let mut errors = 0u64;
            let mut lost = 0u64;
            let mut latencies_s = Vec::new();
            while let Ok(rx) = pending_rx.recv() {
                match rx.recv() {
                    Ok(resp) => {
                        if resp.is_error() {
                            errors += 1;
                        } else {
                            completed += 1;
                            latencies_s.push(resp.latency_s);
                        }
                    }
                    Err(_) => lost += 1,
                }
            }
            (completed, errors, lost, latencies_s)
        });

        let t0 = Instant::now();
        'arrivals: for (i, &at) in schedule.iter().enumerate() {
            // Sleep up to (not past) the arrival offset; finish with a
            // short spin so bursts stay sharp.
            loop {
                let now = t0.elapsed().as_secs_f64();
                if now >= at {
                    break;
                }
                let dt = at - now;
                if dt > 500e-6 {
                    std::thread::sleep(Duration::from_secs_f64(dt - 200e-6));
                } else {
                    std::hint::spin_loop();
                }
            }
            offered += 1;
            loop {
                match server.submit(image_of(i as u64)) {
                    Ok(rx) => {
                        accepted += 1;
                        if pending_tx.send(rx).is_err() {
                            break 'arrivals; // collector died (panic)
                        }
                        break;
                    }
                    Err(Error::Overloaded) => match shed_mode {
                        ShedMode::Drop => {
                            shed += 1;
                            break;
                        }
                        ShedMode::Retry => std::thread::yield_now(),
                    },
                    Err(_) => break 'arrivals, // server shutting down
                }
            }
        }
        drop(pending_tx);
        (t0, collector.join().expect("collector panicked"))
    });

    let (completed, errors, lost, mut latencies_s) = collected;
    latencies_s.sort_by(|a, b| a.partial_cmp(b).expect("NaN latency"));
    let wall_s = t0.elapsed().as_secs_f64();
    LoadReport {
        offered,
        accepted,
        shed,
        completed,
        errors,
        lost,
        wall_s,
        achieved_rps: if wall_s > 0.0 { completed as f64 / wall_s } else { 0.0 },
        latencies_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_percentiles_and_render() {
        let rep = LoadReport {
            offered: 10,
            accepted: 9,
            shed: 1,
            completed: 8,
            errors: 1,
            lost: 0,
            wall_s: 2.0,
            achieved_rps: 4.0,
            latencies_s: vec![0.001, 0.002, 0.003, 0.004],
        };
        assert!(rep.latency_pct_s(0.0) <= rep.latency_pct_s(0.5));
        assert!(rep.latency_pct_s(0.5) <= rep.latency_pct_s(1.0));
        assert_eq!(rep.latency_pct_s(1.0), 0.004);
        let s = rep.render();
        assert!(s.contains("offered 10"));
        assert!(s.contains("shed 1"));
    }

    #[test]
    fn empty_report_is_safe() {
        let rep = LoadReport {
            offered: 0,
            accepted: 0,
            shed: 0,
            completed: 0,
            errors: 0,
            lost: 0,
            wall_s: 0.0,
            achieved_rps: 0.0,
            latencies_s: Vec::new(),
        };
        assert_eq!(rep.latency_pct_s(0.99), 0.0);
    }
}
