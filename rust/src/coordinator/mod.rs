//! Serving coordinator (L3 request path — substrate S12).
//!
//! The deployment vehicle for the generated accelerator: clients submit
//! single images through a **bounded admission gate** (overload is shed at
//! submit time with [`Error::Overloaded`], never queued); a **dynamic
//! batcher** groups admitted requests (size- or deadline-triggered,
//! vLLM-router style); the **sharded execution plane** places each batch
//! on one engine's private work ring, and engine threads — each owning a
//! full backend replica — execute batches, stealing from neighbours when
//! idle.
//!
//! Two deployment shapes share that per-model machinery (an internal
//! `Plane`):
//!
//! * [`Server`] — one model behind its own admission gate (the original
//!   single-model shape; its public API is unchanged);
//! * [`Fleet`] — N per-model-tag planes behind **one shared admission
//!   gate**, so a single overload budget governs the whole host while
//!   each model keeps its own queues, stats and shutdown path
//!   (DESIGN.md §10).
//!
//! Shutdown is deterministic and lossless: the submit channel is closed
//! first (so the batcher's disconnect path flushes every pending
//! request), the batcher is joined, the rings are closed, and engines
//! drain them to empty before exiting. Every admitted request receives a
//! response.
//!
//! Python is never on this path: the engines consume only
//! `artifacts/*.hlo.txt` (or run the synthetic / native backends, which
//! need no artifacts at all).

pub mod batcher;
pub mod fleet;
pub mod loadgen;
pub mod policy;
pub mod queue;
pub(crate) mod shard;
pub mod stats;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::kernel::{CompiledModel, NativeSparseBackend, PipeObs};
use crate::obs::trace::{EventKind, TraceHandle};
use crate::obs::ObsConfig;
use crate::runtime::{InferenceBackend, ModelRuntime, SyntheticRuntime, IMG, NUM_CLASSES};
use crate::util::error::{Error, Result};

pub use batcher::BatchPolicy;
pub use fleet::{Fleet, FleetOptions, FleetSnapshot, ModelSpec, TagHandle};
pub use loadgen::{LoadReport, MixReport, Phase, ShedMode, Submit};
pub use policy::{
    AutotuneConfig, Controller, Decision, FleetTelemetry, Policy, QueueAutotune, SloSpec,
    TagTelemetry, WeightedAdmission,
};
pub use queue::{Admission, AdmissionGate, Entry, PlaneGates, TagBudget};
pub use stats::{ServerStats, StatsSnapshot};

/// One inference request.
pub struct Request {
    /// Monotone per-plane request id (diagnostics only).
    pub id: u64,
    /// 28*28 f32 image.
    pub image: Vec<f32>,
    /// Submit-time instant the end-to-end latency is measured from.
    pub enqueued: Instant,
    /// Channel the response is delivered on.
    pub resp: mpsc::Sender<Response>,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    /// Id of the request this answers.
    pub id: u64,
    /// Raw class logits (`NUM_CLASSES` floats; all-NaN marks a failure).
    pub logits: Vec<f32>,
    /// Queue + batch + execute time.
    pub latency_s: f64,
}

impl Response {
    /// Argmax class of the logits.
    pub fn class(&self) -> usize {
        crate::runtime::argmax_classes(&self.logits)[0]
    }

    /// True when the engine failed this request (NaN logits).
    pub fn is_error(&self) -> bool {
        self.logits.first().map(|l| l.is_nan()).unwrap_or(true)
    }
}

/// A batch formed by the batcher.
pub(crate) struct Batch {
    pub requests: Vec<Request>,
}

/// Which backend each engine replica runs. The spec is `Send + Clone`;
/// the backend itself is constructed inside its engine thread.
#[derive(Debug, Clone)]
pub enum EngineBackend {
    /// PJRT over AOT artifacts (`lenet_<tag>_b*.hlo.txt` under `dir`).
    Artifacts {
        /// Artifacts directory.
        dir: String,
        /// Artifact tag (e.g. "proposed").
        tag: String,
    },
    /// Deterministic synthetic compute with a fixed per-image cost —
    /// engine-free serving (tests, benches, capacity planning).
    Synthetic {
        /// Simulated wall-clock cost per image.
        per_image: Duration,
    },
    /// Baked native kernels (`kernel::CompiledModel`): real engine-free
    /// inference — nnz-only MAC schedules, no PJRT, no artifacts. The
    /// compiled model is immutable, so replicas share one `Arc`.
    Native {
        /// The compiled model every replica executes.
        model: Arc<CompiledModel>,
    },
    /// Baked native kernels executed as a layer pipeline
    /// ([`kernel::StagedExecutor`](crate::kernel::StagedExecutor)):
    /// stages split into cost-balanced groups with one or more workers
    /// per group, bounded rings between them — request k's layer N
    /// overlaps request k+1's layer N−1 (DESIGN.md §13). Spare cores
    /// budget stage groups, and any slack beyond one worker per group
    /// replicates the costliest groups to lift the II floor
    /// (DESIGN.md §15).
    NativePipelined {
        /// The compiled model every replica executes.
        model: Arc<CompiledModel>,
        /// Requested stage groups; 0 = auto (per-engine core budget).
        stages: usize,
        /// Requested bottleneck replication; 0 = auto (spend budget
        /// slack via the water-filling plan), r ≥ 1 pins the costliest
        /// group's worker count (clamped to the core budget).
        replicas: usize,
    },
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Batch formation policy (size / deadline triggers).
    pub policy: BatchPolicy,
    /// Engine replicas (each builds its own backend).
    pub engines: usize,
    /// Backend every engine replica runs.
    pub backend: EngineBackend,
    /// Admission bound: requests admitted but not yet completed. Beyond
    /// it `submit` fast-rejects with [`Error::Overloaded`].
    pub admission_capacity: usize,
    /// Per-engine work-ring depth, in batches.
    pub queue_depth: usize,
    /// Observability wiring (tracer + metrics registry); default off.
    pub obs: ObsConfig,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            policy: BatchPolicy::default(),
            engines: 1,
            backend: EngineBackend::Artifacts {
                dir: "artifacts".into(),
                tag: "proposed".into(),
            },
            admission_capacity: 1024,
            queue_depth: 16,
            obs: ObsConfig::default(),
        }
    }
}

impl ServerOptions {
    /// Artifact-backed serving (the production shape).
    pub fn artifacts(dir: impl Into<String>, tag: impl Into<String>) -> Self {
        ServerOptions {
            backend: EngineBackend::Artifacts { dir: dir.into(), tag: tag.into() },
            ..Default::default()
        }
    }

    /// Engine-free serving with the synthetic backend.
    pub fn synthetic(per_image: Duration) -> Self {
        ServerOptions {
            backend: EngineBackend::Synthetic { per_image },
            ..Default::default()
        }
    }

    /// Engine-free serving with baked native kernels.
    pub fn native(model: Arc<CompiledModel>) -> Self {
        ServerOptions {
            backend: EngineBackend::Native { model },
            ..Default::default()
        }
    }

    /// Engine-free serving with baked native kernels running as a layer
    /// pipeline (`stages` groups; 0 = auto from the core budget).
    /// Replication is auto: budget slack beyond one worker per group is
    /// spent on the costliest groups.
    pub fn native_pipelined(model: Arc<CompiledModel>, stages: usize) -> Self {
        ServerOptions {
            backend: EngineBackend::NativePipelined { model, stages, replicas: 0 },
            ..Default::default()
        }
    }

    /// Engine-free pipelined serving with the costliest group pinned to
    /// `replicas` workers (clamped to the per-engine core budget;
    /// `replicas` = 0 falls back to the auto plan).
    pub fn native_pipelined_replicated(
        model: Arc<CompiledModel>,
        stages: usize,
        replicas: usize,
    ) -> Self {
        ServerOptions {
            backend: EngineBackend::NativePipelined { model, stages, replicas },
            ..Default::default()
        }
    }
}

/// Per-plane knobs [`Plane::start`] consumes — everything a plane needs
/// besides the (possibly shared) host admission gate. Bundled so the
/// single-model [`Server`], the [`Fleet`], and live registration all
/// build planes through one door.
pub(crate) struct PlaneConfig {
    /// Batch formation policy.
    pub policy: BatchPolicy,
    /// Engine replicas.
    pub engines: usize,
    /// Backend every engine replica runs.
    pub backend: EngineBackend,
    /// Initial per-engine work-ring depth, in batches (the policy
    /// control plane may retune it later).
    pub queue_depth: usize,
    /// The tag's SLO, when one is configured (fleet planes only).
    pub slo: Option<policy::SloSpec>,
    /// Plane label: the model tag (fleet) or `"serve"` (single-model).
    /// Prefixes this plane's trace rings and metric names.
    pub tag: String,
    /// Observability wiring; default off costs nothing anywhere.
    pub obs: ObsConfig,
}

/// One per-model serving plane: batcher thread + sharded engines, gated
/// by a [`PlaneGates`] pair — its **own** [`TagBudget`] (retunable by
/// the policy control plane, DESIGN.md §11) in front of a host
/// [`AdmissionGate`] it does **not** own. The single-model [`Server`]
/// gives its plane a private gate, a [`Fleet`] shares one gate across
/// all of its planes. Extracted from the old `Server` body so both
/// shapes run the identical submit / dispatch / drain machinery.
pub(crate) struct Plane {
    /// `Some` while accepting; taken (dropped) first at shutdown so the
    /// batcher's channel-closed exit path actually fires.
    submit_tx: Option<mpsc::Sender<Request>>,
    gates: Arc<PlaneGates>,
    plane: Arc<shard::ExecutionPlane>,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    batcher: Option<JoinHandle<()>>,
    engines: Option<Vec<JoinHandle<()>>>,
    next_id: AtomicU64,
    slo: Option<policy::SloSpec>,
    /// Submit-path trace ring + interned tag id, when tracing is on.
    trace_submit: Option<(TraceHandle, u16)>,
}

impl Plane {
    /// Start one plane; fails fast if the backend cannot be built (each
    /// engine verifies its backend before the plane is returned).
    pub(crate) fn start(cfg: PlaneConfig, gate: Arc<AdmissionGate>) -> Result<Plane> {
        let PlaneConfig { policy, engines, backend, queue_depth, slo, tag, obs } = cfg;
        if engines == 0 {
            return Err(Error::config("engines must be >= 1"));
        }
        if queue_depth == 0 {
            return Err(Error::config("queue_depth must be >= 1"));
        }
        let gates = Arc::new(PlaneGates::new(gate, Arc::new(queue::TagBudget::unlimited())));
        // With a registry attached the plane's counters are the scrape's
        // cells (one write path); detached planes use private atomics.
        let stats = Arc::new(match &obs.metrics {
            Some(reg) => ServerStats::new_in(reg, &format!("{tag}.")),
            None => ServerStats::new(),
        });
        let shutdown = Arc::new(AtomicBool::new(false));
        // Trace rings: one shared MPSC ring on the submit path (clients
        // are many), one per batcher, one per engine worker. Registration
        // locks; recording through the handles never does.
        let tag_id = obs.tracer.as_ref().map(|t| t.intern(&tag)).unwrap_or(0);
        let trace_submit =
            obs.tracer.as_ref().map(|t| (t.register(&format!("{tag}.submit")), tag_id));
        let trace_batcher =
            obs.tracer.as_ref().map(|t| (t.register(&format!("{tag}.batcher")), tag_id));

        let (submit_tx, submit_rx) = mpsc::channel::<Request>();
        let (plane, mailboxes) = shard::ExecutionPlane::new(engines, queue_depth);

        // Engines: verify backends build before declaring the plane up.
        let mut engine_handles = Vec::with_capacity(engines);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for (k, mailbox) in mailboxes.into_iter().enumerate() {
            let plane = Arc::clone(&plane);
            let st = Arc::clone(&stats);
            let g = Arc::clone(&gates);
            let spec = backend.clone();
            let ready = ready_tx.clone();
            let etr =
                obs.tracer.as_ref().map(|t| (t.register(&format!("{tag}.e{k}")), tag_id));
            let pobs = if obs.is_off() {
                PipeObs::default()
            } else {
                PipeObs {
                    tracer: obs.tracer.clone(),
                    metrics: obs.metrics.clone(),
                    label: format!("{tag}.e{k}.pipe"),
                }
            };
            engine_handles.push(std::thread::spawn(move || {
                let backend: Box<dyn InferenceBackend> = match &spec {
                    EngineBackend::Artifacts { dir, tag } => {
                        match ModelRuntime::load(dir, tag) {
                            Ok(rt) => {
                                let _ = ready.send(Ok(()));
                                Box::new(rt)
                            }
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        }
                    }
                    EngineBackend::Synthetic { per_image } => {
                        let _ = ready.send(Ok(()));
                        Box::new(SyntheticRuntime::new(*per_image))
                    }
                    EngineBackend::Native { model } => {
                        // Spare cores become per-engine batch-pool workers
                        // (0 on saturated hosts → plain serial batches).
                        let workers = shard::workers_per_engine(engines);
                        match NativeSparseBackend::with_workers(Arc::clone(model), workers) {
                            Ok(b) => {
                                let _ = ready.send(Ok(()));
                                Box::new(b)
                            }
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        }
                    }
                    EngineBackend::NativePipelined { model, stages, replicas } => {
                        // Spare cores become stage-group workers instead of
                        // batch-pool workers (1 group on saturated hosts →
                        // the serial walk on a single worker). Budget slack
                        // beyond one worker per group replicates bottleneck
                        // groups — auto via the water-filling plan, or pinned
                        // on the costliest group when `replicas` ≥ 1.
                        let groups = shard::pipeline_groups_per_engine(
                            engines,
                            *stages,
                            model.stages().len(),
                        );
                        let built = if *replicas == 0 {
                            let workers =
                                shard::pipeline_workers_per_engine(engines, groups);
                            NativeSparseBackend::with_pipeline_budget_obs(
                                Arc::clone(model),
                                groups,
                                workers,
                                pobs,
                            )
                        } else {
                            let r = shard::pipeline_replicas_per_engine(
                                engines, groups, *replicas,
                            );
                            NativeSparseBackend::with_pipeline_replicated_obs(
                                Arc::clone(model),
                                groups,
                                r,
                                pobs,
                            )
                        };
                        match built {
                            Ok(b) => {
                                let _ = ready.send(Ok(()));
                                Box::new(b)
                            }
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        }
                    }
                };
                shard::worker_loop(&plane, &mailbox, |batch, stolen| {
                    if stolen {
                        st.on_steal();
                        if let Some((h, t)) = &etr {
                            let id = batch.requests.first().map(|r| r.id).unwrap_or(0);
                            h.record(EventKind::Stolen, id, *t, 0, 0);
                        }
                    }
                    execute_batch(backend.as_ref(), batch, &st, &g, etr.as_ref());
                });
            }));
        }
        drop(ready_tx);
        for _ in 0..engines {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    // Unblock any engines that did come up, then bail.
                    shutdown.store(true, Ordering::SeqCst);
                    plane.close();
                    return Err(e);
                }
                Err(_) => {
                    // An engine died before reporting readiness (panic in
                    // backend construction). Close the plane so engines
                    // that did come up drain out instead of leaking.
                    shutdown.store(true, Ordering::SeqCst);
                    plane.close();
                    return Err(Error::QueueClosed);
                }
            }
        }

        // Batcher thread.
        let st = Arc::clone(&stats);
        let sd = Arc::clone(&shutdown);
        let p = Arc::clone(&plane);
        let g = Arc::clone(&gates);
        let batcher = std::thread::spawn(move || {
            batcher::run(submit_rx, p, g, policy, st, sd, trace_batcher);
        });

        // Plane-state gauges: polled at scrape time, zero hot-path cost
        // (the closures read the same state `augment` samples).
        if let Some(reg) = &obs.metrics {
            let g = Arc::clone(&gates);
            reg.gauge_fn(&format!("{tag}.in_flight"), move || g.budget().depth() as f64);
            let p = Arc::clone(&plane);
            reg.gauge_fn(&format!("{tag}.ring_depth"), move || p.depth() as f64);
            let p = Arc::clone(&plane);
            reg.gauge_fn(&format!("{tag}.ring_full_backoffs"), move || {
                p.full_backoffs() as f64
            });
        }

        Ok(Plane {
            submit_tx: Some(submit_tx),
            gates,
            plane,
            stats,
            shutdown,
            batcher: Some(batcher),
            engines: Some(engine_handles),
            next_id: AtomicU64::new(0),
            slo,
            trace_submit,
        })
    }

    /// Submit one image to this plane; returns the response channel.
    ///
    /// Fast paths out: [`Error::Overloaded`] when either admission scope
    /// is spent — the plane's own tag budget (attributed to
    /// `shed_budget`) or the (possibly shared) host bound (attributed to
    /// `shed`) — and [`Error::QueueClosed`] once shutdown began. Nothing
    /// is queued on any of them.
    pub(crate) fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        if image.len() != IMG * IMG {
            return Err(Error::config(format!(
                "image must be {} floats, got {}",
                IMG * IMG,
                image.len()
            )));
        }
        let tx = self.submit_tx.as_ref().ok_or(Error::QueueClosed)?;
        match self.gates.try_enter() {
            Entry::ShedBudget => {
                self.stats.on_shed_budget();
                if let Some((h, t)) = &self.trace_submit {
                    // Sheds have no request id yet; stamp the would-be id.
                    h.request(EventKind::ShedBudget, self.next_id.load(Ordering::Relaxed), *t);
                }
                return Err(Error::Overloaded);
            }
            Entry::ShedHost => {
                self.stats.on_shed();
                if let Some((h, t)) = &self.trace_submit {
                    h.request(EventKind::ShedHost, self.next_id.load(Ordering::Relaxed), *t);
                }
                return Err(Error::Overloaded);
            }
            Entry::Admitted => {}
        }
        let (resp_tx, resp_rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            enqueued: Instant::now(),
            resp: resp_tx,
        };
        self.stats.on_submit();
        if let Some((h, t)) = &self.trace_submit {
            h.request(EventKind::Admitted, req.id, *t);
        }
        if tx.send(req).is_err() {
            self.gates.exit();
            return Err(Error::QueueClosed);
        }
        Ok(resp_rx)
    }

    /// This plane's stats, augmented with the live plane state the
    /// counters cannot see (budget occupancy/cap, ring depth, SLO).
    pub(crate) fn snapshot(&self) -> StatsSnapshot {
        self.augment(self.stats.snapshot())
    }

    /// Counters-only variant for the policy control plane (no latency
    /// clone/sort — percentile fields are zeroed).
    pub(crate) fn snapshot_counters(&self) -> StatsSnapshot {
        self.augment(self.stats.snapshot_counters())
    }

    /// Bounded-cost variant for the policy control plane: percentiles
    /// from the fixed-size recent-completions window (sort of ≤
    /// `stats::WINDOW` values), not the full reservoir — cheap enough
    /// for every telemetry tick, latency-aware unlike
    /// [`Plane::snapshot_counters`].
    pub(crate) fn snapshot_sampled(&self) -> StatsSnapshot {
        self.augment(self.stats.snapshot_sampled())
    }

    fn augment(&self, mut snap: StatsSnapshot) -> StatsSnapshot {
        snap.in_flight = self.gates.budget().depth();
        snap.budget_capacity = self.gates.budget().limit();
        snap.ring_depth = self.plane.depth();
        snap.ring_full_backoffs = self.plane.full_backoffs();
        snap.slo_p99_ms = self.slo.map(|s| s.p99_ms);
        snap
    }

    /// This plane's retunable admission budget.
    pub(crate) fn budget(&self) -> &queue::TagBudget {
        self.gates.budget()
    }

    /// Retune every engine ring of this plane to `depth` batches.
    pub(crate) fn set_queue_depth(&self, depth: usize) {
        self.plane.set_depth(depth);
    }

    /// Graceful, lossless drain: stop accepting, flush, join everything.
    pub(crate) fn shutdown_impl(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Order matters, and each step is deterministic:
        // 1. Drop the submit sender. The batcher's disconnect arm flushes
        //    every pending request and returns. (The seed joined the
        //    batcher while the sender was still alive, so the documented
        //    "channel closed" exit could never fire and in-flight
        //    requests could be dropped.)
        drop(self.submit_tx.take());
        // 2. Join the batcher: after this, everything ever submitted sits
        //    in the work rings.
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        // 3. Close the rings: engines drain them to empty, then exit.
        self.plane.close();
        if let Some(es) = self.engines.take() {
            for e in es {
                let _ = e.join();
            }
        }
    }
}

impl Drop for Plane {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// A running single-model server: admission gate + batcher thread +
/// sharded engines. The multi-model shape is [`Fleet`].
pub struct Server {
    gate: Arc<AdmissionGate>,
    plane: Plane,
}

impl Server {
    /// Start the server; fails fast if the backend cannot be built (each
    /// engine verifies its backend before the server is returned).
    pub fn start(opts: ServerOptions) -> Result<Self> {
        if opts.admission_capacity == 0 {
            return Err(Error::config("admission_capacity must be >= 1"));
        }
        let gate = Arc::new(AdmissionGate::new(opts.admission_capacity));
        let plane = Plane::start(
            PlaneConfig {
                policy: opts.policy,
                engines: opts.engines,
                backend: opts.backend,
                queue_depth: opts.queue_depth,
                slo: None,
                tag: "serve".into(),
                obs: opts.obs,
            },
            Arc::clone(&gate),
        )?;
        Ok(Server { gate, plane })
    }

    /// Submit one image; returns the response channel.
    ///
    /// Fast paths out: [`Error::Overloaded`] when the admission bound is
    /// hit (nothing queued), [`Error::QueueClosed`] once shutdown began.
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.plane.submit(image)
    }

    /// Submit and wait (convenience for examples/tests).
    pub fn infer_blocking(&self, image: Vec<f32>) -> Result<Response> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| Error::QueueClosed)
    }

    /// Snapshot the live serving statistics.
    pub fn stats(&self) -> StatsSnapshot {
        self.plane.snapshot()
    }

    /// In-flight requests currently admitted (queued or executing).
    pub fn in_flight(&self) -> usize {
        self.gate.depth()
    }

    /// Graceful shutdown: stop accepting, drain deterministically, join.
    pub fn shutdown(mut self) -> StatsSnapshot {
        self.plane.shutdown_impl();
        self.plane.snapshot()
    }
}

/// Execute one batch on `backend` and complete its requests. Admission
/// (both scopes: tag budget + host gate) is released per request, after
/// its response is sent. `trace`, when present, records a completion
/// (or failure) event per sampled request on the engine's ring.
fn execute_batch(
    backend: &dyn InferenceBackend,
    batch: Batch,
    stats: &ServerStats,
    gates: &PlaneGates,
    trace: Option<&(TraceHandle, u16)>,
) {
    let n = batch.requests.len();
    if n == 0 {
        return;
    }
    let px = IMG * IMG;
    let mut x = Vec::with_capacity(n * px);
    for r in &batch.requests {
        x.extend_from_slice(&r.image);
    }
    let t0 = Instant::now();
    // Contain backend panics (e.g. an FFI fault inside PJRT): a panic must
    // fail this batch like any engine error, not kill the worker thread —
    // a dead worker would let its ring fill and wedge the dispatcher's
    // full-ring backoff forever, hanging shutdown. (The old mpsc design
    // self-healed via receiver disconnect; rings need the worker alive.)
    let inferred = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        backend.infer_padded(&x, n)
    }))
    .unwrap_or_else(|_| {
        Err(Error::Xla("engine panicked during batch execution".into()))
    });
    match inferred {
        Ok(logits) => {
            let exec_s = t0.elapsed().as_secs_f64();
            stats.on_batch(n, exec_s);
            for (i, req) in batch.requests.into_iter().enumerate() {
                let latency_s = req.enqueued.elapsed().as_secs_f64();
                stats.on_complete(latency_s);
                if let Some((h, t)) = trace {
                    h.request(EventKind::Completed, req.id, *t);
                }
                let resp = Response {
                    id: req.id,
                    logits: logits[i * NUM_CLASSES..(i + 1) * NUM_CLASSES].to_vec(),
                    latency_s,
                };
                let _ = req.resp.send(resp); // client may have gone away
                gates.exit();
            }
        }
        Err(e) => {
            eprintln!("engine [{}]: batch of {n} failed: {e}", backend.label());
            if let Some((h, t)) = trace {
                for req in &batch.requests {
                    h.request(EventKind::Failed, req.id, *t);
                }
            }
            // Completes every request with NaN logits (clients unblock and
            // can distinguish failure via `Response::is_error`) and
            // releases admission — same protocol as an undispatchable
            // batch.
            batcher::fail_batch(batch, stats, gates);
        }
    }
}
