//! Serving coordinator (L3 request path — substrate S12).
//!
//! The deployment vehicle for the generated accelerator: clients submit
//! single images; a **dynamic batcher** groups them (size- or
//! deadline-triggered, vLLM-router style); **engine threads** execute
//! batches on the PJRT runtime and complete per-request futures. The PJRT
//! client is `Rc`-based (not `Send`), so each engine thread owns a full
//! `ModelRuntime` replica — the same shape as one process per accelerator
//! card.
//!
//! Python is never on this path: the engines consume only
//! `artifacts/*.hlo.txt`.

pub mod batcher;
pub mod queue;
pub mod stats;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::runtime::{ModelRuntime, IMG, NUM_CLASSES};
use crate::util::error::{Error, Result};

pub use batcher::BatchPolicy;
pub use stats::{ServerStats, StatsSnapshot};

/// One inference request.
pub struct Request {
    pub id: u64,
    /// 28*28 f32 image.
    pub image: Vec<f32>,
    pub enqueued: Instant,
    pub resp: mpsc::Sender<Response>,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    /// Queue + batch + execute time.
    pub latency_s: f64,
}

impl Response {
    pub fn class(&self) -> usize {
        crate::runtime::argmax_classes(&self.logits)[0]
    }
}

/// A batch formed by the batcher.
pub(crate) struct Batch {
    pub requests: Vec<Request>,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerOptions {
    pub policy: BatchPolicy,
    /// Engine replicas (each compiles its own runtime).
    pub engines: usize,
    pub artifacts_dir: String,
    pub tag: String,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            policy: BatchPolicy::default(),
            engines: 1,
            artifacts_dir: "artifacts".into(),
            tag: "proposed".into(),
        }
    }
}

/// A running server: batcher thread + engine threads.
pub struct Server {
    submit_tx: mpsc::Sender<Request>,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    batcher: Option<JoinHandle<()>>,
    engines: Option<Vec<JoinHandle<()>>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Server {
    /// Start the server; fails fast if artifacts are missing (each engine
    /// verifies its runtime before the server is returned).
    pub fn start(opts: ServerOptions) -> Result<Self> {
        if opts.engines == 0 {
            return Err(Error::config("engines must be >= 1"));
        }
        let stats = Arc::new(ServerStats::new());
        let shutdown = Arc::new(AtomicBool::new(false));

        let (submit_tx, submit_rx) = mpsc::channel::<Request>();
        let (batch_tx, batch_rx) = mpsc::channel::<Batch>();
        let batch_rx = Arc::new(std::sync::Mutex::new(batch_rx));

        // Engines: verify runtimes load before spawning loops.
        let mut engines = Vec::with_capacity(opts.engines);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for eid in 0..opts.engines {
            let rx = Arc::clone(&batch_rx);
            let st = Arc::clone(&stats);
            let sd = Arc::clone(&shutdown);
            let dir = opts.artifacts_dir.clone();
            let tag = opts.tag.clone();
            let ready = ready_tx.clone();
            engines.push(std::thread::spawn(move || {
                let rt = match ModelRuntime::load(&dir, &tag) {
                    Ok(rt) => {
                        let _ = ready.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                engine_loop(eid, rt, rx, st, sd);
            }));
        }
        drop(ready_tx);
        for _ in 0..opts.engines {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    shutdown.store(true, Ordering::SeqCst);
                    return Err(e);
                }
                Err(_) => return Err(Error::QueueClosed),
            }
        }

        // Batcher thread.
        let policy = opts.policy.clone();
        let st = Arc::clone(&stats);
        let sd = Arc::clone(&shutdown);
        let batcher = std::thread::spawn(move || {
            batcher::run(submit_rx, batch_tx, policy, st, sd);
        });

        Ok(Server {
            submit_tx,
            stats,
            shutdown,
            batcher: Some(batcher),
            engines: Some(engines),
            next_id: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Submit one image; returns the response channel.
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        if image.len() != IMG * IMG {
            return Err(Error::config(format!(
                "image must be {} floats, got {}",
                IMG * IMG,
                image.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            image,
            enqueued: Instant::now(),
            resp: tx,
        };
        self.stats.on_submit();
        self.submit_tx.send(req).map_err(|_| Error::QueueClosed)?;
        Ok(rx)
    }

    /// Submit and wait (convenience for examples/tests).
    pub fn infer_blocking(&self, image: Vec<f32>) -> Result<Response> {
        let rx = self.submit(image)?;
        rx.recv().map_err(|_| Error::QueueClosed)
    }

    pub fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }

    /// Graceful shutdown: stop accepting, drain, join.
    pub fn shutdown(mut self) -> StatsSnapshot {
        self.shutdown_impl();
        self.stats.snapshot()
    }

    fn shutdown_impl(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Close the submit channel by dropping a cloned sender set: the
        // batcher exits when the channel is closed AND the flag is set.
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        if let Some(es) = self.engines.take() {
            for e in es {
                let _ = e.join();
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// Engine loop: execute batches until shutdown + drained.
fn engine_loop(
    _eid: usize,
    rt: ModelRuntime,
    rx: Arc<std::sync::Mutex<mpsc::Receiver<Batch>>>,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
) {
    loop {
        let batch = {
            let guard = rx.lock().expect("batch queue poisoned");
            match guard.recv_timeout(std::time::Duration::from_millis(20)) {
                Ok(b) => Some(b),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        };
        let Some(batch) = batch else {
            if shutdown.load(Ordering::SeqCst) {
                // One last non-blocking drain attempt, then exit.
                let drained = {
                    let guard = rx.lock().expect("batch queue poisoned");
                    guard.try_recv().ok()
                };
                match drained {
                    Some(b) => {
                        execute_batch(&rt, b, &stats);
                        continue;
                    }
                    None => break,
                }
            }
            continue;
        };
        execute_batch(&rt, batch, &stats);
    }
}

fn execute_batch(rt: &ModelRuntime, batch: Batch, stats: &ServerStats) {
    let n = batch.requests.len();
    if n == 0 {
        return;
    }
    let px = IMG * IMG;
    let mut x = Vec::with_capacity(n * px);
    for r in &batch.requests {
        x.extend_from_slice(&r.image);
    }
    let t0 = Instant::now();
    match rt.infer_padded(&x, n) {
        Ok(logits) => {
            let exec_s = t0.elapsed().as_secs_f64();
            stats.on_batch(n, exec_s);
            for (i, req) in batch.requests.into_iter().enumerate() {
                let latency_s = req.enqueued.elapsed().as_secs_f64();
                stats.on_complete(latency_s);
                let resp = Response {
                    id: req.id,
                    logits: logits[i * NUM_CLASSES..(i + 1) * NUM_CLASSES].to_vec(),
                    latency_s,
                };
                let _ = req.resp.send(resp); // client may have gone away
            }
        }
        Err(e) => {
            stats.on_error();
            log::error!("batch of {n} failed: {e}");
            // Complete with empty logits so clients unblock.
            for req in batch.requests {
                let _ = req.resp.send(Response {
                    id: req.id,
                    logits: vec![f32::NAN; NUM_CLASSES],
                    latency_s: req.enqueued.elapsed().as_secs_f64(),
                });
            }
        }
    }
}
