//! Multi-model serving fleet: per-tag execution planes under one shared
//! admission gate (DESIGN.md §10).
//!
//! The engine-free premise makes models cheap to replicate — a baked
//! `CompiledModel` is immutable plain data behind an `Arc`, a synthetic
//! backend is a constant, and even PJRT replicas are per-thread anyway —
//! so one host should serve *many* models at once. A [`Fleet`] owns one
//! full serving plane per model **tag** (its own batcher, work rings,
//! engines, stats and shutdown path, with any [`EngineBackend`] mixed
//! freely), while a single shared [`AdmissionGate`] bounds total in-flight
//! work across the host: one overload budget governs everything, so a
//! traffic spike on one model sheds load instead of starving the others'
//! memory and queues.
//!
//! Routing is lock-free on the hot path: a tag resolves to a plane index
//! with one scan of a small immutable `Vec<String>` (no map, no lock),
//! and [`Fleet::handle`] resolves once up front so repeat submitters skip
//! even that. Rejections are distinguishable: [`Error::Overloaded`] means
//! the shared budget is spent (retry later), [`Error::UnknownModel`] means
//! no plane serves the tag (retrying cannot help).
//!
//! Isolation: planes share *only* the admission gate. A wedged or slow
//! model fills its own rings and its own batcher queue; other tags keep
//! their full dispatch and drain paths (asserted in `tests/serving.rs`).
//! Shutdown walks the planes with the same deterministic lossless drain
//! the single-model [`Server`](super::Server) uses — every admitted
//! request of every tag receives a response.

use std::sync::mpsc;
use std::sync::Arc;

use super::queue::AdmissionGate;
use super::{BatchPolicy, EngineBackend, Plane, Response, StatsSnapshot};
use crate::util::error::{Error, Result};

/// Configuration of one fleet member: a model tag plus the per-plane
/// knobs a single-model [`super::ServerOptions`] would carry (everything
/// except the admission bound, which the fleet shares).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Routing key clients submit against (must be unique in the fleet).
    pub tag: String,
    /// Backend every engine replica of this plane runs.
    pub backend: EngineBackend,
    /// Batch formation policy of this plane.
    pub policy: BatchPolicy,
    /// Engine replicas of this plane.
    pub engines: usize,
    /// Per-engine work-ring depth, in batches.
    pub queue_depth: usize,
}

impl ModelSpec {
    /// A spec with the single-model defaults (1 engine, default policy,
    /// 16-deep rings); chain the builder methods to adjust.
    pub fn new(tag: impl Into<String>, backend: EngineBackend) -> Self {
        ModelSpec {
            tag: tag.into(),
            backend,
            policy: BatchPolicy::default(),
            engines: 1,
            queue_depth: 16,
        }
    }

    /// Set the engine replica count.
    pub fn engines(mut self, engines: usize) -> Self {
        self.engines = engines;
        self
    }

    /// Set the batch formation policy.
    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Set the per-engine work-ring depth.
    pub fn queue_depth(mut self, queue_depth: usize) -> Self {
        self.queue_depth = queue_depth;
        self
    }
}

/// Fleet configuration: the member planes plus the one shared admission
/// budget that governs the whole host.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// One entry per model tag (tags must be unique).
    pub models: Vec<ModelSpec>,
    /// Shared admission bound across **all** planes: total requests
    /// admitted but not yet completed, host-wide.
    pub admission_capacity: usize,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions { models: Vec::new(), admission_capacity: 1024 }
    }
}

/// A running multi-model fleet: N per-tag planes behind one shared
/// admission gate. See the [module docs](self) for the architecture.
pub struct Fleet {
    tags: Vec<String>,
    planes: Vec<Plane>,
    gate: Arc<AdmissionGate>,
}

impl Fleet {
    /// Start every plane; fails fast if any backend cannot be built
    /// (planes already started are drained and joined by `Drop`).
    pub fn start(opts: FleetOptions) -> Result<Fleet> {
        if opts.models.is_empty() {
            return Err(Error::config("fleet needs at least one model"));
        }
        if opts.admission_capacity == 0 {
            return Err(Error::config("admission_capacity must be >= 1"));
        }
        for (i, m) in opts.models.iter().enumerate() {
            if opts.models[..i].iter().any(|p| p.tag == m.tag) {
                return Err(Error::config(format!("duplicate model tag '{}'", m.tag)));
            }
        }
        let gate = Arc::new(AdmissionGate::new(opts.admission_capacity));
        let mut tags = Vec::with_capacity(opts.models.len());
        let mut planes = Vec::with_capacity(opts.models.len());
        for spec in opts.models {
            let plane = Plane::start(
                spec.policy,
                spec.engines,
                spec.backend,
                spec.queue_depth,
                Arc::clone(&gate),
            )?;
            tags.push(spec.tag);
            planes.push(plane);
        }
        Ok(Fleet { tags, planes, gate })
    }

    /// The model tags this fleet serves, in plane order.
    pub fn tags(&self) -> &[String] {
        &self.tags
    }

    /// Resolve a tag to its plane index (the one-time routing step);
    /// [`Error::UnknownModel`] if no plane serves the tag.
    pub fn resolve(&self, tag: &str) -> Result<usize> {
        self.tags
            .iter()
            .position(|t| t == tag)
            .ok_or_else(|| Error::unknown_model(tag))
    }

    /// A pre-resolved submit handle for `tag`: repeat submitters pay the
    /// tag scan once here and never again on the hot path.
    pub fn handle(&self, tag: &str) -> Result<TagHandle<'_>> {
        Ok(TagHandle { fleet: self, index: self.resolve(tag)? })
    }

    /// Submit one image to the plane serving `tag`.
    ///
    /// Fast paths out, all without queueing anything:
    /// [`Error::UnknownModel`] when no plane serves the tag,
    /// [`Error::Overloaded`] when the shared admission budget is spent,
    /// [`Error::QueueClosed`] once shutdown began.
    pub fn submit(&self, tag: &str, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.planes[self.resolve(tag)?].submit(image)
    }

    /// Submit to a plane by pre-resolved index (see [`Fleet::resolve`]);
    /// an out-of-range index is a config error, not a panic.
    pub fn submit_at(&self, index: usize, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.planes
            .get(index)
            .ok_or_else(|| {
                Error::config(format!(
                    "plane index {index} out of range for a {}-model fleet",
                    self.planes.len()
                ))
            })?
            .submit(image)
    }

    /// Submit to `tag` and wait (convenience for examples/tests).
    pub fn infer_blocking(&self, tag: &str, image: Vec<f32>) -> Result<Response> {
        let rx = self.submit(tag, image)?;
        rx.recv().map_err(|_| Error::QueueClosed)
    }

    /// In-flight requests currently admitted host-wide (queued or
    /// executing, summed over every plane — the shared budget in use).
    pub fn in_flight(&self) -> usize {
        self.gate.depth()
    }

    /// The shared admission bound the fleet was started with.
    pub fn admission_capacity(&self) -> usize {
        self.gate.capacity()
    }

    /// Snapshot every plane's stats plus the shared-gate shed total.
    pub fn stats(&self) -> FleetSnapshot {
        FleetSnapshot {
            per_model: self
                .tags
                .iter()
                .zip(&self.planes)
                .map(|(t, p)| (t.clone(), p.snapshot()))
                .collect(),
            shed: self.gate.shed_total(),
        }
    }

    /// Graceful shutdown: drain every plane deterministically (same
    /// lossless protocol as [`super::Server::shutdown`], applied per
    /// plane) and return the final roll-up.
    pub fn shutdown(mut self) -> FleetSnapshot {
        for plane in &mut self.planes {
            plane.shutdown_impl();
        }
        self.stats()
    }
}

/// A borrowed, pre-resolved submit target for one fleet tag — the
/// routing scan already happened in [`Fleet::handle`], so every
/// [`TagHandle::submit`] is a direct plane submit. Implements
/// [`super::Submit`], so the open-loop load generator can drive a single
/// fleet tag exactly like a standalone [`super::Server`].
#[derive(Clone, Copy)]
pub struct TagHandle<'a> {
    fleet: &'a Fleet,
    index: usize,
}

impl TagHandle<'_> {
    /// The tag this handle routes to.
    pub fn tag(&self) -> &str {
        &self.fleet.tags[self.index]
    }

    /// The resolved plane index.
    pub fn index(&self) -> usize {
        self.index
    }

    /// Submit one image to this tag's plane (see [`Fleet::submit`] for
    /// the error contract, minus the impossible `UnknownModel`).
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.fleet.submit_at(self.index, image)
    }
}

/// Roll-up of a fleet's statistics: one [`StatsSnapshot`] per tag plus
/// the shared admission gate's shed total. Per-tag sheds (each plane's
/// `shed` counter) and the gate total count the same events from two
/// sides and must agree: `shed == sum(per-tag shed)`.
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    /// `(tag, snapshot)` per plane, in plane order.
    pub per_model: Vec<(String, StatsSnapshot)>,
    /// Host-wide sheds counted by the shared admission gate.
    pub shed: u64,
}

impl FleetSnapshot {
    /// The snapshot of one tag, if present.
    pub fn get(&self, tag: &str) -> Option<&StatsSnapshot> {
        self.per_model.iter().find(|(t, _)| t == tag).map(|(_, s)| s)
    }

    /// Total requests admitted across all tags.
    pub fn submitted(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.submitted).sum()
    }

    /// Total requests served successfully across all tags.
    pub fn completed(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.completed).sum()
    }

    /// Total requests answered with an engine error across all tags.
    pub fn errors(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.errors).sum()
    }

    /// Per-tag sheds summed — must equal [`FleetSnapshot::shed`].
    pub fn shed_by_tag(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.shed).sum()
    }

    /// Fleet summary line plus one indented line per tag.
    pub fn render(&self) -> String {
        let mut s = format!(
            "fleet: {} models | served {}/{} ({} errors, {} shed)",
            self.per_model.len(),
            self.completed(),
            self.submitted(),
            self.errors(),
            self.shed,
        );
        for (tag, snap) in &self.per_model {
            s.push_str(&format!("\n  [{tag}] {}", snap.render()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SyntheticRuntime;
    use std::time::Duration;

    fn synthetic(us: u64) -> EngineBackend {
        EngineBackend::Synthetic { per_image: Duration::from_micros(us) }
    }

    fn image(i: u64) -> Vec<f32> {
        SyntheticRuntime::stripe_image(i as usize)
    }

    #[test]
    fn config_validation() {
        assert!(Fleet::start(FleetOptions::default()).is_err());
        let dup = FleetOptions {
            models: vec![
                ModelSpec::new("a", synthetic(0)),
                ModelSpec::new("a", synthetic(0)),
            ],
            admission_capacity: 16,
        };
        assert!(Fleet::start(dup).is_err());
        let zero_cap = FleetOptions {
            models: vec![ModelSpec::new("a", synthetic(0))],
            admission_capacity: 0,
        };
        assert!(Fleet::start(zero_cap).is_err());
    }

    #[test]
    fn routes_by_tag_and_rejects_unknown() {
        let fleet = Fleet::start(FleetOptions {
            models: vec![
                ModelSpec::new("alpha", synthetic(0)),
                ModelSpec::new("beta", synthetic(0)),
            ],
            admission_capacity: 64,
        })
        .unwrap();
        assert_eq!(fleet.tags(), &["alpha".to_string(), "beta".to_string()]);
        assert_eq!(fleet.resolve("beta").unwrap(), 1);
        assert!(matches!(fleet.resolve("gamma"), Err(Error::UnknownModel(_))));
        assert!(matches!(
            fleet.submit("gamma", image(0)),
            Err(Error::UnknownModel(_))
        ));
        assert!(matches!(fleet.submit_at(7, image(0)), Err(Error::Config(_))));

        let h = fleet.handle("beta").unwrap();
        assert_eq!(h.tag(), "beta");
        assert_eq!(h.index(), 1);
        let resp = fleet.infer_blocking("alpha", image(3)).unwrap();
        assert_eq!(resp.class(), 3);
        let resp = h.submit(image(7)).unwrap().recv().unwrap();
        assert_eq!(resp.class(), 7);

        let snap = fleet.shutdown();
        assert_eq!(snap.get("alpha").unwrap().completed, 1);
        assert_eq!(snap.get("beta").unwrap().completed, 1);
        assert_eq!(snap.completed(), 2);
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.shed_by_tag(), 0);
        assert!(snap.render().contains("[alpha]"));
    }

    #[test]
    fn snapshot_rolls_up_per_tag_counters() {
        let fleet = Fleet::start(FleetOptions {
            models: vec![
                ModelSpec::new("x", synthetic(0)),
                ModelSpec::new("y", synthetic(0)),
            ],
            admission_capacity: 256,
        })
        .unwrap();
        for i in 0..6u64 {
            fleet.infer_blocking("x", image(i)).unwrap();
        }
        for i in 0..4u64 {
            fleet.infer_blocking("y", image(i)).unwrap();
        }
        let snap = fleet.stats();
        assert_eq!(snap.get("x").unwrap().completed, 6);
        assert_eq!(snap.get("y").unwrap().completed, 4);
        assert_eq!(snap.completed(), 10);
        assert_eq!(snap.submitted(), 10);
        assert_eq!(snap.errors(), 0);
        assert_eq!(fleet.in_flight(), 0);
        assert_eq!(fleet.admission_capacity(), 256);
        let _ = fleet.shutdown();
    }
}
