//! Multi-model serving fleet: per-tag execution planes under one shared
//! admission gate, governed by the policy control plane (DESIGN.md §10,
//! §11).
//!
//! The engine-free premise makes models cheap to replicate — a baked
//! `CompiledModel` is immutable plain data behind an `Arc`, a synthetic
//! backend is a constant, and even PJRT replicas are per-thread anyway —
//! so one host should serve *many* models at once. A [`Fleet`] owns one
//! full serving plane per model **tag** (its own batcher, work rings,
//! engines, stats and shutdown path, with any [`EngineBackend`] mixed
//! freely), while a single shared [`AdmissionGate`] bounds total in-flight
//! work across the host: one overload budget governs everything, so a
//! traffic spike on one model sheds load instead of starving the others'
//! memory and queues.
//!
//! On top of that shared budget, each plane carries its **own retunable
//! [`TagBudget`](super::TagBudget)** and the fleet runs a
//! [`Controller`]: [`Fleet::tick`] samples telemetry, asks the policies
//! to decide, and applies the decisions (per-tag admission caps from SLO
//! weights, ring-depth autotuning). Decisions are pure functions of the
//! telemetry snapshot — no wall-clock reads — so control behaviour is
//! replayable (see `coordinator::policy`).
//!
//! **Membership is dynamic**: [`Fleet::register`] adds a tagged plane to
//! a running host and [`Fleet::retire`] drains one losslessly (every
//! in-flight request of the retired tag still receives its response).
//! Retired planes leave a tombstone slot, so stale pre-resolved indices
//! fail with [`Error::UnknownModel`] instead of silently routing to a
//! neighbour.
//!
//! Routing is lock-free on the hot path: a tag resolves to a slot index
//! with one scan of a small slot vector (no map, no lock), and
//! [`Fleet::handle`] resolves once up front so repeat submitters skip
//! even that. Rejections are distinguishable: [`Error::Overloaded`] means
//! an admission budget is spent — the tag's own or the host's, told apart
//! in the stats (`shed_budget` vs `shed`) — while [`Error::UnknownModel`]
//! means no live plane serves the tag (retrying cannot help until an
//! operator registers it).
//!
//! Isolation: planes share *only* the admission gate. A wedged or slow
//! model fills its own rings and its own batcher queue; other tags keep
//! their full dispatch and drain paths (asserted in `tests/serving.rs`).
//! Shutdown walks the planes with the same deterministic lossless drain
//! the single-model [`Server`](super::Server) uses — every admitted
//! request of every tag receives a response.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, RwLock, RwLockReadGuard};

use super::policy::{
    AutotuneConfig, Controller, Decision, FleetTelemetry, QueueAutotune, SloSpec,
    TagTelemetry, WeightedAdmission,
};
use super::queue::AdmissionGate;
use super::{BatchPolicy, EngineBackend, Plane, PlaneConfig, Response, StatsSnapshot};
use crate::obs::ObsConfig;
use crate::util::error::{Error, Result};

/// Configuration of one fleet member: a model tag plus the per-plane
/// knobs a single-model [`super::ServerOptions`] would carry (everything
/// except the admission bound, which the fleet shares), plus an optional
/// per-tag SLO.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Routing key clients submit against (must be unique among live
    /// tags).
    pub tag: String,
    /// Backend every engine replica of this plane runs.
    pub backend: EngineBackend,
    /// Batch formation policy of this plane.
    pub policy: BatchPolicy,
    /// Engine replicas of this plane.
    pub engines: usize,
    /// Initial per-engine work-ring depth, in batches (autotuning may
    /// retune it).
    pub queue_depth: usize,
    /// Per-tag SLO: p99 target + admission weight. When any live tag
    /// carries one, the host budget is partitioned into per-tag caps by
    /// weight (DESIGN.md §11).
    pub slo: Option<SloSpec>,
}

impl ModelSpec {
    /// A spec with the single-model defaults (1 engine, default policy,
    /// 16-deep rings, no SLO); chain the builder methods to adjust.
    pub fn new(tag: impl Into<String>, backend: EngineBackend) -> Self {
        ModelSpec {
            tag: tag.into(),
            backend,
            policy: BatchPolicy::default(),
            engines: 1,
            queue_depth: 16,
            slo: None,
        }
    }

    /// Set the engine replica count.
    pub fn engines(mut self, engines: usize) -> Self {
        self.engines = engines;
        self
    }

    /// Set the batch formation policy.
    pub fn policy(mut self, policy: BatchPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Set the per-engine work-ring depth.
    pub fn queue_depth(mut self, queue_depth: usize) -> Self {
        self.queue_depth = queue_depth;
        self
    }

    /// Set the tag's SLO: a p99 latency target (ms) and an admission
    /// weight (> 0).
    pub fn slo(mut self, p99_ms: f64, weight: f64) -> Self {
        self.slo = Some(SloSpec::new(p99_ms, weight));
        self
    }

    fn plane_config(&self, obs: ObsConfig) -> PlaneConfig {
        PlaneConfig {
            policy: self.policy.clone(),
            engines: self.engines,
            backend: self.backend.clone(),
            queue_depth: self.queue_depth,
            slo: self.slo,
            tag: self.tag.clone(),
            obs,
        }
    }
}

/// Fleet configuration: the member planes, the one shared admission
/// budget that governs the whole host, and the optional queue-depth
/// autotuner.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// One entry per model tag (tags must be unique).
    pub models: Vec<ModelSpec>,
    /// Shared admission bound across **all** planes: total requests
    /// admitted but not yet completed, host-wide.
    pub admission_capacity: usize,
    /// When set, [`Fleet::tick`] additionally runs the queue-depth
    /// autotuner with these bounds (weighted admission always runs).
    pub autotune: Option<AutotuneConfig>,
    /// Observability wiring shared by every plane (each plane prefixes
    /// its rings and metrics with its tag); default off.
    pub obs: ObsConfig,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            models: Vec::new(),
            admission_capacity: 1024,
            autotune: None,
            obs: ObsConfig::default(),
        }
    }
}

/// One membership slot: a tag and its plane, or a tombstone once the tag
/// retired (the slot keeps its index so stale pre-resolved handles fail
/// with `UnknownModel` instead of routing to a shifted neighbour).
struct Slot {
    tag: String,
    plane: Option<Plane>,
    slo: Option<SloSpec>,
}

/// A running multi-model fleet: per-tag planes behind one shared
/// admission gate, with a policy control loop and dynamic membership.
/// See the [module docs](self) for the architecture.
pub struct Fleet {
    /// Membership behind a read-write lock: the hot path (submit,
    /// telemetry) takes cheap read guards, while `register`/`retire`
    /// take the write guard only for the membership edit itself — plane
    /// startup and the lossless retire drain both happen **outside** the
    /// lock, so traffic to other tags never stalls behind them. Interior
    /// mutability is what lets the serve loop, a churn script and the
    /// background cadence thread share one `&Fleet`.
    slots: RwLock<Vec<Slot>>,
    gate: Arc<AdmissionGate>,
    controller: Mutex<Controller>,
    /// Host-gate sheds attributed to tags that have since retired, kept
    /// so the gate-total vs per-tag reconciliation survives membership
    /// churn. Shared (`Arc`) so a fleet-level gauge can read it.
    retired_shed: Arc<AtomicU64>,
    /// Observability wiring handed to every plane — kept so planes
    /// registered live ([`Fleet::register`]) wire up the same sinks.
    obs: ObsConfig,
}

/// Live `(index, slot, plane)` triples of one locked slot vector.
fn live<'a>(slots: &'a [Slot]) -> impl Iterator<Item = (usize, &'a Slot, &'a Plane)> {
    slots
        .iter()
        .enumerate()
        .filter_map(|(i, s)| s.plane.as_ref().map(|p| (i, s, p)))
}

impl Fleet {
    /// Start every plane; fails fast if any backend cannot be built
    /// (planes already started are drained and joined by `Drop`).
    pub fn start(opts: FleetOptions) -> Result<Fleet> {
        if opts.models.is_empty() {
            return Err(Error::config("fleet needs at least one model"));
        }
        if opts.admission_capacity == 0 {
            return Err(Error::config("admission_capacity must be >= 1"));
        }
        for (i, m) in opts.models.iter().enumerate() {
            if opts.models[..i].iter().any(|p| p.tag == m.tag) {
                return Err(Error::config(format!("duplicate model tag '{}'", m.tag)));
            }
        }
        let gate = Arc::new(AdmissionGate::new(opts.admission_capacity));
        let mut controller = Controller::new();
        controller.push(Box::new(WeightedAdmission));
        if let Some(cfg) = opts.autotune {
            controller.push(Box::new(QueueAutotune::new(cfg)));
        }
        let mut slots = Vec::with_capacity(opts.models.len());
        for spec in &opts.models {
            let plane = Plane::start(spec.plane_config(opts.obs.clone()), Arc::clone(&gate))?;
            slots.push(Slot { tag: spec.tag.clone(), plane: Some(plane), slo: spec.slo });
        }
        let retired_shed = Arc::new(AtomicU64::new(0));
        // Fleet-level gauges: the shared gate's state plus the retired
        // shed attribution (per-plane state is registered by each plane).
        if let Some(reg) = &opts.obs.metrics {
            let g = Arc::clone(&gate);
            reg.gauge_fn("fleet.in_flight", move || g.depth() as f64);
            let g = Arc::clone(&gate);
            reg.gauge_fn("fleet.capacity", move || g.capacity() as f64);
            let g = Arc::clone(&gate);
            reg.gauge_fn("fleet.shed_host", move || g.shed_total() as f64);
            let rs = Arc::clone(&retired_shed);
            reg.gauge_fn("fleet.shed_retired", move || {
                rs.load(Ordering::Relaxed) as f64
            });
        }
        let fleet = Fleet {
            slots: RwLock::new(slots),
            gate,
            controller: Mutex::new(controller),
            retired_shed,
            obs: opts.obs,
        };
        // First control tick: applies the weighted budgets (and baselines
        // the autotuner) before any traffic arrives.
        let _ = fleet.tick();
        Ok(fleet)
    }

    /// The slot vector under a read guard (poisoning is unrecoverable
    /// here — a panicked membership edit leaves no sane fleet).
    fn slots(&self) -> RwLockReadGuard<'_, Vec<Slot>> {
        self.slots.read().expect("fleet membership poisoned")
    }

    /// The model tags this fleet currently serves, in slot order.
    pub fn tags(&self) -> Vec<String> {
        live(&self.slots()).map(|(_, s, _)| s.tag.clone()).collect()
    }

    /// Resolve a tag to its slot index (the one-time routing step);
    /// [`Error::UnknownModel`] if no live plane serves the tag.
    pub fn resolve(&self, tag: &str) -> Result<usize> {
        live(&self.slots())
            .find(|(_, s, _)| s.tag == tag)
            .map(|(i, _, _)| i)
            .ok_or_else(|| Error::unknown_model(tag))
    }

    /// A pre-resolved submit handle for `tag`: repeat submitters pay the
    /// tag scan once here and never again on the hot path. Membership may
    /// change under a live handle (`register`/`retire` take `&self`); a
    /// handle whose tag retires fails each submit with
    /// [`Error::UnknownModel`] — tombstone slots keep indices stable, so
    /// it can never silently route to a neighbour.
    pub fn handle(&self, tag: &str) -> Result<TagHandle<'_>> {
        Ok(TagHandle { fleet: self, index: self.resolve(tag)? })
    }

    /// Submit one image to the plane serving `tag`.
    ///
    /// Fast paths out, all without queueing anything:
    /// [`Error::UnknownModel`] when no live plane serves the tag,
    /// [`Error::Overloaded`] when an admission budget is spent (the tag's
    /// own or the shared host budget — attributed separately in the
    /// stats), [`Error::QueueClosed`] once shutdown began.
    pub fn submit(&self, tag: &str, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.submit_at(self.resolve(tag)?, image)
    }

    /// Submit to a plane by pre-resolved index (see [`Fleet::resolve`]).
    /// An out-of-range index is a config error; the index of a retired
    /// tag fails with [`Error::UnknownModel`].
    pub fn submit_at(&self, index: usize, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        let slots = self.slots();
        let slot = slots.get(index).ok_or_else(|| {
            Error::config(format!(
                "plane index {index} out of range for a {}-slot fleet",
                slots.len()
            ))
        })?;
        slot.plane
            .as_ref()
            .ok_or_else(|| Error::unknown_model(&slot.tag))?
            .submit(image)
    }

    /// Submit to `tag` and wait (convenience for examples/tests).
    pub fn infer_blocking(&self, tag: &str, image: Vec<f32>) -> Result<Response> {
        let rx = self.submit(tag, image)?;
        rx.recv().map_err(|_| Error::QueueClosed)
    }

    /// Register a new model on the **running** host: starts a fresh
    /// plane behind the shared admission gate and rebalances per-tag
    /// budgets. Fails (without side effects) if a live plane already
    /// serves the tag or the backend cannot be built. A tag that retired
    /// earlier may be registered again — it gets a new slot; stale
    /// indices keep failing with [`Error::UnknownModel`].
    ///
    /// Takes `&self`: plane startup happens outside the membership lock,
    /// so in-flight traffic on other tags never stalls behind a backend
    /// build. Two racing registers of one tag are serialised by a
    /// re-check under the write guard — the loser's plane is drained and
    /// the loser gets the duplicate-tag error.
    pub fn register(&self, spec: ModelSpec) -> Result<()> {
        let duplicate =
            || Error::config(format!("duplicate model tag '{}': already live", spec.tag));
        // Fail fast without paying for a plane start (best-effort; the
        // authoritative check is under the write guard below).
        if live(&self.slots()).any(|(_, s, _)| s.tag == spec.tag) {
            return Err(duplicate());
        }
        let plane = Plane::start(spec.plane_config(self.obs.clone()), Arc::clone(&self.gate))?;
        {
            let mut slots = self.slots.write().expect("fleet membership poisoned");
            if live(&slots).any(|(_, s, _)| s.tag == spec.tag) {
                drop(slots);
                let mut plane = plane;
                plane.shutdown_impl();
                return Err(duplicate());
            }
            slots.push(Slot { tag: spec.tag, plane: Some(plane), slo: spec.slo });
        }
        let _ = self.tick();
        Ok(())
    }

    /// Retire `tag` from the running host: the plane stops accepting,
    /// drains **losslessly** (every in-flight request of the tag still
    /// receives its response — the §8 shutdown protocol, applied to one
    /// plane), and its final snapshot is returned. The slot becomes a
    /// tombstone, so later submits against the tag or a stale index
    /// fail with [`Error::UnknownModel`]. Budgets rebalance over the
    /// remaining live tags.
    ///
    /// Takes `&self`: the write guard covers only the `plane.take()`
    /// tombstoning; the drain itself runs outside the lock, so other
    /// tags keep their full submit and drain paths while this one winds
    /// down (the isolation property `tests/serving.rs` asserts).
    pub fn retire(&self, tag: &str) -> Result<StatsSnapshot> {
        let mut plane = {
            let mut slots = self.slots.write().expect("fleet membership poisoned");
            let index = live(&slots)
                .find(|(_, s, _)| s.tag == tag)
                .map(|(i, _, _)| i)
                .ok_or_else(|| Error::unknown_model(tag))?;
            slots[index].plane.take().expect("live() returned a live slot")
        };
        plane.shutdown_impl();
        let snap = plane.snapshot();
        drop(plane);
        self.retired_shed.fetch_add(snap.shed, Ordering::Relaxed);
        let _ = self.tick();
        Ok(snap)
    }

    /// Sample the control-plane telemetry: host admission state plus one
    /// [`TagTelemetry`] per live tag. Pure data — policies consume it
    /// without touching the clock. The snapshots are the **sampled**
    /// variant: counters plus latency percentiles from each plane's
    /// bounded recent-completions window (one clone + sort of ≤
    /// `stats::WINDOW` values per tag), so a tick stays O(tags) no
    /// matter how much has been served while still letting policies act
    /// on the tag's *current* p50/p95/p99, not just counters.
    pub fn telemetry(&self) -> FleetTelemetry {
        FleetTelemetry {
            tick: 0, // stamped by the controller
            capacity: self.gate.capacity(),
            in_flight: self.gate.depth(),
            per_tag: live(&self.slots())
                .map(|(_, s, plane)| TagTelemetry {
                    tag: s.tag.clone(),
                    slo: s.slo,
                    stats: plane.snapshot_sampled(),
                })
                .collect(),
        }
    }

    /// Run one control-loop tick: sample [`Fleet::telemetry`], let the
    /// policies decide, apply the decisions (budget caps, ring depths),
    /// and return what was applied. Safe to call from an operator thread
    /// while traffic flows; tests call it directly, which makes control
    /// behaviour deterministic (decisions depend only on the telemetry
    /// sequence, never on the wall clock).
    pub fn tick(&self) -> Vec<Decision> {
        let mut telemetry = self.telemetry();
        let decisions = self
            .controller
            .lock()
            .expect("controller poisoned")
            .tick(&mut telemetry);
        for d in &decisions {
            self.apply(d);
        }
        decisions
    }

    /// Apply one policy decision to the live fleet. Decisions naming a
    /// tag that retired since the telemetry was sampled are dropped
    /// silently — the next tick sees the new membership.
    fn apply(&self, decision: &Decision) {
        let slots = self.slots();
        let plane_of =
            |tag: &str| live(&slots).find(|(_, s, _)| s.tag == tag).map(|(_, _, p)| p);
        match decision {
            Decision::SetTagBudget { tag, budget } => {
                if let Some(p) = plane_of(tag) {
                    p.budget().set_capacity((*budget).max(1));
                }
            }
            Decision::SetTagUnlimited { tag } => {
                if let Some(p) = plane_of(tag) {
                    p.budget().set_unlimited();
                }
            }
            Decision::SetRingDepth { tag, depth } => {
                if let Some(p) = plane_of(tag) {
                    p.set_queue_depth((*depth).max(1));
                }
            }
        }
    }

    /// In-flight requests currently admitted host-wide (queued or
    /// executing, summed over every plane — the shared budget in use).
    pub fn in_flight(&self) -> usize {
        self.gate.depth()
    }

    /// The shared admission bound the fleet was started with.
    pub fn admission_capacity(&self) -> usize {
        self.gate.capacity()
    }

    /// Snapshot every live plane's stats plus the shared-gate state.
    pub fn stats(&self) -> FleetSnapshot {
        FleetSnapshot {
            per_model: live(&self.slots())
                .map(|(_, s, p)| (s.tag.clone(), p.snapshot()))
                .collect(),
            shed: self.gate.shed_total(),
            shed_retired: self.retired_shed.load(Ordering::Relaxed),
            in_flight: self.gate.depth(),
            capacity: self.gate.capacity(),
        }
    }

    /// Graceful shutdown: drain every live plane deterministically (same
    /// lossless protocol as [`super::Server::shutdown`], applied per
    /// plane) and return the final roll-up. Consumes the fleet, so no
    /// lock is contended (`get_mut` reaches the slots directly).
    pub fn shutdown(mut self) -> FleetSnapshot {
        let slots = self.slots.get_mut().expect("fleet membership poisoned");
        for slot in slots.iter_mut() {
            if let Some(plane) = slot.plane.as_mut() {
                plane.shutdown_impl();
            }
        }
        self.stats()
    }
}

/// A borrowed, pre-resolved submit target for one fleet tag — the
/// routing scan already happened in [`Fleet::handle`], so every
/// [`TagHandle::submit`] is a direct plane submit. Implements
/// [`super::Submit`], so the open-loop load generator can drive a single
/// fleet tag exactly like a standalone [`super::Server`]. Membership may
/// change while a handle is live (`register`/`retire` take `&self`); a
/// handle to a retired tag fails each submit with
/// [`Error::UnknownModel`] because tombstone slots keep indices stable.
#[derive(Clone, Copy)]
pub struct TagHandle<'a> {
    fleet: &'a Fleet,
    index: usize,
}

impl TagHandle<'_> {
    /// The tag this handle routes to (owned: the membership table lives
    /// behind a lock, so no borrow can escape it).
    pub fn tag(&self) -> String {
        self.fleet.slots()[self.index].tag.clone()
    }

    /// The resolved slot index.
    pub fn index(&self) -> usize {
        self.index
    }

    /// Submit one image to this tag's plane (see [`Fleet::submit`] for
    /// the error contract, minus the impossible `UnknownModel`).
    pub fn submit(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Response>> {
        self.fleet.submit_at(self.index, image)
    }
}

/// Roll-up of a fleet's statistics: one [`StatsSnapshot`] per live tag
/// plus the shared admission gate's state. Host-gate sheds are counted
/// from two sides and must agree:
/// `shed == sum(per-tag shed) + shed_retired` — per-tag **budget** sheds
/// (`shed_budget`) are deliberately outside this identity because the
/// host gate never sees them.
#[derive(Debug, Clone)]
pub struct FleetSnapshot {
    /// `(tag, snapshot)` per live plane, in slot order.
    pub per_model: Vec<(String, StatsSnapshot)>,
    /// Host-wide sheds counted by the shared admission gate.
    pub shed: u64,
    /// Host-gate sheds attributed to tags retired before this snapshot
    /// (kept so the reconciliation identity survives membership churn).
    pub shed_retired: u64,
    /// Requests admitted host-wide at snapshot time (shared budget in
    /// use).
    pub in_flight: usize,
    /// The shared host admission bound.
    pub capacity: usize,
}

impl FleetSnapshot {
    /// The snapshot of one tag, if present.
    pub fn get(&self, tag: &str) -> Option<&StatsSnapshot> {
        self.per_model.iter().find(|(t, _)| t == tag).map(|(_, s)| s)
    }

    /// Total requests admitted across all live tags.
    pub fn submitted(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.submitted).sum()
    }

    /// Total requests served successfully across all live tags.
    pub fn completed(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.completed).sum()
    }

    /// Total requests answered with an engine error across all live tags.
    pub fn errors(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.errors).sum()
    }

    /// Per-tag **host-gate** sheds summed — must equal
    /// [`FleetSnapshot::shed`] minus [`FleetSnapshot::shed_retired`].
    pub fn shed_by_tag(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.shed).sum()
    }

    /// Per-tag **budget** sheds summed (never counted on the host gate).
    pub fn shed_budget_by_tag(&self) -> u64 {
        self.per_model.iter().map(|(_, s)| s.shed_budget).sum()
    }

    /// Fleet summary line plus one indented line per tag (each tag line
    /// carries its own latency percentiles, budget occupancy and — when
    /// an SLO is set — the p99 conformance verdict).
    pub fn render(&self) -> String {
        let mut s = format!(
            "fleet: {} models | served {}/{} ({} errors, {} shed, {} budget-shed) \
             | in-flight {}/{}",
            self.per_model.len(),
            self.completed(),
            self.submitted(),
            self.errors(),
            self.shed,
            self.shed_budget_by_tag(),
            self.in_flight,
            self.capacity,
        );
        for (tag, snap) in &self.per_model {
            s.push_str(&format!("\n  [{tag}] {}", snap.render()));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SyntheticRuntime;
    use std::time::Duration;

    fn synthetic(us: u64) -> EngineBackend {
        EngineBackend::Synthetic { per_image: Duration::from_micros(us) }
    }

    fn image(i: u64) -> Vec<f32> {
        SyntheticRuntime::stripe_image(i as usize)
    }

    fn two_tag_fleet(admission: usize) -> Fleet {
        Fleet::start(FleetOptions {
            models: vec![
                ModelSpec::new("alpha", synthetic(0)),
                ModelSpec::new("beta", synthetic(0)),
            ],
            admission_capacity: admission,
            autotune: None,
            obs: ObsConfig::default(),
        })
        .unwrap()
    }

    #[test]
    fn config_validation() {
        assert!(Fleet::start(FleetOptions::default()).is_err());
        let dup = FleetOptions {
            models: vec![
                ModelSpec::new("a", synthetic(0)),
                ModelSpec::new("a", synthetic(0)),
            ],
            admission_capacity: 16,
            autotune: None,
            obs: ObsConfig::default(),
        };
        assert!(Fleet::start(dup).is_err());
        let zero_cap = FleetOptions {
            models: vec![ModelSpec::new("a", synthetic(0))],
            admission_capacity: 0,
            autotune: None,
            obs: ObsConfig::default(),
        };
        assert!(Fleet::start(zero_cap).is_err());
    }

    #[test]
    fn routes_by_tag_and_rejects_unknown() {
        let fleet = two_tag_fleet(64);
        assert_eq!(fleet.tags(), vec!["alpha".to_string(), "beta".to_string()]);
        assert_eq!(fleet.resolve("beta").unwrap(), 1);
        assert!(matches!(fleet.resolve("gamma"), Err(Error::UnknownModel(_))));
        assert!(matches!(
            fleet.submit("gamma", image(0)),
            Err(Error::UnknownModel(_))
        ));
        assert!(matches!(fleet.submit_at(7, image(0)), Err(Error::Config(_))));

        let h = fleet.handle("beta").unwrap();
        assert_eq!(h.tag(), "beta");
        assert_eq!(h.index(), 1);
        let resp = fleet.infer_blocking("alpha", image(3)).unwrap();
        assert_eq!(resp.class(), 3);
        let resp = h.submit(image(7)).unwrap().recv().unwrap();
        assert_eq!(resp.class(), 7);

        let snap = fleet.shutdown();
        assert_eq!(snap.get("alpha").unwrap().completed, 1);
        assert_eq!(snap.get("beta").unwrap().completed, 1);
        assert_eq!(snap.completed(), 2);
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.shed_by_tag(), 0);
        assert!(snap.render().contains("[alpha]"));
    }

    #[test]
    fn snapshot_rolls_up_per_tag_counters() {
        let fleet = Fleet::start(FleetOptions {
            models: vec![
                ModelSpec::new("x", synthetic(0)),
                ModelSpec::new("y", synthetic(0)),
            ],
            admission_capacity: 256,
            autotune: None,
            obs: ObsConfig::default(),
        })
        .unwrap();
        for i in 0..6u64 {
            fleet.infer_blocking("x", image(i)).unwrap();
        }
        for i in 0..4u64 {
            fleet.infer_blocking("y", image(i)).unwrap();
        }
        let snap = fleet.stats();
        assert_eq!(snap.get("x").unwrap().completed, 6);
        assert_eq!(snap.get("y").unwrap().completed, 4);
        assert_eq!(snap.completed(), 10);
        assert_eq!(snap.submitted(), 10);
        assert_eq!(snap.errors(), 0);
        assert_eq!(snap.capacity, 256);
        assert_eq!(snap.in_flight, 0);
        // Ring depths are visible in the roll-up (default 16).
        assert_eq!(snap.get("x").unwrap().ring_depth, 16);
        assert_eq!(fleet.in_flight(), 0);
        assert_eq!(fleet.admission_capacity(), 256);
        let _ = fleet.shutdown();
    }

    #[test]
    fn slo_weights_partition_the_host_budget() {
        let fleet = Fleet::start(FleetOptions {
            models: vec![
                ModelSpec::new("gold", synthetic(0)).slo(20.0, 8.0),
                ModelSpec::new("bulk", synthetic(0)),
            ],
            admission_capacity: 63,
            autotune: None,
            obs: ObsConfig::default(),
        })
        .unwrap();
        let snap = fleet.stats();
        assert_eq!(snap.get("gold").unwrap().budget_capacity, Some(56));
        assert_eq!(snap.get("bulk").unwrap().budget_capacity, Some(7));
        assert_eq!(snap.get("gold").unwrap().slo_p99_ms, Some(20.0));
        assert_eq!(snap.get("bulk").unwrap().slo_p99_ms, None);
        // The tick is idempotent once rebalance has run.
        assert!(fleet.tick().is_empty());
        // Retiring the SLO tag lifts every cap (no SLO left).
        let _ = fleet.retire("gold").unwrap();
        assert_eq!(fleet.stats().get("bulk").unwrap().budget_capacity, None);
        let _ = fleet.shutdown();
    }

    #[test]
    fn register_and_retire_drive_membership() {
        let fleet = two_tag_fleet(64);
        // Pre-resolve beta, then retire alpha: beta's index must survive
        // (tombstones keep indices stable).
        let beta_idx = fleet.resolve("beta").unwrap();
        let retired = fleet.retire("alpha").unwrap();
        assert_eq!(retired.errors, 0);
        assert_eq!(fleet.tags(), vec!["beta".to_string()]);
        assert!(matches!(fleet.resolve("alpha"), Err(Error::UnknownModel(_))));
        // The stale index of the retired tag reports UnknownModel, not a
        // silent route to a neighbour.
        assert!(matches!(fleet.submit_at(0, image(0)), Err(Error::UnknownModel(_))));
        let resp = fleet.submit_at(beta_idx, image(4)).unwrap().recv().unwrap();
        assert_eq!(resp.class(), 4);

        // Registering a live duplicate fails; a fresh tag (or the retired
        // one) succeeds and serves immediately.
        assert!(fleet.register(ModelSpec::new("beta", synthetic(0))).is_err());
        fleet.register(ModelSpec::new("alpha", synthetic(0))).unwrap();
        assert_eq!(fleet.tags(), vec!["beta".to_string(), "alpha".to_string()]);
        let resp = fleet.infer_blocking("alpha", image(9)).unwrap();
        assert_eq!(resp.class(), 9);
        // The re-registered tag lives in a new slot; the old index stays
        // dead.
        assert_eq!(fleet.resolve("alpha").unwrap(), 2);
        assert!(matches!(fleet.submit_at(0, image(0)), Err(Error::UnknownModel(_))));
        let snap = fleet.shutdown();
        assert_eq!(snap.per_model.len(), 2);
    }

    #[test]
    fn membership_churn_races_safely_with_traffic() {
        // `register`/`retire` take `&self` now: a churn thread and a
        // submit loop share one `&Fleet` with no outer lock. The
        // surviving tag must serve correctly throughout.
        let fleet = two_tag_fleet(256);
        std::thread::scope(|s| {
            let f = &fleet;
            let churn = s.spawn(move || {
                let snap = f.retire("alpha").unwrap();
                assert_eq!(snap.errors, 0);
                f.register(ModelSpec::new("gamma", synthetic(0))).unwrap();
            });
            for i in 0..50u64 {
                let resp = f.infer_blocking("beta", image(i % 10)).unwrap();
                assert_eq!(resp.class(), (i % 10) as usize);
            }
            churn.join().unwrap();
        });
        assert_eq!(fleet.tags(), vec!["beta".to_string(), "gamma".to_string()]);
        let _ = fleet.shutdown();
    }

    #[test]
    fn racing_duplicate_registers_leave_one_live_plane() {
        // Plane startup happens outside the membership lock, so two
        // racing registers of one tag can both build a plane; the
        // write-guard re-check must let exactly one through and drain
        // the loser's plane.
        let fleet = two_tag_fleet(64);
        let results: Vec<Result<()>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let f = &fleet;
                    s.spawn(move || f.register(ModelSpec::new("gamma", synthetic(0))))
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let ok = results.iter().filter(|r| r.is_ok()).count();
        assert_eq!(ok, 1, "exactly one register must win: {results:?}");
        assert_eq!(fleet.resolve("gamma").unwrap(), 2);
        let resp = fleet.infer_blocking("gamma", image(6)).unwrap();
        assert_eq!(resp.class(), 6);
        let _ = fleet.shutdown();
    }

    #[test]
    fn autotune_tick_grows_rings_under_queue_pressure() {
        // Two ticks under genuine queue-full pressure (the dispatcher
        // backing off on a full ring — the one signal deeper rings can
        // relieve) must double the ring depth once; hysteresis keeps the
        // first tick quiet.
        let fleet = Fleet::start(FleetOptions {
            // 1-deep ring, 1-request batches, 50ms/image: the first
            // batch occupies the engine, the second fills the ring, and
            // the third parks the dispatcher in its full-ring backoff
            // loop for the whole engine busy-window.
            models: vec![ModelSpec::new("only", synthetic(50_000))
                .policy(BatchPolicy {
                    max_batch: 1,
                    max_wait: Duration::from_micros(100),
                })
                .queue_depth(1)],
            admission_capacity: 64,
            autotune: Some(AutotuneConfig {
                min_depth: 1,
                max_depth: 8,
                hysteresis_ticks: 2,
                cooldown_ticks: 2,
                steal_fraction: 0.5,
            }),
            obs: ObsConfig::default(),
        })
        .unwrap();
        let rxs: Vec<_> = (0..3u64)
            .map(|i| fleet.submit("only", image(i)).unwrap())
            .collect();
        // Let the batcher reach the full-ring backoff loop, then tick
        // twice inside the 50ms busy-window.
        std::thread::sleep(Duration::from_millis(15));
        let d1 = fleet.tick(); // full-backoff delta > 0 -> streak 1
        assert!(d1.is_empty(), "hysteresis must hold the first tick: {d1:?}");
        std::thread::sleep(Duration::from_millis(10));
        let d2 = fleet.tick(); // streak 2 -> grow 1 -> 2
        assert_eq!(
            d2,
            vec![Decision::SetRingDepth { tag: "only".into(), depth: 2 }]
        );
        let snap = fleet.stats().get("only").unwrap().clone();
        assert_eq!(snap.ring_depth, 2);
        assert!(snap.ring_full_backoffs > 0, "no queue pressure was recorded");
        // The grown ring relieves the very pressure that triggered it:
        // the parked dispatch lands and everything completes losslessly.
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert!(!resp.is_error());
        }
        let _ = fleet.shutdown();
    }
}
