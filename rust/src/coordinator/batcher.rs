//! Dynamic batcher: size- or deadline-triggered batch formation.
//!
//! The classic serving trade-off (vLLM router, Triton dynamic batching):
//! wait a little to fill bigger batches (throughput) but never longer than
//! `max_wait` (latency). The policy is deliberately simple and fully
//! deterministic given arrival times, so the batching ablation bench can
//! sweep `max_batch`/`max_wait` and attribute effects cleanly.
//!
//! Formed batches are handed to the sharded execution plane
//! (`ExecutionPlane::dispatch`) — per-engine rings with work stealing —
//! instead of a single shared channel. Every serving plane (the
//! single-model [`crate::coordinator::Server`] or each tag of a
//! [`crate::coordinator::Fleet`]) runs its own batcher thread.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::shard::ExecutionPlane;
use super::{Batch, Request};
use crate::coordinator::queue::PlaneGates;
use crate::coordinator::stats::ServerStats;
use crate::obs::trace::{EventKind, TraceHandle};
use crate::runtime::NUM_CLASSES;

/// Batch formation policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many requests are pending.
    pub max_batch: usize,
    /// Dispatch a non-empty batch at latest this long after its oldest
    /// request arrived.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // serve_perf measured the b8 variant as the per-image sweet spot
        // of the interpret-lowered executables (6,983 img/s vs 4,351 at
        // b32 — see EXPERIMENTS.md §Perf), so the default batches to 8.
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) }
    }
}

impl BatchPolicy {
    /// Small batches, tight deadline: favour per-request latency.
    pub fn low_latency() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) }
    }

    /// Large batches, relaxed deadline: favour aggregate throughput.
    pub fn high_throughput() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(5) }
    }
}

/// Batcher loop: drain `rx`, form batches, dispatch to the plane.
///
/// Exits when the submit channel closes (the `Server` drops its sender at
/// shutdown — *before* joining this thread, so this path is the
/// deterministic one) or when the shutdown flag is set and the queue is
/// drained. Every request received is either dispatched or — if the plane
/// is already fully closed, which ordinary shutdown makes impossible —
/// explicitly failed; none are silently dropped.
pub(crate) fn run(
    rx: mpsc::Receiver<Request>,
    plane: Arc<ExecutionPlane>,
    gates: Arc<PlaneGates>,
    policy: BatchPolicy,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    trace: Option<(TraceHandle, u16)>,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(policy.max_batch);
    let mut oldest: Option<Instant> = None;

    let flush =
        |pending: &mut Vec<Request>, oldest: &mut Option<Instant>| -> bool {
            if pending.is_empty() {
                return true;
            }
            let batch = Batch { requests: std::mem::take(pending) };
            stats.on_dispatch(batch.requests.len());
            if let Some((h, t)) = &trace {
                for r in &batch.requests {
                    h.request(EventKind::Dispatched, r.id, *t);
                }
            }
            *oldest = None;
            match plane.dispatch(batch) {
                Ok(()) => true,
                Err(batch) => {
                    // Plane fully closed under us: fail the requests
                    // loudly rather than dropping their response channels.
                    fail_batch(batch, &stats, &gates);
                    false
                }
            }
        };

    loop {
        // How long may we wait? Until the oldest request's deadline.
        let timeout = match oldest {
            Some(t0) => policy
                .max_wait
                .checked_sub(t0.elapsed())
                .unwrap_or(Duration::ZERO),
            None => Duration::from_millis(10),
        };
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if oldest.is_none() {
                    oldest = Some(req.enqueued);
                }
                if let Some((h, t)) = &trace {
                    h.request(EventKind::Enqueued, req.id, *t);
                }
                pending.push(req);
                if pending.len() >= policy.max_batch {
                    if !flush(&mut pending, &mut oldest) {
                        return;
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let deadline_hit = oldest
                    .map(|t0| t0.elapsed() >= policy.max_wait)
                    .unwrap_or(false);
                if deadline_hit && !flush(&mut pending, &mut oldest) {
                    return;
                }
                if shutdown.load(Ordering::SeqCst) {
                    // Drain whatever remains, then exit.
                    while let Ok(req) = rx.try_recv() {
                        if let Some((h, t)) = &trace {
                            h.request(EventKind::Enqueued, req.id, *t);
                        }
                        pending.push(req);
                        if pending.len() >= policy.max_batch
                            && !flush(&mut pending, &mut oldest)
                        {
                            return;
                        }
                    }
                    let _ = flush(&mut pending, &mut oldest);
                    return;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                let _ = flush(&mut pending, &mut oldest);
                return;
            }
        }
    }
}

/// Complete every request of an undispatchable batch with NaN logits (the
/// same client-visible shape as an engine failure) and release admission
/// (both scopes).
///
/// Failures count only toward `errors` — `completed` and the latency
/// percentiles mean *successfully served* throughout the stats, matching
/// `LoadReport`'s convention.
pub(crate) fn fail_batch(batch: Batch, stats: &ServerStats, gates: &PlaneGates) {
    for req in batch.requests {
        stats.on_error();
        let latency_s = req.enqueued.elapsed().as_secs_f64();
        let _ = req.resp.send(super::Response {
            id: req.id,
            logits: vec![f32::NAN; NUM_CLASSES],
            latency_s,
        });
        gates.exit();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::{AdmissionGate, TagBudget};
    use crate::util::ring::PopError;

    fn req(id: u64) -> (Request, mpsc::Receiver<super::super::Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request { id, image: vec![0.0; 784], enqueued: Instant::now(), resp: tx },
            rx,
        )
    }

    struct Harness {
        tx: mpsc::Sender<Request>,
        plane: Arc<ExecutionPlane>,
        gates: Arc<PlaneGates>,
        shutdown: Arc<AtomicBool>,
        handle: std::thread::JoinHandle<()>,
    }

    fn harness(policy: BatchPolicy) -> Harness {
        let (tx, in_rx) = mpsc::channel();
        // One-engine plane: the test inspects ring 0 directly.
        let (plane, _mailboxes) = ExecutionPlane::new(1, 64);
        let gates = Arc::new(PlaneGates::new(
            Arc::new(AdmissionGate::new(1024)),
            Arc::new(TagBudget::unlimited()),
        ));
        let stats = Arc::new(ServerStats::new());
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let p = Arc::clone(&plane);
        let g = Arc::clone(&gates);
        let handle =
            std::thread::spawn(move || run(in_rx, p, g, policy, stats, sd, None));
        Harness { tx, plane, gates, shutdown, handle }
    }

    fn recv_batch(plane: &ExecutionPlane, timeout: Duration) -> Batch {
        match plane.queue(0).pop_timeout(timeout) {
            Ok(b) => b,
            Err(PopError::Empty) => panic!("no batch within {timeout:?}"),
            Err(PopError::Closed) => panic!("ring closed unexpectedly"),
        }
    }

    #[test]
    fn size_triggered_dispatch() {
        let h = harness(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
        });
        let mut keep = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(i);
            keep.push(rx);
            h.tx.send(r).unwrap();
        }
        let batch = recv_batch(&h.plane, Duration::from_secs(2));
        assert_eq!(batch.requests.len(), 4);
        h.shutdown.store(true, Ordering::SeqCst);
        drop(h.tx);
        h.handle.join().unwrap();
    }

    #[test]
    fn deadline_triggered_dispatch() {
        let h = harness(BatchPolicy {
            max_batch: 1000,
            max_wait: Duration::from_millis(5),
        });
        let (r, _rx) = req(0);
        h.tx.send(r).unwrap();
        let t0 = Instant::now();
        let batch = recv_batch(&h.plane, Duration::from_secs(2));
        assert_eq!(batch.requests.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
        h.shutdown.store(true, Ordering::SeqCst);
        drop(h.tx);
        h.handle.join().unwrap();
    }

    #[test]
    fn drains_on_disconnect() {
        let h = harness(BatchPolicy {
            max_batch: 1000,
            max_wait: Duration::from_secs(10),
        });
        let (r, _rx) = req(0);
        h.tx.send(r).unwrap();
        drop(h.tx); // disconnect before any trigger
        let batch = recv_batch(&h.plane, Duration::from_secs(2));
        assert_eq!(batch.requests.len(), 1);
        h.handle.join().unwrap();
    }

    #[test]
    fn closed_plane_fails_requests_instead_of_dropping() {
        let h = harness(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(1),
        });
        h.plane.close();
        let (r, rx) = req(0);
        // Mirror the production flow: the request entered both admission
        // scopes at submit time, so fail_batch's gates.exit() has an
        // enter to match.
        h.gates.try_enter();
        h.tx.send(r).unwrap();
        // The batcher must answer (NaN logits), not drop the channel.
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert!(resp.logits[0].is_nan());
        drop(h.tx);
        h.handle.join().unwrap();
    }
}
