//! Dynamic batcher: size- or deadline-triggered batch formation.
//!
//! The classic serving trade-off (vLLM router, Triton dynamic batching):
//! wait a little to fill bigger batches (throughput) but never longer than
//! `max_wait` (latency). The policy is deliberately simple and fully
//! deterministic given arrival times, so the batching ablation bench can
//! sweep `max_batch`/`max_wait` and attribute effects cleanly.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{Batch, Request};
use crate::coordinator::stats::ServerStats;

/// Batch formation policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Dispatch as soon as this many requests are pending.
    pub max_batch: usize,
    /// Dispatch a non-empty batch at latest this long after its oldest
    /// request arrived.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // serve_perf measured the b8 variant as the per-image sweet spot
        // of the interpret-lowered executables (6,983 img/s vs 4,351 at
        // b32 — see EXPERIMENTS.md §Perf), so the default batches to 8.
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) }
    }
}

impl BatchPolicy {
    pub fn low_latency() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) }
    }

    pub fn high_throughput() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(5) }
    }
}

/// Batcher loop: drain `rx`, form batches, send to `tx`.
///
/// Exits when the submit channel closes (all `Server` senders dropped) or
/// shutdown is flagged and the queue is drained.
pub(crate) fn run(
    rx: mpsc::Receiver<Request>,
    tx: mpsc::Sender<Batch>,
    policy: BatchPolicy,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(policy.max_batch);
    let mut oldest: Option<Instant> = None;

    let flush =
        |pending: &mut Vec<Request>, oldest: &mut Option<Instant>| -> bool {
            if pending.is_empty() {
                return true;
            }
            let batch = Batch { requests: std::mem::take(pending) };
            stats.on_dispatch(batch.requests.len());
            *oldest = None;
            tx.send(batch).is_ok()
        };

    loop {
        // How long may we wait? Until the oldest request's deadline.
        let timeout = match oldest {
            Some(t0) => policy
                .max_wait
                .checked_sub(t0.elapsed())
                .unwrap_or(Duration::ZERO),
            None => Duration::from_millis(10),
        };
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if oldest.is_none() {
                    oldest = Some(req.enqueued);
                }
                pending.push(req);
                if pending.len() >= policy.max_batch {
                    if !flush(&mut pending, &mut oldest) {
                        return;
                    }
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let deadline_hit = oldest
                    .map(|t0| t0.elapsed() >= policy.max_wait)
                    .unwrap_or(false);
                if deadline_hit && !flush(&mut pending, &mut oldest) {
                    return;
                }
                if shutdown.load(Ordering::SeqCst) {
                    // Drain whatever remains, then exit.
                    while let Ok(req) = rx.try_recv() {
                        pending.push(req);
                        if pending.len() >= policy.max_batch
                            && !flush(&mut pending, &mut oldest)
                        {
                            return;
                        }
                    }
                    let _ = flush(&mut pending, &mut oldest);
                    return;
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                let _ = flush(&mut pending, &mut oldest);
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn req(id: u64) -> (Request, mpsc::Receiver<super::super::Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Request { id, image: vec![0.0; 784], enqueued: Instant::now(), resp: tx },
            rx,
        )
    }

    fn harness(policy: BatchPolicy) -> (
        mpsc::Sender<Request>,
        mpsc::Receiver<Batch>,
        Arc<AtomicBool>,
        std::thread::JoinHandle<()>,
    ) {
        let (in_tx, in_rx) = mpsc::channel();
        let (out_tx, out_rx) = mpsc::channel();
        let stats = Arc::new(ServerStats::new());
        let shutdown = Arc::new(AtomicBool::new(false));
        let sd = Arc::clone(&shutdown);
        let h = std::thread::spawn(move || run(in_rx, out_tx, policy, stats, sd));
        (in_tx, out_rx, shutdown, h)
    }

    #[test]
    fn size_triggered_dispatch() {
        let (tx, out, sd, h) = harness(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_secs(10),
        });
        let mut keep = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(i);
            keep.push(rx);
            tx.send(r).unwrap();
        }
        let batch = out.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(batch.requests.len(), 4);
        sd.store(true, Ordering::SeqCst);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn deadline_triggered_dispatch() {
        let (tx, out, sd, h) = harness(BatchPolicy {
            max_batch: 1000,
            max_wait: Duration::from_millis(5),
        });
        let (r, _rx) = req(0);
        tx.send(r).unwrap();
        let t0 = Instant::now();
        let batch = out.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(4));
        sd.store(true, Ordering::SeqCst);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn drains_on_disconnect() {
        let (tx, out, _sd, h) = harness(BatchPolicy {
            max_batch: 1000,
            max_wait: Duration::from_secs(10),
        });
        let (r, _rx) = req(0);
        tx.send(r).unwrap();
        drop(tx); // disconnect before any trigger
        let batch = out.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(batch.requests.len(), 1);
        h.join().unwrap();
    }
}
