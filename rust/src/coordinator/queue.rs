//! Bounded admission queue with load shedding.
//!
//! The raw `mpsc` channel is unbounded; production routers bound admission
//! and shed early under overload rather than letting queue latency grow
//! without bound. [`AdmissionGate`] is that bound: a cheap atomic
//! depth counter consulted at submit time (no lock on the hot path).
//!
//! Wiring: `Server::submit` calls [`AdmissionGate::try_enter`] and maps
//! [`Admission::Shed`] to `Error::Overloaded` (a fast reject — nothing is
//! queued); the engine releases the slot via [`AdmissionGate::exit`] after
//! the response is sent. The gate therefore bounds *total in-flight work*
//! (submit queue + work rings + executing), which is also what guarantees
//! the sharded dispatcher's full-ring backoff always clears.
//!
//! A gate is deliberately shareable: the single-model
//! [`crate::coordinator::Server`] owns a private one, while a
//! [`crate::coordinator::Fleet`] threads **one** gate through every
//! per-tag plane so a single overload budget governs the whole host
//! (DESIGN.md §10).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// The request holds an in-flight slot until `exit` is called.
    Accepted,
    /// Queue at capacity — caller should retry later or drop.
    Shed,
}

/// Depth-bounded admission gate.
pub struct AdmissionGate {
    depth: AtomicUsize,
    capacity: usize,
    shed_total: AtomicU64,
}

impl AdmissionGate {
    /// A gate admitting at most `capacity` in-flight requests.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        AdmissionGate {
            depth: AtomicUsize::new(0),
            capacity,
            shed_total: AtomicU64::new(0),
        }
    }

    /// Try to admit one request.
    pub fn try_enter(&self) -> Admission {
        // Optimistic increment with rollback keeps this a single RMW in
        // the common case.
        let prev = self.depth.fetch_add(1, Ordering::AcqRel);
        if prev >= self.capacity {
            self.depth.fetch_sub(1, Ordering::AcqRel);
            self.shed_total.fetch_add(1, Ordering::Relaxed);
            Admission::Shed
        } else {
            Admission::Accepted
        }
    }

    /// Mark one admitted request as finished.
    pub fn exit(&self) {
        let prev = self.depth.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "exit without enter");
    }

    /// Requests currently admitted (queued or executing).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// Total requests shed since the gate was built.
    pub fn shed_total(&self) -> u64 {
        self.shed_total.load(Ordering::Relaxed)
    }

    /// The admission bound this gate enforces.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sheds_at_capacity() {
        let g = AdmissionGate::new(2);
        assert_eq!(g.try_enter(), Admission::Accepted);
        assert_eq!(g.try_enter(), Admission::Accepted);
        assert_eq!(g.try_enter(), Admission::Shed);
        assert_eq!(g.shed_total(), 1);
        g.exit();
        assert_eq!(g.try_enter(), Admission::Accepted);
        assert_eq!(g.depth(), 2);
    }

    #[test]
    fn concurrent_never_exceeds_capacity() {
        let g = Arc::new(AdmissionGate::new(16));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let g = Arc::clone(&g);
            handles.push(std::thread::spawn(move || {
                let mut max_seen = 0;
                for _ in 0..2000 {
                    if g.try_enter() == Admission::Accepted {
                        max_seen = max_seen.max(g.depth());
                        g.exit();
                    }
                }
                max_seen
            }));
        }
        for h in handles {
            let max_seen = h.join().unwrap();
            assert!(max_seen <= 16, "depth {max_seen} exceeded capacity");
        }
        assert_eq!(g.depth(), 0);
    }
}
