//! Bounded admission queue with load shedding.
//!
//! The raw `mpsc` channel is unbounded; production routers bound admission
//! and shed early under overload rather than letting queue latency grow
//! without bound. [`AdmissionGate`] is that bound: a cheap atomic
//! depth counter consulted at submit time (no lock on the hot path).
//!
//! Wiring: `Server::submit` calls [`AdmissionGate::try_enter`] and maps
//! [`Admission::Shed`] to `Error::Overloaded` (a fast reject — nothing is
//! queued); the engine releases the slot via [`AdmissionGate::exit`] after
//! the response is sent. The gate therefore bounds *total in-flight work*
//! (submit queue + work rings + executing), which is also what guarantees
//! the sharded dispatcher's full-ring backoff always clears.
//!
//! A gate is deliberately shareable: the single-model
//! [`crate::coordinator::Server`] owns a private one, while a
//! [`crate::coordinator::Fleet`] threads **one** gate through every
//! per-tag plane so a single overload budget governs the whole host
//! (DESIGN.md §10).
//!
//! With the policy control plane (DESIGN.md §11) admission is **two
//! scopes deep**: each plane additionally owns a [`TagBudget`] — a
//! retunable cap on *its own* in-flight work — and every submit passes
//! through the [`PlaneGates`] pair (tag budget first, then the shared
//! host gate). Both scopes shed with `Error::Overloaded`, but the stats
//! attribute them separately (`shed` vs `shed_budget`), so an operator
//! can tell "your tag spent its budget" from "the host is full".

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// The request holds an in-flight slot until `exit` is called.
    Accepted,
    /// Queue at capacity — caller should retry later or drop.
    Shed,
}

/// Depth-bounded admission gate.
pub struct AdmissionGate {
    depth: AtomicUsize,
    capacity: usize,
    shed_total: AtomicU64,
}

impl AdmissionGate {
    /// A gate admitting at most `capacity` in-flight requests.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1);
        AdmissionGate {
            depth: AtomicUsize::new(0),
            capacity,
            shed_total: AtomicU64::new(0),
        }
    }

    /// Try to admit one request.
    pub fn try_enter(&self) -> Admission {
        // Optimistic increment with rollback keeps this a single RMW in
        // the common case.
        let prev = self.depth.fetch_add(1, Ordering::AcqRel);
        if prev >= self.capacity {
            self.depth.fetch_sub(1, Ordering::AcqRel);
            self.shed_total.fetch_add(1, Ordering::Relaxed);
            Admission::Shed
        } else {
            Admission::Accepted
        }
    }

    /// Mark one admitted request as finished.
    pub fn exit(&self) {
        let prev = self.depth.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "exit without enter");
    }

    /// Requests currently admitted (queued or executing).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// Total requests shed since the gate was built.
    pub fn shed_total(&self) -> u64 {
        self.shed_total.load(Ordering::Relaxed)
    }

    /// The admission bound this gate enforces.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

/// Sentinel capacity meaning "no per-tag bound".
const UNLIMITED: usize = usize::MAX;

/// Per-tag admission budget: a depth-bounded counter like
/// [`AdmissionGate`], but with a **retunable** capacity so the policy
/// control plane (DESIGN.md §11) can rebalance budgets on a running
/// host. A budget starts unlimited; [`TagBudget::set_capacity`] caps it
/// and [`TagBudget::set_unlimited`] lifts the cap again. Shrinking below
/// the current depth sheds new admits until in-flight work drains under
/// the new bound — nothing already admitted is affected.
pub struct TagBudget {
    depth: AtomicUsize,
    capacity: AtomicUsize,
    shed_total: AtomicU64,
}

impl TagBudget {
    /// A budget with no cap (every `try_enter` is admitted).
    pub fn unlimited() -> Self {
        TagBudget {
            depth: AtomicUsize::new(0),
            capacity: AtomicUsize::new(UNLIMITED),
            shed_total: AtomicU64::new(0),
        }
    }

    /// Try to take one slot of this tag's budget.
    pub fn try_enter(&self) -> Admission {
        let prev = self.depth.fetch_add(1, Ordering::AcqRel);
        if prev >= self.capacity.load(Ordering::Acquire) {
            self.depth.fetch_sub(1, Ordering::AcqRel);
            self.shed_total.fetch_add(1, Ordering::Relaxed);
            Admission::Shed
        } else {
            Admission::Accepted
        }
    }

    /// Release one slot taken by `try_enter`.
    pub fn exit(&self) {
        let prev = self.depth.fetch_sub(1, Ordering::AcqRel);
        debug_assert!(prev > 0, "budget exit without enter");
    }

    /// Requests of this tag currently in flight.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// The current cap, `None` when unlimited.
    pub fn limit(&self) -> Option<usize> {
        match self.capacity.load(Ordering::Acquire) {
            UNLIMITED => None,
            c => Some(c),
        }
    }

    /// Cap the budget at `capacity` (>= 1) in-flight requests.
    pub fn set_capacity(&self, capacity: usize) {
        assert!(capacity >= 1, "tag budget capacity must be >= 1");
        self.capacity.store(capacity, Ordering::Release);
    }

    /// Lift the cap (back to unlimited).
    pub fn set_unlimited(&self) {
        self.capacity.store(UNLIMITED, Ordering::Release);
    }

    /// Total requests this budget has shed since construction.
    pub fn shed_total(&self) -> u64 {
        self.shed_total.load(Ordering::Relaxed)
    }
}

/// Outcome of a two-scope admission attempt ([`PlaneGates::try_enter`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Entry {
    /// Both scopes admitted; the request holds one slot of each until
    /// [`PlaneGates::exit`].
    Admitted,
    /// The tag's own budget is spent (the host may still have room).
    ShedBudget,
    /// The shared host gate is full (counted on the gate's shed total).
    ShedHost,
}

/// The pair of admission scopes one serving plane's requests pass
/// through: the plane's own [`TagBudget`] first, then the (possibly
/// shared) host [`AdmissionGate`]. Checking the budget first keeps the
/// host gate's `shed_total` meaning exactly "host-wide overload", so the
/// gate-total vs per-tag reconciliation (`FleetSnapshot::shed ==
/// sum(per-tag shed)`) survives per-tag budgets.
pub struct PlaneGates {
    host: Arc<AdmissionGate>,
    budget: Arc<TagBudget>,
}

impl PlaneGates {
    /// Pair a host gate with one plane's budget.
    pub fn new(host: Arc<AdmissionGate>, budget: Arc<TagBudget>) -> Self {
        PlaneGates { host, budget }
    }

    /// Try to admit one request through both scopes. On a host shed the
    /// budget slot taken first is rolled back, so the two counters never
    /// drift.
    pub fn try_enter(&self) -> Entry {
        if self.budget.try_enter() == Admission::Shed {
            return Entry::ShedBudget;
        }
        if self.host.try_enter() == Admission::Shed {
            self.budget.exit();
            return Entry::ShedHost;
        }
        Entry::Admitted
    }

    /// Release one admitted request from both scopes.
    pub fn exit(&self) {
        self.host.exit();
        self.budget.exit();
    }

    /// The shared host gate.
    pub fn host(&self) -> &AdmissionGate {
        &self.host
    }

    /// This plane's tag budget.
    pub fn budget(&self) -> &TagBudget {
        &self.budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sheds_at_capacity() {
        let g = AdmissionGate::new(2);
        assert_eq!(g.try_enter(), Admission::Accepted);
        assert_eq!(g.try_enter(), Admission::Accepted);
        assert_eq!(g.try_enter(), Admission::Shed);
        assert_eq!(g.shed_total(), 1);
        g.exit();
        assert_eq!(g.try_enter(), Admission::Accepted);
        assert_eq!(g.depth(), 2);
    }

    #[test]
    fn tag_budget_caps_and_retunes() {
        let b = TagBudget::unlimited();
        assert_eq!(b.limit(), None);
        for _ in 0..64 {
            assert_eq!(b.try_enter(), Admission::Accepted);
        }
        assert_eq!(b.depth(), 64);
        for _ in 0..64 {
            b.exit();
        }
        b.set_capacity(2);
        assert_eq!(b.limit(), Some(2));
        assert_eq!(b.try_enter(), Admission::Accepted);
        assert_eq!(b.try_enter(), Admission::Accepted);
        assert_eq!(b.try_enter(), Admission::Shed);
        assert_eq!(b.shed_total(), 1);
        // Shrinking below the current depth sheds new admits but leaves
        // in-flight work untouched.
        b.set_capacity(1);
        assert_eq!(b.depth(), 2);
        assert_eq!(b.try_enter(), Admission::Shed);
        b.exit();
        b.exit();
        assert_eq!(b.try_enter(), Admission::Accepted);
        b.set_unlimited();
        assert_eq!(b.limit(), None);
    }

    #[test]
    fn plane_gates_roll_back_budget_on_host_shed() {
        let host = Arc::new(AdmissionGate::new(1));
        let budget = Arc::new(TagBudget::unlimited());
        budget.set_capacity(2);
        let gates = PlaneGates::new(Arc::clone(&host), Arc::clone(&budget));
        assert_eq!(gates.try_enter(), Entry::Admitted);
        // Host full, budget has room: the budget slot must be returned.
        assert_eq!(gates.try_enter(), Entry::ShedHost);
        assert_eq!(budget.depth(), 1, "budget slot leaked on host shed");
        assert_eq!(host.shed_total(), 1);
        assert_eq!(budget.shed_total(), 0);
        gates.exit();
        assert_eq!(budget.depth(), 0);
        assert_eq!(host.depth(), 0);
        // Budget spent, host empty: shed attributed to the budget, host
        // untouched.
        budget.set_capacity(1);
        assert_eq!(gates.try_enter(), Entry::Admitted);
        assert_eq!(gates.try_enter(), Entry::ShedBudget);
        assert_eq!(host.depth(), 1);
        assert_eq!(host.shed_total(), 1, "host must not count budget sheds");
        assert_eq!(budget.shed_total(), 1);
        gates.exit();
    }

    #[test]
    fn concurrent_never_exceeds_capacity() {
        let g = Arc::new(AdmissionGate::new(16));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let g = Arc::clone(&g);
            handles.push(std::thread::spawn(move || {
                let mut max_seen = 0;
                for _ in 0..2000 {
                    if g.try_enter() == Admission::Accepted {
                        max_seen = max_seen.max(g.depth());
                        g.exit();
                    }
                }
                max_seen
            }));
        }
        for h in handles {
            let max_seen = h.join().unwrap();
            assert!(max_seen <= 16, "depth {max_seen} exceeded capacity");
        }
        assert_eq!(g.depth(), 0);
    }
}
