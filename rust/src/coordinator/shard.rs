//! Sharded execution plane: per-engine bounded work rings + work stealing.
//!
//! Replaces the single `Mutex<mpsc::Receiver<Batch>>` every engine replica
//! used to contend on. The architecture mirrors the accelerator side of
//! the paper's lineage (HPIPE's layer-pipelined compute units; composable
//! per-unit building blocks): each engine owns a private bounded ring, the
//! batcher *dispatches* to one ring (two-choice: the shorter of the
//! round-robin pick and its successor), and an idle engine *steals* from
//! its neighbours before parking — so a slow engine never strands work
//! while others sit idle, and no global arbitration point exists on the
//! hot path.
//!
//! Shutdown is deterministic: once the batcher has flushed, the server
//! closes every ring; workers drain until every ring reports
//! closed-and-empty and only then exit. Nothing dispatched is ever
//! dropped.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::Batch;
use crate::util::ring::{Parker, PopError, PushError, RingQueue, Unparker};

/// How long an idle worker parks between steal sweeps. An unpark from the
/// dispatcher cuts the wait short; the timeout only bounds shutdown skew.
const IDLE_PARK: Duration = Duration::from_millis(1);

/// Dispatcher-side backoff while every ring is full (admission control
/// bounds total in-flight work, so this clears as soon as an engine pops).
const FULL_BACKOFF: Duration = Duration::from_micros(50);

/// Batch-pool workers each engine's backend gets: spread the host's cores
/// across the plane's engines, keeping one core per engine for the engine
/// thread itself. On a single-core host (or when engines already saturate
/// the cores) this is 0 and the backend's batch path degenerates to the
/// serial loop — no pool threads, no overhead.
pub(crate) fn workers_per_engine(engines: usize) -> usize {
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    (cores / engines.max(1)).saturating_sub(1)
}

/// Pipeline stage groups each engine's staged executor gets, sharing the
/// same per-engine core budget as [`workers_per_engine`] — a pipelined
/// backend spends its spare cores on stage-group workers instead of
/// batch-pool workers, never both. `requested == 0` means auto (use the
/// whole budget); an explicit request is clamped to the budget and to
/// the model's stage count. Always ≥ 1: on saturated hosts the pipeline
/// degenerates to the serial walk on a single worker, mirroring the
/// pool's 0-worker degeneracy.
pub(crate) fn pipeline_groups_per_engine(
    engines: usize,
    requested: usize,
    n_stages: usize,
) -> usize {
    let budget = workers_per_engine(engines).max(1);
    let want = if requested == 0 { budget } else { requested };
    want.min(budget).min(n_stages.max(1)).max(1)
}

/// Total worker threads each engine's staged executor may spend across
/// its `groups` stage groups — the same per-engine core budget as
/// [`workers_per_engine`], but never below one worker per group (the
/// pipeline's liveness floor). The slack beyond `groups` is what the
/// executor's replication plan grants to the costliest group(s); when
/// the stage count capped `groups` below the budget, that surplus
/// becomes replication headroom instead of being wasted.
pub(crate) fn pipeline_workers_per_engine(engines: usize, groups: usize) -> usize {
    workers_per_engine(engines).max(1).max(groups)
}

/// Clamp an explicit `--pipeline NxR` replication request to the same
/// per-engine budget: the pipeline runs `groups - 1` singleton workers
/// plus `R` on the bottleneck group, so `R` may spend at most the
/// budget's slack beyond one worker per group (+1 for the bottleneck's
/// own baseline worker). Always ≥ 1 — an oversubscribed request
/// degrades to the unreplicated pipeline, never to a dead group.
pub(crate) fn pipeline_replicas_per_engine(
    engines: usize,
    groups: usize,
    requested: usize,
) -> usize {
    let budget = workers_per_engine(engines).max(1);
    let slack = budget.saturating_sub(groups);
    requested.clamp(1, slack + 1)
}

/// The shared state of the sharded plane: one ring + unparker per engine.
pub(crate) struct ExecutionPlane {
    queues: Vec<Arc<RingQueue<Batch>>>,
    unparkers: Vec<Unparker>,
    rr: AtomicUsize,
    /// Times the dispatcher found **every** ring full and had to back
    /// off — the queue-pressure signal ring-depth autotuning acts on
    /// (admission sheds happen upstream and say nothing about rings).
    full_backoffs: AtomicU64,
}

/// Per-engine private half: the parker the worker sleeps on.
pub(crate) struct EngineMailbox {
    pub eid: usize,
    pub parker: Parker,
}

impl ExecutionPlane {
    /// Build a plane of `engines` rings, each `depth` batches deep.
    pub fn new(engines: usize, depth: usize) -> (Arc<Self>, Vec<EngineMailbox>) {
        assert!(engines >= 1, "execution plane needs >= 1 engine");
        let mut queues = Vec::with_capacity(engines);
        let mut unparkers = Vec::with_capacity(engines);
        let mut mailboxes = Vec::with_capacity(engines);
        for eid in 0..engines {
            let parker = Parker::new();
            queues.push(Arc::new(RingQueue::new(depth)));
            unparkers.push(parker.unparker());
            mailboxes.push(EngineMailbox { eid, parker });
        }
        let plane = ExecutionPlane {
            queues,
            unparkers,
            rr: AtomicUsize::new(0),
            full_backoffs: AtomicU64::new(0),
        };
        (Arc::new(plane), mailboxes)
    }

    pub fn engines(&self) -> usize {
        self.queues.len()
    }

    pub fn queue(&self, eid: usize) -> &RingQueue<Batch> {
        &self.queues[eid]
    }

    /// Current per-engine ring capacity (every ring shares one bound).
    pub fn depth(&self) -> usize {
        self.queues[0].capacity()
    }

    /// Retune every ring's capacity to `depth` batches (the policy
    /// control plane's queue-autotuning actuator — DESIGN.md §11).
    /// Applies between batches: pushes after this call see the new
    /// bound; queued batches are never dropped.
    pub fn set_depth(&self, depth: usize) {
        for q in &self.queues {
            q.set_capacity(depth);
        }
    }

    /// Place one batch on some engine's ring and wake that engine.
    ///
    /// Placement is round-robin with a two-choice refinement (push to the
    /// shorter of the cursor's ring and its successor); if the pick is
    /// full, the remaining rings are tried in rotation. When *every* ring
    /// is full the dispatcher backs off briefly and retries — it never
    /// drops. `Err(batch)` is returned only when every ring is closed
    /// (shutdown), so the caller can fail the requests explicitly.
    pub fn dispatch(&self, batch: Batch) -> Result<(), Batch> {
        let n = self.queues.len();
        let mut batch = batch;
        loop {
            let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
            let pick = if n >= 2 {
                let next = (start + 1) % n;
                if self.queues[next].len() < self.queues[start].len() {
                    next
                } else {
                    start
                }
            } else {
                0
            };
            let mut closed = 0;
            for k in 0..n {
                let q = (pick + k) % n;
                match self.queues[q].try_push(batch) {
                    Ok(()) => {
                        self.unparkers[q].unpark();
                        return Ok(());
                    }
                    Err(PushError::Full(b)) => batch = b,
                    Err(PushError::Closed(b)) => {
                        batch = b;
                        closed += 1;
                    }
                }
            }
            if closed == n {
                return Err(batch);
            }
            self.full_backoffs.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(FULL_BACKOFF);
        }
    }

    /// Total full-ring backoffs the dispatcher has taken — the
    /// queue-pressure signal ring-depth autotuning consumes.
    pub fn full_backoffs(&self) -> u64 {
        self.full_backoffs.load(Ordering::Relaxed)
    }

    /// Close every ring (idempotent) and wake every worker so drains
    /// start immediately.
    pub fn close(&self) {
        for q in &self.queues {
            q.close();
        }
        for u in &self.unparkers {
            u.unpark();
        }
    }
}

/// Engine-side scheduling loop: drain the own ring, steal from neighbours
/// (nearest-first rotation), park when everything is empty. Exits only
/// when every ring is closed **and** drained, so shutdown loses nothing.
///
/// `execute` receives the batch and whether it was stolen (for stats).
pub(crate) fn worker_loop(
    plane: &ExecutionPlane,
    mailbox: &EngineMailbox,
    mut execute: impl FnMut(Batch, bool),
) {
    let n = plane.engines();
    let eid = mailbox.eid;
    loop {
        let mut all_closed = true;
        let mut got: Option<(Batch, bool)> = None;
        for k in 0..n {
            let q = (eid + k) % n;
            match plane.queue(q).try_pop() {
                Ok(b) => {
                    got = Some((b, q != eid));
                    break;
                }
                Err(PopError::Empty) => all_closed = false,
                Err(PopError::Closed) => {}
            }
        }
        match got {
            Some((batch, stolen)) => execute(batch, stolen),
            None if all_closed => break,
            None => {
                mailbox.parker.park_timeout(IDLE_PARK);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Mutex;

    fn batch(n: usize) -> Batch {
        let mut requests = Vec::with_capacity(n);
        for id in 0..n as u64 {
            let (tx, _rx) = std::sync::mpsc::channel();
            // The receiver is dropped: execute paths in these tests never
            // send responses, they only count batches.
            requests.push(super::super::Request {
                id,
                image: Vec::new(),
                enqueued: std::time::Instant::now(),
                resp: tx,
            });
        }
        Batch { requests }
    }

    #[test]
    fn worker_sizing_leaves_a_core_per_engine() {
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        // One engine: every spare core becomes a batch worker.
        assert_eq!(workers_per_engine(1), cores - 1);
        // Engines >= cores: no spare cores, serial batches.
        assert_eq!(workers_per_engine(cores), 0);
        assert_eq!(workers_per_engine(cores + 7), 0);
        // Degenerate input is clamped, not a panic.
        assert_eq!(workers_per_engine(0), cores - 1);
    }

    #[test]
    fn pipeline_sizing_shares_the_pool_budget() {
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        let budget = workers_per_engine(1).max(1);
        // Auto (0) takes the whole per-engine budget, capped by stages.
        assert_eq!(pipeline_groups_per_engine(1, 0, 7), budget.min(7));
        // Explicit requests clamp to the budget and the stage count.
        assert_eq!(pipeline_groups_per_engine(1, 3, 7), 3.min(budget));
        assert_eq!(pipeline_groups_per_engine(1, 99, 7), budget.min(7));
        assert_eq!(pipeline_groups_per_engine(1, 99, 2), budget.min(2));
        // Saturated hosts degenerate to a single group, never 0.
        assert_eq!(pipeline_groups_per_engine(cores + 7, 0, 7), 1);
        assert_eq!(pipeline_groups_per_engine(cores + 7, 4, 7), 1);
        // A stage-less count never produces 0 groups.
        assert_eq!(pipeline_groups_per_engine(1, 0, 0), 1);
    }

    #[test]
    fn pipeline_replication_spends_budget_slack() {
        let budget = workers_per_engine(1).max(1);
        let groups = pipeline_groups_per_engine(1, 3, 7);
        // Auto: the whole per-engine budget, never below one worker per
        // group — the slack becomes bottleneck replication.
        assert_eq!(pipeline_workers_per_engine(1, groups), budget.max(groups));
        // Explicit NxR clamps to the slack beyond one worker per group.
        let slack = budget.saturating_sub(groups);
        assert_eq!(pipeline_replicas_per_engine(1, groups, 1), 1);
        assert_eq!(pipeline_replicas_per_engine(1, groups, 99), slack + 1);
        // Saturated hosts degrade to the unreplicated pipeline.
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        assert_eq!(pipeline_replicas_per_engine(cores + 7, 1, 5), 1);
        assert_eq!(pipeline_workers_per_engine(cores + 7, 1), 1);
    }

    #[test]
    fn dispatch_spreads_over_engines() {
        let (plane, _mb) = ExecutionPlane::new(2, 4);
        for _ in 0..4 {
            plane.dispatch(batch(1)).map_err(|_| "closed").unwrap();
        }
        assert_eq!(plane.queue(0).len() + plane.queue(1).len(), 4);
        assert!(plane.queue(0).len() >= 1, "round-robin left ring 0 empty");
        assert!(plane.queue(1).len() >= 1, "round-robin left ring 1 empty");
    }

    #[test]
    fn set_depth_retunes_every_ring() {
        let (plane, _mb) = ExecutionPlane::new(2, 4);
        assert_eq!(plane.depth(), 4);
        plane.set_depth(1);
        assert_eq!(plane.depth(), 1);
        for eid in 0..2 {
            plane.queue(eid).try_push(batch(1)).map_err(|_| "full").unwrap();
            assert!(plane.queue(eid).try_push(batch(1)).is_err());
        }
        plane.set_depth(2);
        for eid in 0..2 {
            plane.queue(eid).try_push(batch(1)).map_err(|_| "full").unwrap();
        }
    }

    #[test]
    fn dispatch_after_close_returns_batch() {
        let (plane, _mb) = ExecutionPlane::new(2, 4);
        plane.close();
        assert!(plane.dispatch(batch(3)).is_err());
    }

    #[test]
    fn workers_drain_everything_before_exit() {
        let (plane, mailboxes) = ExecutionPlane::new(3, 2);
        let executed = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = mailboxes
            .into_iter()
            .map(|mb| {
                let plane = Arc::clone(&plane);
                let executed = Arc::clone(&executed);
                std::thread::spawn(move || {
                    worker_loop(&plane, &mb, |b, _stolen| {
                        executed.fetch_add(b.requests.len() as u64, Ordering::SeqCst);
                    });
                })
            })
            .collect();
        let total = 40u64;
        for _ in 0..total {
            plane.dispatch(batch(1)).map_err(|_| "closed").unwrap();
        }
        plane.close();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(executed.load(Ordering::SeqCst), total, "work lost in shutdown");
    }

    #[test]
    fn idle_engine_steals_from_a_busy_one() {
        // Engine 0 is slow (sleeps per batch); engine 1 executes
        // instantly. Overload ring 0 directly, then let both run: engine 1
        // must steal at least one batch for the drain to finish quickly.
        let (plane, mut mailboxes) = ExecutionPlane::new(2, 8);
        for _ in 0..6 {
            plane
                .queue(0)
                .try_push(batch(1))
                .map_err(|_| "ring 0 full")
                .unwrap();
        }
        plane.close();

        let per_engine = Arc::new(Mutex::new([0u64; 2]));
        let mb1 = mailboxes.pop().unwrap();
        let mb0 = mailboxes.pop().unwrap();

        let p0 = Arc::clone(&plane);
        let c0 = Arc::clone(&per_engine);
        let h0 = std::thread::spawn(move || {
            worker_loop(&p0, &mb0, |_b, _stolen| {
                std::thread::sleep(Duration::from_millis(30));
                c0.lock().unwrap()[0] += 1;
            });
        });
        let p1 = Arc::clone(&plane);
        let c1 = Arc::clone(&per_engine);
        let h1 = std::thread::spawn(move || {
            worker_loop(&p1, &mb1, |_b, stolen| {
                assert!(stolen, "engine 1's own ring is empty; pops must be steals");
                c1.lock().unwrap()[1] += 1;
            });
        });
        h0.join().unwrap();
        h1.join().unwrap();

        let counts = *per_engine.lock().unwrap();
        assert_eq!(counts[0] + counts[1], 6, "batches lost");
        assert!(
            counts[1] >= 1,
            "idle engine never stole (engine 0 ran all {} batches)",
            counts[0]
        );
    }
}
