//! Policy control plane (DESIGN.md §11): closes the loop from the
//! serving telemetry the fleet already exports (per-tag steal / shed /
//! queue-full counters, ring depths, budget occupancy) to the three
//! knobs the execution plane exposes — per-tag admission budgets,
//! per-engine ring depths, and fleet membership.
//!
//! The paper's engine-free thesis is that sparsity pays off only when
//! the surrounding dataflow keeps every lane busy; HPIPE makes the same
//! point with heterogeneous per-layer resource allocation. On the
//! serving side the analogous resources are admission slots and queue
//! capacity, and this module allocates them **per tag** instead of
//! FIFO-fair.
//!
//! Design rules:
//!
//! * **Decisions are pure functions of telemetry snapshots.** A
//!   [`Policy`] sees only a [`FleetTelemetry`] value (plus its own state
//!   from earlier ticks) and returns [`Decision`]s; nothing in the
//!   decision path reads the wall clock, so a recorded telemetry trace
//!   replays to the identical decision stream (asserted in the unit
//!   tests) and tests drive ticks on a seeded schedule.
//! * **Mechanism under the trait, policy above it.** The fleet applies
//!   decisions mechanically (`TagBudget::set_capacity`, ring
//!   `set_capacity`); what to decide lives here and is swappable.
//! * **Bounded and hysteretic.** The queue autotuner only moves depths
//!   within `[min_depth, max_depth]`, requires the same pressure signal
//!   on consecutive ticks before acting, and holds a cooldown after each
//!   change, so a noisy tick cannot thrash the rings.

use std::collections::BTreeMap;

use super::stats::StatsSnapshot;

/// Per-tag service-level objective: a p99 latency target (reported and
/// benchmarked against) and an admission **weight** (enforced — the
/// weights partition the host admission budget into per-tag caps).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Target p99 latency in milliseconds (surfaced in renders and the
    /// noisy-neighbour bench; the weight is what enforces it).
    pub p99_ms: f64,
    /// Admission weight (> 0). Tags without an SLO weigh 1.0.
    pub weight: f64,
}

impl SloSpec {
    /// An SLO with the given p99 target and weight. Both must be
    /// positive finite numbers — a zero or negative weight would
    /// silently starve the tag to a 1-slot budget, so it is rejected
    /// here (the CLI and file parsers return config errors for the same
    /// inputs before reaching this constructor).
    pub fn new(p99_ms: f64, weight: f64) -> Self {
        assert!(
            p99_ms.is_finite() && p99_ms > 0.0,
            "slo p99_ms must be a positive finite number, got {p99_ms}"
        );
        assert!(
            weight.is_finite() && weight > 0.0,
            "slo weight must be a positive finite number, got {weight}"
        );
        SloSpec { p99_ms, weight }
    }
}

/// Telemetry of one tag: its identity, its SLO (if any), and the plane's
/// sampled stats snapshot — counters (shed / shed_budget / steals /
/// batches / ring depth / ring-full backoffs / budget occupancy) plus
/// latency percentiles from the plane's bounded recent-completions
/// window, so a policy can act on the tag's current p99 without the
/// control path ever sorting a full-run reservoir — see
/// `Fleet::telemetry`.
#[derive(Debug, Clone)]
pub struct TagTelemetry {
    /// The model tag.
    pub tag: String,
    /// The tag's SLO, when one is configured.
    pub slo: Option<SloSpec>,
    /// The plane's sampled stats snapshot at this tick (bounded-window
    /// percentiles, full counters).
    pub stats: StatsSnapshot,
}

/// One tick's input to every policy: host-level admission state plus one
/// [`TagTelemetry`] per live tag. Pure data — building it samples
/// counters, consuming it never touches the clock.
#[derive(Debug, Clone)]
pub struct FleetTelemetry {
    /// Monotone tick counter (the control loop's logical clock).
    pub tick: u64,
    /// The shared host admission bound.
    pub capacity: usize,
    /// Host-wide in-flight requests at this tick.
    pub in_flight: usize,
    /// Per-live-tag telemetry, in plane order.
    pub per_tag: Vec<TagTelemetry>,
}

/// One actuation the control loop applies to the fleet.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Decision {
    /// Cap `tag`'s admission budget at `budget` in-flight requests.
    SetTagBudget {
        /// Target tag.
        tag: String,
        /// New in-flight cap (>= 1).
        budget: usize,
    },
    /// Lift `tag`'s admission cap entirely.
    SetTagUnlimited {
        /// Target tag.
        tag: String,
    },
    /// Retune `tag`'s per-engine work-ring depth to `depth` batches.
    SetRingDepth {
        /// Target tag.
        tag: String,
        /// New per-engine ring capacity (>= 1).
        depth: usize,
    },
}

/// A control policy: consumes one telemetry tick, emits decisions.
/// Implementations may keep state across ticks (hysteresis, deltas) but
/// must stay deterministic functions of the telemetry stream.
pub trait Policy: Send {
    /// Decide this tick's actuations from the telemetry snapshot.
    fn decide(&mut self, t: &FleetTelemetry) -> Vec<Decision>;
}

/// Partition `capacity` admission slots across `tags` by weight:
/// `budget_i = max(1, floor(capacity * w_i / sum(w)))`. The budgets are
/// **caps**, not reservations — flooring may leave slack, which stays
/// governed by the shared host gate. Returns one `(tag, budget)` pair
/// per input tag, in order.
pub fn weighted_budgets(capacity: usize, tags: &[(String, f64)]) -> Vec<(String, usize)> {
    let sum: f64 = tags.iter().map(|(_, w)| w.max(0.0)).sum();
    tags.iter()
        .map(|(tag, w)| {
            let share = if sum > 0.0 { w.max(0.0) / sum } else { 0.0 };
            let budget = ((capacity as f64) * share).floor() as usize;
            (tag.clone(), budget.max(1))
        })
        .collect()
}

/// Weighted-admission policy: whenever at least one live tag carries an
/// SLO, every tag's budget is set to its weighted share of the host
/// capacity (unweighted tags weigh 1.0); with no SLOs anywhere, all
/// budgets are lifted (the pre-§11 FIFO-fair behaviour). Emits only the
/// decisions that change something, so a steady fleet gets no churn.
#[derive(Debug, Default)]
pub struct WeightedAdmission;

impl Policy for WeightedAdmission {
    fn decide(&mut self, t: &FleetTelemetry) -> Vec<Decision> {
        let any_slo = t.per_tag.iter().any(|tt| tt.slo.is_some());
        if !any_slo {
            return t
                .per_tag
                .iter()
                .filter(|tt| tt.stats.budget_capacity.is_some())
                .map(|tt| Decision::SetTagUnlimited { tag: tt.tag.clone() })
                .collect();
        }
        let weights: Vec<(String, f64)> = t
            .per_tag
            .iter()
            .map(|tt| (tt.tag.clone(), tt.slo.map(|s| s.weight).unwrap_or(1.0)))
            .collect();
        weighted_budgets(t.capacity, &weights)
            .into_iter()
            .zip(&t.per_tag)
            .filter(|((_, budget), tt)| tt.stats.budget_capacity != Some(*budget))
            .map(|((tag, budget), _)| Decision::SetTagBudget { tag, budget })
            .collect()
    }
}

/// Queue-depth autotuner configuration. All counts are in ticks of the
/// control loop, so behaviour is independent of how often the operator
/// ticks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutotuneConfig {
    /// Smallest per-engine ring depth the tuner will set.
    pub min_depth: usize,
    /// Largest per-engine ring depth the tuner will set.
    pub max_depth: usize,
    /// Consecutive same-direction pressure ticks required before acting.
    pub hysteresis_ticks: u32,
    /// Ticks to hold after a change before acting again.
    pub cooldown_ticks: u32,
    /// Shrink signal threshold: steals-per-dispatched-batch above this
    /// (with no queue-full pressure) reads as "work is clumping in
    /// oversized rings".
    pub steal_fraction: f64,
}

impl Default for AutotuneConfig {
    fn default() -> Self {
        AutotuneConfig {
            min_depth: 2,
            max_depth: 64,
            hysteresis_ticks: 2,
            cooldown_ticks: 2,
            steal_fraction: 0.5,
        }
    }
}

/// Per-tag autotuner state: counter values at the previous tick plus the
/// hysteresis bookkeeping.
#[derive(Debug, Clone, Copy, Default)]
struct TagTune {
    full_backoffs: u64,
    steals: u64,
    batches: u64,
    /// Signed signal streak: positive = grow pressure, negative = shrink.
    streak: i32,
    cooldown: u32,
}

/// Queue-depth autotuning policy: grows a tag's rings when its
/// dispatcher is hitting **full-ring backoffs** (the one pressure deeper
/// rings actually relieve — admission sheds happen upstream of the rings
/// and cannot be fixed by buffering, so they deliberately play no part
/// here) and shrinks them when steals dominate dispatches with no
/// queue-full pressure (deep rings let work clump on one engine, which
/// stealing then has to undo). Depth moves by doubling/halving within
/// [`AutotuneConfig`] bounds, gated by hysteresis and cooldown.
/// Deterministic: state advances only on `decide`, from counter deltas.
#[derive(Debug)]
pub struct QueueAutotune {
    cfg: AutotuneConfig,
    state: BTreeMap<String, TagTune>,
}

impl QueueAutotune {
    /// An autotuner with the given bounds and hysteresis.
    pub fn new(cfg: AutotuneConfig) -> Self {
        assert!(cfg.min_depth >= 1, "min_depth must be >= 1");
        assert!(cfg.max_depth >= cfg.min_depth, "max_depth < min_depth");
        QueueAutotune { cfg, state: BTreeMap::new() }
    }
}

impl Policy for QueueAutotune {
    fn decide(&mut self, t: &FleetTelemetry) -> Vec<Decision> {
        // Drop state of retired tags so a re-registered tag starts fresh.
        let live: Vec<&str> = t.per_tag.iter().map(|tt| tt.tag.as_str()).collect();
        self.state.retain(|tag, _| live.contains(&tag.as_str()));

        let mut out = Vec::new();
        for tt in &t.per_tag {
            let depth = tt.stats.ring_depth;
            if depth == 0 {
                continue; // plane did not report a depth; nothing to tune
            }
            let st = self.state.entry(tt.tag.clone()).or_default();
            let d_full = tt.stats.ring_full_backoffs.saturating_sub(st.full_backoffs);
            let d_steals = tt.stats.steals.saturating_sub(st.steals);
            let d_batches = tt.stats.batches.saturating_sub(st.batches);
            st.full_backoffs = tt.stats.ring_full_backoffs;
            st.steals = tt.stats.steals;
            st.batches = tt.stats.batches;

            let signal: i32 = if d_full > 0 {
                1
            } else if d_batches > 0
                && (d_steals as f64) > self.cfg.steal_fraction * (d_batches as f64)
            {
                -1
            } else {
                0
            };

            if st.cooldown > 0 {
                st.cooldown -= 1;
                st.streak = 0;
                continue;
            }
            st.streak = if signal == 0 {
                0
            } else if signal.signum() == st.streak.signum() || st.streak == 0 {
                st.streak + signal
            } else {
                signal
            };
            // A zero streak means "no pressure this tick" and must never
            // act, even with hysteresis_ticks == 0 (where a non-zero
            // signal acts immediately).
            if st.streak == 0 || st.streak.unsigned_abs() < self.cfg.hysteresis_ticks {
                continue;
            }
            let target = if st.streak > 0 {
                (depth * 2).min(self.cfg.max_depth)
            } else {
                (depth / 2).max(self.cfg.min_depth)
            };
            st.streak = 0;
            st.cooldown = self.cfg.cooldown_ticks;
            if target != depth {
                out.push(Decision::SetRingDepth { tag: tt.tag.clone(), depth: target });
            }
        }
        out
    }
}

/// The fleet's control loop: an ordered stack of policies sharing one
/// logical tick counter. The fleet gathers telemetry, the controller
/// decides, the fleet applies — see `Fleet::tick`.
pub struct Controller {
    policies: Vec<Box<dyn Policy>>,
    tick: u64,
}

impl Controller {
    /// An empty controller (ticks are no-ops until policies are pushed).
    pub fn new() -> Self {
        Controller { policies: Vec::new(), tick: 0 }
    }

    /// Append a policy; policies run in insertion order each tick.
    pub fn push(&mut self, policy: Box<dyn Policy>) {
        self.policies.push(policy);
    }

    /// Ticks taken so far.
    pub fn ticks(&self) -> u64 {
        self.tick
    }

    /// Run one tick: stamp the telemetry with the logical tick and
    /// collect every policy's decisions, in order.
    pub fn tick(&mut self, telemetry: &mut FleetTelemetry) -> Vec<Decision> {
        telemetry.tick = self.tick;
        self.tick += 1;
        let mut out = Vec::new();
        for p in &mut self.policies {
            out.extend(p.decide(telemetry));
        }
        out
    }
}

impl Default for Controller {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::stats::ServerStats;

    fn tag_t(tag: &str, slo: Option<SloSpec>, f: impl Fn(&mut StatsSnapshot)) -> TagTelemetry {
        let mut stats = ServerStats::new().snapshot();
        f(&mut stats);
        TagTelemetry { tag: tag.to_string(), slo, stats }
    }

    fn telem(capacity: usize, per_tag: Vec<TagTelemetry>) -> FleetTelemetry {
        FleetTelemetry { tick: 0, capacity, in_flight: 0, per_tag }
    }

    #[test]
    fn weighted_budgets_partition_by_weight() {
        let tags = vec![("a".to_string(), 8.0), ("b".to_string(), 1.0)];
        let b = weighted_budgets(64, &tags);
        assert_eq!(b, vec![("a".to_string(), 56), ("b".to_string(), 7)]);
        // Budgets are caps: the floored sum may undershoot capacity.
        assert!(b.iter().map(|(_, v)| v).sum::<usize>() <= 64);
        // Tiny weights still get a floor of 1.
        let tiny = weighted_budgets(4, &[("x".to_string(), 1e-9), ("y".to_string(), 1.0)]);
        assert_eq!(tiny[0].1, 1);
    }

    #[test]
    fn weighted_admission_caps_only_when_an_slo_exists() {
        let mut p = WeightedAdmission;
        // No SLOs: nothing to do (budgets already unlimited).
        let t = telem(64, vec![tag_t("a", None, |_| {}), tag_t("b", None, |_| {})]);
        assert!(p.decide(&t).is_empty());
        // One SLO: every tag gets its weighted cap.
        let t = telem(
            64,
            vec![
                tag_t("a", Some(SloSpec::new(20.0, 8.0)), |_| {}),
                tag_t("b", None, |_| {}),
            ],
        );
        let d = p.decide(&t);
        assert_eq!(
            d,
            vec![
                Decision::SetTagBudget { tag: "a".into(), budget: 56 },
                Decision::SetTagBudget { tag: "b".into(), budget: 7 },
            ]
        );
        // Idempotent: with the caps already applied, no churn.
        let t = telem(
            64,
            vec![
                tag_t("a", Some(SloSpec::new(20.0, 8.0)), |s| {
                    s.budget_capacity = Some(56)
                }),
                tag_t("b", None, |s| s.budget_capacity = Some(7)),
            ],
        );
        assert!(p.decide(&t).is_empty());
        // Last SLO gone: caps are lifted.
        let t = telem(
            64,
            vec![
                tag_t("a", None, |s| s.budget_capacity = Some(56)),
                tag_t("b", None, |s| s.budget_capacity = Some(7)),
            ],
        );
        let d = p.decide(&t);
        assert_eq!(
            d,
            vec![
                Decision::SetTagUnlimited { tag: "a".into() },
                Decision::SetTagUnlimited { tag: "b".into() },
            ]
        );
    }

    /// Replay a synthetic queue-pressure ramp through the autotuner
    /// twice: the decision streams must be identical (determinism), every
    /// depth must stay within bounds, and a single noisy tick must not
    /// act (hysteresis).
    #[test]
    fn autotune_is_bounded_hysteretic_and_deterministic() {
        let cfg = AutotuneConfig {
            min_depth: 2,
            max_depth: 32,
            hysteresis_ticks: 2,
            cooldown_ticks: 1,
            steal_fraction: 0.5,
        };
        // Tick-indexed (full_backoffs, steals, batches, current depth).
        let trace: Vec<(u64, u64, u64, usize)> = vec![
            (0, 0, 10, 16),    // baseline
            (5, 0, 20, 16),    // rings full (streak 1)
            (9, 0, 30, 16),    // rings full (streak 2) -> grow to 32
            (9, 0, 40, 32),    // cooldown tick
            (9, 0, 50, 32),    // quiet (streak resets)
            (9, 40, 90, 32),   // steals dominate dispatches (streak -1)
            (9, 80, 130, 32),  // streak -2 -> shrink to 16
            (9, 80, 140, 16),  // cooldown tick
        ];
        let run = || {
            let mut p = QueueAutotune::new(cfg);
            let mut all = Vec::new();
            for &(full, steals, batches, depth) in &trace {
                let t = telem(
                    64,
                    vec![tag_t("a", None, |s| {
                        s.ring_full_backoffs = full;
                        s.steals = steals;
                        s.batches = batches;
                        s.ring_depth = depth;
                    })],
                );
                all.push(p.decide(&t));
            }
            all
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same telemetry trace must replay identically");
        let flat: Vec<&Decision> = a.iter().flatten().collect();
        assert_eq!(
            flat,
            vec![
                &Decision::SetRingDepth { tag: "a".into(), depth: 32 },
                &Decision::SetRingDepth { tag: "a".into(), depth: 16 },
            ]
        );
        // One noisy tick never acts: a fresh tuner seeing a single
        // queue-full spike stays quiet (hysteresis needs 2 consecutive
        // signals).
        let mut p = QueueAutotune::new(cfg);
        let quiet = telem(
            64,
            vec![tag_t("a", None, |s| {
                s.ring_depth = 16;
                s.batches = 10;
            })],
        );
        assert!(p.decide(&quiet).is_empty());
        let spike = telem(
            64,
            vec![tag_t("a", None, |s| {
                s.ring_full_backoffs = 3;
                s.ring_depth = 16;
                s.batches = 20;
            })],
        );
        assert!(p.decide(&spike).is_empty(), "single spike must not act");

        // Admission sheds alone must NOT move depth: they happen upstream
        // of the rings, where buffering cannot relieve them.
        let mut p = QueueAutotune::new(cfg);
        for shed in [0u64, 50, 100, 150] {
            let t = telem(
                64,
                vec![tag_t("a", None, |s| {
                    s.shed = shed;
                    s.shed_budget = shed;
                    s.ring_depth = 16;
                    s.batches = shed + 10;
                })],
            );
            assert!(p.decide(&t).is_empty(), "sheds must not drive ring depth");
        }

        // hysteresis_ticks = 0 means "act on the first signal", never
        // "act on no signal": quiet ticks must not shrink healthy rings.
        let mut p = QueueAutotune::new(AutotuneConfig {
            hysteresis_ticks: 0,
            cooldown_ticks: 0,
            ..AutotuneConfig::default()
        });
        for batches in [10u64, 20, 30] {
            let t = telem(
                64,
                vec![tag_t("a", None, |s| {
                    s.ring_depth = 16;
                    s.batches = batches;
                })],
            );
            assert!(p.decide(&t).is_empty(), "quiet tick acted at hysteresis 0");
        }
        let t = telem(
            64,
            vec![tag_t("a", None, |s| {
                s.ring_full_backoffs = 1;
                s.ring_depth = 16;
                s.batches = 40;
            })],
        );
        assert_eq!(
            p.decide(&t),
            vec![Decision::SetRingDepth { tag: "a".into(), depth: 32 }],
            "hysteresis 0 must act on the first real signal"
        );
    }

    #[test]
    fn autotune_forgets_retired_tags() {
        let mut p = QueueAutotune::new(AutotuneConfig::default());
        let t = telem(
            64,
            vec![tag_t("gone", None, |s| {
                s.ring_full_backoffs = 5;
                s.ring_depth = 16;
            })],
        );
        let _ = p.decide(&t);
        assert!(p.state.contains_key("gone"));
        let t = telem(64, vec![tag_t("other", None, |s| s.ring_depth = 16)]);
        let _ = p.decide(&t);
        assert!(!p.state.contains_key("gone"), "retired tag state retained");
    }

    #[test]
    fn controller_stamps_ticks_and_runs_policies_in_order() {
        let mut c = Controller::new();
        c.push(Box::new(WeightedAdmission));
        c.push(Box::new(QueueAutotune::new(AutotuneConfig::default())));
        let mut t = telem(
            16,
            vec![tag_t("a", Some(SloSpec::new(10.0, 1.0)), |s| s.ring_depth = 8)],
        );
        let d = c.tick(&mut t);
        assert_eq!(t.tick, 0);
        assert_eq!(d, vec![Decision::SetTagBudget { tag: "a".into(), budget: 16 }]);
        let mut t2 = telem(16, Vec::new());
        let _ = c.tick(&mut t2);
        assert_eq!(t2.tick, 1);
        assert_eq!(c.ticks(), 2);
    }
}
