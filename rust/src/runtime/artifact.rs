//! Artifact discovery: map `artifacts/lenet_<tag>_b<batch>.hlo.txt` files
//! to (tag, batch) variants without touching their contents (compilation
//! happens lazily in [`super::ModelRuntime::load`]).

use crate::util::error::{Error, Result};
use std::path::{Path, PathBuf};

/// One discovered artifact file.
#[derive(Debug, Clone, PartialEq)]
pub struct Variant {
    /// Model tag embedded in the file name.
    pub tag: String,
    /// Batch size the variant was lowered for.
    pub batch: usize,
    /// Path of the HLO text file.
    pub path: PathBuf,
}

/// Parse `lenet_<tag>_b<batch>.hlo.txt`; tags may contain underscores.
pub fn parse_name(name: &str) -> Option<(String, usize)> {
    let rest = name.strip_prefix("lenet_")?.strip_suffix(".hlo.txt")?;
    let (tag, b) = rest.rsplit_once("_b")?;
    let batch: usize = b.parse().ok()?;
    if tag.is_empty() || batch == 0 {
        return None;
    }
    Some((tag.to_string(), batch))
}

/// Path of the packed parameter store for `tag` — written by the python
/// exporter (stage 1/2) or natively by `ModelParams::to_store`, and read
/// back by `ModelParams::load_artifacts` / the kernel compile pass. One
/// naming rule for every producer and consumer.
pub fn params_path(dir: &Path, tag: &str) -> PathBuf {
    dir.join(format!("params_{tag}.lstw"))
}

/// All batch variants of `tag` in `dir`, sorted by batch.
pub fn discover_variants(dir: &Path, tag: &str) -> Result<Vec<Variant>> {
    if !dir.exists() {
        return Err(Error::Xla(format!("artifact dir {} does not exist", dir.display())));
    }
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some((t, batch)) = parse_name(&name) {
            if t == tag {
                out.push(Variant { tag: t, batch, path: entry.path() });
            }
        }
    }
    out.sort_by_key(|v| v.batch);
    Ok(out)
}

/// All tags present in `dir`.
pub fn discover_tags(dir: &Path) -> Result<Vec<String>> {
    let mut tags: Vec<String> = Vec::new();
    if !dir.exists() {
        return Ok(tags);
    }
    for entry in std::fs::read_dir(dir)? {
        let name = entry?.file_name();
        if let Some((t, _)) = parse_name(&name.to_string_lossy()) {
            if !tags.contains(&t) {
                tags.push(t);
            }
        }
    }
    tags.sort();
    Ok(tags)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_parsing() {
        assert_eq!(parse_name("lenet_dense_b8.hlo.txt"), Some(("dense".into(), 8)));
        assert_eq!(
            parse_name("lenet_unfold_pruned_b32.hlo.txt"),
            Some(("unfold_pruned".into(), 32))
        );
        assert_eq!(parse_name("lenet_dense_b0.hlo.txt"), None);
        assert_eq!(parse_name("other_dense_b8.hlo.txt"), None);
        assert_eq!(parse_name("lenet_dense_b8.hlo"), None);
        assert_eq!(parse_name("lenet__b8.hlo.txt"), None);
    }

    #[test]
    fn discovery_sorted() {
        let dir = std::env::temp_dir().join(format!("lstw_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for b in [32, 1, 8] {
            std::fs::write(dir.join(format!("lenet_x_b{b}.hlo.txt")), "hlo").unwrap();
        }
        std::fs::write(dir.join("lenet_y_b4.hlo.txt"), "hlo").unwrap();
        std::fs::write(dir.join("readme.md"), "not an artifact").unwrap();

        let vs = discover_variants(&dir, "x").unwrap();
        assert_eq!(vs.iter().map(|v| v.batch).collect::<Vec<_>>(), vec![1, 8, 32]);
        let tags = discover_tags(&dir).unwrap();
        assert_eq!(tags, vec!["x", "y"]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn params_naming_matches_exporter() {
        assert_eq!(
            params_path(Path::new("artifacts"), "proposed"),
            PathBuf::from("artifacts/params_proposed.lstw")
        );
    }

    #[test]
    fn missing_dir_errors() {
        assert!(discover_variants(Path::new("/no/such/dir"), "x").is_err());
    }
}
