//! PJRT runtime (substrate S11): load AOT artifacts, execute on the
//! request path.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. HLO **text**
//! is the interchange (jax ≥ 0.5 serialized protos use 64-bit ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! One [`Executable`] per (model variant, batch size); [`ModelRuntime`]
//! owns the set exported by `make artifacts` and picks the best batch
//! variant for each dynamic batch (smallest variant ≥ n, padding the
//! remainder — the classic serving trick the batcher exploits).

pub mod artifact;

use crate::util::error::{Error, Result};
use std::path::Path;

pub use artifact::{discover_variants, Variant};

/// Image geometry of the LeNet artifacts (NHWC).
pub const IMG: usize = 28;
/// Logits per image (MNIST-shaped output).
pub const NUM_CLASSES: usize = 10;

/// A compiled HLO executable with a fixed batch size.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// The batch size this executable was lowered for.
    pub batch: usize,
    /// Source HLO text file the executable was compiled from.
    pub path: String,
}

impl Executable {
    /// Run one batch: `x` is [batch, 28, 28, 1] flattened, f32.
    /// Returns logits [batch, 10] flattened.
    pub fn infer(&self, x: &[f32]) -> Result<Vec<f32>> {
        let expect = self.batch * IMG * IMG;
        if x.len() != expect {
            return Err(Error::Xla(format!(
                "input length {} != batch {} * {}",
                x.len(),
                self.batch,
                IMG * IMG
            )));
        }
        let lit = xla::Literal::vec1(x)
            .reshape(&[self.batch as i64, IMG as i64, IMG as i64, 1])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        let logits = out.to_vec::<f32>()?;
        if logits.len() != self.batch * NUM_CLASSES {
            return Err(Error::Xla(format!(
                "output length {} != batch {} * {NUM_CLASSES}",
                logits.len(),
                self.batch
            )));
        }
        Ok(logits)
    }
}

/// The serving runtime: a PJRT client plus compiled batch variants of one
/// model tag (e.g. "proposed").
pub struct ModelRuntime {
    client: xla::PjRtClient,
    /// Sorted by batch ascending.
    pub executables: Vec<Executable>,
    /// The artifact tag the variants were loaded for.
    pub tag: String,
}

impl ModelRuntime {
    /// Compile every `lenet_<tag>_b*.hlo.txt` under `dir`.
    pub fn load(dir: impl AsRef<Path>, tag: &str) -> Result<Self> {
        let variants = artifact::discover_variants(dir.as_ref(), tag)?;
        if variants.is_empty() {
            return Err(Error::Xla(format!(
                "no artifacts for tag '{tag}' in {} — run `make artifacts`",
                dir.as_ref().display()
            )));
        }
        let client = xla::PjRtClient::cpu()?;
        let mut executables = Vec::with_capacity(variants.len());
        for v in variants {
            let proto = xla::HloModuleProto::from_text_file(
                v.path.to_str().ok_or_else(|| Error::Xla("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            executables.push(Executable {
                exe,
                batch: v.batch,
                path: v.path.display().to_string(),
            });
        }
        executables.sort_by_key(|e| e.batch);
        Ok(ModelRuntime { client, executables, tag: tag.to_string() })
    }

    /// The PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Batch sizes of the loaded variants, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.executables.iter().map(|e| e.batch).collect()
    }

    /// The largest loaded batch variant (0 when none).
    pub fn max_batch(&self) -> usize {
        self.executables.last().map(|e| e.batch).unwrap_or(0)
    }

    /// Smallest variant whose batch ≥ n (or the largest variant).
    pub fn pick(&self, n: usize) -> &Executable {
        self.executables
            .iter()
            .find(|e| e.batch >= n)
            .unwrap_or_else(|| self.executables.last().expect("non-empty"))
    }

    /// Run `n ≤ pick(n).batch` images, padding the tail; returns n*10 logits.
    pub fn infer_padded(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        if n == 0 {
            return Ok(Vec::new());
        }
        let px = IMG * IMG;
        if x.len() != n * px {
            return Err(Error::Xla(format!("expected {n}*{px} inputs, got {}", x.len())));
        }
        let exe = self.pick(n);
        if n > exe.batch {
            // Larger than the largest variant: split into chunks.
            let mut out = Vec::with_capacity(n * NUM_CLASSES);
            for chunk in x.chunks(exe.batch * px) {
                let m = chunk.len() / px;
                out.extend(self.infer_padded(chunk, m)?);
            }
            return Ok(out);
        }
        let mut padded = x.to_vec();
        padded.resize(exe.batch * px, 0.0);
        let mut logits = exe.infer(&padded)?;
        logits.truncate(n * NUM_CLASSES);
        Ok(logits)
    }
}

/// Engine-side inference abstraction: the sharded execution plane drives
/// any backend that can execute one padded batch. [`ModelRuntime`] (PJRT
/// over AOT artifacts) is the production backend; [`SyntheticRuntime`] is
/// a deterministic stand-in with a configurable per-image cost, so the
/// serving plane — queues, stealing, admission, shutdown — can be
/// exercised and benchmarked *engine-free* (no artifacts, no XLA).
///
/// Backends are constructed inside their engine thread (the PJRT client is
/// `Rc`-based and not `Send`), so the trait itself needs no `Send` bound.
pub trait InferenceBackend {
    /// Run `n` images (`x.len() == n * IMG * IMG`); return `n *
    /// NUM_CLASSES` logits.
    fn infer_padded(&self, x: &[f32], n: usize) -> Result<Vec<f32>>;

    /// Human-readable backend label for logs and reports.
    fn label(&self) -> String;
}

impl InferenceBackend for ModelRuntime {
    fn infer_padded(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        ModelRuntime::infer_padded(self, x, n)
    }

    fn label(&self) -> String {
        format!("pjrt/{}", self.tag)
    }
}

/// Deterministic synthetic backend: burns `per_image` of wall time per
/// image (sleep, so replicas scale on any core count) and classifies by a
/// fixed stripe-sum rule — the logit for class `c` is the sum of pixels
/// whose index ≡ c (mod `NUM_CLASSES`). Same image in, same class out,
/// which lets serving tests assert end-to-end correctness without weights.
pub struct SyntheticRuntime {
    /// Simulated wall-clock cost per image (sleep).
    pub per_image: std::time::Duration,
}

impl SyntheticRuntime {
    /// A synthetic backend burning `per_image` of wall time per image.
    pub fn new(per_image: std::time::Duration) -> Self {
        SyntheticRuntime { per_image }
    }

    /// The class this backend will assign to `image` (for test oracles).
    pub fn expected_class(image: &[f32]) -> usize {
        let mut logits = vec![0.0f32; NUM_CLASSES];
        for (j, &v) in image.iter().enumerate() {
            logits[j % NUM_CLASSES] += v;
        }
        argmax_classes(&logits)[0]
    }

    /// A deterministic test image this backend classifies as
    /// `class % NUM_CLASSES`: ones on exactly that stripe. The single
    /// source for synthetic request streams (tests, benches, CLI), so
    /// generators can never drift from the classifier rule above.
    pub fn stripe_image(class: usize) -> Vec<f32> {
        let px = IMG * IMG;
        let mut img = vec![0.0f32; px];
        let mut j = class % NUM_CLASSES;
        while j < px {
            img[j] = 1.0;
            j += NUM_CLASSES;
        }
        img
    }

    /// A deterministic synthetic test set: `n` stripe images (flattened,
    /// testset.lstw layout) with their expected labels — the engine-free
    /// stand-in for the exported test set, shared by the CLI and examples.
    pub fn dataset(n: usize) -> (Vec<f32>, Vec<i32>) {
        let mut images = Vec::with_capacity(n * IMG * IMG);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let img = Self::stripe_image(i);
            labels.push(Self::expected_class(&img) as i32);
            images.extend_from_slice(&img);
        }
        (images, labels)
    }
}

impl InferenceBackend for SyntheticRuntime {
    fn infer_padded(&self, x: &[f32], n: usize) -> Result<Vec<f32>> {
        let px = IMG * IMG;
        if x.len() != n * px {
            return Err(Error::Xla(format!(
                "synthetic backend: expected {n}*{px} inputs, got {}",
                x.len()
            )));
        }
        if !self.per_image.is_zero() {
            std::thread::sleep(self.per_image * n as u32);
        }
        let mut out = vec![0.0f32; n * NUM_CLASSES];
        for i in 0..n {
            let row = &x[i * px..(i + 1) * px];
            let logits = &mut out[i * NUM_CLASSES..(i + 1) * NUM_CLASSES];
            for (j, &v) in row.iter().enumerate() {
                logits[j % NUM_CLASSES] += v;
            }
        }
        Ok(out)
    }

    fn label(&self) -> String {
        format!("synthetic/{}us", self.per_image.as_micros())
    }
}

/// argmax over each row of `logits` ([n, NUM_CLASSES] flattened).
pub fn argmax_classes(logits: &[f32]) -> Vec<usize> {
    logits
        .chunks(NUM_CLASSES)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_backend_is_deterministic_and_shaped() {
        let be = SyntheticRuntime::new(std::time::Duration::ZERO);
        let px = IMG * IMG;
        let mut x = vec![0.0f32; 2 * px];
        // Image 0 biased toward class 3, image 1 toward class 7.
        for j in (3..px).step_by(NUM_CLASSES) {
            x[j] = 1.0;
        }
        for j in (7..px).step_by(NUM_CLASSES) {
            x[px + j] = 1.0;
        }
        let logits = InferenceBackend::infer_padded(&be, &x, 2).unwrap();
        assert_eq!(logits.len(), 2 * NUM_CLASSES);
        assert_eq!(argmax_classes(&logits), vec![3, 7]);
        assert_eq!(SyntheticRuntime::expected_class(&x[..px]), 3);
        assert_eq!(SyntheticRuntime::expected_class(&x[px..]), 7);
        // Generator and classifier agree for every class.
        for c in 0..NUM_CLASSES {
            let img = SyntheticRuntime::stripe_image(c);
            assert_eq!(img.len(), px);
            assert_eq!(SyntheticRuntime::expected_class(&img), c);
        }
        // Length mismatch is rejected.
        assert!(InferenceBackend::infer_padded(&be, &x, 3).is_err());
    }

    #[test]
    fn argmax_rows() {
        let mut logits = vec![0.0f32; 20];
        logits[3] = 5.0; // row 0 -> 3
        logits[10 + 7] = 2.0; // row 1 -> 7
        assert_eq!(argmax_classes(&logits), vec![3, 7]);
    }

    #[test]
    fn missing_artifacts_error_is_actionable() {
        let err = match ModelRuntime::load("/nonexistent-dir", "proposed") {
            Err(e) => e,
            Ok(_) => panic!("load from missing dir must fail"),
        };
        let msg = err.to_string();
        assert!(msg.contains("make artifacts") || msg.contains("nonexistent"), "{msg}");
    }
}
