//! FINN-style folding algebra (substrate S5).
//!
//! A MAC layer is implemented by a Matrix-Vector-Activation Unit with `PE`
//! output lanes and `SIMD` input lanes. Folding trades area for time:
//!
//! ```text
//! II_cycles/frame = out_pixels · (fold_in / SIMD) · (fold_out / PE)
//! ```
//!
//! `PE` must divide the output-channel axis and `SIMD` the input axis
//! (K²·Cin for conv) — the same legality rule FINN's transformation checks.
//! A layer's *style* records how the DSE decided to implement it; styles
//! other than `Folded` are where LogicSparse departs from stock FINN.

pub mod space;

use crate::graph::{Graph, Node};
use crate::util::error::{Error, Result};

/// Implementation style of a MAC layer (paper Sec. II).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Style {
    /// Time-multiplexed PE/SIMD array, dense weights streamed from BRAM.
    Folded,
    /// Fully unrolled; every weight baked into logic (dense).
    UnrolledDense,
    /// Fully unrolled with engine-free unstructured sparsity: pruned
    /// weights synthesise to nothing.
    UnrolledSparse,
    /// Partially unrolled (PE/SIMD > baseline) with sparse packing.
    PartialSparse,
    /// Fully unrolled N:M-structured schedule: at most N surviving
    /// weights in every group of M consecutive input rows, indices
    /// decoded at a fixed stride (the N and M are derived from the
    /// layer's mask at compile time).
    NmStructured,
}

impl Style {
    /// Canonical config-file name of the style.
    pub fn as_str(&self) -> &'static str {
        match self {
            Style::Folded => "folded",
            Style::UnrolledDense => "unrolled_dense",
            Style::UnrolledSparse => "unrolled_sparse",
            Style::PartialSparse => "partial_sparse",
            Style::NmStructured => "nm_structured",
        }
    }

    /// Parse a canonical style name.
    pub fn parse(s: &str) -> Result<Style> {
        match s {
            "folded" => Ok(Style::Folded),
            "unrolled_dense" => Ok(Style::UnrolledDense),
            "unrolled_sparse" => Ok(Style::UnrolledSparse),
            "partial_sparse" => Ok(Style::PartialSparse),
            "nm_structured" => Ok(Style::NmStructured),
            other => Err(Error::folding(format!("unknown style '{other}'"))),
        }
    }

    /// True for the sparse packing styles.
    pub fn is_sparse(&self) -> bool {
        matches!(
            self,
            Style::UnrolledSparse | Style::PartialSparse | Style::NmStructured
        )
    }

    /// True for the fully unrolled styles.
    pub fn is_unrolled(&self) -> bool {
        matches!(
            self,
            Style::UnrolledDense | Style::UnrolledSparse | Style::NmStructured
        )
    }
}

/// Folding decision for one MAC layer.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerFold {
    /// Output (PE) lanes.
    pub pe: usize,
    /// Input (SIMD) lanes.
    pub simd: usize,
    /// Implementation style.
    pub style: Style,
    /// Fraction of weights pruned (0 for dense styles).
    pub sparsity: f64,
}

impl LayerFold {
    /// Minimal folding: one PE, one SIMD lane (the fully folded baseline).
    pub fn minimal() -> Self {
        LayerFold { pe: 1, simd: 1, style: Style::Folded, sparsity: 0.0 }
    }

    /// Full unroll of `node`, dense.
    pub fn unrolled(node: &Node) -> Self {
        LayerFold {
            pe: node.fold_out(),
            simd: node.fold_in(),
            style: Style::UnrolledDense,
            sparsity: 0.0,
        }
    }

    /// Full unroll of `node` with engine-free sparsity.
    pub fn unrolled_sparse(node: &Node, sparsity: f64) -> Self {
        LayerFold {
            pe: node.fold_out(),
            simd: node.fold_in(),
            style: Style::UnrolledSparse,
            sparsity,
        }
    }

    /// Is this folding legal for `node`?
    pub fn check(&self, node: &Node) -> Result<()> {
        if self.pe == 0 || self.simd == 0 {
            return Err(Error::folding(format!("{}: zero PE/SIMD", node.name)));
        }
        if node.fold_out() % self.pe != 0 {
            return Err(Error::folding(format!(
                "{}: PE {} does not divide output axis {}",
                node.name,
                self.pe,
                node.fold_out()
            )));
        }
        if node.fold_in() % self.simd != 0 {
            return Err(Error::folding(format!(
                "{}: SIMD {} does not divide input axis {}",
                node.name,
                self.simd,
                node.fold_in()
            )));
        }
        if !(0.0..1.0).contains(&self.sparsity) {
            return Err(Error::folding(format!(
                "{}: sparsity {} out of [0,1)",
                node.name, self.sparsity
            )));
        }
        if self.style.is_unrolled()
            && (self.pe != node.fold_out() || self.simd != node.fold_in())
        {
            return Err(Error::folding(format!(
                "{}: style {:?} requires full PE/SIMD unroll",
                node.name, self.style
            )));
        }
        if !self.style.is_sparse() && self.sparsity != 0.0 {
            return Err(Error::folding(format!(
                "{}: dense style with nonzero sparsity {}",
                node.name, self.sparsity
            )));
        }
        Ok(())
    }

    /// Initiation interval in cycles per frame for `node` under this fold.
    pub fn cycles_per_frame(&self, node: &Node) -> u64 {
        let in_folds = (node.fold_in() / self.simd) as u64;
        let out_folds = (node.fold_out() / self.pe) as u64;
        node.out_pixels() as u64 * in_folds * out_folds
    }

    /// Total MAC lanes instantiated.
    pub fn lanes(&self) -> u64 {
        (self.pe * self.simd) as u64
    }

    /// Surviving weights under the sparsity annotation.
    pub fn nnz(&self, node: &Node) -> u64 {
        ((node.weights() as f64) * (1.0 - self.sparsity)).round() as u64
    }
}

/// Folding decisions for every MAC layer of a graph, name-keyed and
/// insertion-ordered (stream order).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FoldingConfig {
    /// `(layer, fold)` pairs in stream order.
    pub layers: Vec<(String, LayerFold)>,
}

impl FoldingConfig {
    /// Fully folded baseline for `g` (PE = SIMD = 1 everywhere).
    pub fn minimal(g: &Graph) -> Self {
        FoldingConfig {
            layers: g
                .mac_nodes()
                .map(|n| (n.name.clone(), LayerFold::minimal()))
                .collect(),
        }
    }

    /// Dense full unroll of every MAC layer.
    pub fn unrolled(g: &Graph) -> Self {
        FoldingConfig {
            layers: g
                .mac_nodes()
                .map(|n| (n.name.clone(), LayerFold::unrolled(n)))
                .collect(),
        }
    }

    /// The fold of layer `name`, if present.
    pub fn get(&self, name: &str) -> Option<&LayerFold> {
        self.layers.iter().find(|(n, _)| n == name).map(|(_, f)| f)
    }

    /// Mutable access to the fold of layer `name`, if present.
    pub fn get_mut(&mut self, name: &str) -> Option<&mut LayerFold> {
        self.layers.iter_mut().find(|(n, _)| n == name).map(|(_, f)| f)
    }

    /// Insert or replace the fold of layer `name`.
    pub fn set(&mut self, name: &str, fold: LayerFold) {
        match self.get_mut(name) {
            Some(f) => *f = fold,
            None => self.layers.push((name.to_string(), fold)),
        }
    }

    /// Validate every layer against the graph.
    pub fn check(&self, g: &Graph) -> Result<()> {
        for (name, fold) in &self.layers {
            fold.check(g.node(name)?)?;
        }
        // Every MAC node must be covered.
        for n in g.mac_nodes() {
            if self.get(&n.name).is_none() {
                return Err(Error::folding(format!("layer '{}' missing from config", n.name)));
            }
        }
        Ok(())
    }

    /// The slowest layer's II (cycles/frame) — the pipeline's steady-state
    /// bottleneck.
    pub fn max_ii(&self, g: &Graph) -> Result<u64> {
        let mut max = 0;
        for (name, fold) in &self.layers {
            max = max.max(fold.cycles_per_frame(g.node(name)?));
        }
        Ok(max)
    }

    /// Name of the bottleneck layer.
    pub fn bottleneck<'a>(&'a self, g: &Graph) -> Result<(&'a str, u64)> {
        let mut best: Option<(&str, u64)> = None;
        for (name, fold) in &self.layers {
            let ii = fold.cycles_per_frame(g.node(name)?);
            if best.map(|(_, b)| ii > b).unwrap_or(true) {
                best = Some((name, ii));
            }
        }
        best.ok_or_else(|| Error::folding("empty config"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::lenet5;
    use crate::util::propcheck::check;

    #[test]
    fn minimal_ii_is_total_folds() {
        let g = lenet5();
        let cfg = FoldingConfig::minimal(&g);
        let c1 = g.node("conv1").unwrap();
        let f = cfg.get("conv1").unwrap();
        // 576 pixels * 25 in-folds * 6 out-folds
        assert_eq!(f.cycles_per_frame(c1), 576 * 25 * 6);
    }

    #[test]
    fn unrolled_ii_is_out_pixels() {
        let g = lenet5();
        let cfg = FoldingConfig::unrolled(&g);
        assert_eq!(cfg.get("conv1").unwrap().cycles_per_frame(g.node("conv1").unwrap()), 576);
        assert_eq!(cfg.get("fc1").unwrap().cycles_per_frame(g.node("fc1").unwrap()), 1);
        cfg.check(&g).unwrap();
    }

    #[test]
    fn bottleneck_of_unrolled_lenet_is_conv1() {
        // After full unroll the largest out_pixels dominates: conv1 (576).
        let g = lenet5();
        let cfg = FoldingConfig::unrolled(&g);
        let (name, ii) = cfg.bottleneck(&g).unwrap();
        assert_eq!(name, "conv1");
        assert_eq!(ii, 576);
    }

    #[test]
    fn legality() {
        let g = lenet5();
        let c2 = g.node("conv2").unwrap();
        // fold_in = 150, fold_out = 16
        assert!(LayerFold { pe: 16, simd: 150, style: Style::UnrolledDense, sparsity: 0.0 }
            .check(c2)
            .is_ok());
        assert!(LayerFold { pe: 3, simd: 1, style: Style::Folded, sparsity: 0.0 }
            .check(c2)
            .is_err()); // 3 does not divide 16
        assert!(LayerFold { pe: 1, simd: 7, style: Style::Folded, sparsity: 0.0 }
            .check(c2)
            .is_err()); // 7 does not divide 150
        assert!(LayerFold { pe: 8, simd: 150, style: Style::UnrolledSparse, sparsity: 0.5 }
            .check(c2)
            .is_err()); // sparse-unroll must fully unroll
        assert!(LayerFold { pe: 1, simd: 1, style: Style::Folded, sparsity: 0.5 }
            .check(c2)
            .is_err()); // dense style can't carry sparsity
    }

    #[test]
    fn config_requires_all_layers() {
        let g = lenet5();
        let mut cfg = FoldingConfig::minimal(&g);
        cfg.layers.retain(|(n, _)| n != "fc2");
        assert!(cfg.check(&g).is_err());
    }

    #[test]
    fn prop_legal_folds_have_exact_ii_division() {
        let g = lenet5();
        check("II * PE * SIMD == pixels * in * out", 200, |gen| {
            let node = *gen.choose(&g.mac_nodes().collect::<Vec<_>>());
            let pe = gen.divisor_of(node.fold_out());
            let simd = gen.divisor_of(node.fold_in());
            let f = LayerFold { pe, simd, style: Style::Folded, sparsity: 0.0 };
            f.check(node).unwrap();
            let ii = f.cycles_per_frame(node);
            assert_eq!(
                ii * pe as u64 * simd as u64,
                (node.out_pixels() * node.fold_in() * node.fold_out()) as u64
            );
        });
    }

    #[test]
    fn prop_more_parallelism_never_slower() {
        let g = lenet5();
        check("increasing PE/SIMD never increases II", 200, |gen| {
            let node = *gen.choose(&g.mac_nodes().collect::<Vec<_>>());
            let pe1 = gen.divisor_of(node.fold_out());
            let simd1 = gen.divisor_of(node.fold_in());
            // pick a multiple of pe1 that still divides
            let pe2s: Vec<usize> = (1..=node.fold_out())
                .filter(|p| node.fold_out() % p == 0 && p % pe1 == 0)
                .collect();
            let pe2 = *gen.choose(&pe2s);
            let a = LayerFold { pe: pe1, simd: simd1, style: Style::Folded, sparsity: 0.0 };
            let b = LayerFold { pe: pe2, simd: simd1, style: Style::Folded, sparsity: 0.0 };
            assert!(b.cycles_per_frame(node) <= a.cycles_per_frame(node));
        });
    }

    #[test]
    fn nnz_rounding() {
        let g = lenet5();
        let c1 = g.node("conv1").unwrap(); // 150 weights
        let f = LayerFold::unrolled_sparse(c1, 0.75);
        assert_eq!(f.nnz(c1), 38); // 150 * 0.25 = 37.5 -> 38
    }

    #[test]
    fn style_roundtrip() {
        for st in [
            Style::Folded,
            Style::UnrolledDense,
            Style::UnrolledSparse,
            Style::PartialSparse,
            Style::NmStructured,
        ] {
            assert_eq!(Style::parse(st.as_str()).unwrap(), st);
        }
        assert!(Style::parse("magic").is_err());
    }
}
