//! Design-space enumeration helpers: legal PE/SIMD values, neighbourhood
//! moves for the heuristic search, and exhaustive iteration for small
//! layers (used by tests and the ablation benches).

use crate::graph::Node;

/// All divisors of `n`, ascending.
pub fn divisors(n: usize) -> Vec<usize> {
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Legal PE values for a node (divisors of the output axis).
pub fn legal_pe(node: &Node) -> Vec<usize> {
    divisors(node.fold_out())
}

/// Legal SIMD values for a node (divisors of the input axis).
pub fn legal_simd(node: &Node) -> Vec<usize> {
    divisors(node.fold_in())
}

/// The next legal value above `cur` (None when already maximal) — the
/// "factor unfolding" move of the DSE.
pub fn next_step(legal: &[usize], cur: usize) -> Option<usize> {
    legal.iter().copied().find(|&v| v > cur)
}

/// The previous legal value below `cur` — the relaxation move.
pub fn prev_step(legal: &[usize], cur: usize) -> Option<usize> {
    legal.iter().rev().copied().find(|&v| v < cur)
}

/// Exhaustive (PE, SIMD) space of a node; |divisors(out)|·|divisors(in)|
/// points. LeNet layers are small enough for this to be exact.
pub fn full_space(node: &Node) -> Vec<(usize, usize)> {
    let pes = legal_pe(node);
    let simds = legal_simd(node);
    let mut out = Vec::with_capacity(pes.len() * simds.len());
    for &pe in &pes {
        for &simd in &simds {
            out.push((pe, simd));
        }
    }
    out
}

/// Size of the joint folding space across nodes (reported in DSE logs —
/// it motivates the heuristic search over brute force).
pub fn joint_space_size(nodes: &[&Node]) -> u128 {
    nodes
        .iter()
        .map(|n| (legal_pe(n).len() as u128) * (legal_simd(n).len() as u128))
        .product()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::lenet5;
    use crate::util::propcheck::check;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(25), vec![1, 5, 25]);
        assert_eq!(divisors(150), vec![1, 2, 3, 5, 6, 10, 15, 25, 30, 50, 75, 150]);
    }

    #[test]
    fn prop_divisors_divide_and_sorted() {
        check("divisors are sorted divisors", 300, |g| {
            let n = g.usize(1, 5000);
            let ds = divisors(n);
            assert!(ds.windows(2).all(|w| w[0] < w[1]));
            assert!(ds.iter().all(|&d| n % d == 0));
            assert_eq!(*ds.first().unwrap(), 1);
            assert_eq!(*ds.last().unwrap(), n);
            // completeness: count matches brute force
            let brute = (1..=n).filter(|d| n % d == 0).count();
            assert_eq!(ds.len(), brute);
        });
    }

    #[test]
    fn steps() {
        let legal = divisors(12);
        assert_eq!(next_step(&legal, 1), Some(2));
        assert_eq!(next_step(&legal, 4), Some(6));
        assert_eq!(next_step(&legal, 12), None);
        assert_eq!(prev_step(&legal, 12), Some(6));
        assert_eq!(prev_step(&legal, 1), None);
    }

    #[test]
    fn lenet_space_sizes() {
        let g = lenet5();
        let conv2 = g.node("conv2").unwrap();
        // fold_out 16 -> 5 divisors; fold_in 150 -> 12 divisors
        assert_eq!(full_space(conv2).len(), 5 * 12);
        let nodes: Vec<_> = g.mac_nodes().collect();
        // The joint space motivates heuristics: large even for LeNet.
        assert!(joint_space_size(&nodes) > 100_000);
    }
}
