//! # LogicSparse — engine-free unstructured sparsity for quantised dataflow
//! accelerators (reproduction).
//!
//! This crate is the Layer-3 coordinator of the three-layer stack described
//! in `DESIGN.md`:
//!
//! * [`graph`] — ONNX-like layer graph of the QNN (imported from the python
//!   compile path or built natively);
//! * [`folding`] — FINN-style PE/SIMD folding algebra;
//! * [`cost`] — analytic latency / LUT / BRAM / DSP / f_max models of the
//!   dataflow accelerator (the XCU50 substitute — see DESIGN.md §2);
//! * [`sparsity`] — masks, magnitude pruning statistics, N:M baseline,
//!   compression accounting;
//! * [`dse`] — **the paper's contribution**: heuristic folding search with
//!   secondary relaxation + iterative bottleneck elimination with sparse /
//!   factor unfolding under resource constraints (Fig. 1);
//! * [`kernel`] — **engine-free baked sparse kernels**: a compile pass
//!   turns Graph + masks + W4 codes into per-layer nnz-only MAC schedules
//!   (the software analogue of LUT baking) served natively by the
//!   coordinator — see DESIGN.md §9;
//! * [`sim`] — cycle-level streaming-dataflow simulator that *measures*
//!   latency/throughput of a configured accelerator (Table I's measured
//!   columns);
//! * [`traffic`] — shared arrival-process model (saturated / periodic /
//!   Poisson / burst / replay) driving both the simulator and the serving
//!   load generator, so simulated and served throughput are comparable;
//! * [`runtime`] — xla/PJRT wrapper that loads the AOT artifacts
//!   (`artifacts/*.hlo.txt`) and executes them on the request path;
//! * [`coordinator`] — the serving plane: admission gate, dynamic
//!   batcher, sharded per-engine work rings with stealing, the
//!   multi-model [`coordinator::Fleet`] (per-tag planes under one shared
//!   admission budget, dynamic register/retire membership), and the
//!   [`coordinator::policy`] control plane (per-tag SLO admission
//!   weights, queue-depth autotuning from queue-full/steal telemetry);
//! * [`obs`] — first-party observability plane: lock-free per-request
//!   event-ring tracing (Chrome trace-event export, arrival capture →
//!   [`traffic`] replay) and an atomics-only metrics registry the
//!   serving stats plumb onto;
//! * [`weights`] — LSTW tensor store shared with the python exporter;
//! * [`util`] — offline substrates (JSON, RNG, property testing, CLI,
//!   tables, micro-bench harness) — crates.io is not reachable in this
//!   environment, so these are first-party.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! step that invokes the compile path.

#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod cost;
pub mod device;
pub mod dse;
pub mod experiments;
pub mod folding;
pub mod graph;
pub mod kernel;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod sparsity;
pub mod traffic;
pub mod util;
pub mod weights;

pub use util::error::{Error, Result};
