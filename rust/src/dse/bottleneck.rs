//! Iterative bottleneck elimination with sparse/factor unfolding —
//! Fig. 1's inner loop, the heart of the Proposed strategy.
//!
//! From the balanced baseline:
//!
//! 1. **Free wins** (paper: "If any layer shows lower resource utilisation
//!    after sparse-unfolding, it is directly applied"): any MAC layer
//!    whose engine-free sparse unroll is estimated cheaper in LUTs than
//!    its current folded form is converted immediately — it gets faster
//!    AND smaller, no trade-off to search.
//! 2. **Elimination loop**: estimate per-layer latency and resources from
//!    the graph; take the latency bottleneck and evaluate its candidate
//!    moves — sparse unfold, partial-sparse step, plain factor unfold.
//!    Apply the move with the best whole-design throughput that fits the
//!    budget (ties broken by fewer LUTs). The whole-design evaluation is
//!    what makes the loop *hardware-aware*: a sparse unfold that deepens
//!    the global critical path (f_max) or blows congestion is rejected on
//!    its merits, not by a fixed pattern.
//! 3. Stop when no candidate improves throughput within the constraint,
//!    or the iteration cap is hit.
//! 4. **Latency trimming**: with throughput at its floor, spend remaining
//!    budget reducing first-frame latency — deep per-layer fills (folded
//!    FC stages) are unfolded further while the estimate improves. This
//!    is the "inter-layer balance" the paper credits for Proposed
//!    matching dense Unfold's latency (18.13 vs 18.18 µs) at a fraction
//!    of the area.

use crate::cost::{self, ModelCost};
use crate::device::Device;
use crate::folding::{space, FoldingConfig, LayerFold, Style};
use crate::graph::Graph;
use crate::util::error::Result;

use super::report::{DseReport, Step};
use super::DseOptions;

/// Run bottleneck elimination from `base`.
pub fn eliminate(
    g: &Graph,
    dev: &Device,
    base: FoldingConfig,
    sparsities: &[(String, f64)],
    opts: &DseOptions,
    report: &mut DseReport,
) -> Result<FoldingConfig> {
    let budget = (dev.lut_budget() as f64 * opts.budget_fraction) as u64;
    let spars_of = |name: &str| -> f64 {
        sparsities
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    };

    let mut cfg = base;

    // ---- step 1: free wins ----
    let names: Vec<String> = cfg.layers.iter().map(|(n, _)| n.clone()).collect();
    for name in &names {
        let node = g.node(name)?;
        let cur = cfg.get(name).unwrap().clone();
        if cur.style.is_unrolled() {
            continue;
        }
        let s = spars_of(name);
        if s <= 0.0 {
            continue;
        }
        let sparse = LayerFold::unrolled_sparse(node, s);
        let cur_luts = cost::layer_cost(node, &cur, g.weight_bits, g.act_bits).luts;
        let sp_luts = cost::layer_cost(node, &sparse, g.weight_bits, g.act_bits).luts;
        if sp_luts < cur_luts {
            // Guard: the whole-design cost must not regress (depth!).
            let mut trial = cfg.clone();
            trial.set(name, sparse.clone());
            let before = cost::evaluate(g, &cfg, dev)?;
            let after = cost::evaluate(g, &trial, dev)?;
            if after.throughput_fps >= before.throughput_fps && after.total_luts <= budget {
                report.push(Step::SparseUnfold {
                    layer: name.clone(),
                    sparsity: s,
                    luts_before: cur_luts,
                    luts_after: sp_luts,
                });
                cfg = trial;
            } else {
                report.push(Step::Reject {
                    layer: name.clone(),
                    reason: "sparse unfold cheaper locally but regresses design".into(),
                });
            }
        }
    }

    // ---- step 2: elimination loop ----
    for _ in 0..opts.max_iterations {
        report.next_iteration();
        let cur_cost = cost::evaluate(g, &cfg, dev)?;
        // Bottleneck by the cost model's II (partial-sparse aware).
        let bname = cur_cost
            .layers
            .iter()
            .filter(|l| g.node(&l.name).map(|n| n.op.has_weights()).unwrap_or(false))
            .max_by_key(|l| l.ii_cycles)
            .map(|l| l.name.clone())
            .expect("non-empty model");
        let node = g.node(&bname)?;
        let cur = cfg.get(&bname).unwrap().clone();
        let s = spars_of(&bname);

        let mut candidates: Vec<(Step, LayerFold)> = Vec::new();

        // (a) engine-free sparse unfold.
        if !cur.style.is_unrolled() && s > 0.0 {
            let f = LayerFold::unrolled_sparse(node, s);
            candidates.push((
                Step::SparseUnfold {
                    layer: bname.clone(),
                    sparsity: s,
                    luts_before: cost::layer_cost(node, &cur, g.weight_bits, g.act_bits).luts,
                    luts_after: cost::layer_cost(node, &f, g.weight_bits, g.act_bits).luts,
                },
                f,
            ));
        }
        // (b) partial-sparse factor step (keep/convert style, bump SIMD/PE).
        if !cur.style.is_unrolled() {
            for (dp, ds) in [(false, true), (true, false)] {
                let mut f = cur.clone();
                if ds {
                    match space::next_step(&space::legal_simd(node), f.simd) {
                        Some(v) => f.simd = v,
                        None => continue,
                    }
                }
                if dp {
                    match space::next_step(&space::legal_pe(node), f.pe) {
                        Some(v) => f.pe = v,
                        None => continue,
                    }
                }
                if s > 0.0 {
                    f.style = Style::PartialSparse;
                    f.sparsity = s;
                    candidates.push((
                        Step::PartialSparse {
                            layer: bname.clone(),
                            pe: f.pe,
                            simd: f.simd,
                            sparsity: s,
                        },
                        f,
                    ));
                } else {
                    candidates.push((
                        Step::FactorUnfold {
                            layer: bname.clone(),
                            pe: f.pe,
                            simd: f.simd,
                            ii: f.cycles_per_frame(node),
                        },
                        f,
                    ));
                }
            }
        }

        if candidates.is_empty() {
            report.push(Step::Stop {
                reason: format!("bottleneck {bname} has no remaining moves (II floor)"),
            });
            break;
        }

        // Whole-design evaluation of each candidate.
        let mut best: Option<(ModelCost, Step, LayerFold)> = None;
        for (step, fold) in candidates {
            if fold.check(node).is_err() {
                continue;
            }
            let mut trial = cfg.clone();
            trial.set(&bname, fold.clone());
            let tc = cost::evaluate(g, &trial, dev)?;
            if tc.total_luts > budget {
                report.push(Step::Reject {
                    layer: bname.clone(),
                    reason: format!("{} LUTs exceeds budget {budget}", tc.total_luts),
                });
                continue;
            }
            let better_than_best = match &best {
                None => true,
                Some((bc, _, _)) => {
                    tc.throughput_fps > bc.throughput_fps
                        || (tc.throughput_fps == bc.throughput_fps
                            && tc.total_luts < bc.total_luts)
                }
            };
            if better_than_best {
                best = Some((tc, step, fold));
            }
        }

        match best {
            Some((tc, step, fold))
                if tc.throughput_fps > cur_cost.throughput_fps
                    || (tc.throughput_fps == cur_cost.throughput_fps
                        && tc.total_luts < cur_cost.total_luts) =>
            {
                report.push(step);
                cfg.set(&bname, fold);
            }
            _ => {
                report.push(Step::Stop {
                    reason: format!(
                        "no move on {bname} improves throughput within {budget} LUTs"
                    ),
                });
                break;
            }
        }
    }

    // ---- step 3: latency trimming under the remaining budget ----
    for _ in 0..opts.max_iterations {
        let cur_cost = cost::evaluate(g, &cfg, dev)?;
        // The layer with the largest fill contribution.
        let victim = cur_cost
            .layers
            .iter()
            .filter(|l| g.node(&l.name).map(|n| n.op.has_weights()).unwrap_or(false))
            .max_by_key(|l| l.fill_cycles)
            .map(|l| l.name.clone());
        let Some(name) = victim else { break };
        let node = g.node(&name)?;
        let cur = cfg.get(&name).unwrap().clone();
        if cur.style.is_unrolled() {
            break; // nothing left to trim
        }
        let s = spars_of(&name);

        let mut cands: Vec<LayerFold> = Vec::new();
        for (dp, ds) in [(false, true), (true, false), (true, true)] {
            let mut f = cur.clone();
            if ds {
                match space::next_step(&space::legal_simd(node), f.simd) {
                    Some(v) => f.simd = v,
                    None => continue,
                }
            }
            if dp {
                match space::next_step(&space::legal_pe(node), f.pe) {
                    Some(v) => f.pe = v,
                    None => continue,
                }
            }
            if s > 0.0 {
                f.style = Style::PartialSparse;
                f.sparsity = s;
            }
            cands.push(f);
        }

        let mut applied = false;
        let mut best: Option<(ModelCost, LayerFold)> = None;
        for fold in cands {
            if fold.check(node).is_err() {
                continue;
            }
            let mut trial = cfg.clone();
            trial.set(&name, fold.clone());
            let tc = cost::evaluate(g, &trial, dev)?;
            // Must not regress throughput, must fit, must cut latency >1%.
            if tc.total_luts > budget
                || tc.throughput_fps < cur_cost.throughput_fps
                || tc.latency_s >= cur_cost.latency_s * 0.99
            {
                continue;
            }
            if best
                .as_ref()
                .map(|(bc, _)| tc.latency_s < bc.latency_s)
                .unwrap_or(true)
            {
                best = Some((tc, fold));
            }
        }
        if let Some((_, fold)) = best {
            report.push(Step::PartialSparse {
                layer: name.clone(),
                pe: fold.pe,
                simd: fold.simd,
                sparsity: fold.sparsity,
            });
            cfg.set(&name, fold);
            applied = true;
        }
        if !applied {
            report.push(Step::Stop { reason: "latency trim converged".into() });
            break;
        }
    }

    cfg.check(g)?;
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{TINY, XCU50};
    use crate::dse::heuristic::auto_fold;
    use crate::graph::builder::lenet5;

    fn setup() -> (Graph, Vec<(String, f64)>, DseOptions) {
        let g = lenet5();
        let sp: Vec<(String, f64)> = g.mac_nodes().map(|n| (n.name.clone(), 0.8)).collect();
        (g, sp, DseOptions::default())
    }

    #[test]
    fn improves_over_baseline() {
        let (g, sp, opts) = setup();
        let mut rep = DseReport::new("proposed");
        let base = auto_fold(&g, &XCU50, &opts, None, &mut rep).unwrap();
        let base_cost = cost::evaluate(&g, &base, &XCU50).unwrap();
        let out = eliminate(&g, &XCU50, base, &sp, &opts, &mut rep).unwrap();
        let out_cost = cost::evaluate(&g, &out, &XCU50).unwrap();
        assert!(
            out_cost.throughput_fps > base_cost.throughput_fps * 2.0,
            "elimination should massively improve: {} -> {}",
            base_cost.throughput_fps,
            out_cost.throughput_fps
        );
    }

    #[test]
    fn conv1_gets_sparse_unfolded() {
        // The paper's Sec. III narrative: conv1 is identified and fully
        // unrolled with unstructured pruning.
        let (g, sp, opts) = setup();
        let mut rep = DseReport::new("proposed");
        let base = auto_fold(&g, &XCU50, &opts, None, &mut rep).unwrap();
        let out = eliminate(&g, &XCU50, base, &sp, &opts, &mut rep).unwrap();
        let c1 = out.get("conv1").unwrap();
        assert_eq!(c1.style, Style::UnrolledSparse, "conv1 = {c1:?}");
    }

    #[test]
    fn respects_budget_on_tiny_device() {
        let (g, sp, _) = setup();
        let opts = DseOptions { auto_fold_target_fps: 2_000.0, ..Default::default() };
        let mut rep = DseReport::new("proposed");
        let base = auto_fold(&g, &TINY, &opts, None, &mut rep).unwrap();
        let out = eliminate(&g, &TINY, base, &sp, &opts, &mut rep).unwrap();
        let mc = cost::evaluate(&g, &out, &TINY).unwrap();
        assert!(mc.total_luts <= TINY.lut_budget());
    }

    #[test]
    fn no_sparsity_still_terminates() {
        let (g, _, opts) = setup();
        let none: Vec<(String, f64)> = g.mac_nodes().map(|n| (n.name.clone(), 0.0)).collect();
        let mut rep = DseReport::new("proposed");
        let base = auto_fold(&g, &XCU50, &opts, None, &mut rep).unwrap();
        let out = eliminate(&g, &XCU50, base, &none, &opts, &mut rep).unwrap();
        out.check(&g).unwrap();
        // Without sparsity everything falls back to factor unfolding.
        assert!(out.layers.iter().all(|(_, f)| !f.style.is_sparse()));
    }

    #[test]
    fn trace_is_recorded() {
        let (g, sp, opts) = setup();
        let mut rep = DseReport::new("proposed");
        let base = auto_fold(&g, &XCU50, &opts, None, &mut rep).unwrap();
        let _ = eliminate(&g, &XCU50, base, &sp, &opts, &mut rep).unwrap();
        assert!(rep.moves() > 2, "trace: {}", rep.render());
        assert!(rep.iterations > 0);
    }
}
