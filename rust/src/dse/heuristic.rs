//! Heuristic folding search with secondary relaxation (Fig. 1, step 2).
//!
//! Objective: the cheapest legal folding whose estimated throughput meets
//! the target, i.e. FINN-R's throughput-oriented DSE plus the paper's
//! resource awareness:
//!
//! * **forward pass** — repeatedly raise parallelism (next legal SIMD/PE
//!   divisor) on the current bottleneck layer, choosing the axis with the
//!   best cycles-saved per LUT-added, until the target FPS is met or the
//!   budget would be exceeded;
//! * **secondary relaxation** — walk non-bottleneck layers from most to
//!   least over-provisioned and step their parallelism back down while
//!   the target still holds: inter-layer balance for free LUTs.
//!
//! With `sparsities` provided (Auto+Pruning), folded layers carry the
//! `PartialSparse` style: the packed schedule skips all-zero SIMD blocks,
//! so the same throughput needs less parallelism (fewer LUTs) — the
//! quantitative content of Table I row 4 vs row 3.

use crate::cost::{self};
use crate::device::Device;
use crate::folding::{space, FoldingConfig, LayerFold, Style};
use crate::graph::Graph;
use crate::util::error::{Error, Result};

use super::report::{DseReport, Step};
use super::DseOptions;

/// Run the heuristic folding search.
pub fn auto_fold(
    g: &Graph,
    dev: &Device,
    opts: &DseOptions,
    sparsities: Option<&[(String, f64)]>,
    report: &mut DseReport,
) -> Result<FoldingConfig> {
    let budget = (dev.lut_budget() as f64 * opts.budget_fraction) as u64;
    let spars_of = |name: &str| -> f64 {
        sparsities
            .and_then(|ss| ss.iter().find(|(n, _)| n == name).map(|(_, s)| *s))
            .unwrap_or(0.0)
    };

    // Start minimal; with pruning enabled every folded layer is
    // partial-sparse from the outset.
    let mut cfg = FoldingConfig::minimal(g);
    if sparsities.is_some() {
        for (name, f) in cfg.layers.iter_mut() {
            let s = spars_of(name);
            if s > 0.0 {
                f.style = Style::PartialSparse;
                f.sparsity = s;
            }
        }
    }

    let target_ii = |f_mhz: f64| -> u64 {
        ((f_mhz * 1e6 / opts.auto_fold_target_fps).floor() as u64).max(1)
    };

    // ---- forward pass ----
    for _ in 0..10_000 {
        let mc = cost::evaluate(g, &cfg, dev)?;
        if mc.throughput_fps >= opts.auto_fold_target_fps {
            break;
        }
        // Bottleneck MAC layer (pools are fixed-function), by the cost
        // model's II — partial-sparse layers skip zero blocks, so the
        // dense folding formula would finger the wrong layer.
        let bname = cfg
            .layers
            .iter()
            .map(|(n, f)| (n.clone(), cost::latency::ii_cycles(g.node(n).unwrap(), f)))
            .max_by_key(|(_, ii)| *ii)
            .map(|(n, _)| n)
            .expect("non-empty config");
        let node = g.node(&bname)?;
        let cur = cfg.get(&bname).unwrap().clone();

        // Candidate moves: next SIMD step, next PE step.
        let mut cands: Vec<LayerFold> = Vec::new();
        if let Some(s) = space::next_step(&space::legal_simd(node), cur.simd) {
            cands.push(LayerFold { simd: s, ..cur.clone() });
        }
        if let Some(p) = space::next_step(&space::legal_pe(node), cur.pe) {
            cands.push(LayerFold { pe: p, ..cur.clone() });
        }
        if cands.is_empty() {
            report.push(Step::Stop {
                reason: format!("{bname} fully parallel but target not met"),
            });
            break;
        }

        // Pick the candidate with best cycles-saved per LUT-added.
        let cur_ii = cost::latency::ii_cycles(node, &cur);
        let cur_luts = cost::layer_cost(node, &cur, g.weight_bits, g.act_bits).luts;
        let mut best: Option<(f64, LayerFold, u64)> = None;
        for cand in cands {
            cand.check(node)?;
            let ii = cost::latency::ii_cycles(node, &cand);
            let luts = cost::layer_cost(node, &cand, g.weight_bits, g.act_bits).luts;
            let saved = cur_ii.saturating_sub(ii) as f64;
            let added = (luts.saturating_sub(cur_luts)).max(1) as f64;
            let score = saved / added;
            if best.as_ref().map(|(b, _, _)| score > *b).unwrap_or(true) {
                best = Some((score, cand, ii));
            }
        }
        let (_, chosen, new_ii) = best.unwrap();

        // Budget check on the whole design.
        let mut trial = cfg.clone();
        trial.set(&bname, chosen.clone());
        let tc = cost::evaluate(g, &trial, dev)?;
        if tc.total_luts > budget {
            report.push(Step::Stop {
                reason: format!("budget {budget} LUTs reached at {bname}"),
            });
            break;
        }
        report.push(Step::FoldUp {
            layer: bname.clone(),
            pe: chosen.pe,
            simd: chosen.simd,
            ii: new_ii,
        });
        cfg = trial;
    }

    // ---- secondary relaxation ----
    // The bottleneck sets the frame rate; any layer with slack can give
    // back parallelism as long as it stays at or under the bottleneck II
    // for the achieved clock.
    let mc = cost::evaluate(g, &cfg, dev)?;
    let cost_max_ii = cfg
        .layers
        .iter()
        .map(|(n, f)| cost::latency::ii_cycles(g.node(n).unwrap(), f))
        .max()
        .unwrap_or(1);
    let ii_cap = cost_max_ii.max(target_ii(mc.f_mhz));
    let names: Vec<String> = cfg.layers.iter().map(|(n, _)| n.clone()).collect();
    for name in names {
        loop {
            let node = g.node(&name)?;
            let cur = cfg.get(&name).unwrap().clone();
            let mut relaxed: Option<LayerFold> = None;
            // Prefer stepping the axis whose reduction saves most LUTs.
            let mut options: Vec<LayerFold> = Vec::new();
            if let Some(s) = space::prev_step(&space::legal_simd(node), cur.simd) {
                options.push(LayerFold { simd: s, ..cur.clone() });
            }
            if let Some(p) = space::prev_step(&space::legal_pe(node), cur.pe) {
                options.push(LayerFold { pe: p, ..cur.clone() });
            }
            let cur_luts = cost::layer_cost(node, &cur, g.weight_bits, g.act_bits).luts;
            let mut best_save = 0u64;
            for cand in options {
                if cost::latency::ii_cycles(node, &cand) > ii_cap {
                    continue;
                }
                let luts = cost::layer_cost(node, &cand, g.weight_bits, g.act_bits).luts;
                let save = cur_luts.saturating_sub(luts);
                if save > best_save {
                    best_save = save;
                    relaxed = Some(cand);
                }
            }
            match relaxed {
                Some(r) => {
                    report.push(Step::Relax {
                        layer: name.clone(),
                        pe: r.pe,
                        simd: r.simd,
                        luts_saved: best_save,
                    });
                    cfg.set(&name, r);
                }
                None => break,
            }
        }
    }

    cfg.check(g)?;
    let final_cost = cost::evaluate(g, &cfg, dev)?;
    if final_cost.total_luts > budget {
        return Err(Error::dse(format!(
            "auto-fold exceeded budget: {} > {budget} LUTs",
            final_cost.total_luts
        )));
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{TINY, XCU50};
    use crate::graph::builder::{convnet, lenet5};

    fn opts() -> DseOptions {
        DseOptions::default()
    }

    #[test]
    fn meets_target_on_lenet() {
        let g = lenet5();
        let mut rep = DseReport::new("auto_fold");
        let cfg = auto_fold(&g, &XCU50, &opts(), None, &mut rep).unwrap();
        let mc = cost::evaluate(&g, &cfg, &XCU50).unwrap();
        assert!(
            mc.throughput_fps >= opts().auto_fold_target_fps,
            "got {} FPS",
            mc.throughput_fps
        );
        // Paper scale: auto folding ~9.4k LUTs; allow a generous band.
        assert!(
            (3_000..25_000).contains(&mc.total_luts),
            "auto-fold {} LUTs out of band",
            mc.total_luts
        );
    }

    #[test]
    fn pruned_variant_is_cheaper_at_same_target() {
        let g = lenet5();
        let sp: Vec<(String, f64)> =
            g.mac_nodes().map(|n| (n.name.clone(), 0.8)).collect();
        let mut r1 = DseReport::new("a");
        let mut r2 = DseReport::new("b");
        let dense = auto_fold(&g, &XCU50, &opts(), None, &mut r1).unwrap();
        let pruned = auto_fold(&g, &XCU50, &opts(), Some(&sp), &mut r2).unwrap();
        let cd = cost::evaluate(&g, &dense, &XCU50).unwrap();
        let cp = cost::evaluate(&g, &pruned, &XCU50).unwrap();
        assert!(cp.throughput_fps >= opts().auto_fold_target_fps);
        assert!(
            cp.total_luts < cd.total_luts,
            "pruned {} !< dense {}",
            cp.total_luts,
            cd.total_luts
        );
    }

    #[test]
    fn respects_tiny_budget() {
        let g = lenet5();
        let mut rep = DseReport::new("auto_fold");
        // On the tiny device the target may be unreachable; the search
        // must stop at the budget rather than exceed it.
        let o = DseOptions { auto_fold_target_fps: 1e9, ..opts() };
        let cfg = auto_fold(&g, &TINY, &o, None, &mut rep).unwrap();
        let mc = cost::evaluate(&g, &cfg, &TINY).unwrap();
        assert!(mc.total_luts <= TINY.lut_budget());
    }

    #[test]
    fn relaxation_balances_layers() {
        // After relaxation no layer should be absurdly over-provisioned:
        // every MAC layer's II within ~one step of the cap is acceptable;
        // we check the aggregate: sum of IIs <= n_layers * bottleneck II.
        let g = lenet5();
        let mut rep = DseReport::new("auto_fold");
        let cfg = auto_fold(&g, &XCU50, &opts(), None, &mut rep).unwrap();
        let bottleneck = cfg.max_ii(&g).unwrap();
        for (name, f) in &cfg.layers {
            let node = g.node(name).unwrap();
            assert!(f.cycles_per_frame(node) <= bottleneck);
        }
        assert!(rep.moves() > 0);
    }

    #[test]
    fn works_on_other_topologies() {
        let g = convnet(3, 8, 32, 10);
        let mut rep = DseReport::new("auto_fold");
        let o = DseOptions { auto_fold_target_fps: 5_000.0, ..opts() };
        let cfg = auto_fold(&g, &XCU50, &o, None, &mut rep).unwrap();
        cfg.check(&g).unwrap();
        let mc = cost::evaluate(&g, &cfg, &XCU50).unwrap();
        assert!(mc.throughput_fps >= 5_000.0);
    }
}
